"""L2 model tests: shapes, kernel-vs-ref agreement at model level, gradient
checks, masking invariants, parameter plumbing."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.model import (DEFAULT_CONFIG, ModelConfig, flatten_params,
                           forward, forward_ref, init_params, loss_fn,
                           loss_ref, train_step, unflatten_params)

from .conftest import make_graph

SMALL = ModelConfig(n=16, f=8, h=32, h2=16, c=4)


def _graph(cfg, n_real, seed=0):
    adj, feats, mask, rng = make_graph(cfg.n, n_real, cfg.f, seed)
    labels = np.zeros(cfg.n, np.int32)
    labels[:n_real] = rng.integers(0, cfg.c, n_real)
    return adj, feats, mask, labels


def test_param_layout_roundtrip():
    flat = init_params(SMALL, seed=1)
    assert flat.shape == (SMALL.n_params,)
    named = unflatten_params(SMALL, flat)
    back = flatten_params(SMALL, named)
    assert_allclose(np.asarray(flat), np.asarray(back))


def test_default_param_count_matches_paper_scale():
    # Paper: "The parameters of GCNs are 188k."
    assert DEFAULT_CONFIG.n_params == 193_640
    assert abs(DEFAULT_CONFIG.n_params - 188_000) / 188_000 < 0.1


def test_init_deterministic():
    a = init_params(SMALL, seed=3)
    b = init_params(SMALL, seed=3)
    c = init_params(SMALL, seed=4)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_forward_shapes_and_simplex():
    adj, feats, mask, _ = _graph(SMALL, n_real=9)
    params = init_params(SMALL)
    probs = np.asarray(forward(SMALL, params, adj, feats, mask))
    assert probs.shape == (SMALL.n, SMALL.c)
    assert_allclose(probs.sum(axis=1), np.ones(SMALL.n), rtol=1e-5)
    assert np.all(probs >= 0)


def test_forward_matches_ref_model():
    adj, feats, mask, _ = _graph(SMALL, n_real=11, seed=5)
    params = init_params(SMALL, seed=5)
    a = np.asarray(forward(SMALL, params, adj, feats, mask))
    b = np.asarray(forward_ref(SMALL, params, adj, feats, mask))
    assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_loss_matches_ref_model():
    adj, feats, mask, labels = _graph(SMALL, n_real=11, seed=6)
    params = init_params(SMALL, seed=6)
    l1, (a1, _) = loss_fn(SMALL, params, adj, feats, labels, mask)
    l2, (a2, _) = loss_ref(SMALL, params, adj, feats, labels, mask)
    assert_allclose(float(l1), float(l2), rtol=1e-4)
    assert float(a1) == pytest.approx(float(a2))


def test_initial_loss_near_log_c():
    adj, feats, mask, labels = _graph(SMALL, n_real=12, seed=7)
    params = init_params(SMALL, seed=7)
    loss, _ = loss_fn(SMALL, params, adj, feats, labels, mask)
    assert abs(float(loss) - np.log(SMALL.c)) < 0.5


def test_grad_matches_ref_model():
    adj, feats, mask, labels = _graph(SMALL, n_real=10, seed=8)
    params = init_params(SMALL, seed=8)
    gk = jax.grad(lambda p: loss_fn(SMALL, p, adj, feats, labels, mask)[0])(params)
    gr = jax.grad(lambda p: loss_ref(SMALL, p, adj, feats, labels, mask)[0])(params)
    assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-3, atol=1e-5)


def test_grad_finite_differences_spotcheck():
    """VJP through the full kernelized model vs central differences on a
    random subset of coordinates."""
    cfg = ModelConfig(n=8, f=4, h=8, h2=8, c=2)
    adj, feats, mask, labels = _graph(cfg, n_real=6, seed=9)
    params = np.asarray(init_params(cfg, seed=9)).astype(np.float64)

    def f(p):
        loss, _ = loss_fn(cfg, jnp.asarray(p, jnp.float32), adj, feats,
                          labels, mask)
        return float(loss)

    g = np.asarray(jax.grad(
        lambda p: loss_fn(cfg, p, adj, feats, labels, mask)[0])(
            jnp.asarray(params, jnp.float32)))
    rng = np.random.default_rng(0)
    idx = rng.choice(cfg.n_params, size=12, replace=False)
    eps = 1e-2
    for i in idx:
        pp = params.copy(); pp[i] += eps
        pm = params.copy(); pm[i] -= eps
        fd = (f(pp) - f(pm)) / (2 * eps)
        assert abs(fd - g[i]) < 2e-2, (i, fd, g[i])


def test_padding_rows_do_not_affect_real_nodes():
    """Garbage in padded feature rows must not change real nodes' outputs
    (they are masked out of every layer and disconnected in adj)."""
    adj, feats, mask, _ = _graph(SMALL, n_real=9, seed=10)
    params = init_params(SMALL, seed=10)
    p1 = np.asarray(forward(SMALL, params, adj, feats, mask))
    feats2 = feats.copy()
    feats2[9:] = 1e3
    p2 = np.asarray(forward(SMALL, params, adj, feats2, mask))
    assert_allclose(p1[:9], p2[:9], rtol=1e-5, atol=1e-6)


def test_train_step_decreases_loss():
    adj, feats, mask, labels = _graph(SMALL, n_real=12, seed=11)
    p = init_params(SMALL, seed=11)
    m = jnp.zeros(SMALL.n_params)
    v = jnp.zeros(SMALL.n_params)
    losses = []
    accs = []
    for s in range(1, 61):
        p, m, v, loss, acc = train_step(SMALL, p, m, v, float(s), adj, feats,
                                        labels, mask, 0.01)
        losses.append(float(loss))
        accs.append(float(acc))
    assert min(losses) < losses[0] * 0.5
    assert max(accs) >= 0.75


def test_train_step_overfits_structured_labels():
    """Region-coherent labels (what the oracle emits) should reach ~100%
    quickly — this is the Fig 4 regime."""
    cfg = SMALL
    rng = np.random.default_rng(12)
    n_real = 12
    adj = np.zeros((cfg.n, cfg.n), np.float32)
    labels = np.zeros(cfg.n, np.int32)
    feats = np.zeros((cfg.n, cfg.f), np.float32)
    # Two latency cliques: intra 30ms, inter 300ms; features carry the clique.
    for i in range(n_real):
        labels[i] = 0 if i < 6 else 1
        feats[i, labels[i]] = 1.0
        feats[i, 2:] = rng.normal(0, 0.1, cfg.f - 2)
    for i in range(n_real):
        for j in range(i + 1, n_real):
            w = 30.0 if labels[i] == labels[j] else 300.0
            adj[i, j] = w
            adj[j, i] = w
    mask = np.zeros(cfg.n, np.float32)
    mask[:n_real] = 1.0
    p = init_params(cfg, seed=12)
    m = jnp.zeros(cfg.n_params)
    v = jnp.zeros(cfg.n_params)
    accs = []
    for s in range(1, 41):
        p, m, v, loss, acc = train_step(cfg, p, m, v, float(s), adj, feats,
                                        labels, mask, 0.01)
        accs.append(float(acc))
    # Paper Fig 4 reaches 99% by step 6 on its (unreleased) data; on this
    # synthetic two-clique graph the same model/optimizer separates by ~30
    # Adam steps at the paper's lr. EXPERIMENTS.md discusses the delta.
    assert max(accs) >= 0.99


def test_train_step_ignores_padding_gradient():
    """Params must receive no gradient from padded rows: two train steps on
    graphs differing only in padding content give identical params."""
    adj, feats, mask, labels = _graph(SMALL, n_real=9, seed=13)
    p0 = init_params(SMALL, seed=13)
    z = jnp.zeros(SMALL.n_params)
    feats2 = feats.copy()
    feats2[9:] = 123.0
    labels2 = labels.copy()
    labels2[9:] = 3
    p1, *_ = train_step(SMALL, p0, z, z, 1.0, adj, feats, labels, mask, 0.01)
    p2, *_ = train_step(SMALL, p0, z, z, 1.0, adj, feats2, labels2, mask, 0.01)
    assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-5, atol=1e-7)
