"""Edge-case and numerical-robustness tests for the L1/L2 stack:
degenerate graphs, extreme values, determinism under jit, and the scaling
conventions shared with the Rust side."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import edge_aggregate, gcn_layer, masked_softmax_xent
from compile.kernels.ref import (AFFINITY_REF_LAT_MS, sym_normalize_ref)
from compile.model import (ModelConfig, WSUM_SCALE, forward, init_params,
                           loss_fn, train_step)

TINY = ModelConfig(n=8, f=4, h=8, h2=8, c=2)


# ------------------------------------------------------------- normalization
def test_affinity_clamp_caps_fast_links():
    """A 1 ms link must not out-weigh the self loop (oversmoothing guard)."""
    adj = np.array([[0.0, 1.0], [1.0, 0.0]], np.float32)
    a = np.asarray(sym_normalize_ref(jnp.asarray(adj)))
    # S = [[1, 1], [1, 1]] after clamping → Â = 0.5 everywhere.
    assert_allclose(a, np.full((2, 2), 0.5), atol=1e-6)


def test_affinity_decays_with_latency():
    adj = np.array([[0.0, 100.0], [100.0, 0.0]], np.float32)
    a = np.asarray(sym_normalize_ref(jnp.asarray(adj)))
    # Affinity 10/100 = 0.1 ≪ self 1.0: diagonal dominates.
    assert a[0, 0] > 5 * a[0, 1]


def test_sym_normalize_handles_huge_latencies():
    adj = np.array([[0.0, 1e6], [1e6, 0.0]], np.float32)
    a = np.asarray(sym_normalize_ref(jnp.asarray(adj)))
    assert np.all(np.isfinite(a))
    assert a[0, 1] >= 0.0


# ----------------------------------------------------------------- kernels
def test_edge_aggregate_single_real_node():
    adj = np.zeros((4, 4), np.float32)
    x = np.ones((4, 4), np.float32)
    nbr, deg, wsum = edge_aggregate(adj, x)
    assert np.all(np.asarray(deg) == 0.0)
    assert np.all(np.asarray(nbr) == 0.0)
    assert np.all(np.asarray(wsum) == 0.0)


def test_gcn_layer_zero_weights_give_bias():
    n, d = 4, 8
    a_hat = np.eye(n, dtype=np.float32)
    x = np.ones((n, d), np.float32)
    w = np.zeros((d, d), np.float32)
    ws = np.zeros((d, d), np.float32)
    b = np.full(d, 3.0, np.float32)
    out = np.asarray(gcn_layer(a_hat, x, w, ws, b, False))
    assert_allclose(out, np.full((n, d), 3.0), atol=1e-6)


def test_softmax_xent_extreme_logits_stay_finite():
    n, c = 4, 4
    logits = np.array(
        [[1e4, -1e4, 0, 0], [-1e4, 1e4, 0, 0], [0, 0, 1e4, -1e4],
         [0, 0, 0, 0]],
        np.float32)
    labels = np.array([0, 1, 2, 3], np.int32)
    mask = np.ones(n, np.float32)
    loss, acc, probs = masked_softmax_xent(logits, labels, mask)
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(probs)))
    # Rows 0–2 are confidently correct; row 3 uniform.
    assert float(acc) >= 0.75


def test_softmax_xent_all_masked_is_safe():
    """nvalid clamps at 1: an all-padding batch must not divide by zero."""
    n, c = 4, 2
    logits = np.zeros((n, c), np.float32)
    labels = np.zeros(n, np.int32)
    mask = np.zeros(n, np.float32)
    loss, acc, _ = masked_softmax_xent(logits, labels, mask)
    assert float(loss) == 0.0
    assert float(acc) == 0.0


# ------------------------------------------------------------------- model
def test_forward_on_edgeless_graph():
    """Isolated machines: Â = I; model must still emit valid rows."""
    adj = np.zeros((TINY.n, TINY.n), np.float32)
    feats = np.ones((TINY.n, TINY.f), np.float32)
    mask = np.ones(TINY.n, np.float32)
    probs = np.asarray(forward(TINY, init_params(TINY), adj, feats, mask))
    assert_allclose(probs.sum(axis=1), np.ones(TINY.n), rtol=1e-5)


def test_forward_jit_eager_agree_on_degenerate_inputs():
    adj = np.zeros((TINY.n, TINY.n), np.float32)
    adj[0, 1] = adj[1, 0] = 1e5  # one extreme edge
    feats = np.zeros((TINY.n, TINY.f), np.float32)
    mask = np.zeros(TINY.n, np.float32)
    mask[:2] = 1.0
    p = init_params(TINY)
    eager = forward(TINY, p, adj, feats, mask)
    jitted = jax.jit(lambda *a: forward(TINY, *a))(p, adj, feats, mask)
    assert_allclose(np.asarray(eager), np.asarray(jitted), rtol=1e-5,
                    atol=1e-6)


def test_wsum_scale_keeps_latency_channel_order_one():
    # Latencies up to ~1000 ms → scaled magnitude ≤ ~10.
    assert WSUM_SCALE * 1000.0 <= 10.0
    assert AFFINITY_REF_LAT_MS == 10.0  # rust mirror contract


def test_loss_is_zero_gradient_free_of_nans_on_uniform_graph():
    adj = np.full((TINY.n, TINY.n), 50.0, np.float32)
    np.fill_diagonal(adj, 0.0)
    feats = np.ones((TINY.n, TINY.f), np.float32)
    mask = np.ones(TINY.n, np.float32)
    labels = np.zeros(TINY.n, np.int32)
    p = init_params(TINY)
    g = jax.grad(lambda q: loss_fn(TINY, q, adj, feats, labels, mask)[0])(p)
    assert np.all(np.isfinite(np.asarray(g)))


def test_train_step_zero_lr_is_identity_on_params():
    adj = np.zeros((TINY.n, TINY.n), np.float32)
    adj[0, 1] = adj[1, 0] = 30.0
    feats = np.ones((TINY.n, TINY.f), np.float32)
    mask = np.ones(TINY.n, np.float32)
    labels = np.ones(TINY.n, np.int32)
    p0 = init_params(TINY)
    z = jnp.zeros(TINY.n_params)
    p1, m1, v1, loss, acc = train_step(TINY, p0, z, z, 1.0, adj, feats,
                                       labels, mask, 0.0)
    assert_allclose(np.asarray(p1), np.asarray(p0), atol=1e-7)
    # Moments still accumulate (lr gates the update, not the stats).
    assert float(jnp.sum(jnp.abs(m1))) > 0.0
    assert float(jnp.sum(v1)) > 0.0


def test_two_steps_differ_from_one_big_step():
    """Adam is stateful: 2×lr for 1 step ≠ lr for 2 steps."""
    adj = np.zeros((TINY.n, TINY.n), np.float32)
    adj[0, 1] = adj[1, 0] = 30.0
    feats = np.random.default_rng(0).normal(
        size=(TINY.n, TINY.f)).astype(np.float32)
    mask = np.ones(TINY.n, np.float32)
    labels = np.ones(TINY.n, np.int32)
    p0 = init_params(TINY)
    z = jnp.zeros(TINY.n_params)
    pa, ma, va, *_ = train_step(TINY, p0, z, z, 1.0, adj, feats, labels,
                                mask, 0.02)
    pb, mb, vb, *_ = train_step(TINY, p0, z, z, 1.0, adj, feats, labels,
                                mask, 0.01)
    pb2, *_ = train_step(TINY, pb, mb, vb, 2.0, adj, feats, labels, mask,
                         0.01)
    diff = np.abs(np.asarray(pa) - np.asarray(pb2)).max()
    assert diff > 1e-6


@pytest.mark.parametrize("n_real", [1, 2, TINY.n])
def test_any_real_count_is_valid(n_real):
    adj = np.zeros((TINY.n, TINY.n), np.float32)
    for i in range(n_real):
        for j in range(i + 1, n_real):
            adj[i, j] = adj[j, i] = 40.0
    feats = np.ones((TINY.n, TINY.f), np.float32)
    mask = np.zeros(TINY.n, np.float32)
    mask[:n_real] = 1.0
    labels = np.zeros(TINY.n, np.int32)
    loss, (acc, probs) = loss_fn(TINY, init_params(TINY), adj, feats,
                                 labels, mask)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0
