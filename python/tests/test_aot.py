"""AOT path tests: lowering produces parseable HLO text with the agreed
entry signature, and executing the lowered module through xla_client (the
same XLA the Rust PJRT client embeds a build of) matches the eager model."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc
from numpy.testing import assert_allclose

from compile.aot import lower_forward, lower_train_step, to_hlo_text
from compile.model import (DEFAULT_CONFIG, ModelConfig, forward, init_params,
                           train_step)

from .conftest import make_graph

SMALL = ModelConfig(n=16, f=8, h=32, h2=16, c=4)


def test_forward_hlo_text_structure():
    text = lower_forward(SMALL)
    assert "ENTRY" in text
    assert "HloModule" in text
    # One f32[P] parameter plus adj/feats/mask must appear.
    assert f"f32[{SMALL.n_params}]" in text
    assert f"f32[{SMALL.n},{SMALL.n}]" in text


def test_train_step_hlo_text_structure():
    text = lower_train_step(SMALL)
    assert "ENTRY" in text
    assert f"s32[{SMALL.n}]" in text  # labels
    # Tuple root with params/m/v + loss + acc.
    assert text.count(f"f32[{SMALL.n_params}]") >= 3


def test_hlo_text_is_stable():
    """Same config → byte-identical artifact (required for Makefile no-op
    rebuilds and for rust-side caching)."""
    assert lower_forward(SMALL) == lower_forward(SMALL)


@pytest.mark.parametrize("seed", [0, 1])
def test_lowered_forward_matches_eager(seed):
    """jit-compiled (what the artifact contains) vs eager forward."""
    adj, feats, mask, _ = make_graph(SMALL.n, 10, SMALL.f, seed)
    params = init_params(SMALL, seed=seed)
    eager = forward(SMALL, params, adj, feats, mask)
    jitted = jax.jit(lambda p, a, f, m: forward(SMALL, p, a, f, m))(
        params, adj, feats, mask)
    assert_allclose(np.asarray(eager), np.asarray(jitted), rtol=1e-5,
                    atol=1e-6)


def test_lowered_train_step_matches_eager():
    adj, feats, mask, rng = make_graph(SMALL.n, 10, SMALL.f, 3)
    labels = np.zeros(SMALL.n, np.int32)
    labels[:10] = rng.integers(0, SMALL.c, 10)
    params = init_params(SMALL, seed=3)
    z = jnp.zeros(SMALL.n_params)

    def step(p, m, v, s, a, f, l, k, lr):
        return train_step(SMALL, p, m, v, s[0], a, f, l, k, lr[0])

    eager = step(params, z, z, np.ones(1, np.float32), adj, feats, labels,
                 mask, np.full(1, 0.01, np.float32))
    jitted = jax.jit(step)(params, z, z, np.ones(1, np.float32), adj, feats,
                           labels, mask, np.full(1, 0.01, np.float32))
    for e, j in zip(eager, jitted):
        assert_allclose(np.asarray(e), np.asarray(j), rtol=1e-4, atol=1e-5)


def test_default_manifest_values():
    cfg = DEFAULT_CONFIG
    assert (cfg.n, cfg.f, cfg.h, cfg.h2, cfg.c) == (64, 18, 192, 96, 8)
    assert cfg.n_params == 193_640
