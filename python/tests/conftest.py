"""Shared fixtures: random cluster-like graphs at configurable sizes."""

from __future__ import annotations

import numpy as np
import pytest


def make_graph(n_slots: int, n_real: int, n_feat: int, seed: int,
               density: float = 0.6):
    """Random weighted graph shaped like a Hulk cluster: symmetric latency
    weights in [20, 400) ms, zero diagonal, padded to ``n_slots``."""
    rng = np.random.default_rng(seed)
    adj = np.zeros((n_slots, n_slots), np.float32)
    for i in range(n_real):
        for j in range(i + 1, n_real):
            if rng.random() < density:
                w = np.float32(rng.uniform(20.0, 400.0))
                adj[i, j] = w
                adj[j, i] = w
    feats = np.zeros((n_slots, n_feat), np.float32)
    feats[:n_real] = rng.normal(0.0, 1.0, size=(n_real, n_feat))
    mask = np.zeros((n_slots,), np.float32)
    mask[:n_real] = 1.0
    return adj, feats, mask, rng


@pytest.fixture
def small_graph():
    return make_graph(n_slots=16, n_real=9, n_feat=8, seed=7)
