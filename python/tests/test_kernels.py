"""L1 correctness: every Pallas kernel vs the pure-jnp oracle in ref.py.

Hypothesis sweeps shapes (within the kernels' tiling constraints), seeds and
value distributions; assert_allclose is the contract. These tests are the
core correctness signal for the whole stack — the Rust runtime executes the
HLO these kernels lower into.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import edge_aggregate, gcn_layer, masked_softmax_xent
from compile.kernels.ref import (edge_aggregate_ref, gcn_layer_ref,
                                 masked_softmax_xent_ref, sym_normalize_ref)

from .conftest import make_graph

# Shape sets honoring the kernels' constraints (output dim tiles at 128 when
# divisible, otherwise a single tile).
NS = [4, 8, 16, 64]
FS = [4, 8, 16]
DOUTS = [8, 16, 128, 256]


def _rand(rng, *shape):
    return rng.normal(0.0, 1.0, size=shape).astype(np.float32)


# ---------------------------------------------------------------------- edge
@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from(NS), f=st.sampled_from(FS),
       seed=st.integers(0, 2**31 - 1),
       density=st.floats(0.0, 1.0))
def test_edge_aggregate_matches_ref(n, f, seed, density):
    adj, feats, _, _ = make_graph(n, n, f, seed, density)
    got = edge_aggregate(adj, feats)
    want = edge_aggregate_ref(jnp.asarray(adj), jnp.asarray(feats))
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-5)


def test_edge_aggregate_empty_graph():
    adj = np.zeros((8, 8), np.float32)
    x = np.ones((8, 4), np.float32)
    nbr, deg, wsum = edge_aggregate(adj, x)
    assert np.all(np.asarray(nbr) == 0)
    assert np.all(np.asarray(deg) == 0)
    assert np.all(np.asarray(wsum) == 0)


def test_edge_aggregate_complete_graph():
    n, f = 8, 4
    adj = np.full((n, n), 100.0, np.float32)
    np.fill_diagonal(adj, 0.0)
    x = np.arange(n * f, dtype=np.float32).reshape(n, f)
    nbr, deg, wsum = edge_aggregate(adj, x)
    assert_allclose(np.asarray(deg)[:, 0], np.full(n, n - 1.0))
    assert_allclose(np.asarray(wsum)[:, 0], np.full(n, 100.0 * (n - 1)))
    total = x.sum(axis=0)
    for v in range(n):
        assert_allclose(np.asarray(nbr)[v], total - x[v], rtol=1e-6)


def test_edge_aggregate_grad_matches_ref():
    adj, feats, _, _ = make_graph(8, 8, 4, seed=3)

    def f_kernel(x):
        nbr, _, _ = edge_aggregate(adj, x)
        return jnp.sum(nbr ** 2)

    def f_ref(x):
        nbr, _, _ = edge_aggregate_ref(jnp.asarray(adj), x)
        return jnp.sum(nbr ** 2)

    g1 = jax.grad(f_kernel)(jnp.asarray(feats))
    g2 = jax.grad(f_ref)(jnp.asarray(feats))
    assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------- gcn
@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from(NS), din=st.sampled_from([8, 16, 128, 256]),
       dout=st.sampled_from(DOUTS), relu=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
def test_gcn_layer_matches_ref(n, din, dout, relu, seed):
    rng = np.random.default_rng(seed)
    adj, _, _, _ = make_graph(n, n, 4, seed)
    a_hat = np.asarray(sym_normalize_ref(jnp.asarray(adj)))
    x = _rand(rng, n, din)
    w = _rand(rng, din, dout)
    b = _rand(rng, dout)
    ws = _rand(rng, din, dout)
    got = gcn_layer(a_hat, x, w, ws, b, relu)
    want = gcn_layer_ref(a_hat, x, w, ws, b, relu)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_gcn_layer_identity_adjacency():
    """Â = I reduces the layer to a dense layer."""
    rng = np.random.default_rng(0)
    n, din, dout = 8, 16, 8
    x = _rand(rng, n, din)
    w = _rand(rng, din, dout)
    b = np.zeros(dout, np.float32)
    ws = np.zeros((din, dout), np.float32)
    got = gcn_layer(np.eye(n, dtype=np.float32), x, w, ws, b, False)
    assert_allclose(np.asarray(got), x @ w, rtol=1e-5, atol=1e-5)


def test_gcn_layer_grads_match_ref():
    rng = np.random.default_rng(1)
    n, din, dout = 8, 16, 8
    adj, _, _, _ = make_graph(n, n, 4, seed=2)
    a_hat = np.asarray(sym_normalize_ref(jnp.asarray(adj)))
    x = _rand(rng, n, din)
    w = _rand(rng, din, dout)
    b = _rand(rng, dout)

    ws = _rand(rng, din, dout)

    def f_kernel(x, w, ws, b):
        return jnp.sum(gcn_layer(a_hat, x, w, ws, b, True) ** 2)

    def f_ref(x, w, ws, b):
        return jnp.sum(gcn_layer_ref(a_hat, x, w, ws, b, True) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(x, w, ws, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, w, ws, b)
    for a, c in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-4, atol=1e-4)


def test_gcn_layer_relu_gradient_gate():
    """Gradient must be zero where relu clipped the forward."""
    n = 4
    a_hat = np.eye(n, dtype=np.float32)
    x = np.array([[-1.0], [2.0], [-3.0], [4.0]], np.float32)
    w = np.ones((1, 8), np.float32)
    ws = np.zeros((1, 8), np.float32)
    b = np.zeros(8, np.float32)
    g = jax.grad(
        lambda x: jnp.sum(gcn_layer(a_hat, x, w, ws, b, True)))(x)
    g = np.asarray(g)
    assert np.all(g[0] == 0) and np.all(g[2] == 0)
    assert np.all(g[1] == 8) and np.all(g[3] == 8)


# ---------------------------------------------------------------------- xent
@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from(NS), c=st.sampled_from([2, 4, 8]),
       n_real=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_matches_ref(n, c, n_real, seed):
    n_real = min(n_real, n)
    rng = np.random.default_rng(seed)
    logits = _rand(rng, n, c) * 3.0
    labels = rng.integers(0, c, size=n).astype(np.int32)
    mask = np.zeros(n, np.float32)
    mask[:n_real] = 1.0
    got = masked_softmax_xent(logits, labels, mask)
    want = masked_softmax_xent_ref(jnp.asarray(logits), jnp.asarray(labels),
                                   jnp.asarray(mask))
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-5)


def test_softmax_xent_perfect_prediction():
    n, c = 8, 4
    labels = np.arange(n, dtype=np.int32) % c
    logits = np.full((n, c), -20.0, np.float32)
    logits[np.arange(n), labels] = 20.0
    mask = np.ones(n, np.float32)
    loss, acc, _ = masked_softmax_xent(logits, labels, mask)
    assert float(loss) < 1e-3
    assert float(acc) == 1.0


def test_softmax_xent_mask_excludes_padding():
    """Padded rows must not change loss/acc no matter their logits."""
    n, c = 8, 4
    rng = np.random.default_rng(5)
    logits = _rand(rng, n, c)
    labels = rng.integers(0, c, n).astype(np.int32)
    mask = np.zeros(n, np.float32)
    mask[:5] = 1.0
    l1, a1, _ = masked_softmax_xent(logits, labels, mask)
    logits2 = logits.copy()
    logits2[5:] = 1e4  # garbage in the padding
    l2, a2, _ = masked_softmax_xent(logits2, labels, mask)
    assert_allclose(float(l1), float(l2), rtol=1e-6)
    assert float(a1) == float(a2)


def test_softmax_xent_grad_matches_ref():
    n, c = 8, 4
    rng = np.random.default_rng(9)
    logits = _rand(rng, n, c)
    labels = rng.integers(0, c, n).astype(np.int32)
    mask = np.ones(n, np.float32)
    mask[6:] = 0.0

    def f_kernel(z):
        loss, _, _ = masked_softmax_xent(z, labels, mask)
        return loss

    def f_ref(z):
        loss, _, _ = masked_softmax_xent_ref(z, jnp.asarray(labels),
                                             jnp.asarray(mask))
        return loss

    gk = jax.grad(f_kernel)(jnp.asarray(logits))
    gr = jax.grad(f_ref)(jnp.asarray(logits))
    assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-5, atol=1e-6)
    # Padded rows carry no gradient.
    assert np.all(np.asarray(gk)[6:] == 0)


def test_softmax_xent_grad_finite_differences():
    """Kernel VJP vs central finite differences (the ground truth)."""
    n, c = 4, 3
    rng = np.random.default_rng(11)
    logits = _rand(rng, n, c)
    labels = rng.integers(0, c, n).astype(np.int32)
    mask = np.ones(n, np.float32)

    def f(z):
        loss, _, _ = masked_softmax_xent(z, labels, mask)
        return float(loss)

    g = np.asarray(jax.grad(
        lambda z: masked_softmax_xent(z, labels, mask)[0])(jnp.asarray(logits)))
    eps = 1e-3
    for i in range(n):
        for j in range(c):
            zp = logits.copy(); zp[i, j] += eps
            zm = logits.copy(); zm[i, j] -= eps
            fd = (f(zp) - f(zm)) / (2 * eps)
            assert abs(fd - g[i, j]) < 5e-3, (i, j, fd, g[i, j])


# ----------------------------------------------------------------- normalize
@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from(NS), seed=st.integers(0, 2**31 - 1))
def test_sym_normalize_rows_bounded(n, seed):
    adj, _, _, _ = make_graph(n, n, 4, seed)
    a_hat = np.asarray(sym_normalize_ref(jnp.asarray(adj)))
    assert a_hat.shape == (n, n)
    assert np.all(a_hat >= 0)
    # Spectral radius of sym-normalized adjacency-with-self-loops is <= 1.
    eig = np.max(np.abs(np.linalg.eigvalsh(a_hat)))
    assert eig <= 1.0 + 1e-5


def test_sym_normalize_isolated_node_keeps_self_loop():
    adj = np.zeros((4, 4), np.float32)
    a_hat = np.asarray(sym_normalize_ref(jnp.asarray(adj)))
    assert_allclose(a_hat, np.eye(4), atol=1e-6)
