"""Pure-jnp oracle implementations of every Pallas kernel.

These are the single source of truth for kernel semantics: pytest asserts
``kernel(x) ≈ ref(x)`` over shape/dtype/value sweeps (see
``python/tests/test_kernels.py``), and the L2 model exposes a ``*_ref``
forward built from these ops so model-level divergence can be bisected to a
kernel.

Conventions shared with the kernels:
- ``adj`` is the weighted adjacency matrix, entry = WAN latency in ms per
  64-byte message (paper Table 1); ``0`` means "no edge / cannot
  communicate"; the diagonal is 0.
- ``mask`` is a float vector, 1.0 for a real machine, 0.0 for a padded slot.
"""

from __future__ import annotations

import jax.numpy as jnp


def edge_aggregate_ref(adj: jnp.ndarray, x: jnp.ndarray):
    """Neighborhood aggregation for the edge-pooling layer (paper Eq. 4).

    Returns ``(nbr_sum, deg, wsum)``:
      nbr_sum[v] = sum_{u in N(v)} x[u]        (shape [N, F])
      deg[v]     = |N(v)|                      (shape [N, 1])
      wsum[v]    = sum_{u in N(v)} adj[v, u]   (shape [N, 1], total latency)
    """
    mask = (adj > 0).astype(x.dtype)
    nbr_sum = mask @ x
    deg = jnp.sum(mask, axis=1, keepdims=True)
    wsum = jnp.sum(adj, axis=1, keepdims=True).astype(x.dtype)
    return nbr_sum, deg, wsum


def gcn_layer_ref(a_hat: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
                  w_self: jnp.ndarray, b: jnp.ndarray,
                  relu: bool = True) -> jnp.ndarray:
    """One residual GCN layer (paper Eq. 1 + self path):
    ``act(a_hat @ (x @ w) + x @ w_self + b)``.

    The ``x @ w_self`` residual keeps node identity through depth: with
    strong intra-region affinities, pure aggregation makes same-region
    rows of ``a_hat @ (·)`` nearly identical after one layer, and the
    network collapses to the label marginal (observed empirically; see
    EXPERIMENTS.md §Fig4).
    """
    out = a_hat @ (x @ w) + x @ w_self + b
    return jnp.maximum(out, 0.0) if relu else out


def masked_softmax_xent_ref(logits: jnp.ndarray, labels: jnp.ndarray,
                            mask: jnp.ndarray):
    """Masked softmax cross-entropy (paper Eq. 5) + accuracy + probs.

    Padded rows (mask == 0) contribute neither to the loss mean nor to the
    accuracy. Returns ``(loss, acc, probs)`` with scalar loss/acc.
    """
    z = logits - jnp.max(logits, axis=1, keepdims=True)
    ez = jnp.exp(z)
    probs = ez / jnp.sum(ez, axis=1, keepdims=True)
    n = logits.shape[0]
    onehot = (labels[:, None] == jnp.arange(logits.shape[1])[None, :])
    onehot = onehot.astype(logits.dtype)
    logp = z - jnp.log(jnp.sum(ez, axis=1, keepdims=True))
    nvalid = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(mask * jnp.sum(onehot * logp, axis=1)) / nvalid
    pred = jnp.argmax(logits, axis=1)
    acc = jnp.sum(mask * (pred == labels).astype(logits.dtype)) / nvalid
    del n
    return loss, acc, probs


# Latency (ms) at which a neighbor counts as much as the node itself.
# Self-loops get affinity 1.0 (= a hypothetical 10 ms loopback), an intra-
# region 30 ms link gets 0.33, a cross-continent 300 ms link 0.033 — so the
# aggregation is dominated by low-latency neighbors, which is the paper's
# "edge information is crucial" requirement, and node identity survives even
# on a complete graph (a purely binary connectivity matrix would make all
# rows of Â identical there and oversmooth every layer).
AFFINITY_REF_LAT_MS = 10.0


def sym_normalize_ref(adj: jnp.ndarray) -> jnp.ndarray:
    """Latency-affinity GCN normalization: ``D^{-1/2} (S + I) D^{-1/2}``
    with ``S_uv = min(AFFINITY_REF_LAT_MS / adj_uv, 1)`` on edges, 0
    elsewhere. The clamp caps any neighbor at the self-loop's weight —
    an unclamped 1 ms intra-region link would out-weigh self 10:1 and
    oversmooth the region into a single point.

    Spectral radius ≤ 1 (sym-normalized non-negative symmetric matrix), and
    an isolated node keeps Â_vv = 1.
    """
    edge = adj > 0
    s = jnp.where(
        edge,
        jnp.minimum(AFFINITY_REF_LAT_MS / jnp.maximum(adj, 1e-6), 1.0),
        0.0)
    n = s.shape[0]
    s = s + jnp.eye(n, dtype=jnp.float32)
    d = jnp.sum(s, axis=1)
    dinv = 1.0 / jnp.sqrt(jnp.maximum(d, 1e-12))
    return (dinv[:, None] * s * dinv[None, :]).astype(jnp.float32)
