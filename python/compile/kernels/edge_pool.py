"""Edge-pooling neighborhood aggregation as a Pallas kernel (paper Eq. 4).

A GPU implementation of edge pooling would scatter per-edge messages into
node buckets; scatters serialize on TPU, so the kernel instead performs a
*masked dense aggregation*: the connectivity mask ``adj > 0`` is materialized
in VMEM and the neighbor sum becomes a single [N,N]×[N,F] GEMM on the MXU,
with the degree and latency-sum reductions running on the VPU over the same
VMEM-resident block (one HBM read of ``adj``, one of ``x`` — no round trip
between the three outputs).

Outputs (see ref.edge_aggregate_ref):
    nbr_sum [N, F] — Σ_{u∈N(v)} x_u
    deg     [N, 1] — |N(v)|
    wsum    [N, 1] — Σ_{u∈N(v)} adj[v, u]   (total latency at v, ms/64B)

Backward: custom_vjp; only ``x`` is differentiable (``adj`` is measured WAN
data), and d(nbr_sum)/dx transposes the mask GEMM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _edge_agg_kernel(adj_ref, x_ref, nbr_ref, deg_ref, wsum_ref):
    adj = adj_ref[...]
    x = x_ref[...]
    mask = (adj > 0).astype(jnp.float32)
    nbr_ref[...] = jnp.dot(mask, x, preferred_element_type=jnp.float32)
    deg_ref[...] = jnp.sum(mask, axis=1, keepdims=True)
    wsum_ref[...] = jnp.sum(adj, axis=1, keepdims=True)


def _edge_agg_forward(adj, x):
    n, f = x.shape
    return pl.pallas_call(
        _edge_agg_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n, f), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ),
        interpret=True,
    )(adj, x)


@jax.custom_vjp
def edge_aggregate(adj, x):
    """Neighborhood aggregation for edge pooling. Differentiable in ``x``."""
    return _edge_agg_forward(adj, x)


def _edge_agg_fwd(adj, x):
    out = _edge_agg_forward(adj, x)
    return out, (adj,)


def _edge_agg_bwd(res, cotangents):
    (adj,) = res
    g_nbr, _g_deg, _g_wsum = cotangents
    mask = (adj > 0).astype(g_nbr.dtype)
    dx = mask.T @ g_nbr
    dadj = jnp.zeros_like(adj)  # measured latencies: no gradient
    return dadj, dx


edge_aggregate.defvjp(_edge_agg_fwd, _edge_agg_bwd)
