"""Layer-1 Pallas kernels for the Hulk GCN.

All kernels run under ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls); they lower into the same HLO module as the surrounding
L2 jax model, so the Rust runtime sees a single artifact per entry point.

Each kernel is wrapped in ``jax.custom_vjp`` — Pallas calls have no automatic
transpose rule, and the backward passes are small dense expressions that XLA
fuses well, so they are written in plain jnp (documented per kernel).
"""

from .edge_pool import edge_aggregate
from .gcn_layer import gcn_layer
from .softmax_xent import masked_softmax_xent

__all__ = ["edge_aggregate", "gcn_layer", "masked_softmax_xent"]
