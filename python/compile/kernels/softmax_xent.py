"""Masked softmax cross-entropy + accuracy as one Pallas kernel (Eq. 5).

Fuses, in a single VMEM pass over the [N, C] logits block: the numerically
stable row softmax, the label gather (done as a one-hot inner product —
gathers with int indices serialize on TPU, a one-hot contraction stays on
the VPU/MXU), the mask-weighted loss mean, and the argmax accuracy. The
scalar outputs are (1,1) blocks (TPU scalars live in 2-D lanes).

Backward: custom_vjp in jnp — d logits = (probs − onehot) · mask / n_valid.
Labels and mask are data, not parameters; they carry no gradient.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xent_kernel(logits_ref, labels_ref, mask_ref, loss_ref, acc_ref,
                 probs_ref):
    logits = logits_ref[...]
    labels = labels_ref[...]                      # [N] int32
    mask = mask_ref[...]                          # [N] float32
    n, c = logits.shape

    z = logits - jnp.max(logits, axis=1, keepdims=True)
    ez = jnp.exp(z)
    denom = jnp.sum(ez, axis=1, keepdims=True)
    probs = ez / denom
    probs_ref[...] = probs

    classes = jax.lax.broadcasted_iota(jnp.int32, (n, c), 1)
    onehot = (classes == labels[:, None]).astype(jnp.float32)
    logp = z - jnp.log(denom)
    nvalid = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(mask * jnp.sum(onehot * logp, axis=1)) / nvalid
    loss_ref[...] = loss.reshape(1, 1)

    pred = jnp.argmax(logits, axis=1)
    acc = jnp.sum(mask * (pred == labels).astype(jnp.float32)) / nvalid
    acc_ref[...] = acc.reshape(1, 1)


def _xent_forward(logits, labels, mask):
    n, c = logits.shape
    loss, acc, probs = pl.pallas_call(
        _xent_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, c), jnp.float32),
        ),
        interpret=True,
    )(logits, labels, mask)
    return loss.reshape(()), acc.reshape(()), probs


@jax.custom_vjp
def masked_softmax_xent(logits, labels, mask):
    """Returns ``(loss, acc, probs)``; differentiable in ``logits``."""
    return _xent_forward(logits, labels, mask)


def _xent_fwd(logits, labels, mask):
    loss, acc, probs = _xent_forward(logits, labels, mask)
    return (loss, acc, probs), (labels, mask, probs)


def _xent_bwd(res, cotangents):
    labels, mask, probs = res
    g_loss, _g_acc, g_probs = cotangents
    n, c = probs.shape
    onehot = (labels[:, None] == jnp.arange(c)[None, :]).astype(probs.dtype)
    nvalid = jnp.maximum(jnp.sum(mask), 1.0)
    dlogits = g_loss * (probs - onehot) * mask[:, None] / nvalid
    # probs output may also be used downstream (inference path shares code):
    # softmax jacobian-vector product.
    if g_probs is not None:
        inner = jnp.sum(g_probs * probs, axis=1, keepdims=True)
        dlogits = dlogits + probs * (g_probs - inner)
    dlabels = jnp.zeros_like(labels)
    dmask = jnp.zeros_like(mask)
    return dlogits, dlabels, dmask


masked_softmax_xent.defvjp(_xent_fwd, _xent_bwd)
