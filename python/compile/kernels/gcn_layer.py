"""Fused GCN layer as a Pallas kernel: ``act(a_hat @ (x @ w) + b)``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the two chained GEMMs of a
GCN layer are fused into one kernel so the intermediate ``x @ w`` block never
leaves VMEM. The grid tiles the *output-feature* dimension; per grid step the
kernel holds

    x      [N, Din]    (node-feature block, VMEM-resident)
    w      [Din, T]    (weight column tile → MXU)
    a_hat  [N, N]      (normalized connectivity, reused across tiles)
    out    [N, T]

For the repo's shapes (N=64, Din≤256, T=128) that is ≈0.42 MiB — far under
VMEM, so HBM traffic is exactly one read per operand and one write of the
output, which an unfused XLA lowering does not guarantee (it spills the
intermediate between the two dots).

Backward pass: a ``jax.custom_vjp`` in plain jnp (Pallas has no transpose
rule); the expressions are three small GEMMs that XLA fuses on its own.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(dout: int) -> int:
    """Output-feature tile: 128 (MXU lane width) when divisible, else the
    whole dimension (head layers have Dout = C = 8)."""
    return 128 if dout % 128 == 0 else dout


def _gcn_kernel(a_ref, x_ref, w_ref, ws_ref, b_ref, o_ref, *, relu: bool):
    x = x_ref[...]
    xw = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    out = jnp.dot(a_ref[...], xw, preferred_element_type=jnp.float32)
    out = out + jnp.dot(x, ws_ref[...], preferred_element_type=jnp.float32)
    out = out + b_ref[...]
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out


def _gcn_forward(a_hat, x, w, w_self, b, relu: bool):
    n, din = x.shape
    dout = w.shape[1]
    t = _pick_tile(dout)
    grid = (dout // t,)
    return pl.pallas_call(
        functools.partial(_gcn_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),        # a_hat: reused
            pl.BlockSpec((n, din), lambda j: (0, 0)),      # x: reused
            pl.BlockSpec((din, t), lambda j: (0, j)),      # w: column tile
            pl.BlockSpec((din, t), lambda j: (0, j)),      # w_self tile
            pl.BlockSpec((1, t), lambda j: (0, j)),        # b: column tile
        ],
        out_specs=pl.BlockSpec((n, t), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, dout), jnp.float32),
        interpret=True,
    )(a_hat, x, w, w_self, b.reshape(1, dout))


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def gcn_layer(a_hat, x, w, w_self, b, relu: bool = True):
    """One residual GCN layer (Eq. 1 + self path), Pallas-fused:
    ``act(Â (x w) + x w_self + b)``. Differentiable in ``x``, ``w``,
    ``w_self``, ``b`` (``a_hat`` is data — the cluster topology)."""
    return _gcn_forward(a_hat, x, w, w_self, b, relu)


def _gcn_fwd(a_hat, x, w, w_self, b, relu: bool):
    out = _gcn_forward(a_hat, x, w, w_self, b, relu)
    return out, (a_hat, x, w, w_self, out)


def _gcn_bwd(relu: bool, res, g):
    a_hat, x, w, w_self, out = res
    if relu:
        g = g * (out > 0).astype(g.dtype)
    # out = a @ (x @ w) + x @ ws + b  (a treated as constant)
    atg = a_hat.T @ g                    # [N, Dout]
    dx = atg @ w.T + g @ w_self.T        # [N, Din]
    dw = x.T @ atg                       # [Din, Dout]
    dws = x.T @ g                        # [Din, Dout]
    db = jnp.sum(g, axis=0)              # [Dout] (bias added after the a@ ·)
    da = jnp.zeros_like(a_hat)           # topology carries no gradient
    return da, dx, dw, dws, db


gcn_layer.defvjp(_gcn_fwd, _gcn_bwd)
