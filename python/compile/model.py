"""Layer-2 JAX model: the Hulk GCN (paper §3–§4).

Architecture (paper Fig. 2–3):
  edge-pool layer (Eq. 4)  F  → H     folds WAN-latency edge data into nodes
  GCN layer 1     (Eq. 1)  H  → H
  GCN layer 2     (Eq. 1)  H  → H
  GCN layer 3     (Eq. 1)  H  → H2
  GCN head        (Eq. 1)  H2 → C     logits, no activation
  masked softmax cross-entropy (Eq. 5)

Every GCN layer carries a residual self path (``x @ w_self``): without
it, strong intra-region affinities make same-region rows of the
aggregation identical and the network collapses to the label marginal
(EXPERIMENTS.md Fig4 notes). Default dims (N=64 node slots, F=18, H=192,
H2=96, C=8) give 193,640 parameters — the paper reports "188k"; the
small delta is the paper not specifying layer widths (F is 18 because
the region one-hot covers the 12-region catalog, not just the paper's
ten regions). Optimizer: Adam(lr=0.01) per the paper's learning
rate; Fig. 4's "99% accuracy by step 6" reproduces under these settings
(see EXPERIMENTS.md).

All hot ops route through the L1 Pallas kernels; the only jnp glue is the
edge-pool linear combine and the Adam update (pure element-wise, XLA fuses
them into the surrounding kernels' HLO).

Parameters travel as ONE flat f32 vector so the Rust runtime manages a
single device buffer; ``param_layout()`` is the offset contract and is
emitted into ``artifacts/manifest.kv``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import edge_aggregate, gcn_layer, masked_softmax_xent
from .kernels import ref as _ref  # noqa: F401  (re-exported for tests)
from .kernels.ref import (edge_aggregate_ref, gcn_layer_ref,
                          masked_softmax_xent_ref, sym_normalize_ref)

# Latencies are O(100) ms; this keeps the edge-pool latency channel O(1).
WSUM_SCALE = 0.01

# Adam hyper-parameters (paper specifies only lr = 0.01).
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape contract shared with the Rust runtime."""
    n: int = 64    # node slots (46-server fleet + scale-out headroom)
    f: int = 18    # input features per node (graph::features in rust)
    h: int = 192   # hidden width
    h2: int = 96   # pre-head width
    c: int = 8     # task classes (max concurrent tasks)

    def param_layout(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """(name, shape) in flat-vector order. The Rust side mirrors this
        only through the total length P; slicing happens here."""
        f, h, h2, c = self.f, self.h, self.h2, self.c
        return [
            ("ep_w_self", (f, h)),
            ("ep_w_nbr", (f, h)),
            ("ep_w_e", (1, h)),
            ("ep_b", (h,)),
            ("g1_w", (h, h)),
            ("g1_ws", (h, h)),
            ("g1_b", (h,)),
            ("g2_w", (h, h)),
            ("g2_ws", (h, h)),
            ("g2_b", (h,)),
            ("g3_w", (h, h2)),
            ("g3_ws", (h, h2)),
            ("g3_b", (h2,)),
            ("hd_w", (h2, c)),
            ("hd_ws", (h2, c)),
            ("hd_b", (c,)),
        ]

    @property
    def n_params(self) -> int:
        total = 0
        for _, shape in self.param_layout():
            size = 1
            for d in shape:
                size *= d
            total += size
        return total


DEFAULT_CONFIG = ModelConfig()


def unflatten_params(cfg: ModelConfig, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Slice the flat parameter vector into named tensors (static offsets —
    lowers to HLO slices, no gather)."""
    out: Dict[str, jnp.ndarray] = {}
    off = 0
    for name, shape in cfg.param_layout():
        size = 1
        for d in shape:
            size *= d
        out[name] = flat[off:off + size].reshape(shape)
        off += size
    assert off == cfg.n_params
    return out


def flatten_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in cfg.param_layout()])


def init_params(cfg: ModelConfig = DEFAULT_CONFIG, seed: int = 0) -> jnp.ndarray:
    """Glorot-uniform weights (head scaled 0.1× so initial logits stay near
    zero → initial loss ≈ ln C), zero biases. Deterministic in ``seed`` —
    the same vector is serialized to ``artifacts/init_params.f32`` for
    Rust."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in cfg.param_layout():
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            parts.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in, fan_out = shape[0], shape[-1]
            bound = jnp.sqrt(6.0 / (fan_in + fan_out))
            if name in ("hd_w", "hd_ws"):
                bound = bound * 0.1
            parts.append(
                jax.random.uniform(sub, shape, jnp.float32, -bound, bound)
                .reshape(-1))
    return jnp.concatenate([p.reshape(-1) for p in parts])


def _edge_pool(p: Dict[str, jnp.ndarray], adj, x, mask):
    """Paper Eq. 4 with mean normalization (the 1/c_{u,v} of Eq. 1):
    h_v = relu(W_s x_v + W_n mean_{u∈N(v)} x_u + w_e · latsum_v + b)."""
    nbr_sum, deg, wsum = edge_aggregate(adj, x)
    degc = jnp.maximum(deg, 1.0)
    nbr_mean = nbr_sum / degc
    wmean = (wsum / degc) * WSUM_SCALE
    h = (x @ p["ep_w_self"] + nbr_mean @ p["ep_w_nbr"]
         + wmean @ p["ep_w_e"] + p["ep_b"])
    return jnp.maximum(h, 0.0) * mask[:, None]


def forward(cfg: ModelConfig, flat_params, adj, feats, mask) -> jnp.ndarray:
    """Full forward pass → class probabilities [N, C]."""
    p = unflatten_params(cfg, flat_params)
    a_hat = sym_normalize_ref(adj)
    h0 = _edge_pool(p, adj, feats, mask)
    h1 = gcn_layer(a_hat, h0, p["g1_w"], p["g1_ws"], p["g1_b"], True) * mask[:, None]
    h2 = gcn_layer(a_hat, h1, p["g2_w"], p["g2_ws"], p["g2_b"], True) * mask[:, None]
    h3 = gcn_layer(a_hat, h2, p["g3_w"], p["g3_ws"], p["g3_b"], True) * mask[:, None]
    logits = gcn_layer(a_hat, h3, p["hd_w"], p["hd_ws"], p["hd_b"], False)
    z = logits - jnp.max(logits, axis=1, keepdims=True)
    ez = jnp.exp(z)
    return ez / jnp.sum(ez, axis=1, keepdims=True)


def _logits(cfg: ModelConfig, flat_params, adj, feats, mask):
    p = unflatten_params(cfg, flat_params)
    a_hat = sym_normalize_ref(adj)
    h0 = _edge_pool(p, adj, feats, mask)
    h1 = gcn_layer(a_hat, h0, p["g1_w"], p["g1_ws"], p["g1_b"], True) * mask[:, None]
    h2 = gcn_layer(a_hat, h1, p["g2_w"], p["g2_ws"], p["g2_b"], True) * mask[:, None]
    h3 = gcn_layer(a_hat, h2, p["g3_w"], p["g3_ws"], p["g3_b"], True) * mask[:, None]
    return gcn_layer(a_hat, h3, p["hd_w"], p["hd_ws"], p["hd_b"], False)


def loss_fn(cfg: ModelConfig, flat_params, adj, feats, labels, mask):
    """Masked cross-entropy (Eq. 5). Returns (loss, (acc, probs))."""
    logits = _logits(cfg, flat_params, adj, feats, mask)
    loss, acc, probs = masked_softmax_xent(logits, labels, mask)
    return loss, (acc, probs)


def train_step(cfg: ModelConfig, flat_params, m, v, step, adj, feats,
               labels, mask, lr):
    """One Adam step. ``step`` is the 1-based step counter as f32 (bias
    correction). Returns (params', m', v', loss, acc)."""
    (loss, (acc, _)), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, adj, feats, labels, mask),
        has_aux=True)(flat_params)
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * grads * grads
    mhat = m / (1.0 - ADAM_B1 ** step)
    vhat = v / (1.0 - ADAM_B2 ** step)
    new_params = flat_params - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return new_params, m, v, loss, acc


# ---------------------------------------------------------------------------
# Pure-jnp reference model (same math through ref.py ops) — used by pytest to
# bisect model-level divergence down to a kernel.
# ---------------------------------------------------------------------------

def forward_ref(cfg: ModelConfig, flat_params, adj, feats, mask):
    p = unflatten_params(cfg, flat_params)
    a_hat = sym_normalize_ref(adj)
    nbr_sum, deg, wsum = edge_aggregate_ref(adj, feats)
    degc = jnp.maximum(deg, 1.0)
    h0 = (feats @ p["ep_w_self"] + (nbr_sum / degc) @ p["ep_w_nbr"]
          + (wsum / degc) * WSUM_SCALE @ p["ep_w_e"] + p["ep_b"])
    h0 = jnp.maximum(h0, 0.0) * mask[:, None]
    h1 = gcn_layer_ref(a_hat, h0, p["g1_w"], p["g1_ws"], p["g1_b"], True) * mask[:, None]
    h2 = gcn_layer_ref(a_hat, h1, p["g2_w"], p["g2_ws"], p["g2_b"], True) * mask[:, None]
    h3 = gcn_layer_ref(a_hat, h2, p["g3_w"], p["g3_ws"], p["g3_b"], True) * mask[:, None]
    logits = gcn_layer_ref(a_hat, h3, p["hd_w"], p["hd_ws"], p["hd_b"], False)
    z = logits - jnp.max(logits, axis=1, keepdims=True)
    ez = jnp.exp(z)
    return ez / jnp.sum(ez, axis=1, keepdims=True)


def loss_ref(cfg: ModelConfig, flat_params, adj, feats, labels, mask):
    p = unflatten_params(cfg, flat_params)
    a_hat = sym_normalize_ref(adj)
    nbr_sum, deg, wsum = edge_aggregate_ref(adj, feats)
    degc = jnp.maximum(deg, 1.0)
    h0 = (feats @ p["ep_w_self"] + (nbr_sum / degc) @ p["ep_w_nbr"]
          + (wsum / degc) * WSUM_SCALE @ p["ep_w_e"] + p["ep_b"])
    h0 = jnp.maximum(h0, 0.0) * mask[:, None]
    h1 = gcn_layer_ref(a_hat, h0, p["g1_w"], p["g1_ws"], p["g1_b"], True) * mask[:, None]
    h2 = gcn_layer_ref(a_hat, h1, p["g2_w"], p["g2_ws"], p["g2_b"], True) * mask[:, None]
    h3 = gcn_layer_ref(a_hat, h2, p["g3_w"], p["g3_ws"], p["g3_b"], True) * mask[:, None]
    logits = gcn_layer_ref(a_hat, h3, p["hd_w"], p["hd_ws"], p["hd_b"], False)
    loss, acc, probs = masked_softmax_xent_ref(logits, labels, mask)
    return loss, (acc, probs)
