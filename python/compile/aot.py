"""AOT lowering: JAX model → HLO *text* artifacts for the Rust runtime.

Run once via ``make artifacts`` (no-op when inputs are unchanged). Python
never runs on the request path — the Rust binary is self-contained once
``artifacts/`` exists.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate binds) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Artifacts:
  gcn_forward.hlo.txt    (params[P], adj[N,N], feats[N,F], mask[N])
                         → (probs[N,C],)
  gcn_train_step.hlo.txt (params[P], m[P], v[P], step[1], adj[N,N],
                          feats[N,F], labels[N]i32, mask[N], lr[1])
                         → (params'[P], m'[P], v'[P], loss[], acc[])
  manifest.kv            shape contract consumed by rust (runtime::artifact)
  init_params.f32        deterministic init vector, little-endian f32
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import DEFAULT_CONFIG, ModelConfig, init_params, forward, train_step


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_forward(cfg: ModelConfig) -> str:
    def fn(params, adj, feats, mask):
        return (forward(cfg, params, adj, feats, mask),)

    specs = (
        jax.ShapeDtypeStruct((cfg.n_params,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n, cfg.n), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n, cfg.f), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n,), jnp.float32),
    )
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_train_step(cfg: ModelConfig) -> str:
    def fn(params, m, v, step, adj, feats, labels, mask, lr):
        # step/lr arrive as [1] f32 buffers (simplest rust marshalling).
        p, m2, v2, loss, acc = train_step(
            cfg, params, m, v, step[0], adj, feats, labels, mask, lr[0])
        return (p, m2, v2, loss, acc)

    pshape = jax.ShapeDtypeStruct((cfg.n_params,), jnp.float32)
    specs = (
        pshape, pshape, pshape,
        jax.ShapeDtypeStruct((1,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n, cfg.n), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n, cfg.f), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n,), jnp.int32),
        jax.ShapeDtypeStruct((cfg.n,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
    return to_hlo_text(jax.jit(fn).lower(*specs))


def write_manifest(cfg: ModelConfig, out_dir: str) -> None:
    """Plain key-value manifest (offline registry has no serde; rust parses
    this with util::kv)."""
    lines = [
        "format 1",
        f"n {cfg.n}",
        f"f {cfg.f}",
        f"h {cfg.h}",
        f"h2 {cfg.h2}",
        f"c {cfg.c}",
        f"p {cfg.n_params}",
        "forward gcn_forward.hlo.txt",
        "train_step gcn_train_step.hlo.txt",
        "init_params init_params.f32",
    ]
    with open(os.path.join(out_dir, "manifest.kv"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = DEFAULT_CONFIG
    os.makedirs(args.out_dir, exist_ok=True)

    fwd = lower_forward(cfg)
    with open(os.path.join(args.out_dir, "gcn_forward.hlo.txt"), "w") as f:
        f.write(fwd)
    print(f"gcn_forward.hlo.txt: {len(fwd)} chars")

    ts = lower_train_step(cfg)
    with open(os.path.join(args.out_dir, "gcn_train_step.hlo.txt"), "w") as f:
        f.write(ts)
    print(f"gcn_train_step.hlo.txt: {len(ts)} chars")

    params = np.asarray(init_params(cfg, seed=args.seed), dtype="<f4")
    params.tofile(os.path.join(args.out_dir, "init_params.f32"))
    print(f"init_params.f32: {params.size} f32 ({cfg.n_params} expected)")
    assert params.size == cfg.n_params

    write_manifest(cfg, args.out_dir)
    print("manifest.kv written")


if __name__ == "__main__":
    main()
