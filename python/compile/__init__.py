"""Build-time Python package for Hulk (L1 Pallas kernels + L2 JAX model).

Nothing in this package is imported at runtime: ``aot.py`` lowers the model
to HLO text once (``make artifacts``) and the Rust coordinator loads the
artifacts through PJRT.
"""
