//! Disaster recovery (paper §1 / Contributions): run the multi-task
//! leader, kill machines mid-training, and watch the coordinator promote
//! spares or re-queue tasks — then verify the assignment stays valid and
//! quantify the interruption with the discrete-event simulator.
//!
//! Run: `cargo run --release --example failure_recovery`

use hulk::cluster::Fleet;
use hulk::coordinator::{Coordinator, CoordinatorEvent, CoordinatorReply};
use hulk::graph::ClusterGraph;
use hulk::models::ModelSpec;
use hulk::parallel::PipelinePlan;
use hulk::planner::chain_order;
use hulk::sim::{simulate_pipeline, FailurePlan};
use hulk::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let fleet = Fleet::paper_evaluation(0);
    let mut coordinator = Coordinator::new(fleet);
    let mut rng = Rng::new(7);

    // Admit the four-model workload.
    for model in ModelSpec::paper_four() {
        let name = model.name;
        match coordinator.handle(CoordinatorEvent::Submit {
            model, iterations: 100 }) {
            CoordinatorReply::Admitted { task_id, machines } => {
                println!("task {task_id} ({name}) running on {} machines",
                         machines.len());
            }
            CoordinatorReply::Queued { task_id } => {
                println!("task {task_id} ({name}) queued");
            }
            _ => {}
        }
    }

    // Micro-view: simulate one iteration of task 0's pipeline with a
    // failure injected mid-flight.
    let task0 = coordinator.tasks[0].clone();
    let graph = ClusterGraph::from_fleet(&coordinator.fleet);
    let ordered = chain_order(&graph, &task0.machines);
    let stages: Vec<usize> =
        ordered.into_iter().take(task0.model.layers).collect();
    let plan = PipelinePlan::proportional(&coordinator.fleet, stages,
                                          &task0.model);
    let healthy = simulate_pipeline(&coordinator.fleet, &plan, &task0.model,
                                    false, None);
    println!("\nhealthy iteration of {}: {:.1} ms \
              ({} DES events, {:.0}% mean stage utilization)",
             task0.model.name, healthy.makespan_ms,
             healthy.events_processed, healthy.mean_utilization * 100.0);
    let victim = plan.stages[plan.stages.len() / 2];
    let failed = simulate_pipeline(
        &coordinator.fleet, &plan, &task0.model, false,
        Some(FailurePlan { at_ms: healthy.makespan_ms * 0.4,
                           machine: victim }));
    let outcome = failed.failure.expect("failure fires");
    println!("injected failure of machine {victim} at {:.1} ms → \
              {} microbatches survived",
             outcome.at_ms, outcome.completed_microbatches);

    // Macro-view: the coordinator's recovery policy.
    println!("\ncoordinator recovery:");
    for _ in 0..3 {
        let victim = rng.below(coordinator.fleet.len());
        if let CoordinatorReply::Recovered { action } = coordinator
            .handle(CoordinatorEvent::MachineFailed { machine: victim })
        {
            println!("  machine {victim:>2} failed → {action:?}");
        }
    }
    coordinator
        .assignment
        .validate_disjoint(coordinator.fleet.len())
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("\nassignment still disjoint after failures ✓");
    println!("\nleader metrics:\n{}", coordinator.metrics.render());
    Ok(())
}
