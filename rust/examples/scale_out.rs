//! Scalability (paper §5.2 / Fig. 6): add machines to a live system —
//! including the paper's node 45 {Rome, 7, 384} — and watch incremental
//! re-assignment; then scale one back in.
//!
//! Run: `cargo run --release --example scale_out`

use hulk::cluster::paper_data::fig6_node_45;
use hulk::cluster::{Fleet, GpuModel, Region};
use hulk::coordinator::{scale_in, scale_out};
use hulk::graph::ClusterGraph;
use hulk::models::ModelSpec;
use hulk::scheduler::{oracle_partition, OracleOptions};

fn main() -> anyhow::Result<()> {
    // Start from a 45-machine system (leave room for the paper's id 45).
    let mut fleet = Fleet::paper_evaluation(0);
    fleet.remove_machine(45);
    let graph = ClusterGraph::from_fleet(&fleet);
    let mut tasks = ModelSpec::paper_four();
    ModelSpec::sort_largest_first(&mut tasks);
    let mut assignment = oracle_partition(&fleet, &graph, &tasks,
                                          &OracleOptions::default());
    println!("initial assignment over {} machines:", fleet.len());
    for (t, g) in assignment.groups.iter().enumerate() {
        println!("  {}: {} machines", tasks[t].name, g.len());
    }

    // Fig. 6: join node 45.
    let spec = fig6_node_45();
    let (id, placed) = scale_out(&mut fleet, &mut assignment, &tasks,
                                 spec.region, spec.gpu, spec.n_gpus);
    println!("\n+ machine {id} {} joined", spec.label());
    match placed {
        Some(t) => println!("  → task {t} ({})", tasks[t].name),
        None => println!("  → spare pool"),
    }

    // Add two more machines in different regions.
    for (region, gpu) in [(Region::California, GpuModel::A100),
                          (Region::Brasilia, GpuModel::TitanXp)] {
        let (id, placed) = scale_out(&mut fleet, &mut assignment, &tasks,
                                     region, gpu, 8);
        println!("+ machine {id} {{{}, {}, {}}} joined → {:?}",
                 region.name(), gpu.compute_capability(),
                 (gpu.memory_gb() * 8.0) as i64,
                 placed.map(|t| tasks[t].name));
    }

    assignment
        .validate_disjoint(fleet.len())
        .map_err(|e| anyhow::anyhow!(e))?;
    assignment
        .validate_memory(&fleet, &tasks)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("\nassignment valid after scale-out ✓");

    // Scale one machine back in (graceful departure).
    let graph = ClusterGraph::from_fleet(&fleet);
    let victim = assignment.groups[3][0];
    let action = scale_in(&fleet, &graph, &mut assignment, &tasks, victim);
    println!("- machine {victim} departed → {action:?}");
    assignment
        .validate_disjoint(fleet.len())
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("assignment valid after scale-in ✓");
    Ok(())
}
