//! Multi-task workload (paper Fig. 10): six models on the 46-server
//! fleet, evaluated under all four systems, with the paper's headline
//! "who wins and by how much" comparison.
//!
//! Run: `cargo run --release --example multi_task`

use hulk::cluster::Fleet;
use hulk::models::ModelSpec;
use hulk::planner::HulkSplitterKind;
use hulk::scenarios::evaluate_all;

fn main() -> anyhow::Result<()> {
    let fleet = Fleet::paper_evaluation(0);
    println!("fleet: {} servers / {} GPUs / {:.1} TB",
             fleet.len(), fleet.total_gpus(),
             fleet.total_memory_gb() / 1e3);

    let workload = ModelSpec::paper_six();
    let eval = evaluate_all(&fleet, &workload, HulkSplitterKind::Oracle)?;
    println!("\n{}", eval.render());

    // Per-system aggregate over the feasible subset.
    println!("aggregate totals (feasible models only):");
    for (s, meta) in eval.systems.iter().enumerate() {
        let total: f64 = eval
            .costs
            .iter()
            .map(|row| row[s].total_ms())
            .filter(|t| t.is_finite())
            .sum();
        let feasible = eval
            .costs
            .iter()
            .filter(|row| row[s].is_feasible())
            .count();
        println!("  {:<22} {:>12.0} ms/iter  ({feasible}/{} models)",
                 meta.name, total, eval.models.len());
    }
    println!("\nHulk improvement over best baseline: {:.1}% \
              (paper: >20%)", eval.hulk_improvement() * 100.0);
    Ok(())
}
