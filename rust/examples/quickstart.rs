//! Quickstart: build the paper's Fig. 1 eight-node cluster graph, embed
//! features, and split it for two training tasks (GPT-2 vs BERT-large —
//! the paper's §5.1 walkthrough / Fig. 5).
//!
//! Run: `cargo run --release --example quickstart`

use hulk::cluster::Fleet;
use hulk::graph::{node_features, ClusterGraph, FEATURE_DIM};
use hulk::models::ModelSpec;
use hulk::scheduler::{oracle_partition, OracleOptions};

fn main() -> anyhow::Result<()> {
    // 1. The Fig. 1 toy fleet: 8 machines over 8 regions.
    let fleet = Fleet::paper_toy(0);
    println!("fleet:");
    for m in &fleet.machines {
        println!("  node {} {}", m.id, m.label());
    }

    // 2. Graph representation (§3): weighted adjacency + node features.
    let graph = ClusterGraph::from_fleet(&fleet);
    println!("\nedges (ms per 64 B):");
    for i in 0..graph.n {
        for j in (i + 1)..graph.n {
            if graph.has_edge(i, j) {
                println!("  {i} ↔ {j}: {:.1}", graph.weight(i, j));
            }
        }
    }
    let feats = node_features(&fleet.machines, &graph, graph.n);
    println!("\nnode 0 features ({} dims): {:?}", FEATURE_DIM,
             &feats[..FEATURE_DIM]);

    // 3. Two-task split (paper §5.1: GPT-2 : BERT ≈ 4.4 : 1).
    let tasks = vec![ModelSpec::gpt2_xl(), ModelSpec::bert_large()];
    let assignment = oracle_partition(&fleet, &graph, &tasks,
                                      &OracleOptions::default());
    println!("\n{}", assignment.render_table(&tasks));
    assignment
        .validate_memory(&fleet, &tasks)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("assignment is memory-feasible ✓");
    println!("intra-group comm cost: {:.0}",
             assignment.total_cost(&graph));
    Ok(())
}
