//! End-to-end driver: the full Hulk stack on a real small workload,
//! proving all three layers compose (EXPERIMENTS.md §E2E).
//!
//! 1. Build the 46-server fleet and oracle-label a training corpus of
//!    random clusters (L3).
//! 2. Train the GCN **from Rust through PJRT** — the Pallas/JAX artifact
//!    compiled by `make artifacts` (L1+L2) — logging the loss curve.
//! 3. Use the trained GCN as Algorithm 1's splitter `F` to deploy the
//!    paper's four-model workload.
//! 4. Evaluate against Systems A/B/C and report the headline >20%
//!    improvement.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use hulk::cluster::Fleet;
use hulk::gnn::trainer::evaluate_accuracy;
use hulk::gnn::{make_dataset, train_gcn, Classifier, TrainerOptions};
use hulk::models::ModelSpec;
use hulk::planner::{HulkPlanner, HulkSplitterKind, PlanContext, Planner};
use hulk::runtime::client::TrainState;
use hulk::runtime::{GcnRuntime, Manifest};
use hulk::scenarios::evaluate_all;

fn main() -> anyhow::Result<()> {
    // ---- L1/L2: load the AOT artifacts --------------------------------
    let rt = GcnRuntime::load(&Manifest::default_dir())?;
    println!("PJRT platform: {} | GCN params: {} (paper: 188k)",
             rt.platform(), rt.manifest.p);

    // ---- L3: corpus generation (oracle labels) ------------------------
    let train_set = make_dataset(48, rt.manifest.n, 1);
    let test_set = make_dataset(12, rt.manifest.n, 2);
    println!("dataset: {} train / {} test labeled cluster graphs",
             train_set.len(), test_set.len());

    // ---- Train the GCN from Rust (a few hundred steps) ----------------
    let mut state = TrainState::fresh(rt.manifest.load_init_params()?);
    let opts = TrainerOptions { steps: 300, lr: 0.01, log_every: 25 };
    let t0 = std::time::Instant::now();
    let curve = train_gcn(&rt, &mut state, &train_set, &opts)?;
    let wall = t0.elapsed().as_secs_f64();
    let train_acc = curve.iter().rev().take(20).map(|p| p.acc as f64)
        .sum::<f64>() / 20.0;
    let test_acc = evaluate_accuracy(&rt, &state.params, &test_set)?;
    println!("trained {} steps in {:.1} s ({:.1} ms/step) — \
              train acc {:.3}, held-out acc {:.3}",
             opts.steps, wall, wall * 1e3 / opts.steps as f64,
             train_acc, test_acc);

    // ---- Deploy the paper workload with the trained GCN ---------------
    let fleet = Fleet::paper_evaluation(0);
    let params = state.params.clone();
    let classifier = Classifier::Runtime(rt);
    let eval = evaluate_all(
        &fleet,
        &ModelSpec::paper_four(),
        HulkSplitterKind::Gnn { classifier: &classifier, params: &params },
    )?;
    println!("\n{}", eval.render());
    let imp = eval.hulk_improvement();
    println!("Hulk total-time improvement over best feasible baseline: \
              {:.1}%  (paper headline: >20%)", imp * 100.0);
    anyhow::ensure!(imp > 0.0, "Hulk regressed against baselines");

    // ---- Assignment quality: GNN vs chance (exact-label accuracy is
    // permutation-pessimistic; this is the operational metric) ----------
    let graph = hulk::graph::ClusterGraph::from_fleet(&fleet);
    let mut workload = ModelSpec::paper_four();
    ModelSpec::sort_largest_first(&mut workload);
    let ctx = PlanContext::new(
        &fleet,
        &graph,
        &workload,
        HulkSplitterKind::Gnn { classifier: &classifier, params: &params },
    );
    let placement = HulkPlanner.plan(&ctx)?;
    let assignment = placement.to_assignment();
    let ratio = hulk::gnn::cost_vs_random(&fleet, &graph, &assignment, 0);
    println!("GNN grouping comm-cost vs random baseline: {:.2}× \
              (lower is better; 1.0 = chance)", ratio);
    anyhow::ensure!(ratio < 1.0, "GNN grouping no better than chance");
    Ok(())
}
