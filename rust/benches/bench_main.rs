//! `cargo bench` entry point (harness = false; criterion is not in the
//! offline registry — `hulk::benchkit` provides the measurement
//! discipline). Runs every paper table/figure reproduction plus the
//! microbenchmarks; pass names to filter, e.g.
//! `cargo bench --bench bench_main -- fig8 micro`.

use hulk::cli::Cli;
use hulk::scenarios::bench;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // cargo passes `--bench`; drop flags it injects.
    let names: Vec<String> = args
        .iter()
        .filter(|a| !a.starts_with("--"))
        .cloned()
        .collect();
    let cli = Cli::parse(&["bench".to_string()])?;
    bench::run(&names, &cli)
}
