//! End-to-end pins for `hulk serve`: an in-process daemon on an
//! ephemeral port, exercised over real sockets.
//!
//! The load-bearing contracts:
//! 1. A served `Place` answer is **byte-identical** to planning
//!    directly on an equal world — and the machines in the reply match
//!    a direct `Planner::plan` exactly.
//! 2. Batched answers are byte-identical to unbatched answers, and a
//!    concurrent burst pays **one** GCN forward.
//! 3. Admin mutations flow through the incremental graph seam only:
//!    a failed machine disappears from subsequent placements, the
//!    dense-rebuild counter stays 0 and `max_dense_n` stays under the
//!    oracle ceiling.
//! 4. Framing hardening: garbage gets typed errors on a live
//!    connection; oversized frames error-then-close; partial writes
//!    reassemble; stalled clients are disconnected; the daemon never
//!    panics or wedges.
//! 5. Self-healing: injected worker/shard panics are supervised and
//!    restarted in-process (`worker_restarts` visible in stats, and
//!    only when `--fault-injection` armed them); overload sheds at the
//!    accept door with a typed `overloaded` reply; shutdown drains the
//!    in-flight batch before any thread exits; chaos admin ops
//!    (`fail_region`, `wan`) mutate the world through the same
//!    incremental seam as `fail`/`join`.

use std::io::Write;
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use hulk::gnn::GnnSplitter;
use hulk::planner::{CostBackend, HulkSplitterKind, PlanContext,
                    PlannerRegistry};
use hulk::serve::{default_classifier, parse_request, read_frame,
                  roundtrip, write_frame, LiveWorld, Request,
                  ServeConfig, Server, MAX_FRAME};
use hulk::util::json::Json;

fn spawn(seed: u64, batch_window_ms: u64) -> (Server, TcpStream) {
    let config = ServeConfig {
        seed,
        batch_window_ms,
        ..ServeConfig::default()
    };
    let server = Server::spawn(&config).expect("daemon spawns");
    let stream = TcpStream::connect(server.addr().unwrap())
        .expect("daemon accepts");
    (server, stream)
}

fn rpc(stream: &mut TcpStream, request: &str) -> String {
    let reply =
        roundtrip(stream, request.as_bytes()).expect("round-trip");
    String::from_utf8(reply).expect("replies are UTF-8 JSON")
}

fn reply_machines(reply: &str) -> Vec<Vec<usize>> {
    let parsed = Json::parse(reply).expect("reply parses");
    assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true),
               "{reply}");
    let results = parsed.get("results").and_then(Json::as_arr).unwrap();
    let tasks = results[0].get("tasks").and_then(Json::as_arr).unwrap();
    tasks
        .iter()
        .map(|t| {
            t.get("machines")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|m| m.as_usize().unwrap())
                .collect()
        })
        .collect()
}

const PLACE: &str = r#"{"op":"place","workload":[
    {"model":"bert_large"},{"model":"gpt2_xl","batch":32}],
    "systems":["hulk"]}"#;

#[test]
fn served_place_is_byte_identical_to_direct_planning() {
    let (_server, mut stream) = spawn(7, 0);
    let served = rpc(&mut stream, PLACE);

    // An equal world, planned without any daemon in the way.
    let world = LiveWorld::planet(7, CostBackend::Analytic);
    let (classifier, params) = default_classifier(7);
    let splitter = GnnSplitter::new(&classifier, &params);
    let Ok(Request::Place(req)) = parse_request(PLACE.as_bytes()) else {
        panic!("fixture request parses")
    };
    assert_eq!(served, world.plan_place(&req, &splitter),
               "served reply must be byte-identical to direct planning");

    // And the reply's machine lists match Planner::plan exactly (the
    // per-request Gnn splitter arm, not SharedGnn — pinning that the
    // two arms agree).
    let hulk_planner = PlannerRegistry::standard();
    let hulk_planner = hulk_planner.find("hulk").unwrap();
    let ctx = PlanContext::new(
        &world.fleet, &world.hier, &req.workload,
        HulkSplitterKind::Gnn { classifier: &classifier,
                                params: &params })
        .with_hier(&world.hier);
    let placement = hulk_planner.plan(&ctx).unwrap();
    let machines = reply_machines(&served);
    assert_eq!(machines.len(), 2);
    for (t, got) in machines.iter().enumerate() {
        assert_eq!(got.as_slice(), placement.machines(t), "task {t}");
    }
}

#[test]
fn batched_replies_match_unbatched_and_share_one_forward() {
    // Unbatched baseline.
    let (_plain, mut stream) = spawn(11, 0);
    let expected = rpc(&mut stream, PLACE);

    // Batching daemon: a 25ms window easily covers a concurrent burst.
    // (Drop the helper connection so it doesn't pin a worker: each
    // worker owns one connection until it closes or times out.)
    let (server, keepalive) = spawn(11, 25);
    drop(keepalive);
    let addr = server.addr().unwrap();
    let burst = 8;
    let mut handles = Vec::new();
    for _ in 0..burst {
        handles.push(thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            rpc(&mut s, PLACE)
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), expected,
                   "batched reply must be byte-identical to unbatched");
    }

    // The whole burst shared one GCN forward (the splitter survives
    // across batch windows until an admin mutation re-keys the graph).
    let mut s = TcpStream::connect(addr).unwrap();
    let stats = Json::parse(&rpc(&mut s, r#"{"op":"stats"}"#)).unwrap();
    let counter = |name: &str| {
        stats.get("metrics").unwrap().get("counters").unwrap()
            .get(name).and_then(Json::as_f64).unwrap_or(0.0)
    };
    assert_eq!(counter("place_requests"), burst as f64);
    assert_eq!(counter("gcn_forwards"), 1.0,
               "a burst against a frozen world pays one forward");
    assert!(counter("batches") >= 1.0);
}

#[test]
fn sharded_cached_replies_match_single_shard_uncached() {
    // Baseline: one shard, cache off, no batch window — the slowest,
    // simplest configuration. Subject: 4 shards, cache on, 2ms window
    // — the full PR-9 fast path. Byte-identity across the two is the
    // non-negotiable contract: perf knobs must never change answers.
    let base_cfg = ServeConfig {
        seed: 21,
        batch_window_ms: 0,
        shards: 1,
        cache_capacity: 0,
        ..ServeConfig::default()
    };
    let base = Server::spawn(&base_cfg).expect("baseline spawns");
    let mut base_conn =
        TcpStream::connect(base.addr().unwrap()).unwrap();

    let fast_cfg = ServeConfig {
        seed: 21,
        batch_window_ms: 2,
        shards: 4,
        cache_capacity: 1024,
        ..ServeConfig::default()
    };
    let fast = Server::spawn(&fast_cfg).expect("sharded daemon spawns");
    assert_eq!(fast.n_shards(), 4);
    let mut fast_conn =
        TcpStream::connect(fast.addr().unwrap()).unwrap();

    // Distinct workloads exercise digest routing across shards;
    // repeats exercise the per-shard caches.
    const PLACE_B: &str = r#"{"op":"place","workload":[
        {"model":"gpt2_xl","batch":64}],"systems":["hulk"]}"#;
    const PLACE_C: &str = r#"{"op":"place","workload":[
        {"model":"bert_large","batch":128},{"model":"t5_11b"}],
        "systems":["hulk"]}"#;
    let stream = [PLACE, PLACE_B, PLACE_C, PLACE, PLACE_B, PLACE,
                  PLACE_C, PLACE_B];
    let repeats = 5; // requests 4..8 all repeat an earlier workload
    for req in stream {
        let fast_reply = rpc(&mut fast_conn, req);
        let base_reply = rpc(&mut base_conn, req);
        assert!(fast_reply.starts_with("{\"ok\":true"), "{fast_reply}");
        assert_eq!(fast_reply, base_reply,
                   "sharded+cached reply must be byte-identical to \
                    single-shard uncached");
    }

    // The fast daemon's own accounting: every repeat hit a cache, and
    // forwards never exceeded the distinct-workload count (each shard
    // pays at most one forward against a frozen world).
    let stats =
        Json::parse(&rpc(&mut fast_conn, r#"{"op":"stats"}"#)).unwrap();
    assert_eq!(stats.get("shards").and_then(Json::as_usize), Some(4));
    let counter = |name: &str| {
        stats.get("metrics").unwrap().get("counters").unwrap()
            .get(name).and_then(Json::as_f64).unwrap_or(0.0)
    };
    assert_eq!(counter("place_requests"), stream.len() as f64);
    assert_eq!(counter("cache_hits"), f64::from(repeats));
    assert_eq!(counter("cache_misses"),
               (stream.len() - repeats as usize) as f64);
    assert!(counter("gcn_forwards") <= 3.0,
            "at most one forward per distinct workload's shard, got {}",
            counter("gcn_forwards"));
    // Per-shard breakdown is present and sums to the merged view.
    let per_shard = stats.get("per_shard").and_then(Json::as_arr)
        .expect("stats reply carries per_shard");
    assert_eq!(per_shard.len(), 4);
    let shard_sum: f64 = per_shard.iter()
        .map(|m| m.get("counters")
            .and_then(|c| c.get("place_requests"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0))
        .sum();
    assert_eq!(shard_sum, stream.len() as f64);
}

#[test]
fn admin_mutations_use_the_incremental_seam_only() {
    let (_server, mut stream) = spawn(3, 0);

    // Fail machine 5.
    let reply =
        rpc(&mut stream, r#"{"op":"admin","action":"fail","machine":5}"#);
    let parsed = Json::parse(&reply).unwrap();
    assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(parsed.get("alive_machines").and_then(Json::as_usize),
               Some(219));
    // Double-fail is a typed decline, not a panic.
    let reply =
        rpc(&mut stream, r#"{"op":"admin","action":"fail","machine":5}"#);
    assert!(reply.contains("already failed"), "{reply}");

    // Every subsequent placement avoids the dead machine.
    let reply = rpc(&mut stream, PLACE);
    for (t, machines) in reply_machines(&reply).iter().enumerate() {
        assert!(!machines.contains(&5),
                "task {t} placed on failed machine: {machines:?}");
        assert!(machines.iter().all(|&m| m < 220));
    }

    // A join extends the dense id range, fleet and graph in lockstep.
    let region = hulk::cluster::Region::ALL[0].name();
    let gpu = hulk::cluster::GpuModel::ALL[0].name();
    let reply = rpc(&mut stream, &format!(
        r#"{{"op":"admin","action":"join","region":"{region}",
             "gpu":"{gpu}","n_gpus":8}}"#));
    let parsed = Json::parse(&reply).unwrap();
    assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true),
               "{reply}");
    assert_eq!(parsed.get("machine").and_then(Json::as_usize), Some(220));
    assert_eq!(parsed.get("fleet_machines").and_then(Json::as_usize),
               Some(221));

    // Still planning fine; still no machine 5; ids stay in range.
    let reply = rpc(&mut stream, PLACE);
    for machines in reply_machines(&reply) {
        assert!(!machines.contains(&5));
        assert!(machines.iter().all(|&m| m < 221));
    }

    // The incremental-update proof: zero world rebuilds, and nothing
    // allocated a dense adjacency past the oracle ceiling.
    let stats = Json::parse(&rpc(&mut stream, r#"{"op":"stats"}"#))
        .unwrap();
    assert_eq!(stats.get("dense_rebuilds").and_then(Json::as_usize),
               Some(0));
    assert!(stats.get("max_dense_n").and_then(Json::as_usize).unwrap()
            <= 1000);
    let counters = stats.get("metrics").unwrap().get("counters").unwrap();
    assert_eq!(counters.get("admin_fails").and_then(Json::as_usize),
               Some(1));
    assert_eq!(counters.get("admin_joins").and_then(Json::as_usize),
               Some(1));
    assert_eq!(counters.get("admin_errors").and_then(Json::as_usize),
               Some(1));
}

#[test]
fn garbage_gets_typed_errors_on_a_live_connection() {
    let (_server, mut stream) = spawn(0, 0);

    // Zero-length frame: typed error, connection survives.
    write_frame(&mut stream, b"").unwrap();
    let reply = read_frame(&mut stream).unwrap().unwrap();
    let reply = String::from_utf8(reply).unwrap();
    assert!(reply.contains("\"ok\":false") && reply.contains("empty"),
            "{reply}");

    // Malformed JSON, wrong op, bad fields — all keep-alive.
    for (garbage, needle) in [
        ("{nope", "malformed JSON"),
        (r#"{"op":"warp"}"#, "unknown op"),
        (r#"{"op":"place","workload":[{"model":"gpt5"}]}"#,
         "unknown model slug"),
        (r#"{"op":"place","workload":[{"model":"bert_large"}],
            "systems":["warp"]}"#, "unknown planner"),
        (r#"{"op":"admin","action":"fail","machine":100000}"#,
         "out of range"),
    ] {
        let reply = rpc(&mut stream, garbage);
        assert!(reply.contains("\"ok\":false"), "{garbage}: {reply}");
        assert!(reply.contains(needle), "{garbage}: {reply}");
    }

    // The same connection still serves real requests.
    let reply = rpc(&mut stream, r#"{"op":"stats"}"#);
    assert!(reply.contains("\"ok\":true"), "{reply}");
}

#[test]
fn partial_writes_reassemble_and_oversized_frames_close() {
    let (_server, mut stream) = spawn(0, 0);

    // Dribble a request out in four fragments with pauses: the daemon
    // must reassemble across partial reads.
    let payload = br#"{"op":"stats"}"#;
    let header = (payload.len() as u32).to_be_bytes();
    stream.write_all(&header[..2]).unwrap();
    stream.flush().unwrap();
    thread::sleep(Duration::from_millis(30));
    stream.write_all(&header[2..]).unwrap();
    stream.write_all(&payload[..5]).unwrap();
    stream.flush().unwrap();
    thread::sleep(Duration::from_millis(30));
    stream.write_all(&payload[5..]).unwrap();
    stream.flush().unwrap();
    let reply = read_frame(&mut stream).unwrap().unwrap();
    assert!(String::from_utf8(reply).unwrap().contains("\"ok\":true"));

    // An oversized length prefix: one typed error, then the daemon
    // closes (the stream cannot be resynchronized).
    stream.write_all(&(MAX_FRAME + 1).to_be_bytes()).unwrap();
    stream.flush().unwrap();
    let reply = read_frame(&mut stream).unwrap().unwrap();
    let reply = String::from_utf8(reply).unwrap();
    assert!(reply.contains("\"ok\":false") && reply.contains("exceeds"),
            "{reply}");
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    match read_frame(&mut stream) {
        Ok(None) => {}                  // clean close observed
        Ok(Some(other)) => panic!(
            "daemon kept talking on a desynced stream: {other:?}"),
        Err(_) => {}                    // reset also counts as closed
    }
}

#[test]
fn stalled_clients_are_disconnected_by_the_read_timeout() {
    let config = ServeConfig {
        seed: 0,
        batch_window_ms: 0,
        read_timeout_ms: 150,
        ..ServeConfig::default()
    };
    let server = Server::spawn(&config).unwrap();
    let mut stream = TcpStream::connect(server.addr().unwrap()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    // Send nothing: within ~150ms the daemon should hang up.
    match read_frame(&mut stream) {
        Ok(None) | Err(_) => {}
        Ok(Some(bytes)) => {
            panic!("unexpected unsolicited frame: {bytes:?}")
        }
    }
}

#[test]
fn shutdown_reply_then_every_thread_exits() {
    let (server, mut stream) = spawn(0, 2);
    let reply = rpc(&mut stream, r#"{"op":"shutdown"}"#);
    assert!(reply.contains("\"ok\":true")
        && reply.contains("shutdown"), "{reply}");
    drop(stream);
    // join() hangs forever if any worker/batcher/acceptor wedges —
    // the test timing out IS the failure signal.
    server.join();
}

#[test]
fn shutdown_drains_the_in_flight_batch() {
    // A 300ms batch window guarantees the place below is still sitting
    // in its shard's open batch when the shutdown lands.
    let (server, mut place_conn) = spawn(5, 300);
    write_frame(&mut place_conn, PLACE.as_bytes()).unwrap();
    // Let the place reach its shard and open the batch window.
    thread::sleep(Duration::from_millis(100));
    let mut admin = TcpStream::connect(server.addr().unwrap()).unwrap();
    let reply = rpc(&mut admin, r#"{"op":"shutdown"}"#);
    assert!(reply.contains("\"ok\":true"), "{reply}");
    // The in-flight batch must drain: the already-accepted place gets
    // its full reply, not a dropped connection.
    place_conn
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let reply = read_frame(&mut place_conn)
        .expect("read survives shutdown")
        .expect("in-flight place is answered before exit");
    let reply = String::from_utf8(reply).unwrap();
    assert!(reply.starts_with("{\"ok\":true"), "{reply}");
    assert_eq!(reply_machines(&reply).len(), 2);
    drop(place_conn);
    drop(admin);
    server.join();
}

#[test]
fn overload_sheds_at_the_door_with_a_typed_reply() {
    let config = ServeConfig {
        seed: 0,
        batch_window_ms: 0,
        workers: 1,
        queue_depth: 1,
        ..ServeConfig::default()
    };
    let server = Server::spawn(&config).unwrap();
    let addr = server.addr().unwrap();
    // The only worker claims this connection and holds it mid-session.
    let mut held = TcpStream::connect(addr).unwrap();
    thread::sleep(Duration::from_millis(100));
    // The single queue slot fills.
    let mut queued = TcpStream::connect(addr).unwrap();
    thread::sleep(Duration::from_millis(100));
    // The third arrival finds the queue full: typed refusal, then
    // close — never a silent hang.
    let mut shed = TcpStream::connect(addr).unwrap();
    shed.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let reply = read_frame(&mut shed).unwrap().expect("shed reply");
    let reply = String::from_utf8(reply).unwrap();
    assert!(reply.contains("\"ok\":false")
        && reply.contains("overloaded"), "{reply}");
    match read_frame(&mut shed) {
        Ok(None) | Err(_) => {} // closed, as promised
        Ok(Some(other)) => {
            panic!("shed connection kept talking: {other:?}")
        }
    }
    // The held connection is unharmed and the shed is accounted.
    let stats = Json::parse(&rpc(&mut held, r#"{"op":"stats"}"#)).unwrap();
    let shed_count = stats.get("metrics").unwrap().get("counters")
        .unwrap().get("connections_shed").and_then(Json::as_f64)
        .unwrap_or(0.0);
    assert_eq!(shed_count, 1.0);
    // Releasing the worker drains the queued connection normally.
    drop(held);
    queued.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let reply = rpc(&mut queued, r#"{"op":"stats"}"#);
    assert!(reply.contains("\"ok\":true"), "{reply}");
}

#[test]
fn injected_panics_are_supervised_and_recovered_in_process() {
    let config = ServeConfig {
        seed: 2,
        batch_window_ms: 0,
        shards: 1,
        fault_injection: true,
        ..ServeConfig::default()
    };
    let server = Server::spawn(&config).unwrap();
    let addr = server.addr().unwrap();

    // Worker scope: the acknowledgment arrives *before* the crash,
    // then the handling worker dies and this connection drops.
    let mut conn = TcpStream::connect(addr).unwrap();
    let reply = rpc(&mut conn,
                    r#"{"op":"admin","action":"panic","scope":"worker"}"#);
    assert!(reply.contains("\"ok\":true")
        && reply.contains("\"scope\":\"worker\""), "{reply}");
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    match read_frame(&mut conn) {
        Ok(None) | Err(_) => {} // the panicking worker hung up
        Ok(Some(other)) => {
            panic!("worker survived an injected panic: {other:?}")
        }
    }

    // Shard scope: poison the (single) batcher shard's channel.
    let mut conn = TcpStream::connect(addr).unwrap();
    let reply = rpc(&mut conn,
                    r#"{"op":"admin","action":"panic","scope":"shard"}"#);
    assert!(reply.contains("\"ok\":true")
        && reply.contains("\"scope\":\"shard\""), "{reply}");

    // Both crashes are recovered by the supervisor, visibly: the
    // restart counter reaches 2 and the same process keeps serving.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let stats =
            Json::parse(&rpc(&mut conn, r#"{"op":"stats"}"#)).unwrap();
        let restarts = stats.get("worker_restarts")
            .and_then(Json::as_f64).unwrap_or(0.0);
        if restarts >= 2.0 {
            let counters =
                stats.get("metrics").unwrap().get("counters").unwrap();
            let role = |name: &str| counters.get(name)
                .and_then(Json::as_f64).unwrap_or(0.0);
            assert!(role("restarts_worker") >= 1.0,
                    "worker restart not attributed");
            assert!(role("restarts_shard") >= 1.0,
                    "shard restart not attributed");
            break;
        }
        assert!(std::time::Instant::now() < deadline,
                "supervisor never recovered both panics \
                 (worker_restarts = {restarts})");
        thread::sleep(Duration::from_millis(50));
    }
    // The restarted pool still places — the crash cost nothing lasting.
    let reply = rpc(&mut conn, PLACE);
    assert!(reply.starts_with("{\"ok\":true"), "{reply}");
}

#[test]
fn unarmed_daemons_decline_panic_injection() {
    // No --fault-injection: the panic op is a typed refusal on a
    // connection that stays alive, never a crash.
    let (_server, mut stream) = spawn(0, 0);
    let reply = rpc(&mut stream,
                    r#"{"op":"admin","action":"panic","scope":"worker"}"#);
    assert!(reply.contains("\"ok\":false")
        && reply.contains("fault injection is disabled"), "{reply}");
    let reply = rpc(&mut stream, r#"{"op":"stats"}"#);
    assert!(reply.contains("\"ok\":true"), "{reply}");
    let stats = Json::parse(&reply).unwrap();
    assert_eq!(stats.get("worker_restarts").and_then(Json::as_f64),
               Some(0.0));
}

#[test]
fn region_outages_and_wan_brownouts_flow_through_admin() {
    let (_server, mut stream) = spawn(13, 0);
    let stats = Json::parse(&rpc(&mut stream, r#"{"op":"stats"}"#))
        .unwrap();
    let alive0 = stats.get("alive_machines").and_then(Json::as_usize)
        .unwrap();

    // Fail the first region that actually has machines: one admin op,
    // one epoch, every doomed id reported.
    let mut doomed: Vec<usize> = Vec::new();
    let mut dead_region = "";
    for region in hulk::cluster::Region::ALL {
        let reply = rpc(&mut stream, &format!(
            r#"{{"op":"admin","action":"fail_region","region":"{}"}}"#,
            region.name()));
        let parsed = Json::parse(&reply).unwrap();
        if parsed.get("ok").and_then(Json::as_bool) == Some(true) {
            doomed = parsed.get("machines").and_then(Json::as_arr)
                .unwrap().iter().map(|m| m.as_usize().unwrap())
                .collect();
            assert!(!doomed.is_empty(), "{reply}");
            assert_eq!(
                parsed.get("alive_machines").and_then(Json::as_usize),
                Some(alive0 - doomed.len()), "{reply}");
            dead_region = region.name();
            break;
        }
        assert!(reply.contains("no alive machines"), "{reply}");
    }
    assert!(!doomed.is_empty(),
            "the planet fleet has at least one populated region");

    // Re-failing the same region is a typed decline, not a panic.
    let reply = rpc(&mut stream, &format!(
        r#"{{"op":"admin","action":"fail_region","region":"{dead_region}"}}"#));
    assert!(reply.contains("no alive machines"), "{reply}");

    // Every subsequent placement avoids the dead region wholesale.
    let reply = rpc(&mut stream, PLACE);
    for (t, machines) in reply_machines(&reply).iter().enumerate() {
        for m in machines {
            assert!(!doomed.contains(m),
                    "task {t} placed on dead-region machine {m}");
        }
    }

    // WAN brownout: the factor lands, placements still answer.
    let reply =
        rpc(&mut stream, r#"{"op":"admin","action":"wan","factor":8}"#);
    let parsed = Json::parse(&reply).unwrap();
    assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true),
               "{reply}");
    assert_eq!(parsed.get("wan_factor").and_then(Json::as_f64),
               Some(8.0));
    let reply = rpc(&mut stream, PLACE);
    assert!(reply.starts_with("{\"ok\":true"), "{reply}");

    // Restore is factor 1.0; a repeated restore is a typed no-op
    // decline (a no-op must not invalidate caches), and an absurd
    // factor is refused at the parse boundary.
    let reply =
        rpc(&mut stream, r#"{"op":"admin","action":"wan","factor":1}"#);
    assert!(reply.contains("\"ok\":true"), "{reply}");
    let reply =
        rpc(&mut stream, r#"{"op":"admin","action":"wan","factor":1}"#);
    assert!(reply.contains("already"), "{reply}");
    let reply = rpc(&mut stream,
                    r#"{"op":"admin","action":"wan","factor":1000}"#);
    assert!(reply.contains("\"ok\":false") && reply.contains("factor"),
            "{reply}");
}

#[cfg(unix)]
#[test]
fn stale_sockets_are_reclaimed_but_live_daemons_are_not_clobbered() {
    let path = std::env::temp_dir().join(format!(
        "hulk-serve-stale-{}.sock", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    // A stale socket file: a listener once lived here and died without
    // unlinking. A fresh daemon must probe, find nobody answering, and
    // reclaim the path.
    let dead = std::os::unix::net::UnixListener::bind(&path).unwrap();
    drop(dead);
    assert!(std::fs::metadata(&path).is_ok(), "stale file persists");
    let config = ServeConfig {
        addr: None,
        uds: Some(path.clone()),
        batch_window_ms: 0,
        ..ServeConfig::default()
    };
    let server = Server::spawn(&config).expect("stale socket reclaimed");
    let mut stream =
        std::os::unix::net::UnixStream::connect(&path).unwrap();
    let reply = roundtrip(&mut stream, r#"{"op":"stats"}"#.as_bytes())
        .unwrap();
    assert!(String::from_utf8(reply).unwrap().contains("\"ok\":true"));
    // But a *live* daemon on the path is never clobbered: the second
    // spawn probes, gets an answer, and refuses with a typed error.
    let err = match Server::spawn(&config) {
        Err(err) => err,
        Ok(_) => panic!("binding over a live daemon must refuse"),
    };
    assert!(format!("{err:#}").contains("refusing to bind"), "{err:#}");
    // The refusal did not unlink the live daemon's socket.
    let mut stream =
        std::os::unix::net::UnixStream::connect(&path).unwrap();
    let reply = roundtrip(&mut stream, r#"{"op":"stats"}"#.as_bytes())
        .unwrap();
    assert!(String::from_utf8(reply).unwrap().contains("\"ok\":true"));
    drop(server);
    let _ = std::fs::remove_file(&path);
}

#[cfg(unix)]
#[test]
fn unix_domain_socket_serves_the_same_protocol() {
    let path = std::env::temp_dir()
        .join(format!("hulk-serve-test-{}.sock", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    let config = ServeConfig {
        addr: None,
        uds: Some(path.clone()),
        batch_window_ms: 0,
        ..ServeConfig::default()
    };
    let server = Server::spawn(&config).unwrap();
    assert!(server.addr().is_none(), "UDS-only daemon has no TCP addr");
    let mut stream =
        std::os::unix::net::UnixStream::connect(&path).unwrap();
    let reply = roundtrip(&mut stream, r#"{"op":"stats"}"#.as_bytes())
        .unwrap();
    let reply = String::from_utf8(reply).unwrap();
    assert!(reply.contains("\"ok\":true"), "{reply}");
    assert!(reply.contains("fleet_machines"), "{reply}");
    let _ = std::fs::remove_file(&path);
}
