//! CSR-vs-dense GCN forward parity on real fleets: the sparse
//! aggregation path must reproduce the padded-dense oracle within 1e-5
//! on every real machine row, for the Table 1 evaluation fleet and the
//! planet-scale synthetic fleet, and the automatic path selection must
//! be invisible to `classify`.

use hulk::cluster::Fleet;
use hulk::gnn::{classify, classify_with_graph, Classifier, RefGcn,
                RefGcnConfig};
use hulk::graph::{node_features, node_features_csr, ClusterGraph,
                  CsrGraph, FEATURE_DIM, CSR_DENSITY_MAX};
use hulk::util::rng::Rng;

fn reference_gcn(slots: usize, seed: u64) -> RefGcn {
    let cfg = RefGcnConfig { n: slots, f: FEATURE_DIM, h: 24, h2: 12,
                             c: 8 };
    let mut rng = Rng::new(seed);
    let params: Vec<f32> = (0..cfg.n_params())
        .map(|_| (rng.normal() * 0.1) as f32)
        .collect();
    RefGcn::new(cfg, &params)
}

fn assert_forward_parity(fleet: &Fleet, slots: usize, seed: u64) {
    let graph = ClusterGraph::from_fleet(fleet);
    let gcn = reference_gcn(slots, seed);
    let adj = graph.padded_adj(slots);
    let feats = node_features(&fleet.machines, &graph, slots);
    let mask = graph.padded_mask(slots);
    let dense = gcn.forward(&adj, &feats, &mask);

    let csr = CsrGraph::padded(&graph, slots);
    assert_eq!(csr.real, fleet.len());
    let sparse_feats = node_features_csr(&fleet.machines, &csr);
    assert_eq!(feats, sparse_feats, "feature builds diverged");
    let sparse = gcn.forward_csr(&csr, &sparse_feats, &mask);

    // Real machine rows agree within 1e-5 (padded rows are never
    // consumed — the sparse path does not materialize them).
    for i in 0..fleet.len() {
        for k in 0..8 {
            let (d, s) = (dense.at(i, k), sparse.at(i, k));
            assert!((d - s).abs() < 1e-5,
                    "row {i} class {k}: dense {d} vs csr {s}");
            assert!(s.is_finite());
        }
        let row_sum: f32 = sparse.row(i).iter().sum();
        assert!((row_sum - 1.0).abs() < 1e-5, "row {i} sums to {row_sum}");
    }
}

#[test]
fn table1_fleet_forward_parity() {
    assert_forward_parity(&Fleet::paper_evaluation(0), 64, 11);
}

#[test]
fn planet_scale_forward_parity() {
    // 220 machines in a 256-slot (planet-capable) artifact.
    assert_forward_parity(&Fleet::synthetic(220, 12, 0), 256, 13);
}

#[test]
fn padding_and_policy_blocks_keep_real_inputs_on_the_csr_path() {
    // The density rule must route both production fleets through CSR:
    // padding headroom plus the Beijing↔Paris block keep nnz below the
    // ceiling on the 64-slot table1 artifact and a 256-slot planet one.
    for (fleet, slots) in [(Fleet::paper_evaluation(0), 64),
                           (Fleet::synthetic(220, 12, 0), 256)] {
        let graph = ClusterGraph::from_fleet(&fleet);
        let csr = CsrGraph::padded(&graph, slots);
        assert!(csr.density() <= CSR_DENSITY_MAX,
                "density {} over the CSR ceiling", csr.density());
    }
    // A fully occupied complete graph falls back to the dense oracle.
    let toy = Fleet::paper_toy(0);
    let graph = ClusterGraph::from_fleet(&toy);
    let tight = CsrGraph::padded(&graph, toy.len());
    assert!(tight.density() > CSR_DENSITY_MAX,
            "unpadded near-complete graph should stay dense: {}",
            tight.density());
}

#[test]
fn classify_is_path_independent() {
    // classify() (auto-selected path — CSR at this density) and an
    // explicit dense forward must produce the same classes.
    let fleet = Fleet::synthetic(120, 10, 7);
    let slots = 160;
    let cfg = RefGcnConfig { n: slots, f: FEATURE_DIM, h: 24, h2: 12,
                             c: 8 };
    let mut rng = Rng::new(17);
    let params: Vec<f32> = (0..cfg.n_params())
        .map(|_| (rng.normal() * 0.1) as f32)
        .collect();
    let clf = Classifier::Reference(RefGcn::new(cfg, &params));
    let graph = ClusterGraph::from_fleet(&fleet);
    assert!(CsrGraph::padded(&graph, slots).density() <= CSR_DENSITY_MAX,
            "test fleet should exercise the CSR path");
    let auto = classify(&clf, &params, &fleet).unwrap();
    assert_eq!(auto,
               classify_with_graph(&clf, &params, &fleet, &graph)
                   .unwrap());
    // Dense reference: pad the tensors by hand and argmax the oracle.
    let dense_gcn = RefGcn::new(cfg, &params);
    let adj = graph.padded_adj(slots);
    let feats = node_features(&fleet.machines, &graph, slots);
    let mask = graph.padded_mask(slots);
    let probs = dense_gcn.forward(&adj, &feats, &mask);
    let dense: Vec<usize> = (0..fleet.len())
        .map(|i| {
            let row = probs.row(i);
            let mut best = 0;
            for (k, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = k;
                }
            }
            best
        })
        .collect();
    assert_eq!(auto, dense);
}
