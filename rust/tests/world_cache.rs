//! Cache-on vs cache-off byte identity: sharing one `ScenarioWorld`
//! across a spec's cells (the production mode) must write exactly the
//! bytes the rebuild-per-cell mode writes — for `BENCH_scenarios.json`
//! and `BENCH_placements.json`, analytic and sim backends, serial and
//! parallel.

use hulk::benchkit::BenchReport;
use hulk::planner::{CostBackend, PlannerRegistry};
use hulk::scenarios::{resolve_scenarios, run_specs_sharing,
                      ScenarioResult, ScenarioSpec, WorldSharing};

fn report_bytes(results: &[ScenarioResult], suite: &str,
                placements: bool) -> String
{
    let mut report = BenchReport::new(suite);
    for r in results {
        if placements {
            report.extend(r.placements.iter().cloned());
        } else {
            report.extend(r.entries.iter().cloned());
        }
    }
    let mut text = report.to_json().render();
    text.push('\n');
    text
}

fn assert_cache_invisible(specs: &[ScenarioSpec], backend: CostBackend,
                          suite: &str)
{
    let planners = PlannerRegistry::standard();
    let cached =
        run_specs_sharing(specs, 0, 1, &planners, backend,
                          WorldSharing::Shared)
            .expect("cache-on run");
    let rebuilt =
        run_specs_sharing(specs, 0, 1, &planners, backend,
                          WorldSharing::Rebuild)
            .expect("cache-off run");
    assert_eq!(report_bytes(&cached, suite, false),
               report_bytes(&rebuilt, suite, false),
               "{suite}: scenarios artifact diverged cache-on vs off");
    assert_eq!(report_bytes(&cached, "placements", true),
               report_bytes(&rebuilt, "placements", true),
               "{suite}: placements artifact diverged cache-on vs off");
    let rendered = |rs: &[ScenarioResult]| -> Vec<String> {
        rs.iter().map(|r| r.rendered.clone()).collect()
    };
    assert_eq!(rendered(&cached), rendered(&rebuilt));
    // Parallel cache-on matches serial cache-off too — the full
    // commutation square.
    let parallel_cached =
        run_specs_sharing(specs, 0, 4, &planners, backend,
                          WorldSharing::Shared)
            .expect("parallel cache-on run");
    assert_eq!(report_bytes(&parallel_cached, suite, false),
               report_bytes(&rebuilt, suite, false),
               "{suite}: parallel cache-on diverged from serial cache-off");
}

#[test]
fn analytic_artifacts_are_cache_invisible() {
    let (specs, _) = resolve_scenarios(&[], CostBackend::Analytic)
        .expect("resolve analytic all");
    assert_cache_invisible(&specs, CostBackend::Analytic, "scenarios");
}

#[test]
fn sim_artifacts_are_cache_invisible() {
    // A subset covering Evaluate cells (table1_fleet, planet_scale) and
    // a sim-only custom body; the full suite runs in CI's release-build
    // determinism gates.
    let (specs, _) = resolve_scenarios(
        &["table1_fleet".to_string(), "planet_scale".to_string(),
          "sim_vs_analytic".to_string()],
        CostBackend::Simulated,
    )
    .expect("resolve sim subset");
    assert_cache_invisible(&specs, CostBackend::Simulated,
                           "scenarios_cost_sim");
}
