//! System-level integration: the full planning path (fleet → graph →
//! oracle/Algorithm 1 → pipelines → costs) across seeds and workloads —
//! the artifact-free half of the paper's evaluation, driven through the
//! `Planner` trait.

use hulk::cluster::Fleet;
use hulk::graph::ClusterGraph;
use hulk::models::ModelSpec;
use hulk::parallel::pipeline_cost;
use hulk::planner::{HulkPlanner, HulkSplitterKind, PlanContext, Planner};
use hulk::scenarios::evaluate_all;
use hulk::sim::simulate_pipeline;

/// Hulk's placement for a workload via the trait API (oracle splitter).
fn hulk_placement(fleet: &Fleet, graph: &ClusterGraph,
                  workload: &[ModelSpec])
    -> (Vec<ModelSpec>, hulk::planner::Placement)
{
    let mut wl = workload.to_vec();
    ModelSpec::sort_largest_first(&mut wl);
    let ctx = PlanContext::new(fleet, graph, &wl, HulkSplitterKind::Oracle);
    let placement = HulkPlanner.plan(&ctx).expect("hulk plans");
    (wl, placement)
}

#[test]
fn fig8_shape_reproduces_across_seeds() {
    for seed in [0, 1, 2] {
        let fleet = Fleet::paper_evaluation(seed);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        let h = eval.hulk_column().expect("hulk registered");
        for (m, row) in eval.costs.iter().enumerate() {
            let hulk = row[h];
            assert!(hulk.is_feasible(),
                    "seed {seed}: hulk infeasible for {}",
                    eval.models[m].name);
            assert!(hulk.comm_ms < row[1].comm_ms,
                    "seed {seed}: hulk comm must beat System B");
            assert!(hulk.comm_ms < row[2].comm_ms,
                    "seed {seed}: hulk comm must beat System C");
        }
        let imp = eval.hulk_improvement();
        assert!(imp > 0.20,
                "seed {seed}: improvement {:.1}% below paper's 20%",
                imp * 100.0);
    }
}

#[test]
fn fig10_six_models_also_hold() {
    let fleet = Fleet::paper_evaluation(0);
    let eval = evaluate_all(&fleet, &ModelSpec::paper_six(),
                            HulkSplitterKind::Oracle)
        .unwrap();
    assert_eq!(eval.models.len(), 6);
    let imp = eval.hulk_improvement();
    assert!(imp > 0.20, "fig10 improvement {:.1}%", imp * 100.0);
    // System A infeasible exactly for the models that don't fit one
    // machine (OPT-175B).
    for (m, row) in eval.costs.iter().enumerate() {
        let a_feasible = row[0].is_feasible();
        let fits = eval.models[m].train_gb() <= 640.0;
        assert_eq!(a_feasible, fits, "System A feasibility mismatch for {}",
                   eval.models[m].name);
    }
}

#[test]
fn system_ordering_is_paper_consistent() {
    // For every model: Hulk total ≤ System B total (grouping can only
    // help a pipeline), and System C is the worst on comm.
    let fleet = Fleet::paper_evaluation(0);
    let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                            HulkSplitterKind::Oracle)
        .unwrap();
    for row in &eval.costs {
        let (b, c, hulk) = (row[1], row[2], row[3]);
        assert!(hulk.total_ms() <= b.total_ms() * 1.05);
        assert!(c.comm_ms >= b.comm_ms,
                "Megatron TP must out-communicate GPipe over WAN");
    }
}

#[test]
fn hulk_pipelines_simulate_consistently() {
    // The DES simulator and analytic model must agree within a small
    // factor on every Hulk group (they model the same schedule).
    let fleet = Fleet::paper_evaluation(0);
    let graph = ClusterGraph::from_fleet(&fleet);
    let (wl, placement) =
        hulk_placement(&fleet, &graph, &ModelSpec::paper_four());
    for (t, task) in wl.iter().enumerate() {
        let pipe = placement.pipeline(t).expect("hulk tasks are pipelined");
        let analytic = pipeline_cost(&fleet, &pipe, task);
        let sim = simulate_pipeline(&fleet, &pipe, task, false, None);
        assert!(sim.makespan_ms.is_finite());
        let ratio = sim.makespan_ms / analytic.total_ms();
        assert!((0.2..5.0).contains(&ratio),
                "{}: sim/analytic ratio {ratio}", task.name);
    }
}

#[test]
fn spares_exist_for_recovery_on_four_task_workload() {
    let fleet = Fleet::paper_evaluation(0);
    let graph = ClusterGraph::from_fleet(&fleet);
    let (_wl, placement) =
        hulk_placement(&fleet, &graph, &ModelSpec::paper_four());
    let assigned: usize = (0..placement.n_tasks())
        .map(|t| placement.machines(t).len())
        .sum();
    assert!(assigned < fleet.len(),
            "paper Table 2 leaves spare machines (39/46 assigned); \
             we assigned {assigned}/46");
}

#[test]
fn every_system_name_is_reported() {
    let fleet = Fleet::paper_evaluation(0);
    let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                            HulkSplitterKind::Oracle)
        .unwrap();
    let render = eval.render();
    for meta in &eval.systems {
        assert!(render.contains(meta.name), "missing {}", meta.name);
    }
}

#[test]
fn gnn_splitter_with_reference_classifier_plans_feasibly() {
    // Artifact-free GNN path: an untrained reference-forward classifier
    // must still produce a *valid* plan (Algorithm 1 enforces the memory
    // thresholds regardless of classification quality).
    use hulk::gnn::reference::{RefGcn, RefGcnConfig};
    use hulk::gnn::Classifier;
    use hulk::util::rng::Rng;

    let cfg = RefGcnConfig { n: 64, f: 16, h: 16, h2: 8, c: 8 };
    let mut rng = Rng::new(42);
    let params: Vec<f32> =
        (0..cfg.n_params()).map(|_| (rng.normal() * 0.1) as f32).collect();
    let classifier = Classifier::Reference(RefGcn::new(cfg, &params));

    let fleet = Fleet::paper_evaluation(0);
    let graph = ClusterGraph::from_fleet(&fleet);
    let mut wl = ModelSpec::paper_four();
    ModelSpec::sort_largest_first(&mut wl);
    let ctx = PlanContext::new(
        &fleet,
        &graph,
        &wl,
        HulkSplitterKind::Gnn { classifier: &classifier, params: &params },
    );
    let placement = HulkPlanner.plan(&ctx).expect("plan");
    let assignment = placement.to_assignment();
    assignment.validate_disjoint(fleet.len()).unwrap();
    assignment.validate_memory(&fleet, &wl).unwrap();
    for (t, task) in wl.iter().enumerate() {
        let c = HulkPlanner.cost(&ctx, &placement, t);
        assert!(c.is_feasible(), "{} infeasible under GNN plan", task.name);
    }
}

#[test]
fn oracle_grouping_beats_chance_by_a_wide_margin() {
    use hulk::gnn::cost_vs_random;
    let fleet = Fleet::paper_evaluation(0);
    let graph = ClusterGraph::from_fleet(&fleet);
    let (_wl, placement) =
        hulk_placement(&fleet, &graph, &ModelSpec::paper_four());
    let assignment = placement.to_assignment();
    let ratio = cost_vs_random(&fleet, &graph, &assignment, 3);
    assert!(ratio < 0.8, "oracle grouping only {ratio:.2}× of chance");
}
