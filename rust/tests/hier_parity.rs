//! Hierarchical-substrate parity gates (the PR-5 cache-gate pattern,
//! applied to the graph layer): planning every ≤220-machine scenario on
//! the hierarchical substrate must write exactly the bytes the demoted
//! dense oracle writes — and planning a 100k-machine fleet must never
//! materialize a dense n×n adjacency anywhere in the process.

use std::sync::Arc;

use hulk::benchkit::BenchReport;
use hulk::cluster::Fleet;
use hulk::graph::{max_dense_n, HierarchicalGraph, DENSE_ORACLE_MAX};
use hulk::models::ModelSpec;
use hulk::planner::{CostBackend, HulkPlanner, HulkSplitterKind,
                    PlanContext, Planner, PlannerRegistry};
use hulk::scenarios::{resolve_scenarios, run_specs_sharing,
                      ScenarioResult, ScenarioSpec, WorldSharing};

fn report_bytes(results: &[ScenarioResult], suite: &str,
                placements: bool) -> String
{
    let mut report = BenchReport::new(suite);
    for r in results {
        if placements {
            report.extend(r.placements.iter().cloned());
        } else {
            report.extend(r.entries.iter().cloned());
        }
    }
    let mut text = report.to_json().render();
    text.push('\n');
    text
}

fn assert_substrate_invisible(specs: &[ScenarioSpec], backend: CostBackend,
                              suite: &str)
{
    let planners = PlannerRegistry::standard();
    let hier =
        run_specs_sharing(specs, 0, 1, &planners, backend,
                          WorldSharing::Shared)
            .expect("hierarchical-substrate run");
    let dense =
        run_specs_sharing(specs, 0, 1, &planners, backend,
                          WorldSharing::DenseOracle)
            .expect("dense-oracle run");
    assert_eq!(report_bytes(&hier, suite, false),
               report_bytes(&dense, suite, false),
               "{suite}: scenarios artifact diverged hier vs dense");
    assert_eq!(report_bytes(&hier, "placements", true),
               report_bytes(&dense, "placements", true),
               "{suite}: placements artifact diverged hier vs dense");
    let rendered = |rs: &[ScenarioResult]| -> Vec<String> {
        rs.iter().map(|r| r.rendered.clone()).collect()
    };
    assert_eq!(rendered(&hier), rendered(&dense),
               "{suite}: rendered tables diverged hier vs dense");
}

#[test]
fn analytic_artifacts_match_the_dense_oracle() {
    // `all` excludes the heavy scale scenarios, so every spec here is a
    // ≤220-machine fleet the dense oracle can still build.
    let (specs, _) = resolve_scenarios(&[], CostBackend::Analytic)
        .expect("resolve analytic all");
    assert_substrate_invisible(&specs, CostBackend::Analytic, "scenarios");
}

#[test]
fn sim_artifacts_match_the_dense_oracle() {
    // The Evaluate-cell specs are where the substrate switch actually
    // bites (the runner builds their worlds); same subset as the
    // world_cache sim gate.
    let (specs, _) = resolve_scenarios(
        &["table1_fleet".to_string(), "planet_scale".to_string(),
          "sim_vs_analytic".to_string()],
        CostBackend::Simulated,
    )
    .expect("resolve sim subset");
    assert_substrate_invisible(&specs, CostBackend::Simulated,
                               "scenarios_cost_sim");
}

#[test]
fn global_fleet_plans_without_a_dense_adjacency() {
    // 100k machines: build the two-level graph, plan region-first, and
    // prove no code path asked `ClusterGraph::from_fleet` for anything
    // past the ≤1k oracle ceiling (`max_dense_n` is the process-wide
    // high-water mark, so this holds across every test in this binary).
    let fleet = Arc::new(Fleet::synthetic(100_000, 12, 0));
    let hier = HierarchicalGraph::from_fleet(fleet.clone());
    assert!(hier.is_coarse(), "100k fleet must stay lazily refined");
    let mut workload = ModelSpec::paper_four();
    ModelSpec::sort_largest_first(&mut workload);
    let ctx = PlanContext::new(&fleet, &hier, &workload,
                               HulkSplitterKind::Oracle)
        .with_hier(&hier);
    let placement = HulkPlanner.plan(&ctx).expect("100k plan");
    placement.validate_machines(&fleet).expect("machines exist");
    let assignment = placement.to_assignment();
    assignment.validate_disjoint(fleet.len()).expect("disjoint");
    assignment.validate_memory(&fleet, &workload).expect("memory fits");
    for t in 0..workload.len() {
        assert!(!placement.machines(t).is_empty(),
                "task {t} got no machines");
    }
    assert!(
        max_dense_n() <= DENSE_ORACLE_MAX,
        "dense adjacency of {} nodes was materialized (ceiling {})",
        max_dense_n(),
        DENSE_ORACLE_MAX
    );
}
