//! Placement-cache invalidation pins: a cached placement must never
//! outlive the fleet it was planned against.
//!
//! Two layers:
//! 1. Property-style, library-level: over generator-drawn fleets
//!    (`scenarios::generate_case`), plan → cache → fail a machine the
//!    placement uses → the cache scope dies, the lookup misses, and the
//!    replan never references the dead machine.
//! 2. End-to-end over a real socket: place twice (second is a hit),
//!    `admin fail` a machine from the reply, and the next place is a
//!    replanned miss that excludes the victim.

use std::net::TcpStream;

use hulk::gnn::GnnSplitter;
use hulk::models::ModelSpec;
use hulk::planner::CostBackend;
use hulk::scenarios::generate_case;
use hulk::serve::{default_classifier, roundtrip, LiveWorld,
                  PlaceRequest, PlacementCache, ServeConfig, Server,
                  SERVE_SLOTS};
use hulk::util::json::Json;

/// Machine ids per task from a `Place` reply's first (only) system
/// entry; `None` when that system declined the workload.
fn reply_machines(reply: &str) -> Option<Vec<Vec<usize>>> {
    let parsed = Json::parse(reply).expect("reply parses");
    assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true),
               "{reply}");
    let results = parsed.get("results").and_then(Json::as_arr).unwrap();
    if results[0].get("ok").and_then(Json::as_bool) != Some(true) {
        return None;
    }
    let tasks = results[0].get("tasks").and_then(Json::as_arr).unwrap();
    Some(tasks.iter()
        .map(|t| {
            t.get("machines")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|m| m.as_usize().unwrap())
                .collect()
        })
        .collect())
}

#[test]
fn failed_machines_never_leak_out_of_the_cache() {
    let (classifier, params) = default_classifier(9);
    let mut exercised = 0;
    for index in 0..16 {
        let mut case = generate_case(42, index);
        // The serving classifier caps both dimensions: fleet at
        // SERVE_SLOTS nodes, workload at its 8 output classes.
        let Ok(mut world) = LiveWorld::new(
            case.fleet.clone(), CostBackend::Analytic, SERVE_SLOTS)
        else {
            continue;
        };
        case.workload.truncate(8);
        ModelSpec::sort_largest_first(&mut case.workload);
        let req = PlaceRequest {
            workload: case.workload.clone(),
            systems: vec!["hulk".to_string()],
        };
        let digest = req.digest();
        let mut cache = PlacementCache::new(64);

        let splitter = GnnSplitter::new(&classifier, &params);
        let scope = world.cache_scope();
        let reply = world.plan_place(&req, &splitter);
        // Infeasible draws (workload too big for the fleet) can't
        // exercise invalidation — skip them, the count below keeps the
        // test honest.
        let Some(machines) = reply_machines(&reply) else { continue };
        let victim = machines[0][0];
        cache.insert(scope, digest, &reply);
        assert_eq!(cache.get(scope, digest).as_deref(), Some(&*reply));

        // The victim fails: the epoch advances, the scope dies, and
        // the stale placement is unreachable before anything can
        // serve it.
        world.fail(victim).unwrap();
        let scope_after = world.cache_scope();
        assert_ne!(scope, scope_after,
                   "a failure must move the cache scope");
        assert!(cache.get(scope_after, digest).is_none(),
                "a cached placement survived the machine it uses \
                 failing (case {})", case.repro());

        // The replan (fresh splitter: the graph re-keyed) avoids the
        // dead machine in every task.
        let splitter = GnnSplitter::new(&classifier, &params);
        let replanned = world.plan_place(&req, &splitter);
        if let Some(machines) = reply_machines(&replanned) {
            for (t, ms) in machines.iter().enumerate() {
                assert!(!ms.contains(&victim),
                        "task {t} replanned onto failed machine \
                         {victim} (case {})", case.repro());
            }
        }
        exercised += 1;
    }
    assert!(exercised >= 5,
            "only {exercised} generated cases were plannable — the \
             property needs more coverage");
}

fn rpc(stream: &mut TcpStream, request: &str) -> String {
    let reply =
        roundtrip(stream, request.as_bytes()).expect("round-trip");
    String::from_utf8(reply).expect("replies are UTF-8 JSON")
}

#[test]
fn admin_fail_invalidates_cached_placements_end_to_end() {
    let config = ServeConfig {
        seed: 5,
        batch_window_ms: 0,
        ..ServeConfig::default() // cache on, shards auto
    };
    let server = Server::spawn(&config).expect("daemon spawns");
    let mut conn = TcpStream::connect(server.addr().unwrap()).unwrap();
    const PLACE: &str = r#"{"op":"place","workload":[
        {"model":"bert_large"},{"model":"gpt2_xl","batch":32}],
        "systems":["hulk"]}"#;

    let first = rpc(&mut conn, PLACE);
    let second = rpc(&mut conn, PLACE);
    assert_eq!(first, second, "a cache hit must be byte-identical");
    let victim = reply_machines(&first)
        .expect("planet fleet places the fixture")[0][0];

    let counters = |conn: &mut TcpStream| -> (f64, f64) {
        let stats =
            Json::parse(&rpc(conn, r#"{"op":"stats"}"#)).unwrap();
        let get = |name: &str| {
            stats.get("metrics").unwrap().get("counters").unwrap()
                .get(name).and_then(Json::as_f64).unwrap_or(0.0)
        };
        (get("cache_hits"), get("cache_misses"))
    };
    let (hits, misses) = counters(&mut conn);
    assert_eq!((hits, misses), (1.0, 1.0),
               "one miss then one hit for a repeated workload");

    let reply = rpc(&mut conn, &format!(
        r#"{{"op":"admin","action":"fail","machine":{victim}}}"#));
    assert!(reply.contains("\"ok\":true"), "{reply}");

    // Same workload again: the epoch moved, so this must be a
    // replanned miss that avoids the failed machine.
    let third = rpc(&mut conn, PLACE);
    assert_ne!(third, first,
               "the placement was served stale after its machine died");
    for (t, ms) in reply_machines(&third)
        .expect("survivors still place the fixture")
        .iter()
        .enumerate()
    {
        assert!(!ms.contains(&victim),
                "task {t} still placed on failed machine {victim}");
    }
    let (hits, misses) = counters(&mut conn);
    assert_eq!((hits, misses), (1.0, 2.0),
               "the post-failure place must miss, not hit");
}
