//! Planner property tests over the seeded scenario generator: every
//! registered planner (ablations included) runs across hundreds of
//! randomized `(Fleet, Workload, failure script)` cases per seed, and
//! the cross-cutting invariants must hold on all of them — structural
//! feasibility + capacity, plan determinism, self-pricing vs
//! `evaluate_world`, analytic/sim winner agreement, the exhaustive
//! oracle bound on small fleets, and survivor replanning after spot
//! revocations. A failing case shrinks to a minimal seed+shape repro
//! (`hulk scenarios generate --seed S --count N --check` replays it).
//!
//! The deliberate-break test proves the harness has teeth: a planner
//! that assigns work to a machine past the end of the fleet — the
//! "failed machine" class of bug — must be caught, shrunk, and
//! reported reproducibly.

use anyhow::Result;
use hulk::planner::{PlanContext, Placement, Planner, PlannerKind,
                    PlannerRegistry, TaskPlacement};
use hulk::scenarios::{check_case, check_generator_determinism,
                      generate_case, run_generated, shrink_case,
                      CheckOptions};

fn assert_sweep_clean(seed: u64, count: usize) {
    let planners = PlannerRegistry::catalog();
    let run = run_generated(seed, count, &planners,
                            &CheckOptions::default());
    if let Some(report) = &run.failure {
        panic!("seed {seed}:\n{report}");
    }
    assert_eq!(run.cases, count);
    assert_eq!(run.violations, 0);
    // Declining (Algorithm 1 deferring an oversized task) is legal but
    // must stay the exception, or the sweep stops exercising the
    // pricing/backends/oracle invariants.
    assert!(run.fully_planned >= count / 4,
            "only {}/{count} cases fully planned — the generator is \
             drawing mostly unplannable shapes",
            run.fully_planned);
}

#[test]
fn seed_zero_200_cases_uphold_every_invariant() {
    assert_sweep_clean(0, 200);
}

#[test]
fn seed_one_200_cases_uphold_every_invariant() {
    assert_sweep_clean(1, 200);
}

#[test]
fn generator_determinism_holds_across_seeds() {
    for seed in [0, 1, 7, 42] {
        for index in [0, 3, 19] {
            let case = generate_case(seed, index);
            assert!(check_generator_determinism(&case).is_none(),
                    "seed {seed} case {index} not regenerable");
        }
    }
}

/// A planner with the exact bug the harness exists to catch: every
/// task is assigned to the machine one past the end of the fleet —
/// i.e. a machine that does not exist (or has failed and been
/// compacted away).
struct RoguePlanner;

impl Planner for RoguePlanner {
    fn name(&self) -> &'static str {
        "Rogue (dead machine)"
    }

    fn slug(&self) -> &'static str {
        "rogue"
    }

    fn kind(&self) -> PlannerKind {
        PlannerKind::Baseline
    }

    fn plan(&self, ctx: &PlanContext) -> Result<Placement> {
        Ok(Placement {
            per_task: ctx
                .workload
                .iter()
                .map(|_| TaskPlacement::Replicated {
                    participants: vec![ctx.fleet.len()],
                })
                .collect(),
        })
    }
}

#[test]
fn a_deliberate_invariant_break_is_caught_and_shrunk() {
    let mut planners = PlannerRegistry::empty();
    planners.register(Box::new(RoguePlanner)).unwrap();
    let opts = CheckOptions::default();

    // check_case flags the structural violation directly…
    let case = generate_case(42, 0);
    let report = check_case(&case, &planners, &opts);
    assert!(report
        .violations
        .iter()
        .any(|v| v.invariant == "feasibility" && v.planner == "rogue"),
        "violations: {:?}", report.violations);
    assert!(!report.fully_planned);

    // …and the end-to-end sweep shrinks it into an actionable repro.
    let run = run_generated(42, 5, &planners, &opts);
    assert!(run.violations > 0);
    assert_eq!(run.cases, 1, "sweep must stop at the first failure");
    let text = run.failure.expect("failure report");
    assert!(text.contains("[feasibility] rogue"), "{text}");
    assert!(text.contains("original shape:"), "{text}");
    assert!(text.contains("shrunk to:"), "{text}");
    assert!(
        text.contains(
            "reproduce with: hulk scenarios generate --seed 42 \
             --count 1 --check"),
        "{text}");

    // The shrunk case is genuinely minimal: halving stops at two
    // machines / one task, and the violation still reproduces there.
    let (minimal, violations) = shrink_case(&case, &planners, &opts);
    assert!(!violations.is_empty());
    assert!(minimal.fleet.len() <= 3,
            "shrink left {} machines", minimal.fleet.len());
    assert_eq!(minimal.workload.len(), 1);
    assert!(minimal.fleet.len() <= case.fleet.len());
}

/// A planner whose self-reported pricing disagrees with the shared
/// pricing path — the "lying cost model" class of bug the self-pricing
/// invariant exists for.
struct MispricedPlanner;

impl Planner for MispricedPlanner {
    fn name(&self) -> &'static str {
        "Mispriced (halved costs)"
    }

    fn slug(&self) -> &'static str {
        "mispriced"
    }

    fn kind(&self) -> PlannerKind {
        PlannerKind::Baseline
    }

    fn plan(&self, ctx: &PlanContext) -> Result<Placement> {
        // A legal placement: every task data-parallel over machine 0.
        Ok(Placement {
            per_task: ctx
                .workload
                .iter()
                .map(|_| TaskPlacement::Replicated {
                    participants: vec![0],
                })
                .collect(),
        })
    }

    fn cost(&self, ctx: &PlanContext, placement: &Placement,
            task_idx: usize) -> hulk::parallel::IterCost
    {
        let mut c = placement.cost(ctx.fleet, &ctx.workload[task_idx],
                                   task_idx);
        c.comp_ms *= 0.5; // lie
        c
    }
}

#[test]
fn a_lying_cost_model_trips_the_self_pricing_invariant() {
    let mut planners = PlannerRegistry::empty();
    planners.register(Box::new(MispricedPlanner)).unwrap();
    let opts = CheckOptions::default();
    let mut tripped = false;
    for index in 0..5 {
        let case = generate_case(7, index);
        let report = check_case(&case, &planners, &opts);
        if report.violations.iter().any(|v| {
            v.invariant == "self-pricing" && v.planner == "mispriced"
        }) {
            tripped = true;
            break;
        }
    }
    assert!(tripped,
            "halved self-pricing never detected across 5 cases");
}
