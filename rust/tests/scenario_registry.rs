//! Scenario-registry integration: every registered scenario runs
//! end-to-end on a small seed, covers all four systems, is deterministic
//! across runs with the same seed, and Hulk is never worse than the best
//! baseline on the paper's Table 1 scenario. Also round-trips the
//! benchkit JSON report the scenarios feed.

use hulk::benchkit::{BenchEntry, BenchReport};
use hulk::scenarios::{all_scenarios, find_scenario, run_all};

#[test]
fn every_scenario_runs_and_covers_all_four_systems() {
    for scenario in all_scenarios() {
        let result = scenario
            .run(0)
            .unwrap_or_else(|e| panic!("{} failed: {e}", scenario.name));
        assert_eq!(result.scenario, scenario.name);
        assert!(!result.entries.is_empty(), "{}: no entries",
                scenario.name);
        assert!(!result.rendered.is_empty());
        for slug in ["system_a", "system_b", "system_c", "hulk"] {
            let marker = format!("/{slug}/");
            assert!(
                result.entries.iter().any(|e| e.name.contains(&marker)),
                "{}: no entry for {slug}",
                scenario.name
            );
        }
        for entry in &result.entries {
            assert!(entry.value.is_finite(),
                    "{}: non-finite {}", scenario.name, entry.name);
            assert!(entry.name.starts_with(scenario.name),
                    "{}: entry {} not namespaced", scenario.name,
                    entry.name);
        }
    }
}

#[test]
fn scenarios_are_deterministic_for_a_fixed_seed() {
    for scenario in all_scenarios() {
        let a = scenario.run(7).expect("first run");
        let b = scenario.run(7).expect("second run");
        let rows = |entries: &[BenchEntry]| -> Vec<(String, f64, String)> {
            entries
                .iter()
                .map(|e| (e.name.clone(), e.value, e.unit.clone()))
                .collect()
        };
        assert_eq!(rows(&a.entries), rows(&b.entries),
                   "{} is not seed-stable", scenario.name);
    }
}

#[test]
fn seeds_actually_change_the_numbers() {
    // Not a tautology of determinism: different seeds must reach the
    // runners (different fleets → different iteration times somewhere).
    let a = find_scenario("table1_fleet").unwrap().run(0).unwrap();
    let b = find_scenario("table1_fleet").unwrap().run(1).unwrap();
    let differs = a.entries.iter().zip(&b.entries).any(|(x, y)| {
        x.name != y.name || (x.value - y.value).abs() > 1e-12
    });
    assert!(differs || a.entries.len() != b.entries.len());
}

#[test]
fn hulk_never_worse_than_best_baseline_on_table1() {
    let result = find_scenario("table1_fleet")
        .expect("table1_fleet registered")
        .run(0)
        .expect("table1_fleet runs");
    let improvement = result
        .entries
        .iter()
        .find(|e| e.name == "table1_fleet/hulk_improvement_pct")
        .expect("improvement entry present");
    assert!(improvement.value >= 0.0,
            "Hulk worse than best baseline: {:.1}%", improvement.value);
    // The paper's headline on its own scenario.
    assert!(improvement.value > 20.0,
            "headline regression: {:.1}% ≤ 20%", improvement.value);
    // Per model: Hulk beats System B (id-order GPipe over the same WAN).
    for model in ["opt_175b", "t5_11b", "gpt_2_1_5b", "bert_large_340m"] {
        let get = |slug: &str| {
            result
                .entries
                .iter()
                .find(|e| {
                    e.name == format!("table1_fleet/{slug}/{model}/iter_ms")
                })
                .map(|e| e.value)
        };
        let hulk = get("hulk").expect("hulk entry");
        let system_b = get("system_b").expect("system_b entry");
        assert!(hulk <= system_b * 1.05,
                "{model}: hulk {hulk} vs system_b {system_b}");
    }
}

#[test]
fn run_all_emits_the_acceptance_coverage() {
    // ≥ 5 distinct scenarios × 4 systems in one combined report.
    let results = run_all(0).expect("run_all");
    assert!(results.len() >= 5);
    let mut report = BenchReport::new("scenarios");
    for r in results {
        report.extend(r.entries);
    }
    let scenario_names: std::collections::BTreeSet<String> = report
        .entries
        .iter()
        .filter_map(|e| e.name.split('/').next().map(str::to_string))
        .collect();
    assert!(scenario_names.len() >= 5, "only {scenario_names:?}");
    for slug in ["system_a", "system_b", "system_c", "hulk"] {
        for name in &scenario_names {
            let marker = format!("/{slug}/");
            assert!(
                report.entries.iter().any(|e| {
                    e.name.starts_with(name.as_str())
                        && e.name.contains(&marker)
                }),
                "scenario {name} lacks a {slug} entry"
            );
        }
    }

    // The combined report round-trips through the benchkit writer.
    let dir = std::env::temp_dir().join("hulk_scenario_report_test");
    let path = report.write(&dir).expect("write report");
    assert_eq!(path.file_name().unwrap(), "BENCH_scenarios.json");
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("customSmallerIsBetter"));
    assert!(text.contains("table1_fleet/hulk/"));
    // Balanced braces/brackets — cheap structural sanity for the
    // hand-rolled JSON writer on a large document.
    let balance = |open: char, close: char| {
        text.chars().filter(|&c| c == open).count()
            == text.chars().filter(|&c| c == close).count()
    };
    assert!(balance('{', '}') && balance('[', ']'));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sim_only_rejection_survives_a_systems_filter() {
    use hulk::planner::{CostBackend, PlannerRegistry};
    use hulk::scenarios::{resolve_scenarios, run_specs};

    // `scenarios run generated_sweep table1_fleet --systems a,hulk`
    // without `--cost sim` must fail up front — and the error has to
    // hand the user both halves of the fix: the sim-only list (so they
    // know which names need `--cost sim`) and the analytic-capable
    // list (so they can pick a valid combination instead).
    let names = vec!["generated_sweep".to_string(),
                     "table1_fleet".to_string()];
    let err = resolve_scenarios(&names, CostBackend::Analytic)
        .expect_err("sim-only scenario must be rejected on analytic");
    let msg = err.to_string();
    assert!(msg.contains("--cost sim"), "{msg}");
    assert!(msg.contains("generated_sweep"), "{msg}");
    assert!(msg.contains("contended_links"), "{msg}");
    assert!(msg.contains("table1_fleet"),
            "error must list analytic-capable scenarios: {msg}");

    // The same request under `--cost sim` resolves and runs, honoring
    // the planner filter: System A and Hulk report, System B does not.
    let planners = PlannerRegistry::resolve("a,hulk").unwrap();
    let (specs, _) =
        resolve_scenarios(&["generated_sweep".to_string()],
                          CostBackend::Simulated)
            .unwrap();
    assert_eq!(specs.len(), 1);
    let results = run_specs(&specs, 0, 1, &planners,
                            CostBackend::Simulated)
        .unwrap();
    assert_eq!(results.len(), 1);
    assert!(results[0].entries.iter().any(|e| e.name.contains("/hulk/")));
    assert!(!results[0]
        .entries
        .iter()
        .any(|e| e.name.contains("/system_b/")));
}
