//! Edge-case coverage for `sim/failure.rs`: injected machine failures
//! must halt (or be ignored) deterministically wherever they land —
//! mid-transfer, at t=0, or wiping out a whole region — and the engine
//! must never hang or leave a half-finished run priced as feasible.

use hulk::cluster::Fleet;
use hulk::models::ModelSpec;
use hulk::parallel::PipelinePlan;
use hulk::planner::{Placement, TaskPlacement};
use hulk::sim::{execute_placement_with, ExecOptions, FailurePlan};

/// A single-task pipeline placement over `stages`, layer-split by the
/// same throughput-proportional rule the planners use.
fn pipeline_placement(fleet: &Fleet, stages: Vec<usize>,
                      model: &ModelSpec) -> Placement
{
    let plan = PipelinePlan::proportional(fleet, stages, model);
    Placement {
        per_task: vec![TaskPlacement::PipelineStages {
            stages: plan.stages,
            layers: plan.layers,
            microbatches: plan.microbatches,
        }],
    }
}

#[test]
fn mid_run_failure_always_halts_deterministically() {
    let fleet = Fleet::paper_toy(0);
    let model = ModelSpec::bert_large();
    let workload = vec![model.clone()];
    let placement = pipeline_placement(&fleet, vec![0, 4], &model);

    let healthy = execute_placement_with(&fleet, &workload, &placement,
                                         ExecOptions::default());
    let makespan = healthy.report.makespan_ms;
    assert!(makespan.is_finite() && makespan > 0.0);

    // Kill the second stage at every phase of the run — during the
    // first microbatch, mid-transfer, near the tail. Each injection
    // must halt with the exact (time, machine) recorded, an infinite
    // makespan, an infeasible task cost, and a bit-identical rerun.
    for pct in [5u32, 20, 35, 50, 65, 80, 95] {
        let at_ms = makespan * f64::from(pct) / 100.0;
        let opts = ExecOptions {
            failure: Some(FailurePlan { at_ms, machine: 4 }),
            ..ExecOptions::default()
        };
        let hit = execute_placement_with(&fleet, &workload, &placement,
                                         opts);
        let outcome = hit.failure.unwrap_or_else(|| {
            panic!("failure at {pct}% of the run was not observed")
        });
        assert_eq!(outcome.at_ms, at_ms);
        assert_eq!(outcome.machine, 4);
        assert!(hit.report.makespan_ms.is_infinite(),
                "halted run at {pct}% still reports a finite makespan");
        assert!(!hit.tasks[0].cost.is_feasible(),
                "interrupted task priced feasible at {pct}%");
        // Determinism: the same failure script replays event-for-event.
        let again = execute_placement_with(&fleet, &workload, &placement,
                                           opts);
        assert_eq!(again.failure, hit.failure);
        assert_eq!(again.report.events_processed,
                   hit.report.events_processed);
    }
}

#[test]
fn whole_region_failure_halts_for_every_member_and_spares_complete() {
    let fleet = Fleet::paper_evaluation(0);
    let model = ModelSpec::bert_large();
    let workload = vec![model.clone()];
    let home = fleet.machines[0].region;
    let members: Vec<usize> = fleet
        .machines
        .iter()
        .filter(|m| m.region == home)
        .map(|m| m.id)
        .collect();
    assert!(members.len() >= 2,
            "need a multi-machine region for this test");

    // A data-parallel task spanning exactly the region: every single
    // member dying must halt it, immediately and identically.
    let placement = Placement {
        per_task: vec![TaskPlacement::Replicated {
            participants: members.clone(),
        }],
    };
    for &victim in &members {
        let opts = ExecOptions {
            failure: Some(FailurePlan { at_ms: 1.0, machine: victim }),
            ..ExecOptions::default()
        };
        let hit = execute_placement_with(&fleet, &workload, &placement,
                                         opts);
        let outcome = hit
            .failure
            .unwrap_or_else(|| panic!("machine {victim} dying was \
                                       not observed"));
        assert_eq!(outcome.machine, victim);
        assert_eq!(outcome.completed_microbatches, 0,
                   "nothing can have completed 1ms in");
        assert!(hit.report.makespan_ms.is_infinite());
    }

    // A machine outside the placement dying is a non-event: the run
    // completes with a makespan identical to the healthy one.
    let pair = Placement {
        per_task: vec![TaskPlacement::Replicated {
            participants: vec![members[0], members[1]],
        }],
    };
    let healthy = execute_placement_with(&fleet, &workload, &pair,
                                         ExecOptions::default());
    let spare = fleet.len() - 1;
    assert!(!members.contains(&spare));
    let spared = execute_placement_with(&fleet, &workload, &pair,
        ExecOptions {
            failure: Some(FailurePlan { at_ms: 1.0, machine: spare }),
            ..ExecOptions::default()
        });
    assert!(spared.failure.is_none(),
            "a bystander failure must not halt the task");
    assert_eq!(spared.report.makespan_ms, healthy.report.makespan_ms);
}

#[test]
fn failure_at_time_zero_halts_cleanly() {
    let fleet = Fleet::paper_toy(0);
    let model = ModelSpec::bert_large();
    let workload = vec![model.clone()];
    let placement =
        pipeline_placement(&fleet, vec![0, 1, 2, 3], &model);

    let opts = ExecOptions {
        failure: Some(FailurePlan { at_ms: 0.0, machine: 0 }),
        ..ExecOptions::default()
    };
    let hit = execute_placement_with(&fleet, &workload, &placement,
                                     opts);
    let outcome = hit.failure.expect("t=0 failure must be observed");
    assert_eq!(outcome.at_ms, 0.0);
    assert_eq!(outcome.machine, 0);
    assert_eq!(outcome.completed_microbatches, 0);
    assert!(hit.report.makespan_ms.is_infinite());
    assert_eq!(hit.report.straggler_wait_ms, 0.0);
    assert!(!hit.tasks[0].cost.is_feasible());
    // And it replays deterministically.
    let again = execute_placement_with(&fleet, &workload, &placement,
                                       opts);
    assert_eq!(again.report.events_processed,
               hit.report.events_processed);
    assert_eq!(again.failure, hit.failure);
}
