//! Cost-backend parity: the `Simulated` whole-placement executor must
//! stay anchored to the `Analytic` closed forms where they model the
//! same thing — single-task, no-contention workloads — and the two
//! backends must tell the same *story* (feasibility pattern, per-model
//! winner, System C worst) on the paper's table1 fleet and the
//! planet-scale scenario fleet, where contention separates the numbers.

use hulk::cluster::Fleet;
use hulk::graph::ClusterGraph;
use hulk::models::ModelSpec;
use hulk::planner::{CostBackend, HulkSplitterKind, PlanContext, Planner,
                    PlannerKind, PlannerRegistry};
use hulk::scenarios::{evaluate_with_backend, feasible_workload,
                      SystemEval};

/// Price one single-model workload under every standard planner with
/// both backends; returns (slug, kind, analytic, simulated) rows.
fn single_task_rows(fleet: &Fleet, model: &ModelSpec)
    -> Vec<(&'static str, PlannerKind, f64, f64)>
{
    let graph = ClusterGraph::from_fleet(fleet);
    let wl = vec![model.clone()];
    let registry = PlannerRegistry::standard();
    let mut rows = Vec::new();
    for planner in registry.iter() {
        let a_ctx = PlanContext::new(fleet, &graph, &wl,
                                     HulkSplitterKind::Oracle);
        let placement = match planner.plan(&a_ctx) {
            Ok(p) => p,
            Err(_) => continue, // Algorithm 1 deferral: nothing to price
        };
        let analytic = planner.price(&a_ctx, &placement).per_task[0];
        let s_ctx = PlanContext::new(fleet, &graph, &wl,
                                     HulkSplitterKind::Oracle)
            .with_backend(CostBackend::Simulated);
        let sim = planner.price(&s_ctx, &placement).per_task[0];
        assert_eq!(analytic.is_feasible(), sim.is_feasible(),
                   "{}: backends disagree on feasibility", planner.slug());
        if analytic.is_feasible() {
            rows.push((planner.slug(), planner.kind(),
                       analytic.total_ms(), sim.total_ms()));
        }
    }
    rows
}

#[test]
fn single_task_no_contention_pins_sim_to_analytic() {
    let fleet = Fleet::paper_evaluation(0);
    for model in [ModelSpec::bert_large(), ModelSpec::gpt2_xl()] {
        for (slug, _, analytic, sim) in single_task_rows(&fleet, &model) {
            match slug {
                // Ring collectives are barrier-stepped in both models:
                // with one task there is nothing to contend with, so the
                // executor must reproduce the closed form exactly.
                "system_a" | "system_c" => {
                    assert!((sim - analytic).abs() / analytic < 1e-9,
                            "{}/{slug}: sim {sim} vs analytic {analytic}",
                            model.name);
                }
                // Hulk's short regional chains: GPipe execution vs the
                // steady-state formula agree to a small factor (the
                // historical pipeline_sim tolerance).
                "hulk" => {
                    let ratio = sim / analytic;
                    assert!((0.2..5.0).contains(&ratio),
                            "{}/{slug}: ratio {ratio}", model.name);
                }
                // System B's fleet-wide id-order pipelines: the analytic
                // model serializes ALL boundary traffic (2KΣ — its
                // deliberate pessimism about topology-oblivious
                // pipelines) while execution overlaps distinct links, so
                // wide pipelines land far below 1; only the order of
                // magnitude is pinned.
                _ => {
                    let ratio = sim / analytic;
                    assert!((0.005..5.0).contains(&ratio),
                            "{}/{slug}: ratio {ratio}", model.name);
                }
            }
        }
    }
}

/// Index of the cheapest system for model row `m`.
fn winner(eval: &SystemEval, m: usize) -> usize {
    (0..eval.systems.len())
        .min_by(|&x, &y| {
            eval.costs[m][x]
                .total_ms()
                .total_cmp(&eval.costs[m][y].total_ms())
        })
        .expect("non-empty registry")
}

/// The ranking story both backends must agree on, per workload row:
/// identical feasibility, the same per-model winner (Hulk), and System C
/// the most expensive feasible system.
fn assert_ranking_agreement(fleet: &Fleet, workload: &[ModelSpec]) {
    let registry = PlannerRegistry::standard();
    let analytic = evaluate_with_backend(&registry, fleet, workload,
                                         HulkSplitterKind::Oracle,
                                         CostBackend::Analytic)
        .expect("analytic eval");
    let sim = evaluate_with_backend(&registry, fleet, workload,
                                    HulkSplitterKind::Oracle,
                                    CostBackend::Simulated)
        .expect("sim eval");
    let hulk = analytic.hulk_column().expect("hulk registered");
    for m in 0..analytic.models.len() {
        for s in 0..analytic.systems.len() {
            assert_eq!(analytic.costs[m][s].is_feasible(),
                       sim.costs[m][s].is_feasible(),
                       "feasibility differs: model {m} system {s}");
        }
        // Same winner under both backends — and it is Hulk.
        assert_eq!(winner(&analytic, m), winner(&sim, m),
                   "winner differs for {}", analytic.models[m].name);
        assert_eq!(winner(&sim, m), hulk,
                   "{}: Hulk dethroned under contention",
                   analytic.models[m].name);
        // System C (fleet-wide tensor parallelism over WAN) stays the
        // most expensive feasible system under both pricings.
        for eval in [&analytic, &sim] {
            let c = eval.costs[m][2];
            assert_eq!(eval.systems[2].slug, "system_c");
            for s in 0..eval.systems.len() {
                if s != 2 && eval.costs[m][s].is_feasible() {
                    assert!(eval.costs[m][s].total_ms() <= c.total_ms(),
                            "{}: system {s} above C",
                            eval.models[m].name);
                }
            }
        }
    }
    // The headline survives both pricings.
    assert!(analytic.hulk_improvement() > 0.0);
    assert!(sim.hulk_improvement() > 0.0);
}

#[test]
fn ranking_agrees_on_the_table1_fleet() {
    let fleet = Fleet::paper_evaluation(0);
    assert_ranking_agreement(&fleet, &ModelSpec::paper_four());
    // On the paper's own scenario the analytic headline stays >20%.
    let eval = evaluate_with_backend(&PlannerRegistry::standard(), &fleet,
                                     &ModelSpec::paper_four(),
                                     HulkSplitterKind::Oracle,
                                     CostBackend::Analytic)
        .unwrap();
    assert!(eval.hulk_improvement() > 0.20);
}

#[test]
fn ranking_agrees_at_planet_scale() {
    let fleet = Fleet::synthetic(220, 12, 0);
    let workload = feasible_workload(&fleet, &ModelSpec::paper_six());
    assert!(!workload.is_empty());
    assert_ranking_agreement(&fleet, &workload);
}
