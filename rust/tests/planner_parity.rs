//! Refactor-parity suite for the planner seam: each trait planner's
//! `Placement` and `IterCost` must be **identical** to what the
//! pre-refactor per-system free functions produced. The old functions
//! (`system_a::cost`, `system_b::plan`/`cost`, `system_c::cost`,
//! `hulk_plan` + `hulk::cost`) are reimplemented here verbatim from
//! their public building blocks and compared golden-value (exact `f64`
//! equality — everything is deterministic) on the three seeds the issue
//! names: the Table 1 fleet, the planet-scale synthetic fleet, and the
//! ×4 WAN-degradation fleet.

use hulk::cluster::Fleet;
use hulk::graph::{ClusterGraph, GraphView};
use hulk::models::ModelSpec;
use hulk::parallel::data_parallel::{data_parallel_cost, replica_capable};
use hulk::parallel::{pipeline_cost, tensor_parallel_cost, IterCost,
                     PipelinePlan};
use hulk::planner::{chain_order, HulkSplitterKind, PlanContext, Planner,
                    PlannerRegistry, TaskPlacement};
use hulk::scheduler::{algorithm1, Assignment, TaskSplitter};
use hulk::scenarios::feasible_workload;

/// The three situations the parity contract covers.
fn situations() -> Vec<(&'static str, Fleet, Vec<ModelSpec>)> {
    let planet = Fleet::synthetic(220, 12, 0);
    let planet_workload = feasible_workload(&planet, &ModelSpec::paper_six());
    vec![
        ("table1_fleet", Fleet::paper_evaluation(0), ModelSpec::paper_four()),
        ("planet_scale", planet, planet_workload),
        ("wan_degradation_x4",
         Fleet::paper_evaluation(0).with_wan_scaled(4.0),
         ModelSpec::paper_four()),
    ]
}

// --------------------------------------------------------------------
// Verbatim pre-refactor reference implementations.
// --------------------------------------------------------------------

/// `system_a::cost` as it was: DP over every replica-capable machine.
fn ref_system_a(fleet: &Fleet, model: &ModelSpec)
    -> (Vec<usize>, IterCost)
{
    let replicas = replica_capable(fleet, model);
    let cost = data_parallel_cost(fleet, &replicas, model);
    (replicas, cost)
}

/// `system_b::plan`/`cost` as they were: first `min(layers, n)` machines
/// in id order.
fn ref_system_b(fleet: &Fleet, model: &ModelSpec)
    -> (PipelinePlan, IterCost)
{
    let n_stages = fleet.len().min(model.layers);
    let stages: Vec<usize> = (0..n_stages).collect();
    let plan = PipelinePlan::proportional(fleet, stages, model);
    let cost = pipeline_cost(fleet, &plan, model);
    (plan, cost)
}

/// `system_c::cost` as it was: tensor parallelism over the whole fleet.
fn ref_system_c(fleet: &Fleet, model: &ModelSpec)
    -> (Vec<usize>, IterCost)
{
    let all: Vec<usize> = (0..fleet.len()).collect();
    let cost = tensor_parallel_cost(fleet, &all, model);
    (all, cost)
}

/// The oracle splitter exactly as `systems::hulk` wired it into
/// Algorithm 1 (grow_group with 1.3 headroom).
struct RefOracleSplitter;

impl TaskSplitter for RefOracleSplitter {
    fn split(&self, fleet: &Fleet, graph: &dyn GraphView,
             remaining: &[usize], task: &ModelSpec, _class: usize)
        -> Vec<usize>
    {
        hulk::scheduler::oracle::grow_group(&fleet.machines, graph,
                                            remaining, task, 1.3)
    }
}

/// `hulk_plan` + `hulk::cost` as they were: sort largest-first, run
/// Algorithm 1, chain-order each group, truncate to the layer count,
/// proportional split, pipeline cost.
fn ref_hulk(fleet: &Fleet, graph: &ClusterGraph, workload: &[ModelSpec])
    -> (Vec<ModelSpec>, Assignment, Vec<PipelinePlan>, Vec<IterCost>)
{
    let mut tasks = workload.to_vec();
    ModelSpec::sort_largest_first(&mut tasks);
    let assignment = algorithm1(fleet, graph, &tasks, &RefOracleSplitter)
        .expect("parity fleets assign cleanly");
    let mut pipelines = Vec::with_capacity(tasks.len());
    let mut costs = Vec::with_capacity(tasks.len());
    for (t, task) in tasks.iter().enumerate() {
        let group = assignment.group(t);
        assert!(!group.is_empty(), "task {} got no machines", task.name);
        let ordered = chain_order(graph, group);
        let n_stages = ordered.len().min(task.layers);
        let stages: Vec<usize> = ordered.into_iter().take(n_stages).collect();
        let plan = PipelinePlan::proportional(fleet, stages, task);
        costs.push(pipeline_cost(fleet, &plan, task));
        pipelines.push(plan);
    }
    (tasks, assignment, pipelines, costs)
}

// --------------------------------------------------------------------
// Parity assertions.
// --------------------------------------------------------------------

#[test]
fn trait_planners_match_the_pre_refactor_free_functions() {
    let registry = PlannerRegistry::standard();
    for (label, fleet, workload) in situations() {
        let graph = ClusterGraph::from_fleet(&fleet);
        let mut wl = workload.clone();
        ModelSpec::sort_largest_first(&mut wl);
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);

        let (ref_tasks, ref_assignment, ref_pipelines, ref_hulk_costs) =
            ref_hulk(&fleet, &graph, &workload);
        assert_eq!(ref_tasks, wl, "{label}: canonical order differs");

        for planner in registry.iter() {
            let placement = planner.plan(&ctx)
                .unwrap_or_else(|e| panic!("{label}/{}: {e}",
                                           planner.slug()));
            for (t, model) in wl.iter().enumerate() {
                let got = planner.cost(&ctx, &placement, t);
                match planner.slug() {
                    "system_a" => {
                        let (participants, want) =
                            ref_system_a(&fleet, model);
                        assert_eq!(placement.machines(t), &participants[..],
                                   "{label}/system_a/{}", model.name);
                        assert_eq!(got, want,
                                   "{label}/system_a/{}", model.name);
                        assert!(matches!(
                            placement.per_task[t],
                            TaskPlacement::Replicated { .. }
                        ));
                    }
                    "system_b" => {
                        let (plan, want) = ref_system_b(&fleet, model);
                        let got_plan = placement.pipeline(t).unwrap();
                        assert_eq!(got_plan.stages, plan.stages,
                                   "{label}/system_b/{}", model.name);
                        assert_eq!(got_plan.layers, plan.layers,
                                   "{label}/system_b/{}", model.name);
                        assert_eq!(got_plan.microbatches, plan.microbatches);
                        assert_eq!(got, want,
                                   "{label}/system_b/{}", model.name);
                    }
                    "system_c" => {
                        let (all, want) = ref_system_c(&fleet, model);
                        assert_eq!(placement.machines(t), &all[..],
                                   "{label}/system_c/{}", model.name);
                        assert_eq!(got, want,
                                   "{label}/system_c/{}", model.name);
                    }
                    "hulk" => {
                        assert_eq!(placement.machines(t),
                                   ref_assignment.group(t),
                                   "{label}/hulk/{} group", model.name);
                        let got_plan = placement.pipeline(t).unwrap();
                        assert_eq!(got_plan.stages, ref_pipelines[t].stages,
                                   "{label}/hulk/{} chain", model.name);
                        assert_eq!(got_plan.layers, ref_pipelines[t].layers,
                                   "{label}/hulk/{} layers", model.name);
                        assert_eq!(got, ref_hulk_costs[t],
                                   "{label}/hulk/{}", model.name);
                    }
                    other => panic!("unexpected planner {other}"),
                }
            }
        }
    }
}

#[test]
fn evaluate_all_matches_the_reference_costs_cell_by_cell() {
    // The registry-driven harness reproduces the old `evaluate_all`
    // matrix exactly: reference column s for model m == costs[m][s].
    for (label, fleet, workload) in situations() {
        let graph = ClusterGraph::from_fleet(&fleet);
        let eval = hulk::scenarios::evaluate_all(
            &fleet, &workload, HulkSplitterKind::Oracle)
            .unwrap_or_else(|e| panic!("{label}: {e}"));
        let (_tasks, _assignment, _pipelines, hulk_costs) =
            ref_hulk(&fleet, &graph, &workload);
        for (m, model) in eval.models.iter().enumerate() {
            assert_eq!(eval.costs[m][0], ref_system_a(&fleet, model).1,
                       "{label}: A × {}", model.name);
            assert_eq!(eval.costs[m][1], ref_system_b(&fleet, model).1,
                       "{label}: B × {}", model.name);
            assert_eq!(eval.costs[m][2], ref_system_c(&fleet, model).1,
                       "{label}: C × {}", model.name);
            assert_eq!(eval.costs[m][3], hulk_costs[m],
                       "{label}: Hulk × {}", model.name);
        }
    }
}

#[test]
fn golden_column_slugs_are_stable() {
    // The artifact column ids the dashboards depend on.
    assert_eq!(PlannerRegistry::standard().slugs(),
               vec!["system_a", "system_b", "system_c", "hulk"]);
    assert_eq!(
        PlannerRegistry::catalog().slugs(),
        vec!["system_a", "system_b", "system_c", "hulk", "hulk_no_gcn"]
    );
}
