//! Integration tests over the real AOT artifacts (require
//! `make artifacts`; each test skips with a notice when artifacts are
//! absent so `cargo test` stays green on a fresh checkout).
//!
//! These are the cross-language correctness signal: the PJRT-executed
//! HLO must agree with the pure-Rust reference forward, and training
//! through the artifact must learn.

use std::path::Path;

use hulk::cluster::Fleet;
use hulk::gnn::reference::{RefGcn, RefGcnConfig};
use hulk::gnn::trainer::evaluate_accuracy;
use hulk::gnn::{make_dataset, train_gcn, TrainerOptions};
use hulk::graph::{node_features, ClusterGraph};
use hulk::runtime::client::TrainState;
use hulk::runtime::{GcnRuntime, Manifest};

fn runtime() -> Option<GcnRuntime> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.kv").exists() {
        eprintln!("[skip] artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(GcnRuntime::load(Path::new(&dir)).expect("artifacts load"))
}

#[test]
fn manifest_contract_matches_reference_config() {
    let Some(rt) = runtime() else { return };
    let cfg = RefGcnConfig::default_artifact();
    assert_eq!(rt.manifest.n, cfg.n);
    assert_eq!(rt.manifest.f, cfg.f);
    assert_eq!(rt.manifest.h, cfg.h);
    assert_eq!(rt.manifest.h2, cfg.h2);
    assert_eq!(rt.manifest.c, cfg.c);
    assert_eq!(rt.manifest.p, cfg.n_params());
}

#[test]
fn pjrt_forward_matches_pure_rust_reference() {
    let Some(rt) = runtime() else { return };
    let params = rt.manifest.load_init_params().unwrap();
    let fleet = Fleet::paper_evaluation(0);
    let graph = ClusterGraph::from_fleet(&fleet);
    let slots = rt.manifest.n;
    let adj = graph.padded_adj(slots);
    let feats = node_features(&fleet.machines, &graph, slots);
    let mask = graph.padded_mask(slots);

    let pjrt = rt.forward(&params, &adj, &feats, &mask).unwrap();
    let refm = RefGcn::new(RefGcnConfig::default_artifact(), &params);
    let want = refm.forward(&adj, &feats, &mask);

    assert_eq!(pjrt.len(), slots * rt.manifest.c);
    let mut max_diff = 0.0f32;
    for (a, b) in pjrt.iter().zip(&want.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 2e-3,
            "PJRT vs reference forward diverged: max |Δ| = {max_diff}");
}

#[test]
fn train_step_learns_on_oracle_labels() {
    let Some(rt) = runtime() else { return };
    let dataset = make_dataset(8, rt.manifest.n, 3);
    let mut state = TrainState::fresh(rt.manifest.load_init_params().unwrap());
    let opts = TrainerOptions { steps: 40, lr: 0.01, log_every: 0 };
    let curve = train_gcn(&rt, &mut state, &dataset, &opts).unwrap();
    let first = curve.first().unwrap();
    let best_acc = curve.iter().map(|p| p.acc).fold(0.0f32, f32::max);
    // Initial loss ≈ ln 8 (8 classes); training must improve accuracy
    // well beyond the ~1/8 random baseline.
    assert!((first.loss - (8.0f32).ln()).abs() < 1.0,
            "initial loss {} far from ln(8)", first.loss);
    assert!(best_acc > 0.5, "best acc only {best_acc}");
    let min_loss = curve.iter().map(|p| p.loss).fold(f32::MAX, f32::min);
    assert!(min_loss < first.loss * 0.7,
            "loss did not decrease: {} → {}", first.loss, min_loss);
}

#[test]
fn trained_params_generalize_to_heldout_graphs() {
    let Some(rt) = runtime() else { return };
    let train_set = make_dataset(24, rt.manifest.n, 5);
    let test_set = make_dataset(8, rt.manifest.n, 6);
    let mut state = TrainState::fresh(rt.manifest.load_init_params().unwrap());
    let opts = TrainerOptions { steps: 120, lr: 0.01, log_every: 0 };
    train_gcn(&rt, &mut state, &train_set, &opts).unwrap();
    let acc = evaluate_accuracy(&rt, &state.params, &test_set).unwrap();
    // Spare/task structure is region-correlated: the GCN must beat the
    // random-guess baseline (1/8) by a wide margin out of sample.
    assert!(acc > 0.4, "held-out accuracy only {acc:.3}");
}

#[test]
fn forward_is_deterministic_across_calls() {
    let Some(rt) = runtime() else { return };
    let params = rt.manifest.load_init_params().unwrap();
    let fleet = Fleet::paper_toy(0);
    let graph = ClusterGraph::from_fleet(&fleet);
    let slots = rt.manifest.n;
    let adj = graph.padded_adj(slots);
    let feats = node_features(&fleet.machines, &graph, slots);
    let mask = graph.padded_mask(slots);
    let a = rt.forward(&params, &adj, &feats, &mask).unwrap();
    let b = rt.forward(&params, &adj, &feats, &mask).unwrap();
    assert_eq!(a, b);
}

#[test]
fn probe_execute_b_output_arity() {
    // Probe: does the PJRT executable untuple the 5-tuple root into 5
    // buffers (enabling a device-resident training loop)?
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n;
    let dataset = make_dataset(1, n, 0);
    let mut state = TrainState::fresh(rt.manifest.load_init_params().unwrap());
    let g = &dataset[0];
    let arity = rt
        .probe_train_output_arity(&mut state, &g.adj, &g.feats, &g.labels,
                                  &g.mask)
        .unwrap();
    eprintln!("execute outputs arity = {arity}");
    assert!(arity == 1 || arity == 5);
}

#[test]
#[ignore] // perf probe: run explicitly with --ignored
fn perf_probe_train_step_breakdown() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n;
    let dataset = make_dataset(1, n, 0);
    let g = &dataset[0];
    let mut state = TrainState::fresh(rt.manifest.load_init_params().unwrap());
    // Warmup.
    for _ in 0..5 {
        rt.train_step(&mut state, &g.adj, &g.feats, &g.labels, &g.mask, 0.01)
            .unwrap();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        rt.train_step(&mut state, &g.adj, &g.feats, &g.labels, &g.mask, 0.01)
            .unwrap();
    }
    let full = t0.elapsed().as_secs_f64() * 10.0; // ms/step
    eprintln!("full train_step: {full:.3} ms/step");

    // Execute-only: pre-built literals, skip state readback.
    let p = rt.manifest.p as i64;
    let nn = n as i64;
    let f = rt.manifest.f as i64;
    let args = [
        hulk::runtime::literal::f32_literal(&state.params, &[p]).unwrap(),
        hulk::runtime::literal::f32_literal(&state.m, &[p]).unwrap(),
        hulk::runtime::literal::f32_literal(&state.v, &[p]).unwrap(),
        hulk::runtime::literal::f32_literal(&[1.0], &[1]).unwrap(),
        hulk::runtime::literal::f32_literal(&g.adj, &[nn, nn]).unwrap(),
        hulk::runtime::literal::f32_literal(&g.feats, &[nn, f]).unwrap(),
        hulk::runtime::literal::i32_literal(&g.labels, &[nn]).unwrap(),
        hulk::runtime::literal::f32_literal(&g.mask, &[nn]).unwrap(),
        hulk::runtime::literal::f32_literal(&[0.01], &[1]).unwrap(),
    ];
    let exe = rt.train_executable();
    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        let _ = exe.execute(&args).unwrap();
    }
    let exec_only = t0.elapsed().as_secs_f64() * 10.0;
    eprintln!("execute-only:   {exec_only:.3} ms/step");

    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        let out = exe.execute(&args).unwrap()[0][0].to_literal_sync().unwrap();
        let _ = out.to_tuple().unwrap();
    }
    let exec_sync = t0.elapsed().as_secs_f64() * 10.0;
    eprintln!("execute+sync:   {exec_sync:.3} ms/step");
}

#[test]
fn fast_path_matches_slow_path() {
    // The literal-resident hot path must be numerically identical to the
    // vector round-trip path.
    let Some(rt) = runtime() else { return };
    let dataset = make_dataset(3, rt.manifest.n, 7);
    let init = rt.manifest.load_init_params().unwrap();

    let mut slow = TrainState::fresh(init.clone());
    for s in 0..9usize {
        let g = &dataset[s % dataset.len()];
        rt.train_step(&mut slow, &g.adj, &g.feats, &g.labels, &g.mask, 0.01)
            .unwrap();
    }

    let mut fast = TrainState::fresh(init);
    let opts = TrainerOptions { steps: 9, lr: 0.01, log_every: 0 };
    train_gcn(&rt, &mut fast, &dataset, &opts).unwrap();

    assert_eq!(slow.step, fast.step);
    let max_diff = slow
        .params
        .iter()
        .zip(&fast.params)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff == 0.0, "fast/slow paths diverged: {max_diff}");
}

#[test]
#[ignore] // perf probe: run explicitly with --ignored
fn perf_probe_fast_vs_slow_train() {
    let Some(rt) = runtime() else { return };
    let dataset = make_dataset(1, rt.manifest.n, 0);
    let g = &dataset[0];
    let mut state = TrainState::fresh(rt.manifest.load_init_params().unwrap());
    for _ in 0..5 {
        rt.train_step(&mut state, &g.adj, &g.feats, &g.labels, &g.mask, 0.01)
            .unwrap();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        rt.train_step(&mut state, &g.adj, &g.feats, &g.labels, &g.mask, 0.01)
            .unwrap();
    }
    eprintln!("slow path: {:.3} ms/step",
              t0.elapsed().as_secs_f64() * 10.0);

    let opts = TrainerOptions { steps: 100, lr: 0.01, log_every: 0 };
    let t0 = std::time::Instant::now();
    train_gcn(&rt, &mut state, &dataset, &opts).unwrap();
    eprintln!("fast path: {:.3} ms/step",
              t0.elapsed().as_secs_f64() * 10.0);
}
