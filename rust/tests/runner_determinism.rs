//! Parallel-vs-serial determinism suite for the scenario runner — now
//! including planner-filtered (`--systems`) and sim-priced (`--cost
//! sim`) runs — plus smoke tests for the `planet_scale` and
//! `burst_arrivals` scenarios and the `hulk_no_gcn` ablation planner.
//!
//! The acceptance bar: `hulk scenarios run all --json --parallel` must
//! produce a `BENCH_scenarios.json` byte-identical to the serial run's
//! (CI diffs the two artifacts as a gate; this suite is the in-repo
//! version of that gate) — for either cost backend — and a `--systems`
//! subset must be byte-identical serial vs parallel *and* a strict
//! subset of the all-systems artifact columns.

use std::collections::BTreeMap;

use hulk::benchkit::BenchReport;
use hulk::planner::{CostBackend, PlannerRegistry};
use hulk::scenarios::{find_scenario, resolve_scenarios, run_specs,
                      ScenarioResult, ScenarioSpec};

/// The specs an analytic `hulk scenarios run all` executes.
fn analytic_specs() -> Vec<ScenarioSpec> {
    resolve_scenarios(&[], CostBackend::Analytic)
        .expect("resolve all")
        .0
}

fn report_bytes(results: Vec<ScenarioResult>) -> String {
    let mut report = BenchReport::new("scenarios");
    for r in results {
        report.extend(r.entries);
    }
    let mut text = report.to_json().render();
    text.push('\n');
    text
}

#[test]
fn parallel_run_is_byte_identical_to_serial() {
    let specs = analytic_specs();
    let planners = PlannerRegistry::standard();
    let serial = run_specs(&specs, 0, 1, &planners, CostBackend::Analytic)
        .expect("serial run");
    let serial_rendered: Vec<String> =
        serial.iter().map(|r| r.rendered.clone()).collect();
    let serial_bytes = report_bytes(serial);
    for threads in [2, 4, 8] {
        let parallel =
            run_specs(&specs, 0, threads, &planners, CostBackend::Analytic)
                .unwrap_or_else(|e| panic!("{threads}-thread run: {e}"));
        let parallel_rendered: Vec<String> =
            parallel.iter().map(|r| r.rendered.clone()).collect();
        assert_eq!(serial_rendered, parallel_rendered,
                   "rendered output diverged at {threads} threads");
        assert_eq!(serial_bytes, report_bytes(parallel),
                   "BENCH_scenarios.json diverged at {threads} threads");
    }
}

#[test]
fn sim_priced_run_is_byte_identical_serial_vs_parallel() {
    // The `--cost sim` half of the CI determinism gate, on a subset
    // that exercises both Evaluate cells and the sim-only customs (the
    // full suite runs in CI on the release build).
    let (specs, _) = resolve_scenarios(
        &["table1_fleet".to_string(), "contended_links".to_string(),
          "sim_vs_analytic".to_string()],
        CostBackend::Simulated,
    )
    .expect("resolve sim subset");
    let planners = PlannerRegistry::standard();
    let serial =
        run_specs(&specs, 0, 1, &planners, CostBackend::Simulated)
            .expect("serial sim run");
    // Sim pricing adds the contention digests on evaluated scenarios.
    assert!(serial[0]
        .entries
        .iter()
        .any(|e| e.name == "table1_fleet/hulk/sim/makespan_ms"));
    let serial_rendered: Vec<String> =
        serial.iter().map(|r| r.rendered.clone()).collect();
    let serial_bytes = report_bytes(serial);
    for threads in [2, 4] {
        let parallel =
            run_specs(&specs, 0, threads, &planners,
                      CostBackend::Simulated)
                .unwrap_or_else(|e| panic!("{threads}-thread run: {e}"));
        let parallel_rendered: Vec<String> =
            parallel.iter().map(|r| r.rendered.clone()).collect();
        assert_eq!(serial_rendered, parallel_rendered,
                   "sim rendered output diverged at {threads} threads");
        assert_eq!(serial_bytes, report_bytes(parallel),
                   "sim artifact diverged at {threads} threads");
    }
}

#[test]
fn analytic_artifact_carries_no_exec_digest_rows() {
    // The byte-identity guarantee vs pre-backend artifacts, in spirit:
    // an analytic run must not leak any backend exec-digest column into
    // BENCH_scenarios.json. (failure_storm's historical
    // `failure_storm/sim/healthy_makespan_ms` /
    // `…/sim/microbatches_salvaged` DES rows predate the backend and
    // legitimately remain — only the new digest suffixes are banned.)
    let specs = analytic_specs();
    assert!(specs.iter().all(|s| !s.sim_only));
    let results =
        run_specs(&specs, 0, 1, &PlannerRegistry::standard(),
                  CostBackend::Analytic)
            .unwrap();
    const DIGEST_SUFFIXES: [&str; 4] = [
        "/sim/makespan_ms",
        "/sim/straggler_wait_ms",
        "/sim/max_link_utilization_pct",
        "/sim/events",
    ];
    for r in &results {
        for e in &r.entries {
            assert!(
                DIGEST_SUFFIXES.iter().all(|s| !e.name.ends_with(s)),
                "{}: leaked exec-digest row {}", r.scenario, e.name
            );
        }
        assert!(!r.rendered.contains("simulated execution"),
                "{} leaked sim rendering", r.scenario);
    }
}

#[test]
fn parallel_written_artifact_matches_serial_file_bytes() {
    // End-to-end through the benchkit writer, as CI diffs it — the
    // placements artifact included.
    let specs = analytic_specs();
    let planners = PlannerRegistry::standard();
    let base = std::env::temp_dir().join("hulk_runner_determinism_test");
    let write = |results: Vec<ScenarioResult>, sub: &str| {
        let mut report = BenchReport::new("scenarios");
        let mut placements = BenchReport::new("placements");
        for r in results {
            report.extend(r.entries);
            placements.extend(r.placements);
        }
        let dir = base.join(sub);
        (report.write(&dir).expect("write report"),
         placements.write(&dir).expect("write placements"))
    };
    let (a, pa) = write(
        run_specs(&specs, 7, 1, &planners, CostBackend::Analytic).unwrap(),
        "serial",
    );
    let (b, pb) = write(
        run_specs(&specs, 7, 4, &planners, CostBackend::Analytic).unwrap(),
        "parallel",
    );
    assert_eq!(std::fs::read(a).unwrap(), std::fs::read(b).unwrap());
    assert_eq!(std::fs::read(pa).unwrap(), std::fs::read(pb).unwrap());
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn planner_filtered_run_is_deterministic_and_a_column_subset() {
    let specs = analytic_specs();

    // The all-systems reference: name → value over every entry.
    let all = run_specs(&specs, 0, 1, &PlannerRegistry::standard(),
                        CostBackend::Analytic)
        .expect("all-systems run");
    let mut all_rows: BTreeMap<String, f64> = BTreeMap::new();
    let mut all_count = 0usize;
    for r in &all {
        for e in &r.entries {
            all_rows.insert(e.name.clone(), e.value);
            all_count += 1;
        }
    }

    // `--systems a,hulk`: byte-identical serial vs parallel.
    let filtered = PlannerRegistry::resolve("a,hulk").unwrap();
    let serial = run_specs(&specs, 0, 1, &filtered, CostBackend::Analytic)
        .expect("filtered run");
    let parallel =
        run_specs(&specs, 0, 4, &filtered, CostBackend::Analytic)
            .expect("parallel");
    let serial_entries: Vec<(String, f64)> = serial
        .iter()
        .flat_map(|r| r.entries.iter().map(|e| (e.name.clone(), e.value)))
        .collect();
    let parallel_entries: Vec<(String, f64)> = parallel
        .iter()
        .flat_map(|r| r.entries.iter().map(|e| (e.name.clone(), e.value)))
        .collect();
    assert_eq!(serial_entries, parallel_entries,
               "filtered run diverged serial vs parallel");

    // Strict subset: fewer entries overall…
    assert!(serial_entries.len() < all_count,
            "filtered run should drop the unselected systems' columns");
    // …no column from an unselected system…
    for (name, _) in &serial_entries {
        assert!(!name.contains("/system_b/") && !name.contains("/system_c/"),
                "unselected system leaked into filtered run: {name}");
    }
    // …and every selected per-system column matches the all-systems
    // value exactly. (Aggregates like hulk_improvement_pct legitimately
    // change when the baseline pool shrinks, so only per-system columns
    // are value-compared.)
    for (name, value) in &serial_entries {
        if name.contains("/system_a/") || name.contains("/hulk/") {
            let reference = all_rows.get(name).unwrap_or_else(|| {
                panic!("filtered column {name} missing from all-systems run")
            });
            assert_eq!(value, reference, "{name} diverged from all-systems");
        }
    }
}

#[test]
fn hulk_no_gcn_runs_every_scenario_end_to_end() {
    // The ablation planner exercises the whole seam: every scenario
    // completes under `--systems hulk_no_gcn,a` and emits its columns.
    let planners = PlannerRegistry::resolve("hulk_no_gcn,a").unwrap();
    let specs = analytic_specs();
    let results = run_specs(&specs, 0, 2, &planners, CostBackend::Analytic)
        .expect("hulk_no_gcn suite runs");
    assert_eq!(results.len(), specs.len());
    // Evaluate-shaped scenarios carry hulk_no_gcn columns and digests.
    let table1 = results
        .iter()
        .find(|r| r.scenario == "table1_fleet")
        .unwrap();
    assert!(table1
        .entries
        .iter()
        .any(|e| e.name.contains("/hulk_no_gcn/")));
    assert!(table1
        .placements
        .iter()
        .any(|e| e.name == "table1_fleet/hulk_no_gcn/placement/group_count"));
}

#[test]
fn placement_digests_cover_every_planning_scenario() {
    let planners = PlannerRegistry::standard();
    let results = run_specs(&analytic_specs(), 0, 1, &planners,
                            CostBackend::Analytic)
        .unwrap();
    for r in &results {
        // Every scenario that runs a full evaluation — the Evaluate
        // bodies plus the custom ones embedding one (wan_degradation ×4,
        // fleet_growth n24, failure_storm survivors) — emits one digest
        // triple per registered planner. The pure leader-loop scenarios
        // have no Placement to digest.
        if matches!(r.scenario, "multi_tenant" | "burst_arrivals") {
            assert!(r.placements.is_empty(), "{}", r.scenario);
            continue;
        }
        // 4 planners × 3 digest rows.
        assert_eq!(r.placements.len(), 12, "{}", r.scenario);
        for e in &r.placements {
            assert!(e.name.starts_with(r.scenario), "{}", e.name);
            assert!(e.name.contains("/placement/"), "{}", e.name);
            assert!(e.value.is_finite() && e.value >= 0.0);
        }
    }
}

#[test]
fn planet_scale_smoke() {
    let result = find_scenario("planet_scale")
        .expect("planet_scale registered")
        .run(0)
        .expect("planet_scale runs");
    // All four systems show up on the 220-server fleet.
    for slug in ["system_a", "system_b", "system_c", "hulk"] {
        let marker = format!("/{slug}/");
        assert!(result.entries.iter().any(|e| e.name.contains(&marker)),
                "no {slug} entry");
    }
    // Hulk is at least as fast as the best feasible baseline in
    // aggregate — regional grouping must not lose at planet scale.
    let improvement = result
        .entries
        .iter()
        .find(|e| e.name == "planet_scale/hulk_improvement_pct")
        .expect("improvement entry");
    assert!(improvement.value > 0.0,
            "Hulk loses at planet scale: {:.1}%", improvement.value);
    // Per model: Hulk beats System B (id-order GPipe) wherever both ran.
    for model in ["opt_175b", "t5_11b", "gpt_2_1_5b"] {
        let get = |slug: &str| {
            result
                .entries
                .iter()
                .find(|e| {
                    e.name == format!("planet_scale/{slug}/{model}/iter_ms")
                })
                .map(|e| e.value)
        };
        if let (Some(hulk), Some(b)) = (get("hulk"), get("system_b")) {
            assert!(hulk <= b, "{model}: hulk {hulk} vs system_b {b}");
        }
    }
    let servers = result
        .entries
        .iter()
        .find(|e| e.name == "planet_scale/fleet_servers")
        .expect("fleet size entry");
    assert!(servers.value >= 200.0, "planet fleet too small: {}",
            servers.value);
    let regions = result
        .entries
        .iter()
        .find(|e| e.name == "planet_scale/fleet_regions")
        .expect("region entry");
    assert_eq!(regions.value, 12.0);
    // Entry volume stays bounded (6 models × 4 systems + metadata).
    assert!(result.entries.len() <= 40,
            "entry blowup: {}", result.entries.len());
}

#[test]
fn burst_arrivals_smoke_is_bounded_and_consistent() {
    let spec = find_scenario("burst_arrivals").expect("registered");
    let result = spec.run(0).expect("burst_arrivals runs");
    let get = |name: &str| -> f64 {
        result
            .entries
            .iter()
            .find(|e| e.name == format!("burst_arrivals/{name}"))
            .unwrap_or_else(|| panic!("missing entry {name}"))
            .value
    };
    // The stream is seeded Poisson: something must arrive, and every
    // submission is either admitted or queued. Queued (or requeued)
    // tasks that later re-admit increment `tasks_admitted` again, so
    // the sum lies between `submitted` and `2 × submitted + failures`.
    let submitted = get("tasks_submitted");
    let settled = get("tasks_admitted") + get("tasks_queued");
    assert!(submitted >= 1.0);
    assert!(settled >= submitted, "{settled} < {submitted}");
    assert!(settled <= 2.0 * submitted + get("machine_failures"),
            "counter blowup: {settled} vs {submitted} submitted");
    assert_eq!(get("machine_failures"), 2.0);
    // Leader event count is bounded by slots + arrivals + failures +
    // the drain-tick budget — wall-clock cannot run away with the seed.
    let events = get("events_processed");
    assert!(events >= 24.0, "at least one event per slot: {events}");
    assert!(events <= 24.0 + submitted + 2.0 + 64.0,
            "event blowup: {events}");
    assert!(get("drain_ticks") <= 64.0);
    // Determinism across runs.
    let again = spec.run(0).expect("second run");
    let rows = |r: &ScenarioResult| -> Vec<(String, f64)> {
        r.entries.iter().map(|e| (e.name.clone(), e.value)).collect()
    };
    assert_eq!(rows(&result), rows(&again));
}

#[test]
fn subset_runs_only_requested_scenarios_in_order() {
    let (specs, ran_all) = resolve_scenarios(
        &["burst_arrivals".to_string(), "table1_fleet".to_string()],
        CostBackend::Analytic,
    )
    .unwrap();
    assert!(!ran_all);
    let results = run_specs(&specs, 0, 2, &PlannerRegistry::standard(),
                            CostBackend::Analytic)
        .unwrap();
    let names: Vec<&str> = results.iter().map(|r| r.scenario).collect();
    assert_eq!(names, vec!["burst_arrivals", "table1_fleet"]);
}
