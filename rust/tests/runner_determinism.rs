//! Parallel-vs-serial determinism suite for the scenario runner, plus
//! smoke tests for the `planet_scale` and `burst_arrivals` scenarios.
//!
//! The acceptance bar: `hulk scenarios run all --json --parallel` must
//! produce a `BENCH_scenarios.json` byte-identical to the serial run's
//! (CI diffs the two artifacts as a gate; this suite is the in-repo
//! version of that gate).

use hulk::benchkit::BenchReport;
use hulk::scenarios::{all_scenarios, find_scenario, run_specs,
                      ScenarioResult};

fn report_bytes(results: Vec<ScenarioResult>) -> String {
    let mut report = BenchReport::new("scenarios");
    for r in results {
        report.extend(r.entries);
    }
    let mut text = report.to_json().render();
    text.push('\n');
    text
}

#[test]
fn parallel_run_is_byte_identical_to_serial() {
    let specs = all_scenarios();
    let serial = run_specs(&specs, 0, 1).expect("serial run");
    let serial_rendered: Vec<String> =
        serial.iter().map(|r| r.rendered.clone()).collect();
    let serial_bytes = report_bytes(serial);
    for threads in [2, 4, 8] {
        let parallel = run_specs(&specs, 0, threads)
            .unwrap_or_else(|e| panic!("{threads}-thread run: {e}"));
        let parallel_rendered: Vec<String> =
            parallel.iter().map(|r| r.rendered.clone()).collect();
        assert_eq!(serial_rendered, parallel_rendered,
                   "rendered output diverged at {threads} threads");
        assert_eq!(serial_bytes, report_bytes(parallel),
                   "BENCH_scenarios.json diverged at {threads} threads");
    }
}

#[test]
fn parallel_written_artifact_matches_serial_file_bytes() {
    // End-to-end through the benchkit writer, as CI diffs it.
    let specs = all_scenarios();
    let base = std::env::temp_dir().join("hulk_runner_determinism_test");
    let write = |results: Vec<ScenarioResult>, sub: &str| {
        let mut report = BenchReport::new("scenarios");
        for r in results {
            report.extend(r.entries);
        }
        report.write(&base.join(sub)).expect("write report")
    };
    let a = write(run_specs(&specs, 7, 1).unwrap(), "serial");
    let b = write(run_specs(&specs, 7, 4).unwrap(), "parallel");
    let bytes_a = std::fs::read(a).unwrap();
    let bytes_b = std::fs::read(b).unwrap();
    assert_eq!(bytes_a, bytes_b);
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn planet_scale_smoke() {
    let result = find_scenario("planet_scale")
        .expect("planet_scale registered")
        .run(0)
        .expect("planet_scale runs");
    // All four systems show up on the 220-server fleet.
    for slug in ["system_a", "system_b", "system_c", "hulk"] {
        let marker = format!("/{slug}/");
        assert!(result.entries.iter().any(|e| e.name.contains(&marker)),
                "no {slug} entry");
    }
    // Hulk is at least as fast as the best feasible baseline in
    // aggregate — regional grouping must not lose at planet scale.
    let improvement = result
        .entries
        .iter()
        .find(|e| e.name == "planet_scale/hulk_improvement_pct")
        .expect("improvement entry");
    assert!(improvement.value > 0.0,
            "Hulk loses at planet scale: {:.1}%", improvement.value);
    // Per model: Hulk beats System B (id-order GPipe) wherever both ran.
    for model in ["opt_175b", "t5_11b", "gpt_2_1_5b"] {
        let get = |slug: &str| {
            result
                .entries
                .iter()
                .find(|e| {
                    e.name == format!("planet_scale/{slug}/{model}/iter_ms")
                })
                .map(|e| e.value)
        };
        if let (Some(hulk), Some(b)) = (get("hulk"), get("system_b")) {
            assert!(hulk <= b, "{model}: hulk {hulk} vs system_b {b}");
        }
    }
    let servers = result
        .entries
        .iter()
        .find(|e| e.name == "planet_scale/fleet_servers")
        .expect("fleet size entry");
    assert!(servers.value >= 200.0, "planet fleet too small: {}",
            servers.value);
    let regions = result
        .entries
        .iter()
        .find(|e| e.name == "planet_scale/fleet_regions")
        .expect("region entry");
    assert_eq!(regions.value, 12.0);
    // Entry volume stays bounded (6 models × 4 systems + metadata).
    assert!(result.entries.len() <= 40,
            "entry blowup: {}", result.entries.len());
}

#[test]
fn burst_arrivals_smoke_is_bounded_and_consistent() {
    let spec = find_scenario("burst_arrivals").expect("registered");
    let result = spec.run(0).expect("burst_arrivals runs");
    let get = |name: &str| -> f64 {
        result
            .entries
            .iter()
            .find(|e| e.name == format!("burst_arrivals/{name}"))
            .unwrap_or_else(|| panic!("missing entry {name}"))
            .value
    };
    // The stream is seeded Poisson: something must arrive, and every
    // submission is either admitted or queued. Queued (or requeued)
    // tasks that later re-admit increment `tasks_admitted` again, so
    // the sum lies between `submitted` and `2 × submitted + failures`.
    let submitted = get("tasks_submitted");
    let settled = get("tasks_admitted") + get("tasks_queued");
    assert!(submitted >= 1.0);
    assert!(settled >= submitted, "{settled} < {submitted}");
    assert!(settled <= 2.0 * submitted + get("machine_failures"),
            "counter blowup: {settled} vs {submitted} submitted");
    assert_eq!(get("machine_failures"), 2.0);
    // Leader event count is bounded by slots + arrivals + failures +
    // the drain-tick budget — wall-clock cannot run away with the seed.
    let events = get("events_processed");
    assert!(events >= 24.0, "at least one event per slot: {events}");
    assert!(events <= 24.0 + submitted + 2.0 + 64.0,
            "event blowup: {events}");
    assert!(get("drain_ticks") <= 64.0);
    // Determinism across runs.
    let again = spec.run(0).expect("second run");
    let rows = |r: &ScenarioResult| -> Vec<(String, f64)> {
        r.entries.iter().map(|e| (e.name.clone(), e.value)).collect()
    };
    assert_eq!(rows(&result), rows(&again));
}

#[test]
fn subset_runs_only_requested_scenarios_in_order() {
    let (specs, ran_all) = hulk::scenarios::resolve_scenarios(&[
        "burst_arrivals".to_string(),
        "table1_fleet".to_string(),
    ])
    .unwrap();
    assert!(!ran_all);
    let results = run_specs(&specs, 0, 2).unwrap();
    let names: Vec<&str> = results.iter().map(|r| r.scenario).collect();
    assert_eq!(names, vec!["burst_arrivals", "table1_fleet"]);
}
