//! Coordinator integration: the threaded leader loop end-to-end —
//! submissions, ticks, failures, scale-out — over the channel interface.

use hulk::cluster::{Fleet, GpuModel, Region};
use hulk::coordinator::{Coordinator, CoordinatorEvent, CoordinatorReply,
                        TaskState};
use hulk::models::ModelSpec;
use hulk::util::rng::Rng;

#[test]
fn full_leader_session_over_channels() {
    let coordinator = Coordinator::new(Fleet::paper_evaluation(0));
    let (tx, rx, handle) = coordinator.spawn();

    // Submit the paper's four models.
    let mut admitted = 0;
    for model in ModelSpec::paper_four() {
        tx.send(CoordinatorEvent::Submit { model, iterations: 20 }).unwrap();
        match rx.recv().unwrap() {
            CoordinatorReply::Admitted { .. } => admitted += 1,
            CoordinatorReply::Queued { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(admitted >= 3, "at least 3 of 4 tasks must admit immediately");

    // Fail two machines.
    for machine in [0, 17] {
        tx.send(CoordinatorEvent::MachineFailed { machine }).unwrap();
        assert!(matches!(rx.recv().unwrap(),
                         CoordinatorReply::Recovered { .. }));
    }

    // Scale out node 45-style.
    tx.send(CoordinatorEvent::ScaleOut {
        region: Region::Rome,
        gpu: GpuModel::V100,
        n_gpus: 12,
    })
    .unwrap();
    match rx.recv().unwrap() {
        CoordinatorReply::ScaledOut { machine_id, .. } => {
            assert_eq!(machine_id, 46);
        }
        other => panic!("unexpected {other:?}"),
    }

    // Run to completion.
    tx.send(CoordinatorEvent::Tick { iterations: 20 }).unwrap();
    match rx.recv().unwrap() {
        CoordinatorReply::Ticked { completed } => {
            assert!(!completed.is_empty(), "tasks must complete");
        }
        other => panic!("unexpected {other:?}"),
    }

    tx.send(CoordinatorEvent::Shutdown).unwrap();
    match rx.recv().unwrap() {
        CoordinatorReply::Stopped { metrics_render } => {
            assert!(metrics_render.contains("tasks_submitted"));
            assert!(metrics_render.contains("machine_failures"));
        }
        other => panic!("unexpected {other:?}"),
    }
    handle.join().unwrap();
}

#[test]
fn randomized_failure_storm_never_corrupts_state() {
    let mut c = Coordinator::new(Fleet::paper_evaluation(1));
    for model in ModelSpec::paper_four() {
        c.handle(CoordinatorEvent::Submit { model, iterations: 1_000 });
    }
    let mut rng = Rng::new(99);
    let n = c.fleet.len();
    for _ in 0..15 {
        let victim = rng.below(n);
        c.handle(CoordinatorEvent::MachineFailed { machine: victim });
        c.assignment
            .validate_disjoint(c.fleet.len())
            .expect("disjointness violated during failure storm");
    }
    // Every surviving running task still has machines.
    for t in &c.tasks {
        if t.state == TaskState::Running {
            assert!(!t.machines.is_empty());
        }
    }
}

#[test]
fn queued_tasks_eventually_run_as_capacity_frees() {
    let mut c = Coordinator::new(Fleet::paper_evaluation(2));
    // Saturate with OPT-scale tasks.
    let mut statuses = Vec::new();
    for _ in 0..4 {
        let reply = c.handle(CoordinatorEvent::Submit {
            model: ModelSpec::opt_175b(),
            iterations: 10,
        });
        statuses.push(matches!(reply, CoordinatorReply::Admitted { .. }));
    }
    let initially_admitted = statuses.iter().filter(|&&a| a).count();
    assert!(initially_admitted >= 1);
    if initially_admitted == 4 {
        return; // fleet swallowed everything; nothing queued to check
    }
    // Complete the running tasks; queued ones must then admit.
    c.handle(CoordinatorEvent::Tick { iterations: 10 });
    let running_after = c
        .tasks
        .iter()
        .filter(|t| t.state == TaskState::Running)
        .count();
    assert!(running_after >= 1,
            "queue must drain into freed capacity");
}
