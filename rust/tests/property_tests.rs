//! Property-based tests over the coordinator-side invariants (routing,
//! grouping, recovery, simulation) using the `hulk::prop` mini-harness —
//! random fleets, workloads and failure sequences — plus the
//! discrete-event engine's ordering/resource invariants the
//! whole-placement executor builds on.

use hulk::cluster::Fleet;
use hulk::coordinator::{recover, RecoveryAction};
use hulk::graph::{node_features, ClusterGraph, FEATURE_DIM};
use hulk::models::ModelSpec;
use hulk::parallel::{pipeline_cost, ring_allreduce_ms, PipelinePlan};
use hulk::planner::chain_order;
use hulk::prop::forall;
use hulk::scheduler::{oracle_partition, OracleOptions};
use hulk::sim::engine::{Engine, Resource};
use hulk::sim::simulate_pipeline;

fn random_workload(g: &mut hulk::prop::Gen) -> Vec<ModelSpec> {
    let catalog = [
        ModelSpec::t5_11b(),
        ModelSpec::gpt2_xl(),
        ModelSpec::bert_large(),
        ModelSpec::roberta_large(),
    ];
    let n = g.usize_in(1..=3);
    (0..n).map(|i| catalog[(i * 2 + g.usize_in(0..=1)) % 4].clone())
        .collect()
}

#[test]
fn oracle_assignments_always_disjoint_and_memory_feasible() {
    forall("oracle invariants", 40, |g| {
        let n = g.usize_in(6..=24);
        let fleet = Fleet::random(n, g.usize_in(0..=100_000) as u64);
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = random_workload(g);
        let total_need: f64 = tasks.iter().map(|t| t.train_gb()).sum();
        if total_need > fleet.total_memory_gb() * 0.8 {
            return true; // infeasible workload: vacuous case
        }
        let a = oracle_partition(&fleet, &graph, &tasks,
                                 &OracleOptions::default());
        a.validate_disjoint(fleet.len()).is_ok()
            && a.validate_memory(&fleet, &tasks).is_ok()
    });
}

#[test]
fn recovery_preserves_disjointness_under_any_failure() {
    forall("recovery invariants", 40, |g| {
        let n = g.usize_in(8..=24);
        let fleet = Fleet::random(n, g.usize_in(0..=100_000) as u64);
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = vec![ModelSpec::gpt2_xl(), ModelSpec::bert_large()];
        if fleet.total_memory_gb() < 100.0 {
            return true;
        }
        let mut a = oracle_partition(&fleet, &graph, &tasks,
                                     &OracleOptions::default());
        let victim = g.usize_in(0..=n - 1);
        let action = recover(&fleet, &graph, &mut a, &tasks, victim);
        // Whatever the action, disjointness must hold and (except for
        // Requeue/NoOp) the failed machine must be gone from groups.
        let disjoint = a.validate_disjoint(fleet.len()).is_ok();
        let gone = match action {
            RecoveryAction::NoOp => true,
            _ => a.task_of(victim).is_none(),
        };
        disjoint && gone
    });
}

#[test]
fn chain_order_is_always_a_permutation() {
    forall("chain order permutation", 60, |g| {
        let n = g.usize_in(4..=20);
        let fleet = Fleet::random(n, g.usize_in(0..=1_000_000) as u64);
        let graph = ClusterGraph::from_fleet(&fleet);
        let k = g.usize_in(1..=n);
        let group: Vec<usize> = (0..k).collect();
        let chain = chain_order(&graph, &group);
        let mut sorted = chain.clone();
        sorted.sort_unstable();
        sorted == group
    });
}

#[test]
fn ring_allreduce_monotone_in_bytes_and_nodes() {
    forall("allreduce monotonicity", 40, |g| {
        let fleet = Fleet::paper_evaluation(g.usize_in(0..=10) as u64);
        let k = g.usize_in(2..=12);
        let nodes: Vec<usize> = (0..k).collect();
        let b1 = g.f64_in(1e3, 1e8);
        let b2 = b1 * g.f64_in(1.5, 10.0);
        match (ring_allreduce_ms(&fleet, &nodes, b1),
               ring_allreduce_ms(&fleet, &nodes, b2)) {
            (Some(t1), Some(t2)) => t2 >= t1,
            _ => true, // blocked ring: vacuous
        }
    });
}

#[test]
fn pipeline_cost_positive_and_sim_agrees_when_feasible() {
    forall("pipeline cost sanity", 25, |g| {
        let fleet = Fleet::paper_evaluation(g.usize_in(0..=5) as u64);
        let model = ModelSpec::gpt2_xl();
        let k = g.usize_in(2..=10);
        let stages: Vec<usize> = (0..k).collect();
        let plan = PipelinePlan::proportional(&fleet, stages, &model);
        let cost = pipeline_cost(&fleet, &plan, &model);
        if !cost.is_feasible() {
            return true;
        }
        if cost.comm_ms < 0.0 || cost.comp_ms <= 0.0 {
            return false;
        }
        let sim = simulate_pipeline(&fleet, &plan, &model, false, None);
        sim.makespan_ms.is_finite() && sim.makespan_ms > 0.0
    });
}

/// Reference pop for the engine model: the first-inserted event among
/// those with the minimum time (strict `<` keeps insertion order).
fn model_pop(pending: &mut Vec<(f64, usize)>) -> usize {
    let mut best = 0;
    for i in 1..pending.len() {
        if pending[i].0 < pending[best].0 {
            best = i;
        }
    }
    pending.remove(best).1
}

#[test]
fn engine_equal_time_events_fire_fifo_under_interleaved_schedule_pop() {
    forall("engine FIFO ties", 60, |g| {
        let mut engine: Engine<usize> = Engine::new();
        // Times are drawn from a tiny offset set so ties are common; the
        // engine is compared op-by-op against a brute-force stable model.
        let mut pending: Vec<(f64, usize)> = Vec::new();
        let mut next_id = 0usize;
        let n_ops = g.usize_in(5..=40);
        for _ in 0..n_ops {
            if g.bool() || engine.is_empty() {
                let t = engine.now_ms() + g.usize_in(0..=3) as f64;
                engine.schedule(t, next_id);
                pending.push((t, next_id));
                next_id += 1;
            } else {
                let ev = engine.next().expect("non-empty engine pops");
                if ev.payload != model_pop(&mut pending) {
                    return false;
                }
            }
        }
        while let Some(ev) = engine.next() {
            if ev.payload != model_pop(&mut pending) {
                return false;
            }
        }
        pending.is_empty()
    });
}

#[test]
fn resource_occupy_completions_are_monotone() {
    forall("resource monotone completions", 80, |g| {
        let mut r = Resource::default();
        let mut last = 0.0f64;
        let n = g.usize_in(1..=30);
        for _ in 0..n {
            let earliest = g.f64_in(0.0, 100.0);
            let dur = g.f64_in(0.0, 10.0);
            let done = r.occupy(earliest, dur);
            // A serially shared resource can only finish later and never
            // before the request could physically complete.
            if done < last || done < earliest + dur - 1e-9 {
                return false;
            }
            last = done;
        }
        true
    });
}

#[test]
fn resource_busy_ms_is_the_sum_of_occupied_durations() {
    forall("resource busy accounting", 80, |g| {
        let mut r = Resource::default();
        let mut total = 0.0f64;
        let n = g.usize_in(0..=30);
        for _ in 0..n {
            let dur = g.f64_in(0.0, 25.0);
            r.occupy(g.f64_in(0.0, 50.0), dur);
            total += dur;
        }
        (r.busy_ms() - total).abs() <= 1e-9 * total.max(1.0)
    });
}

#[test]
fn features_are_bounded_for_any_fleet() {
    forall("feature ranges", 60, |g| {
        let n = g.usize_in(1..=40);
        let fleet = Fleet::random(n, g.usize_in(0..=1_000_000) as u64);
        let graph = ClusterGraph::from_fleet(&fleet);
        let feats = node_features(&fleet.machines, &graph, 64);
        feats.len() == 64 * FEATURE_DIM
            && feats.iter().all(|&v| (0.0..=2.0).contains(&v))
    });
}

#[test]
fn padded_adjacency_keeps_symmetry() {
    forall("padding symmetry", 60, |g| {
        let n = g.usize_in(1..=40);
        let fleet = Fleet::random(n, g.usize_in(0..=1_000_000) as u64);
        let graph = ClusterGraph::from_fleet(&fleet);
        let adj = graph.padded_adj(64);
        for i in 0..64 {
            for j in 0..64 {
                if (adj[i * 64 + j] - adj[j * 64 + i]).abs() > 1e-6 {
                    return false;
                }
            }
            if adj[i * 64 + i] != 0.0 {
                return false;
            }
        }
        true
    });
}
