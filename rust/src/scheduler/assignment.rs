//! Task assignment: which machines train which model (paper Table 2).

use crate::cluster::Fleet;
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::util::table::Table;

/// Machines per task. `groups[t]` are the machine ids assigned to task
/// `t`; machines in no group are spares (available for recovery).
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub groups: Vec<Vec<usize>>,
}

impl Assignment {
    pub fn new(groups: Vec<Vec<usize>>) -> Assignment {
        Assignment { groups }
    }

    pub fn n_tasks(&self) -> usize {
        self.groups.len()
    }

    pub fn group(&self, task: usize) -> &[usize] {
        &self.groups[task]
    }

    /// Machine → task lookup (`None` = spare).
    pub fn task_of(&self, machine: usize) -> Option<usize> {
        self.groups
            .iter()
            .position(|g| g.contains(&machine))
    }

    /// Ids not assigned to any task.
    pub fn spares(&self, n_machines: usize) -> Vec<usize> {
        (0..n_machines)
            .filter(|&m| self.task_of(m).is_none())
            .collect()
    }

    /// Groups must be disjoint and ids in range.
    pub fn validate_disjoint(&self, n_machines: usize) -> Result<(), String> {
        let mut seen = vec![false; n_machines];
        for (t, group) in self.groups.iter().enumerate() {
            for &m in group {
                if m >= n_machines {
                    return Err(format!("task {t}: machine {m} out of range"));
                }
                if seen[m] {
                    return Err(format!("machine {m} assigned twice"));
                }
                seen[m] = true;
            }
        }
        Ok(())
    }

    /// Memory feasibility: each group's total memory covers its model's
    /// training footprint.
    pub fn validate_memory(&self, fleet: &Fleet, tasks: &[ModelSpec])
        -> Result<(), String>
    {
        assert_eq!(self.groups.len(), tasks.len());
        for (t, group) in self.groups.iter().enumerate() {
            let mem: f64 = group
                .iter()
                .map(|&m| fleet.machines[m].total_memory_gb())
                .sum();
            if mem < tasks[t].train_gb() {
                return Err(format!(
                    "task {} ({}) has {:.0} GB < required {:.0} GB",
                    t, tasks[t].name, mem, tasks[t].train_gb()
                ));
            }
        }
        Ok(())
    }

    /// Every group's induced subgraph must be connected (a pipeline must
    /// be able to traverse it).
    pub fn validate_connected(&self, graph: &ClusterGraph)
        -> Result<(), String>
    {
        for (t, group) in self.groups.iter().enumerate() {
            if !graph.subset_connected(group) {
                return Err(format!("task {t} group is disconnected"));
            }
        }
        Ok(())
    }

    /// Total intra-group communication cost (the objective Hulk
    /// minimizes).
    pub fn total_cost(&self, graph: &ClusterGraph) -> f64 {
        self.groups.iter().map(|g| graph.subset_cost(g)).sum()
    }

    /// Paper Table 2 rendering: model → node list.
    pub fn render_table(&self, tasks: &[ModelSpec]) -> String {
        let mut t = Table::new(&["Model", "Nodes"]);
        for (i, task) in tasks.iter().enumerate() {
            let mut nodes = self.groups[i].clone();
            nodes.sort_unstable();
            let list = nodes
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join(", ");
            t.row(&[task.name.to_string(), list]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fleet;

    #[test]
    fn disjointness_checked() {
        let a = Assignment::new(vec![vec![0, 1], vec![2]]);
        assert!(a.validate_disjoint(3).is_ok());
        let b = Assignment::new(vec![vec![0, 1], vec![1]]);
        assert!(b.validate_disjoint(3).is_err());
        let c = Assignment::new(vec![vec![5]]);
        assert!(c.validate_disjoint(3).is_err());
    }

    #[test]
    fn task_lookup_and_spares() {
        let a = Assignment::new(vec![vec![0, 2], vec![3]]);
        assert_eq!(a.task_of(2), Some(0));
        assert_eq!(a.task_of(3), Some(1));
        assert_eq!(a.task_of(1), None);
        assert_eq!(a.spares(5), vec![1, 4]);
    }

    #[test]
    fn memory_validation_flags_small_groups() {
        let fleet = Fleet::paper_toy(0);
        let tasks = vec![ModelSpec::opt_175b()];
        // All 8 toy machines ≈ 1.7 TB < 2.8 TB required.
        let all = Assignment::new(vec![(0..8).collect()]);
        assert!(all.validate_memory(&fleet, &tasks).is_err());
        let bert = vec![ModelSpec::bert_large()];
        let one = Assignment::new(vec![vec![2]]);
        assert!(one.validate_memory(&fleet, &bert).is_ok());
    }

    #[test]
    fn connectivity_validation() {
        let g = ClusterGraph {
            n: 3,
            adj: vec![0.0, 5.0, 0.0, 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        };
        assert!(Assignment::new(vec![vec![0, 1]])
            .validate_connected(&g)
            .is_ok());
        assert!(Assignment::new(vec![vec![0, 2]])
            .validate_connected(&g)
            .is_err());
    }

    #[test]
    fn table_rendering_contains_all_models() {
        let a = Assignment::new(vec![vec![1, 0], vec![2]]);
        let tasks = vec![ModelSpec::gpt2_xl(), ModelSpec::bert_large()];
        let out = a.render_table(&tasks);
        assert!(out.contains("GPT-2"));
        assert!(out.contains("BERT-large"));
        assert!(out.contains("0, 1")); // sorted node list
    }
}
