//! Task assignment: the paper's core scheduling contribution.
//!
//! - [`assignment`] — the assignment type + feasibility validation.
//! - [`oracle`] — communication-aware partitioner (greedy seed + local
//!   search). Plays two roles: the labeling oracle for GCN training data
//!   (the paper's "sparsely label this subgraph"), and the strongest
//!   non-learned baseline for ablations.
//! - [`algorithm1`] — the paper's Algorithm 1 ("Task Assignments")
//!   driving a pluggable splitter `F` (GNN or oracle).

pub mod algorithm1;
pub mod assignment;
pub mod oracle;

pub use algorithm1::{algorithm1, algorithm1_pool, Algorithm1Error,
                     TaskSplitter};
pub use assignment::Assignment;
pub use oracle::{oracle_partition, OracleOptions};
