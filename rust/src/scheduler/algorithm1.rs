//! The paper's Algorithm 1: "Task Assignments".
//!
//! ```text
//! Require: Graph Data G₁, Trained GNN F, Number of Tasks N,
//!          Minimum Memory Threshold Mₙ per task
//! 1: C ← 0
//! 2: if G₁ does not meet the requirements of all tasks: error
//! 5: for i in 1..N:
//! 6:   Gᵢ, Gᵢ₊₁ ← F(Gᵢ)            # split off task i's group
//! 7:   assign the smaller graph Gᵢ to a task with appropriate Mₙ
//! 8:   if Gᵢ insufficient: C ← i and continue (merge carry later)
//! 16:  if Gᵢ₊₁ insufficient for the remaining tasks:
//! 17:    break; wait for other tasks to complete
//! ```
//!
//! `F` is pluggable ([`TaskSplitter`]): the trained GCN
//! (`gnn::inference`) in the full system, the oracle in ablations.

use crate::cluster::Fleet;
use crate::graph::GraphView;
use crate::models::ModelSpec;

use super::assignment::Assignment;

/// The trained network `F` of Algorithm 1: given the remaining machine
/// pool, split off the group for `task` (class index `class_idx`). The
/// graph is any [`GraphView`] — dense oracle, direct CSR, or a
/// hierarchical refinement subset.
pub trait TaskSplitter {
    /// Returns machine ids (⊆ `remaining`) proposed for `task`.
    fn split(&self, fleet: &Fleet, graph: &dyn GraphView,
             remaining: &[usize], task: &ModelSpec, class_idx: usize)
        -> Vec<usize>;
}

/// Algorithm 1 failure modes (paper lines 3 and 17).
#[derive(Clone, Debug, PartialEq)]
pub enum Algorithm1Error {
    /// Line 3: the whole graph cannot satisfy all tasks at once.
    InsufficientResources { required_gb: f64, available_gb: f64 },
    /// Line 17: some tasks must wait for others to complete. Carries the
    /// partial assignment and the indices of deferred tasks.
    MustWait { partial: Assignment, deferred: Vec<usize> },
}

impl std::fmt::Display for Algorithm1Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Algorithm1Error::InsufficientResources { required_gb,
                                                     available_gb } => {
                write!(f, "graph does not meet task requirements: \
                           need {required_gb:.0} GB, have {available_gb:.0} GB")
            }
            Algorithm1Error::MustWait { deferred, .. } => {
                write!(f, "tasks {deferred:?} must wait for others to \
                           complete")
            }
        }
    }
}

/// Memory a group must reach for a task (the task's Mₙ).
fn group_gb(fleet: &Fleet, group: &[usize]) -> f64 {
    group.iter().map(|&i| fleet.machines[i].total_memory_gb()).sum()
}

/// Run Algorithm 1 over the whole fleet. Tasks are processed in the
/// order given (the paper feeds them largest-first; the Hulk planner's
/// `PlanContext` contract guarantees the sorting).
pub fn algorithm1(fleet: &Fleet, graph: &dyn GraphView,
                  tasks: &[ModelSpec], splitter: &dyn TaskSplitter)
    -> Result<Assignment, Algorithm1Error>
{
    let pool: Vec<usize> = (0..fleet.len()).collect();
    algorithm1_pool(fleet, graph, tasks, splitter, &pool)
}

/// [`algorithm1`] restricted to an initial machine pool — the seam the
/// live-fleet serve path uses to keep failed machines out of every
/// split. With the full pool `0..fleet.len()` the behavior (including
/// the f64 summation order of the line-2 feasibility check) is
/// byte-identical to the historical whole-fleet entry point.
pub fn algorithm1_pool(fleet: &Fleet, graph: &dyn GraphView,
                       tasks: &[ModelSpec], splitter: &dyn TaskSplitter,
                       pool: &[usize])
    -> Result<Assignment, Algorithm1Error>
{
    // Line 2: global feasibility over the pool.
    let required: f64 = tasks.iter().map(|t| t.train_gb()).sum();
    let available = group_gb(fleet, pool);
    if available < required {
        return Err(Algorithm1Error::InsufficientResources {
            required_gb: required,
            available_gb: available,
        });
    }

    // Membership is tracked in a fixed-size bitset keyed by machine id
    // (ids are dense 0..n by `Fleet::new`'s contract), so every
    // per-member check is O(1) instead of an O(n) scan — the difference
    // between O(n·tasks) and O(n²·tasks) on 200+-server fleets. The
    // ordered `remaining` list is kept in sync for the splitter API and
    // preserves exactly the iteration order the scan-based version had.
    let n = fleet.len();
    let mut in_pool = vec![false; n];
    for &m in pool {
        in_pool[m] = true;
    }
    let mut remaining: Vec<usize> = pool.to_vec();
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); tasks.len()];
    let mut carry: Vec<usize> = Vec::new(); // the C of Algorithm 1
    let mut deferred: Vec<usize> = Vec::new();
    let mut in_group = vec![false; n]; // scratch, cleared after each task

    for (i, task) in tasks.iter().enumerate() {
        // Line 6: split off G_i via F.
        let mut g_i = splitter.split(fleet, graph, &remaining, task, i);
        g_i.retain(|&m| {
            let keep = m < n && in_pool[m] && !in_group[m];
            if keep {
                in_group[m] = true;
            }
            keep
        });

        // Line 10–13: merge the carry-over set into G_i.
        for m in carry.drain(..) {
            if in_pool[m] && !in_group[m] {
                in_group[m] = true;
                g_i.push(m);
            }
        }

        // Line 7–9: assign if the memory threshold Mₙ is met.
        if group_gb(fleet, &g_i) >= task.train_gb() {
            for &m in &g_i {
                in_pool[m] = false;
            }
            remaining.retain(|&m| in_pool[m]);
            for &m in &g_i {
                in_group[m] = false;
            }
            g_i.sort_unstable();
            groups[i] = g_i;
        } else {
            // Line 9: C ← i; the insufficient split carries forward.
            for &m in &g_i {
                in_group[m] = false;
            }
            carry = g_i;
            deferred.push(i);
            continue;
        }

        // Line 16–18: can the remainder still host the remaining tasks?
        let rest_required: f64 =
            tasks[i + 1..].iter().map(|t| t.train_gb()).sum();
        if rest_required > 0.0
            && group_gb(fleet, &remaining) < rest_required
        {
            deferred.extend(i + 1..tasks.len());
            return Err(Algorithm1Error::MustWait {
                partial: Assignment::new(groups),
                deferred,
            });
        }
    }

    if !deferred.is_empty() {
        return Err(Algorithm1Error::MustWait {
            partial: Assignment::new(groups),
            deferred,
        });
    }
    Ok(Assignment::new(groups))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ClusterGraph;

    /// The pre-bitset implementation (O(n²) `contains` scans), kept
    /// verbatim as the behavioral reference: the bitset rewrite must
    /// produce byte-for-byte identical assignments.
    fn algorithm1_reference(fleet: &Fleet, graph: &dyn GraphView,
                            tasks: &[ModelSpec], splitter: &dyn TaskSplitter)
        -> Result<Assignment, Algorithm1Error>
    {
        let required: f64 = tasks.iter().map(|t| t.train_gb()).sum();
        let available = fleet.total_memory_gb();
        if available < required {
            return Err(Algorithm1Error::InsufficientResources {
                required_gb: required,
                available_gb: available,
            });
        }
        let mut remaining: Vec<usize> = (0..fleet.len()).collect();
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); tasks.len()];
        let mut carry: Vec<usize> = Vec::new();
        let mut deferred: Vec<usize> = Vec::new();
        for (i, task) in tasks.iter().enumerate() {
            let mut g_i = splitter.split(fleet, graph, &remaining, task, i);
            g_i.retain(|m| remaining.contains(m));
            if !carry.is_empty() {
                for m in carry.drain(..) {
                    if remaining.contains(&m) && !g_i.contains(&m) {
                        g_i.push(m);
                    }
                }
            }
            if group_gb(fleet, &g_i) >= task.train_gb() {
                remaining.retain(|m| !g_i.contains(m));
                g_i.sort_unstable();
                groups[i] = g_i;
            } else {
                carry = g_i;
                deferred.push(i);
                continue;
            }
            let rest_required: f64 =
                tasks[i + 1..].iter().map(|t| t.train_gb()).sum();
            if rest_required > 0.0
                && group_gb(fleet, &remaining) < rest_required
            {
                deferred.extend(i + 1..tasks.len());
                return Err(Algorithm1Error::MustWait {
                    partial: Assignment::new(groups),
                    deferred,
                });
            }
        }
        if !deferred.is_empty() {
            return Err(Algorithm1Error::MustWait {
                partial: Assignment::new(groups),
                deferred,
            });
        }
        Ok(Assignment::new(groups))
    }

    /// Splitter backed by the oracle (tests don't need artifacts).
    struct OracleSplitter;

    impl TaskSplitter for OracleSplitter {
        fn split(&self, fleet: &Fleet, graph: &dyn GraphView,
                 remaining: &[usize], task: &ModelSpec, _class: usize)
            -> Vec<usize>
        {
            crate::scheduler::oracle::grow_group(&fleet.machines, graph,
                                                 remaining, task, 1.3)
        }
    }

    #[test]
    fn assigns_paper_workload() {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = ModelSpec::paper_four();
        let a = algorithm1(&fleet, &graph, &tasks, &OracleSplitter)
            .expect("should assign");
        a.validate_disjoint(fleet.len()).unwrap();
        a.validate_memory(&fleet, &tasks).unwrap();
    }

    #[test]
    fn line3_error_when_fleet_too_small() {
        let fleet = Fleet::paper_toy(0); // ≈1.7 TB total
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = vec![ModelSpec::opt_175b()]; // 2.8 TB
        match algorithm1(&fleet, &graph, &tasks, &OracleSplitter) {
            Err(Algorithm1Error::InsufficientResources { required_gb,
                                                         available_gb }) => {
                assert!(required_gb > available_gb);
            }
            other => panic!("expected InsufficientResources, got {other:?}"),
        }
    }

    /// A splitter that always returns too-small groups: exercises the
    /// carry-set (C) path.
    struct StingySplitter;

    impl TaskSplitter for StingySplitter {
        fn split(&self, _f: &Fleet, _g: &dyn GraphView, remaining: &[usize],
                 _t: &ModelSpec, _c: usize) -> Vec<usize>
        {
            remaining.iter().copied().take(1).collect()
        }
    }

    #[test]
    fn carry_set_merges_across_iterations() {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        // Two tasks needing ~2 machines each; the stingy splitter gives 1
        // at a time, so the carry path must fire and eventually satisfy.
        let tasks = vec![ModelSpec::t5_11b(), ModelSpec::t5_11b()];
        match algorithm1(&fleet, &graph, &tasks, &StingySplitter) {
            Ok(a) => {
                a.validate_disjoint(fleet.len()).unwrap();
            }
            Err(Algorithm1Error::MustWait { partial, deferred }) => {
                // Acceptable per the paper (line 17) — but the carry must
                // have accumulated at least one group.
                assert!(partial.groups.iter().any(|g| !g.is_empty())
                        || !deferred.is_empty());
            }
            Err(e) => panic!("unexpected {e:?}"),
        }
    }

    #[test]
    fn bitset_matches_reference_on_existing_fleets() {
        // The hot-path rewrite must not change a single assignment:
        // compare against the scan-based reference on the paper fleet,
        // a truncated fleet, and a planet-scale synthetic fleet, with
        // both a well-behaved and a pathological splitter.
        let workloads = [ModelSpec::paper_four(), ModelSpec::paper_six()];
        let fleets = [
            Fleet::paper_evaluation(0),
            Fleet::paper_evaluation(7),
            Fleet::synthetic(200, 12, 0),
        ];
        for fleet in &fleets {
            let graph = ClusterGraph::from_fleet(fleet);
            for tasks in &workloads {
                for splitter in
                    [&OracleSplitter as &dyn TaskSplitter, &StingySplitter]
                {
                    let fast = algorithm1(fleet, &graph, tasks, splitter);
                    let slow =
                        algorithm1_reference(fleet, &graph, tasks, splitter);
                    assert_eq!(fast, slow, "divergence on {} servers",
                               fleet.len());
                }
            }
        }
    }

    #[test]
    fn full_pool_matches_whole_fleet_entry_point() {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = ModelSpec::paper_four();
        let pool: Vec<usize> = (0..fleet.len()).collect();
        assert_eq!(algorithm1(&fleet, &graph, &tasks, &OracleSplitter),
                   algorithm1_pool(&fleet, &graph, &tasks, &OracleSplitter,
                                   &pool));
    }

    #[test]
    fn restricted_pool_never_assigns_excluded_machines() {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = vec![ModelSpec::t5_11b(), ModelSpec::gpt2_xl()];
        // Exclude machines 0..5 (a "failed" slice of the fleet).
        let pool: Vec<usize> = (5..fleet.len()).collect();
        let a = algorithm1_pool(&fleet, &graph, &tasks, &OracleSplitter,
                                &pool)
            .expect("46-machine fleet minus 5 still plans two mid tasks");
        for g in &a.groups {
            assert!(!g.is_empty());
            assert!(g.iter().all(|&m| m >= 5),
                    "excluded machine assigned: {g:?}");
        }
        // Even a splitter that proposes excluded ids gets them filtered.
        struct DefiantSplitter;
        impl TaskSplitter for DefiantSplitter {
            fn split(&self, _f: &Fleet, _g: &dyn GraphView,
                     remaining: &[usize], _t: &ModelSpec, _c: usize)
                -> Vec<usize>
            {
                let mut v = vec![0, 1, 2]; // outside the pool
                v.extend(remaining.iter().copied().take(12));
                v
            }
        }
        if let Ok(a) = algorithm1_pool(&fleet, &graph, &tasks,
                                       &DefiantSplitter, &pool)
        {
            for g in &a.groups {
                assert!(g.iter().all(|&m| m >= 5), "pool breached: {g:?}");
            }
        }
    }

    #[test]
    fn must_wait_reports_deferred_tasks() {
        let fleet = Fleet::paper_toy(0); // small fleet
        let graph = ClusterGraph::from_fleet(&fleet);
        // Many mid-size tasks: total fits line 2 but per-task splits run
        // dry.
        let tasks = vec![
            ModelSpec::gpt2_xl(),
            ModelSpec::gpt2_xl(),
            ModelSpec::gpt2_xl(),
            ModelSpec::gpt2_xl(),
            ModelSpec::gpt2_xl(),
            ModelSpec::gpt2_xl(),
            ModelSpec::gpt2_xl(),
            ModelSpec::gpt2_xl(),
            ModelSpec::gpt2_xl(),
        ];
        match algorithm1(&fleet, &graph, &tasks, &OracleSplitter) {
            Err(Algorithm1Error::InsufficientResources { .. }) => {}
            Err(Algorithm1Error::MustWait { deferred, .. }) => {
                assert!(!deferred.is_empty());
            }
            Ok(a) => {
                // If it fits, it must be valid.
                a.validate_disjoint(fleet.len()).unwrap();
            }
        }
    }
}
