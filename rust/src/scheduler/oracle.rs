//! Communication-aware partitioning oracle.
//!
//! Two roles (DESIGN.md §Substitutions):
//! 1. **Labeling oracle** for GCN training data — the paper "sparsely
//!    labels the subgraph" by hand; this partitioner plays the human.
//! 2. **Ablation baseline**: Hulk-with-oracle vs Hulk-with-GNN separates
//!    the value of the learned model from the value of the grouping
//!    policy.
//!
//! Method: group sizes from memory floors + a log-parameter share (the
//! paper sizes groups "according to this scale" of parameter ratios,
//! §5.1), greedy growth minimizing added intra-group latency, then
//! swap-based local search to a fixed point.

use crate::cluster::{Fleet, Machine};
use crate::graph::{ClusterGraph, GraphView};
use crate::models::ModelSpec;

use super::assignment::Assignment;

/// Oracle tuning knobs.
#[derive(Clone, Debug)]
pub struct OracleOptions {
    /// Local-search sweep limit (each sweep is O(n² · groups)).
    pub max_sweeps: usize,
    /// Memory headroom factor over the model's training footprint.
    pub memory_headroom: f64,
}

impl Default for OracleOptions {
    fn default() -> Self {
        OracleOptions { max_sweeps: 8, memory_headroom: 1.2 }
    }
}

/// Group-size targets: memory floor ∨ log-parameter share of the fleet.
fn target_sizes(fleet: &Fleet, tasks: &[ModelSpec], headroom: f64)
    -> Vec<usize>
{
    let n = fleet.len();
    let avg_mem =
        fleet.total_memory_gb() / n as f64;
    let weights: Vec<f64> = tasks
        .iter()
        .map(|t| (t.params.log10() - 7.0).max(0.5)) // 10M → 0.5, 175B → 4.2
        .collect();
    let wsum: f64 = weights.iter().sum();
    tasks
        .iter()
        .zip(&weights)
        .map(|(t, w)| {
            let mem_floor =
                (t.train_gb() * headroom / avg_mem).ceil() as usize;
            let share = ((w / wsum) * n as f64).round() as usize;
            mem_floor.max(share).max(1).min(t.layers).min(n)
        })
        .collect()
}

/// Grow one task group from a restricted machine pool: seed on the best
/// (memory × locality) machine, then add the reachable machine with the
/// least added intra-group latency until the task's memory threshold (with
/// headroom) is cleared. This is the "smaller graph Gᵢ" a splitter hands
/// Algorithm 1 — it deliberately does NOT grab the whole pool.
///
/// Takes machines + any [`GraphView`] (dense oracle, direct CSR, or a
/// hierarchical refinement subset) — pool indices address `machines` and
/// the graph's node space, which must agree.
pub fn grow_group(machines: &[Machine], graph: &dyn GraphView,
                  pool: &[usize], task: &ModelSpec, headroom: f64)
    -> Vec<usize>
{
    if pool.is_empty() {
        return Vec::new();
    }
    let seed = *pool
        .iter()
        .max_by(|&&a, &&b| {
            let score = |i: usize| {
                let mem = machines[i].total_memory_gb();
                let loc = graph.mean_latency(i).unwrap_or(1e4) as f64;
                mem / loc.max(1.0)
            };
            score(a).partial_cmp(&score(b)).unwrap()
        })
        .unwrap();
    let mut group = vec![seed];
    let mut mem = machines[seed].total_memory_gb();
    while mem < task.train_gb() * headroom || group.len() < 2 {
        let next = pool
            .iter()
            .copied()
            .filter(|m| !group.contains(m))
            .filter(|&m| group.iter().any(|&j| graph.has_edge(m, j)))
            .min_by(|&a, &b| {
                let cost = |i: usize| -> f64 {
                    group
                        .iter()
                        .map(|&j| {
                            let w = graph.weight(i, j);
                            if w > 0.0 { w as f64 } else { 2e3 }
                        })
                        .sum()
                };
                cost(a).partial_cmp(&cost(b)).unwrap()
            });
        match next {
            Some(m) => {
                mem += machines[m].total_memory_gb();
                group.push(m);
            }
            None => break,
        }
    }
    group.sort_unstable();
    group
}

/// Partition `fleet` for `tasks` (largest model first is conventional but
/// not required). Machines left over become spares.
pub fn oracle_partition(fleet: &Fleet, graph: &ClusterGraph,
                        tasks: &[ModelSpec], opts: &OracleOptions)
    -> Assignment
{
    let n = fleet.len();
    let mut sizes = target_sizes(fleet, tasks, opts.memory_headroom);
    // Shrink proportionally if oversubscribed.
    let total: usize = sizes.iter().sum();
    if total > n {
        // Largest models keep their memory floors; shave the rest.
        let mut excess = total - n;
        for s in sizes.iter_mut().rev() {
            while excess > 0 && *s > 1 {
                *s -= 1;
                excess -= 1;
            }
        }
    }

    let mut assigned: Vec<Option<usize>> = vec![None; n];
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); tasks.len()];

    // Assign tasks in descending parameter order (Algorithm 1 iterates
    // largest-first so the big model gets the pick of the fleet).
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    // Same total, tie-stable comparator as ModelSpec::sort_largest_first
    // (params descending via total_cmp, name ascending) — no NaN panic,
    // and tied-params models order identically to the Hulk path.
    order.sort_by(|&a, &b| {
        tasks[b]
            .params
            .total_cmp(&tasks[a].params)
            .then_with(|| tasks[a].name.cmp(tasks[b].name))
    });

    for &t in &order {
        // Seed: unassigned machine with the best (memory × locality).
        let seed = (0..n)
            .filter(|&i| assigned[i].is_none())
            .max_by(|&a, &b| {
                let score = |i: usize| {
                    let mem = fleet.machines[i].total_memory_gb();
                    let loc = graph.mean_latency(i).unwrap_or(1e4) as f64;
                    mem / loc.max(1.0)
                };
                score(a).partial_cmp(&score(b)).unwrap()
            });
        let Some(seed) = seed else { break };
        assigned[seed] = Some(t);
        groups[t].push(seed);

        // Grow to the target size (and to memory feasibility), always
        // adding the reachable machine with the least added latency.
        loop {
            let mem: f64 = groups[t]
                .iter()
                .map(|&i| fleet.machines[i].total_memory_gb())
                .sum();
            let need_more_mem =
                mem < tasks[t].train_gb() * opts.memory_headroom;
            if groups[t].len() >= sizes[t] && !need_more_mem {
                break;
            }
            let cand = (0..n)
                .filter(|&i| assigned[i].is_none())
                .filter(|&i| {
                    groups[t].iter().any(|&j| graph.has_edge(i, j))
                })
                .min_by(|&a, &b| {
                    let cost = |i: usize| -> f64 {
                        groups[t]
                            .iter()
                            .map(|&j| {
                                let w = graph.weight(i, j);
                                if w > 0.0 { w as f64 } else { 2e3 }
                            })
                            .sum()
                    };
                    cost(a).partial_cmp(&cost(b)).unwrap()
                });
            match cand {
                Some(i) => {
                    assigned[i] = Some(t);
                    groups[t].push(i);
                }
                None => break, // nothing reachable left
            }
        }
    }

    // Local search: single-machine swaps between groups that reduce total
    // intra-group cost while keeping both groups memory-feasible.
    let feasible = |g: &[usize], t: usize| -> bool {
        let mem: f64 =
            g.iter().map(|&i| fleet.machines[i].total_memory_gb()).sum();
        mem >= tasks[t].train_gb() && graph.subset_connected(g)
    };
    for _ in 0..opts.max_sweeps {
        let mut improved = false;
        for ta in 0..groups.len() {
            for tb in (ta + 1)..groups.len() {
                for ia in 0..groups[ta].len() {
                    for ib in 0..groups[tb].len() {
                        let a = groups[ta][ia];
                        let b = groups[tb][ib];
                        let before = graph.subset_cost(&groups[ta])
                            + graph.subset_cost(&groups[tb]);
                        let mut ga = groups[ta].clone();
                        let mut gb = groups[tb].clone();
                        ga[ia] = b;
                        gb[ib] = a;
                        let after = graph.subset_cost(&ga)
                            + graph.subset_cost(&gb);
                        if after + 1e-9 < before
                            && feasible(&ga, ta)
                            && feasible(&gb, tb)
                        {
                            groups[ta] = ga;
                            groups[tb] = gb;
                            improved = true;
                        }
                    }
                }
            }
        }
        if !improved {
            break;
        }
    }

    for g in &mut groups {
        g.sort_unstable();
    }
    Assignment::new(groups)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval_setup() -> (Fleet, ClusterGraph) {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        (fleet, graph)
    }

    #[test]
    fn partitions_paper_workload_feasibly() {
        let (fleet, graph) = eval_setup();
        let tasks = ModelSpec::paper_four();
        let a = oracle_partition(&fleet, &graph, &tasks,
                                 &OracleOptions::default());
        a.validate_disjoint(fleet.len()).unwrap();
        a.validate_memory(&fleet, &tasks).unwrap();
        a.validate_connected(&graph).unwrap();
        // Every task got a non-empty group.
        for g in &a.groups {
            assert!(!g.is_empty());
        }
    }

    #[test]
    fn opt_gets_the_largest_group() {
        let (fleet, graph) = eval_setup();
        let tasks = ModelSpec::paper_four();
        let a = oracle_partition(&fleet, &graph, &tasks,
                                 &OracleOptions::default());
        let sizes: Vec<usize> = a.groups.iter().map(Vec::len).collect();
        assert!(sizes[0] >= *sizes.iter().max().unwrap() - 1,
                "OPT group should be (near-)largest: {sizes:?}");
        assert!(sizes[0] >= 8, "OPT needs many machines: {sizes:?}");
    }

    #[test]
    fn grouping_beats_random_on_comm_cost() {
        let (fleet, graph) = eval_setup();
        let tasks = ModelSpec::paper_four();
        let a = oracle_partition(&fleet, &graph, &tasks,
                                 &OracleOptions::default());
        // Random assignment with the same group sizes.
        let mut rng = crate::util::rng::Rng::new(1);
        let mut ids: Vec<usize> = (0..fleet.len()).collect();
        rng.shuffle(&mut ids);
        let mut off = 0;
        let mut rand_groups = Vec::new();
        for g in &a.groups {
            rand_groups.push(ids[off..off + g.len()].to_vec());
            off += g.len();
        }
        let rand = Assignment::new(rand_groups);
        assert!(a.total_cost(&graph) < rand.total_cost(&graph),
                "oracle {} vs random {}", a.total_cost(&graph),
                rand.total_cost(&graph));
    }

    #[test]
    fn two_task_toy_split_is_disjoint_and_sized() {
        // Fig. 5 scenario: GPT-2 vs BERT-large on the 8-node toy graph.
        let fleet = Fleet::paper_toy(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = vec![ModelSpec::gpt2_xl(), ModelSpec::bert_large()];
        let a = oracle_partition(&fleet, &graph, &tasks,
                                 &OracleOptions::default());
        a.validate_disjoint(8).unwrap();
        a.validate_memory(&fleet, &tasks).unwrap();
        assert!(a.groups[0].len() >= a.groups[1].len(),
                "GPT-2 (4.4× params) should get at least as many machines");
    }

    #[test]
    fn deterministic() {
        let (fleet, graph) = eval_setup();
        let tasks = ModelSpec::paper_four();
        let a = oracle_partition(&fleet, &graph, &tasks,
                                 &OracleOptions::default());
        let b = oracle_partition(&fleet, &graph, &tasks,
                                 &OracleOptions::default());
        assert_eq!(a, b);
    }
}
