//! # Hulk — GNN-driven scheduling for regionally distributed training
//!
//! Reproduction of *"Hulk: Graph Neural Networks for Optimizing Regionally
//! Distributed Computing Systems"* (Yuan et al., 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the coordinator: cluster/WAN modelling, the
//!   labeling oracle, the paper's Algorithm 1 task assignment, the
//!   [`planner`] seam (baseline Systems A/B/C, Hulk and its ablations as
//!   `Planner` implementations behind a typed `Placement` IR), a
//!   discrete-event execution simulator, disaster recovery, the
//!   multi-task leader loop and the [`serve`] placement-as-a-service
//!   daemon. The GCN is *trained and served from Rust* through PJRT.
//! - **Layer 2 (python/compile/model.py, build-time only)** — the Hulk GCN
//!   (edge pooling + GCN stack + masked softmax head), AOT-lowered to HLO
//!   text artifacts.
//! - **Layer 1 (python/compile/kernels/, build-time only)** — Pallas
//!   kernels for the hot ops, verified against a pure-jnp oracle.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once; the `hulk` binary is self-contained afterwards.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! `EXPERIMENTS.md` for paper-vs-measured results, and
//! [`scenarios`] for the named-scenario registry behind
//! `hulk scenarios run all --json`.

pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod gnn;
pub mod graph;
pub mod models;
pub mod parallel;
pub mod planner;
pub mod prop;
pub mod runtime;
pub mod scenarios;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod systems;
pub mod util;
