//! The multi-task coordinator: the long-running leader that owns the
//! fleet state, admits training tasks, reacts to machine failures
//! (disaster recovery, §1) and scale-out/in events (Fig. 6), and keeps
//! per-task metrics.
//!
//! - [`tasks`] — task specs, queue and lifecycle states.
//! - [`metrics`] — counters/timers the leader exports.
//! - [`recovery`] — failure handling: spare promotion or group re-plan.
//! - [`scale`] — add/remove machines with incremental re-assignment.
//! - [`leader`] — the event loop (std threads + channels; tokio is not in
//!   the offline registry — DESIGN.md §Substitutions).

pub mod checkpoint;
pub mod leader;
pub mod metrics;
pub mod recovery;
pub mod scale;
pub mod tasks;

pub use checkpoint::{load_checkpoint, parse_checkpoint, render_checkpoint, save_checkpoint};
pub use leader::{Coordinator, CoordinatorEvent, CoordinatorReply};
pub use metrics::{Histogram, Metrics, ShardedMetrics, SharedMetrics,
                  SloReport, SloWindow};
pub use recovery::{recover, RecoveryAction};
pub use scale::{scale_in, scale_out};
pub use tasks::{TaskState, TrainingTask};
