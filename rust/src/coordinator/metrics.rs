//! Leader metrics: counters, gauges and latency histograms exported by
//! the coordinator (printed by `hulk simulate`) and by the `hulk serve`
//! daemon, whose `Stats` reply renders [`Metrics::to_json`] over the
//! wire. [`SharedMetrics`] is the thread-safe handle the daemon's
//! connection workers share; [`ShardedMetrics`] splits the serve hot
//! path across per-shard instances so a `place` observation never
//! takes a daemon-global lock — the shards are merged
//! ([`Metrics::merge`]) only when a `Stats` request asks for them.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Log-bucketed latency histogram: bounded memory (fixed bucket count),
/// mergeable, with quantiles interpolated inside the winning bucket.
/// Bucket `i` covers `[GROWTH^i, GROWTH^(i+1))` with `GROWTH = 2^(1/4)`
/// (~19% resolution per bucket) — values are dimensionless (the serve
/// daemon feeds microseconds; batch sizes work just as well).
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

/// Per-bucket growth factor: 2^(1/4).
const GROWTH: f64 = 1.189_207_115_002_721;
/// 160 buckets cover [1, 2^40) ≈ [1 µs, ~12.7 days in µs].
const BUCKETS: usize = 160;

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    fn bucket_of(value: f64) -> usize {
        if value < 1.0 {
            return 0;
        }
        ((value.log2() * 4.0) as usize).min(BUCKETS - 1)
    }

    pub fn observe(&mut self, value: f64) {
        if !value.is_finite() {
            return;
        }
        let value = value.max(0.0);
        self.counts[Self::bucket_of(value)] += 1;
        self.count += 1;
        self.sum += value;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Quantile estimate, `q` in [0, 1]: walk buckets to the one holding
    /// the target rank, interpolate linearly inside it. Clamped to the
    /// observed min/max so tiny samples don't report bucket edges.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let target = target.max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = GROWTH.powi(i as i32);
                let hi = lo * GROWTH;
                let frac = (target - seen) as f64 / c as f64;
                let est = lo + (hi - lo) * frac;
                return est.clamp(self.min, self.max);
            }
            seen += c;
        }
        self.max
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("count", Json::Num(self.count as f64));
        obj.set("mean", Json::Num(self.mean()));
        obj.set("p50", Json::Num(self.quantile(0.50)));
        obj.set("p99", Json::Num(self.quantile(0.99)));
        obj.set("max", Json::Num(if self.count == 0 {
            0.0
        } else {
            self.max
        }));
        obj
    }
}

/// Monotone counters + gauges + histograms. BTreeMap for stable
/// rendering order.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Record one sample into the named histogram (created on first use).
    pub fn observe(&mut self, name: &str, value: f64) {
        self.histograms.entry(name.to_string()).or_default().observe(value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Machine-readable dump.
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, Json::Num(*v as f64));
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges.set(k, Json::Num(*v));
        }
        let mut histograms = Json::obj();
        for (k, h) in &self.histograms {
            histograms.set(k, h.to_json());
        }
        obj.set("counters", counters);
        obj.set("gauges", gauges);
        obj.set("histograms", histograms);
        obj
    }

    /// Fold `other` into `self`: counters add, histograms merge
    /// bucket-wise, gauges **sum** (the sharded-serve convention — a
    /// per-shard level like `cache_entries` aggregates to the daemon
    /// total; a gauge present on one side only carries over unchanged).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            *self.gauges.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Human-readable dump.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k:<32} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("{k:<32} {v:.3}\n"));
        }
        for (k, h) in &self.histograms {
            out.push_str(&format!(
                "{k:<32} n={} p50={:.1} p99={:.1}\n",
                h.count(),
                h.quantile(0.50),
                h.quantile(0.99)
            ));
        }
        out
    }
}

/// Thread-safe [`Metrics`] handle: clone freely across the serve
/// daemon's worker and batcher threads. Every method takes `&self` —
/// the mutex lives inside.
#[derive(Clone, Debug, Default)]
pub struct SharedMetrics(Arc<Mutex<Metrics>>);

impl SharedMetrics {
    pub fn new() -> SharedMetrics {
        SharedMetrics::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Metrics> {
        // A panic while holding the lock poisons it; metrics are
        // monitoring, not correctness, so keep serving the data.
        self.0.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    pub fn inc(&self, name: &str) {
        self.lock().inc(name);
    }

    pub fn add(&self, name: &str, delta: u64) {
        self.lock().add(name, delta);
    }

    pub fn set_gauge(&self, name: &str, value: f64) {
        self.lock().set_gauge(name, value);
    }

    pub fn observe(&self, name: &str, value: f64) {
        self.lock().observe(name, value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.lock().counter(name)
    }

    /// A point-in-time copy (for rendering outside the lock).
    pub fn snapshot(&self) -> Metrics {
        self.lock().clone()
    }
}

/// Hot-path metrics for the sharded serve daemon: one [`SharedMetrics`]
/// per batcher shard plus one `global` instance for connection-level
/// bookkeeping (accepts, protocol errors, admin/stats counters).
///
/// The point is lock locality, not lock-freedom: a `place` routed to
/// shard k only ever touches `shard(k)`'s mutex — contended by that
/// shard's batcher and the workers whose requests hashed there, never
/// by the other shards. The merged view ([`merged`](Self::merged)) is
/// built on demand at `Stats` time, so observing a latency sample never
/// serializes the whole worker pool the way one daemon-global
/// `SharedMetrics` did.
#[derive(Clone, Debug)]
pub struct ShardedMetrics {
    global: SharedMetrics,
    shards: Vec<SharedMetrics>,
}

impl ShardedMetrics {
    pub fn new(n_shards: usize) -> ShardedMetrics {
        assert!(n_shards >= 1, "ShardedMetrics needs >= 1 shard");
        ShardedMetrics {
            global: SharedMetrics::new(),
            shards: (0..n_shards).map(|_| SharedMetrics::new()).collect(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The daemon-global instance (connection/admin/stats counters —
    /// off the place hot path).
    pub fn global(&self) -> &SharedMetrics {
        &self.global
    }

    /// Shard `i`'s instance. Panics on an out-of-range shard index —
    /// routing bugs should be loud.
    pub fn shard(&self, i: usize) -> &SharedMetrics {
        &self.shards[i]
    }

    /// Global + every shard folded into one [`Metrics`]
    /// ([`Metrics::merge`] semantics: counters add, gauges sum,
    /// histograms merge). This is what the `Stats` reply renders, so
    /// the wire shape is unchanged from the single-batcher daemon.
    pub fn merged(&self) -> Metrics {
        let mut m = self.global.snapshot();
        for s in &self.shards {
            m.merge(&s.snapshot());
        }
        m
    }

    /// Per-shard snapshots, shard order (for the `Stats` reply's
    /// `per_shard` breakdown).
    pub fn shard_snapshots(&self) -> Vec<Metrics> {
        self.shards.iter().map(SharedMetrics::snapshot).collect()
    }
}

/// Counter names the serve SLO window reads. The daemon increments
/// these; the chaos harness diffs them across a fault window.
const SLO_REQUESTS: &str = "place_requests";
const SLO_ERRORS: &str = "place_errors";
const SLO_SHED: &str = "connections_shed";

/// A serve-plane SLO measurement window: capture a [`Metrics`] snapshot
/// when the window opens (`begin`), diff against a later snapshot
/// (`close`) and get availability / error-rate over exactly the traffic
/// that fell inside the window. Built for the chaos harness, where the
/// interesting interval is "from fault injection to recovery", not
/// "since daemon start" — a daemon that served a million healthy
/// replies before the outage must not dilute the outage's error rate.
///
/// Demand is `place_requests + connections_shed`: a connection the
/// daemon refused at the door never reaches the batcher, so it never
/// counts as a `place_requests`, but the client still experienced it —
/// shed load is unavailability, not invisibility.
#[derive(Clone, Copy, Debug)]
pub struct SloWindow {
    requests: u64,
    errors: u64,
    shed: u64,
}

impl SloWindow {
    /// Open a window at `before`'s counter values.
    pub fn begin(before: &Metrics) -> SloWindow {
        SloWindow {
            requests: before.counter(SLO_REQUESTS),
            errors: before.counter(SLO_ERRORS),
            shed: before.counter(SLO_SHED),
        }
    }

    /// Close the window against a later snapshot of the *same* daemon.
    /// Saturating diffs: a daemon restart resets counters to zero, and
    /// a window spanning the restart should report the post-restart
    /// traffic rather than wrap.
    pub fn close(&self, after: &Metrics) -> SloReport {
        let requests =
            after.counter(SLO_REQUESTS).saturating_sub(self.requests);
        let errors = after.counter(SLO_ERRORS).saturating_sub(self.errors);
        let shed = after.counter(SLO_SHED).saturating_sub(self.shed);
        SloReport { requests, errors, shed }
    }
}

/// Traffic deltas over one [`SloWindow`], with the derived SLO numbers
/// the chaos gate consumes (`serve/availability_pct`,
/// `serve/error_rate` BENCH rows).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloReport {
    /// `place` requests the batcher answered (ok or error) in-window.
    pub requests: u64,
    /// `place` requests answered with an error reply in-window.
    pub errors: u64,
    /// Connections refused at the accept queue in-window.
    pub shed: u64,
}

impl SloReport {
    /// Total demand: answered requests plus connections shed at the
    /// door.
    pub fn demand(&self) -> u64 {
        self.requests + self.shed
    }

    /// Failed demand: error replies plus shed connections.
    pub fn failed(&self) -> u64 {
        self.errors + self.shed
    }

    /// Percentage of demand that got a successful reply. An empty
    /// window is vacuously 100% available — no demand went unmet.
    pub fn availability_pct(&self) -> f64 {
        let demand = self.demand();
        if demand == 0 {
            return 100.0;
        }
        100.0 * (demand - self.failed().min(demand)) as f64 / demand as f64
    }

    /// Fraction of demand that failed, in [0, 1].
    pub fn error_rate(&self) -> f64 {
        let demand = self.demand();
        if demand == 0 {
            return 0.0;
        }
        self.failed().min(demand) as f64 / demand as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.inc("tasks_admitted");
        m.inc("tasks_admitted");
        m.add("iterations", 10);
        assert_eq!(m.counter("tasks_admitted"), 2);
        assert_eq!(m.counter("iterations"), 10);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let mut m = Metrics::new();
        m.set_gauge("fleet_util", 0.5);
        m.set_gauge("fleet_util", 0.75);
        assert_eq!(m.gauge("fleet_util"), Some(0.75));
    }

    #[test]
    fn json_dump_contains_everything() {
        let mut m = Metrics::new();
        m.inc("a");
        m.set_gauge("g", 1.5);
        m.observe("lat_us", 100.0);
        let s = m.to_json().render();
        assert!(s.contains("\"a\":1"));
        assert!(s.contains("\"g\":1.5"));
        assert!(s.contains("\"lat_us\":{\"count\":1"));
    }

    #[test]
    fn histogram_quantiles_bracket_the_data() {
        let mut h = Histogram::new();
        for v in 1..=1000 {
            h.observe(v as f64);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        // Log buckets are ~19% wide: generous but meaningful brackets.
        assert!((400.0..620.0).contains(&p50), "p50 = {p50}");
        assert!((850.0..=1000.0).contains(&p99), "p99 = {p99}");
        assert!(p50 <= p99);
        assert!((h.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_single_sample_reports_itself() {
        let mut h = Histogram::new();
        h.observe(137.0);
        assert_eq!(h.quantile(0.5), 137.0);
        assert_eq!(h.quantile(0.99), 137.0);
        assert_eq!(h.mean(), 137.0);
    }

    #[test]
    fn histogram_empty_and_degenerate_inputs() {
        let mut h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 0, "non-finite samples are dropped");
        h.observe(0.0); // below bucket 1.0 floor
        h.observe(-5.0); // clamped to 0
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_merge_equals_sequential() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        for v in 1..500 {
            let x = (v * 37 % 10_000) as f64;
            if v % 2 == 0 {
                a.observe(x);
            } else {
                b.observe(x);
            }
            all.observe(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.quantile(0.5), all.quantile(0.5));
        assert_eq!(a.quantile(0.99), all.quantile(0.99));
        assert!((a.mean() - all.mean()).abs() < 1e-9);
    }

    #[test]
    fn metrics_merge_adds_counters_sums_gauges_merges_histograms() {
        let mut a = Metrics::new();
        a.add("place_requests", 3);
        a.set_gauge("cache_entries", 2.0);
        a.observe("lat_us", 100.0);
        let mut b = Metrics::new();
        b.add("place_requests", 4);
        b.inc("cache_hits");
        b.set_gauge("cache_entries", 5.0);
        b.observe("lat_us", 400.0);
        b.observe("other_us", 7.0);
        a.merge(&b);
        assert_eq!(a.counter("place_requests"), 7);
        assert_eq!(a.counter("cache_hits"), 1);
        assert_eq!(a.gauge("cache_entries"), Some(7.0));
        let h = a.histogram("lat_us").unwrap();
        assert_eq!(h.count(), 2);
        assert!((h.mean() - 250.0).abs() < 1e-9);
        assert_eq!(a.histogram("other_us").unwrap().count(), 1);
    }

    #[test]
    fn sharded_metrics_merged_equals_the_sum_of_its_parts() {
        let sharded = ShardedMetrics::new(3);
        sharded.global().inc("connections");
        for i in 0..3 {
            sharded.shard(i).add("place_requests", (i + 1) as u64);
            sharded.shard(i).observe("place_latency_us",
                                     ((i + 1) * 100) as f64);
            sharded.shard(i).set_gauge("cache_entries", 1.0);
        }
        let merged = sharded.merged();
        assert_eq!(merged.counter("connections"), 1);
        assert_eq!(merged.counter("place_requests"), 6);
        assert_eq!(merged.gauge("cache_entries"), Some(3.0));
        assert_eq!(merged.histogram("place_latency_us").unwrap().count(),
                   3);
        assert_eq!(sharded.shard_snapshots().len(), 3);
        assert_eq!(sharded.n_shards(), 3);
        // Per-shard instances stayed independent.
        assert_eq!(sharded.shard(0).counter("place_requests"), 1);
        assert_eq!(sharded.shard(2).counter("place_requests"), 3);
    }

    #[test]
    fn slo_window_diffs_only_in_window_traffic() {
        let mut m = Metrics::new();
        m.add("place_requests", 1_000_000); // healthy pre-outage traffic
        m.add("place_errors", 10);
        let window = SloWindow::begin(&m);
        // Outage: 200 requests, 4 errors, 6 shed connections.
        m.add("place_requests", 200);
        m.add("place_errors", 4);
        m.add("connections_shed", 6);
        let report = window.close(&m);
        assert_eq!(report.requests, 200);
        assert_eq!(report.errors, 4);
        assert_eq!(report.shed, 6);
        assert_eq!(report.demand(), 206);
        assert_eq!(report.failed(), 10);
        let availability = report.availability_pct();
        assert!((availability - 100.0 * 196.0 / 206.0).abs() < 1e-12,
                "availability = {availability}");
        assert!((report.error_rate() - 10.0 / 206.0).abs() < 1e-12);
        // The million pre-window requests never entered the math.
    }

    #[test]
    fn slo_report_edge_cases() {
        let empty = SloWindow::begin(&Metrics::new())
            .close(&Metrics::new());
        assert_eq!(empty.availability_pct(), 100.0);
        assert_eq!(empty.error_rate(), 0.0);

        // Shed-only window: refused connections count as failed demand.
        let mut m = Metrics::new();
        let window = SloWindow::begin(&m);
        m.add("connections_shed", 5);
        let report = window.close(&m);
        assert_eq!(report.availability_pct(), 0.0);
        assert_eq!(report.error_rate(), 1.0);

        // A counter reset (daemon restart) saturates instead of
        // wrapping to u64::MAX deltas.
        let mut before = Metrics::new();
        before.add("place_requests", 500);
        let window = SloWindow::begin(&before);
        let mut after = Metrics::new();
        after.add("place_requests", 40);
        let report = window.close(&after);
        assert_eq!(report.requests, 0);
        assert_eq!(report.availability_pct(), 100.0);
    }

    #[test]
    fn shared_metrics_is_send_sync_and_aggregates_across_threads() {
        let shared = SharedMetrics::new();
        std::thread::scope(|scope| {
            for t in 0..4 {
                let handle = shared.clone();
                scope.spawn(move || {
                    for i in 0..100 {
                        handle.inc("requests");
                        handle.observe("lat_us", (t * 100 + i) as f64);
                    }
                });
            }
        });
        assert_eq!(shared.counter("requests"), 400);
        let snap = shared.snapshot();
        assert_eq!(snap.histogram("lat_us").unwrap().count(), 400);
    }
}
