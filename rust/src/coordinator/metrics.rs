//! Leader metrics: counters and timers exported by the coordinator (and
//! printed by `hulk simulate`).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Monotone counters + gauges. BTreeMap for stable rendering order.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Machine-readable dump.
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, Json::Num(*v as f64));
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges.set(k, Json::Num(*v));
        }
        obj.set("counters", counters);
        obj.set("gauges", gauges);
        obj
    }

    /// Human-readable dump.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k:<32} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("{k:<32} {v:.3}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.inc("tasks_admitted");
        m.inc("tasks_admitted");
        m.add("iterations", 10);
        assert_eq!(m.counter("tasks_admitted"), 2);
        assert_eq!(m.counter("iterations"), 10);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let mut m = Metrics::new();
        m.set_gauge("fleet_util", 0.5);
        m.set_gauge("fleet_util", 0.75);
        assert_eq!(m.gauge("fleet_util"), Some(0.75));
    }

    #[test]
    fn json_dump_contains_everything() {
        let mut m = Metrics::new();
        m.inc("a");
        m.set_gauge("g", 1.5);
        let s = m.to_json().render();
        assert!(s.contains("\"a\":1"));
        assert!(s.contains("\"g\":1.5"));
    }
}
