//! Disaster recovery (paper §1, Contribution "Disaster Recovery"):
//! "Since GCNs are utilized to assign tasks ... it becomes evident which
//! tasks each machine is responsible for. In the event of a machine
//! failure, the system can quickly recover the entire computation."
//!
//! Policy: on machine failure, (1) promote the nearest memory-sufficient
//! spare into the failed machine's group, else (2) re-plan the affected
//! group from the remaining pool. The rest of the fleet is untouched —
//! this is the recovery-locality advantage of group-wise assignment over
//! global schemes, quantified by the recovery bench.

use crate::cluster::Fleet;
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::scheduler::Assignment;

/// Outcome of a recovery attempt.
#[derive(Clone, Debug, PartialEq)]
pub enum RecoveryAction {
    /// Spare machine `spare` replaces `failed` in task `task`.
    PromoteSpare { task: usize, failed: usize, spare: usize },
    /// The group absorbed the loss (still memory-feasible without a
    /// replacement).
    ShrinkGroup { task: usize, failed: usize },
    /// No spare and the group is infeasible: the task must be re-queued.
    Requeue { task: usize },
    /// The failed machine held no task — nothing to do.
    NoOp,
}

/// Handle the failure of `failed` under `assignment`. Mutates the
/// assignment in place to reflect the action taken.
pub fn recover(fleet: &Fleet, graph: &ClusterGraph,
               assignment: &mut Assignment, tasks: &[ModelSpec],
               failed: usize) -> RecoveryAction
{
    let Some(task) = assignment.task_of(failed) else {
        return RecoveryAction::NoOp;
    };
    // Remove the failed machine from its group.
    assignment.groups[task].retain(|&m| m != failed);
    let group = assignment.groups[task].clone();

    let group_gb = |g: &[usize]| -> f64 {
        g.iter().map(|&i| fleet.machines[i].total_memory_gb()).sum()
    };

    // Option 1: group still feasible → shrink.
    if group_gb(&group) >= tasks[task].train_gb()
        && graph.subset_connected(&group)
        && !group.is_empty()
    {
        return RecoveryAction::ShrinkGroup { task, failed };
    }

    // Option 2: promote the best spare (lowest added latency, reachable,
    // not the failed machine itself).
    let spares = assignment.spares(fleet.len());
    let candidate = spares
        .iter()
        .copied()
        .filter(|&s| s != failed)
        .filter(|&s| group.iter().any(|&j| graph.has_edge(s, j))
                     || group.is_empty())
        .min_by(|&a, &b| {
            let cost = |i: usize| -> f64 {
                group
                    .iter()
                    .map(|&j| {
                        let w = graph.weight(i, j);
                        if w > 0.0 { w as f64 } else { 2e3 }
                    })
                    .sum::<f64>()
                    - fleet.machines[i].total_memory_gb() * 0.1
            };
            cost(a).partial_cmp(&cost(b)).unwrap()
        });
    if let Some(spare) = candidate {
        assignment.groups[task].push(spare);
        assignment.groups[task].sort_unstable();
        let new_group = assignment.groups[task].clone();
        if group_gb(&new_group) >= tasks[task].train_gb() {
            return RecoveryAction::PromoteSpare { task, failed, spare };
        }
        // Even with the spare it doesn't fit → undo and requeue.
        assignment.groups[task].retain(|&m| m != spare);
    }
    RecoveryAction::Requeue { task }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{oracle_partition, OracleOptions};

    fn setup() -> (Fleet, ClusterGraph, Assignment, Vec<ModelSpec>) {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = ModelSpec::paper_four();
        let a = oracle_partition(&fleet, &graph, &tasks,
                                 &OracleOptions::default());
        (fleet, graph, a, tasks)
    }

    #[test]
    fn noop_for_spare_failure() {
        let (fleet, graph, mut a, tasks) = setup();
        let spares = a.spares(fleet.len());
        if let Some(&s) = spares.first() {
            let action = recover(&fleet, &graph, &mut a, &tasks, s);
            assert_eq!(action, RecoveryAction::NoOp);
        }
    }

    #[test]
    fn failure_in_small_group_recovers() {
        let (fleet, graph, mut a, tasks) = setup();
        // Fail a machine in the BERT group (task 3, smallest model).
        let victim = a.groups[3][0];
        let before = a.groups[3].len();
        let action = recover(&fleet, &graph, &mut a, &tasks, victim);
        match action {
            RecoveryAction::ShrinkGroup { task, failed } => {
                assert_eq!((task, failed), (3, victim));
                assert_eq!(a.groups[3].len(), before - 1);
            }
            RecoveryAction::PromoteSpare { task, failed, spare } => {
                assert_eq!((task, failed), (3, victim));
                assert!(a.groups[3].contains(&spare));
            }
            other => panic!("unexpected {other:?}"),
        }
        // Post-recovery the group must be memory-feasible.
        a.validate_memory(&fleet, &tasks).unwrap();
        a.validate_disjoint(fleet.len()).unwrap();
    }

    #[test]
    fn opt_group_failure_promotes_or_requeues() {
        let (fleet, graph, mut a, tasks) = setup();
        // OPT (task 0) runs close to its memory floor: failing its largest
        // member forces a spare promotion or a requeue, not a silent
        // infeasible state.
        let victim = *a.groups[0]
            .iter()
            .max_by(|&&x, &&y| {
                fleet.machines[x]
                    .total_memory_gb()
                    .partial_cmp(&fleet.machines[y].total_memory_gb())
                    .unwrap()
            })
            .unwrap();
        let action = recover(&fleet, &graph, &mut a, &tasks, victim);
        match action {
            RecoveryAction::Requeue { task } => assert_eq!(task, 0),
            RecoveryAction::PromoteSpare { task, .. }
            | RecoveryAction::ShrinkGroup { task, .. } => {
                assert_eq!(task, 0);
                a.validate_memory(&fleet, &tasks).unwrap();
            }
            RecoveryAction::NoOp => panic!("victim held a task"),
        }
    }

    #[test]
    fn recovery_touches_only_the_affected_group() {
        let (fleet, graph, mut a, tasks) = setup();
        let before: Vec<Vec<usize>> = a.groups.clone();
        let victim = a.groups[3][0];
        recover(&fleet, &graph, &mut a, &tasks, victim);
        for t in 0..3 {
            assert_eq!(a.groups[t], before[t],
                       "group {t} must be untouched");
        }
    }
}
