//! Scalability (paper §5.2, Fig. 6): "If we need to add one or more
//! machines to this system, we can simply define their {City, Compute
//! Capability, Memory} and connect them to the existing nodes"; removal
//! "simply removes the corresponding edge information".
//!
//! Scale-out places the new machine into the task group where it reduces
//! the marginal cost the most (or leaves it as a spare); scale-in is a
//! recovery-style departure.

use crate::cluster::{Fleet, GpuModel, Region};
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::scheduler::Assignment;

use super::recovery::{recover, RecoveryAction};

/// Add a machine to the fleet and decide its placement. Returns
/// `(machine_id, Some(task))` if it joined a group, `(id, None)` if it
/// became a spare.
pub fn scale_out(fleet: &mut Fleet, assignment: &mut Assignment,
                 tasks: &[ModelSpec], region: Region, gpu: GpuModel,
                 n_gpus: usize) -> (usize, Option<usize>)
{
    let id = fleet.add_machine(region, gpu, n_gpus);
    let graph = ClusterGraph::from_fleet(fleet);

    // Marginal placement score per task: added intra-group latency per
    // unit of group need (groups running nearer their memory floor value
    // the machine more).
    let mut best: Option<(usize, f64)> = None;
    for (t, group) in assignment.groups.iter().enumerate() {
        if group.is_empty() {
            continue;
        }
        if !group.iter().any(|&j| graph.has_edge(id, j)) {
            continue; // unreachable group
        }
        let added_lat: f64 = group
            .iter()
            .map(|&j| {
                let w = graph.weight(id, j);
                if w > 0.0 { w as f64 } else { 2e3 }
            })
            .sum::<f64>()
            / group.len() as f64;
        let group_gb: f64 = group
            .iter()
            .map(|&i| fleet.machines[i].total_memory_gb())
            .sum();
        let pressure = tasks[t].train_gb() / group_gb; // >→ needier
        let score = added_lat / pressure.max(1e-3);
        if best.is_none_or(|(_, s)| score < s) {
            best = Some((t, score));
        }
    }

    // Join only if the best group is "close": mean added latency below
    // the fleet-wide mean edge latency (otherwise stay a spare — joining
    // a far group would degrade its communication time).
    if let Some((t, score)) = best {
        let mean_lat = mean_edge_latency(&graph);
        let group_gb: f64 = assignment.groups[t]
            .iter()
            .map(|&i| fleet.machines[i].total_memory_gb())
            .sum();
        let pressure = tasks[t].train_gb() / group_gb;
        let added = score * pressure.max(1e-3);
        if added <= mean_lat {
            assignment.groups[t].push(id);
            assignment.groups[t].sort_unstable();
            return (id, Some(t));
        }
    }
    (id, None)
}

/// Remove a machine (graceful scale-in = the same path as a failure, but
/// the caller chose the victim). Returns the action taken. NOTE: the
/// machine stays in the fleet (ids stay dense); it simply holds no task —
/// matching the paper's "remove the corresponding edge information".
pub fn scale_in(fleet: &Fleet, graph: &ClusterGraph,
                assignment: &mut Assignment, tasks: &[ModelSpec],
                machine: usize) -> RecoveryAction
{
    recover(fleet, graph, assignment, tasks, machine)
}

fn mean_edge_latency(graph: &ClusterGraph) -> f64 {
    let mut sum = 0.0;
    let mut count = 0usize;
    for i in 0..graph.n {
        for j in (i + 1)..graph.n {
            let w = graph.weight(i, j);
            if w > 0.0 {
                sum += w as f64;
                count += 1;
            }
        }
    }
    if count == 0 { 0.0 } else { sum / count as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::paper_data::fig6_node_45;
    use crate::scheduler::{oracle_partition, OracleOptions};

    #[test]
    fn fig6_node45_joins_the_system() {
        // Reproduce Fig. 6: 45-machine fleet + node 45 {Rome, 7, 384}.
        let mut fleet = Fleet::paper_evaluation(0);
        fleet.remove_machine(45); // make room: ids 0..45
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = ModelSpec::paper_four();
        let mut a = oracle_partition(&fleet, &graph, &tasks,
                                     &OracleOptions::default());
        let spec = fig6_node_45();
        let (id, placed) = scale_out(&mut fleet, &mut a, &tasks,
                                     spec.region, spec.gpu, spec.n_gpus);
        assert_eq!(id, 45);
        // Either it joined a group or became a spare — both are "works
        // fine" per the paper; the assignment must stay valid.
        a.validate_disjoint(fleet.len()).unwrap();
        a.validate_memory(&fleet, &tasks).unwrap();
        if let Some(t) = placed {
            assert!(a.groups[t].contains(&45));
        } else {
            assert!(a.spares(fleet.len()).contains(&45));
        }
    }

    #[test]
    fn scale_out_prefers_near_groups() {
        let mut fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = ModelSpec::paper_four();
        let mut a = oracle_partition(&fleet, &graph, &tasks,
                                     &OracleOptions::default());
        let (id, placed) = scale_out(&mut fleet, &mut a, &tasks,
                                     Region::California, GpuModel::A100, 8);
        if let Some(t) = placed {
            // The chosen group must actually be reachable & mostly near.
            let graph2 = ClusterGraph::from_fleet(&fleet);
            assert!(a.groups[t].iter().any(|&j| j != id
                && graph2.has_edge(id, j)));
        }
    }

    #[test]
    fn scale_in_keeps_assignment_valid() {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let tasks = ModelSpec::paper_four();
        let mut a = oracle_partition(&fleet, &graph, &tasks,
                                     &OracleOptions::default());
        let victim = a.groups[1][0];
        let action = scale_in(&fleet, &graph, &mut a, &tasks, victim);
        assert_ne!(action, RecoveryAction::NoOp);
        a.validate_disjoint(fleet.len()).unwrap();
    }
}
