//! Leader-state checkpointing: serialize the coordinator's task table and
//! assignment to a `util::kv` file so a restarted leader resumes where
//! the old one died — the control-plane half of the paper's disaster-
//! recovery story (the data plane recovers via `recovery::recover`).
//!
//! Format (kv, one key per line):
//! ```text
//! format        1
//! n_tasks       3
//! task.0.model  GPT-2 (1.5B)
//! task.0.state  running
//! task.0.done   17
//! task.0.target 100
//! task.0.machines 4,7,9
//! …
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::models::ModelSpec;
use crate::util::kv::KvFile;

use super::tasks::{TaskState, TrainingTask};

/// Serialize tasks to the checkpoint format.
pub fn render_checkpoint(tasks: &[TrainingTask]) -> String {
    let mut out = String::from("format 1\n");
    out.push_str(&format!("n_tasks {}\n", tasks.len()));
    for t in tasks {
        let state = match &t.state {
            TaskState::Queued => "queued".to_string(),
            TaskState::Running => "running".to_string(),
            TaskState::Recovering => "recovering".to_string(),
            TaskState::Completed => "completed".to_string(),
            TaskState::Failed(msg) => {
                format!("failed:{}", msg.replace(['\n', ' '], "_"))
            }
        };
        let machines = t
            .machines
            .iter()
            .map(|m| m.to_string())
            .collect::<Vec<_>>()
            .join(",");
        out.push_str(&format!("task.{}.model {}\n", t.id, t.model.name));
        out.push_str(&format!("task.{}.state {}\n", t.id, state));
        out.push_str(&format!("task.{}.done {}\n", t.id, t.iterations_done));
        out.push_str(&format!("task.{}.target {}\n", t.id,
                              t.iterations_target));
        out.push_str(&format!("task.{}.machines {}\n", t.id,
                              if machines.is_empty() { "-" } else { &machines }));
    }
    out
}

fn model_by_name(name: &str) -> Result<ModelSpec> {
    ModelSpec::paper_six()
        .into_iter()
        .find(|m| m.name == name)
        .with_context(|| format!("unknown model in checkpoint: {name:?}"))
}

/// Parse a checkpoint back into tasks.
pub fn parse_checkpoint(text: &str) -> Result<Vec<TrainingTask>> {
    let kv = KvFile::parse(text)?;
    if kv.get("format")? != "1" {
        bail!("unsupported checkpoint format");
    }
    let n = kv.get_usize("n_tasks")?;
    let mut tasks = Vec::with_capacity(n);
    for id in 0..n {
        let model = model_by_name(kv.get(&format!("task.{id}.model"))?)?;
        let state = match kv.get(&format!("task.{id}.state"))? {
            "queued" => TaskState::Queued,
            "running" => TaskState::Running,
            "recovering" => TaskState::Recovering,
            "completed" => TaskState::Completed,
            s if s.starts_with("failed:") => {
                TaskState::Failed(s["failed:".len()..].to_string())
            }
            other => bail!("bad task state {other:?}"),
        };
        let done = kv.get_usize(&format!("task.{id}.done"))? as u64;
        let target = kv.get_usize(&format!("task.{id}.target"))? as u64;
        let machines_raw = kv.get(&format!("task.{id}.machines"))?;
        let machines: Vec<usize> = if machines_raw == "-" {
            Vec::new()
        } else {
            machines_raw
                .split(',')
                .map(|s| s.parse().context("bad machine id"))
                .collect::<Result<_>>()?
        };
        let mut task = TrainingTask::new(id, model, target);
        task.state = state;
        task.iterations_done = done;
        task.machines = machines;
        tasks.push(task);
    }
    Ok(tasks)
}

/// Write a checkpoint file.
pub fn save_checkpoint(path: &Path, tasks: &[TrainingTask]) -> Result<()> {
    std::fs::write(path, render_checkpoint(tasks))
        .with_context(|| format!("writing checkpoint {}", path.display()))
}

/// Load a checkpoint file.
pub fn load_checkpoint(path: &Path) -> Result<Vec<TrainingTask>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    parse_checkpoint(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tasks() -> Vec<TrainingTask> {
        let mut a = TrainingTask::new(0, ModelSpec::gpt2_xl(), 100);
        a.state = TaskState::Running;
        a.iterations_done = 17;
        a.machines = vec![4, 7, 9];
        let mut b = TrainingTask::new(1, ModelSpec::bert_large(), 50);
        b.state = TaskState::Queued;
        let mut c = TrainingTask::new(2, ModelSpec::t5_11b(), 10);
        c.state = TaskState::Failed("machine 3 died".into());
        vec![a, b, c]
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let tasks = sample_tasks();
        let text = render_checkpoint(&tasks);
        let back = parse_checkpoint(&text).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[0].machines, vec![4, 7, 9]);
        assert_eq!(back[0].iterations_done, 17);
        assert_eq!(back[0].state, TaskState::Running);
        assert_eq!(back[1].state, TaskState::Queued);
        assert!(back[1].machines.is_empty());
        assert!(matches!(back[2].state, TaskState::Failed(_)));
        assert_eq!(back[0].model.name, "GPT-2 (1.5B)");
    }

    #[test]
    fn file_roundtrip() {
        let tasks = sample_tasks();
        let path = std::env::temp_dir().join("hulk_ckpt_test.kv");
        save_checkpoint(&path, &tasks).unwrap();
        let back = load_checkpoint(&path).unwrap();
        assert_eq!(back.len(), tasks.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unknown_model_rejected() {
        let text = "format 1\nn_tasks 1\ntask.0.model Mystery\n\
                    task.0.state queued\ntask.0.done 0\ntask.0.target 1\n\
                    task.0.machines -\n";
        assert!(parse_checkpoint(text).is_err());
    }

    #[test]
    fn bad_format_rejected() {
        assert!(parse_checkpoint("format 2\nn_tasks 0\n").is_err());
    }
}
