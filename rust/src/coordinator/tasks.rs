//! Training-task lifecycle.

use crate::models::ModelSpec;

/// Lifecycle of a submitted task.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Waiting for resources (Algorithm 1 line 17).
    Queued,
    /// Machines assigned, training in progress.
    Running,
    /// A participating machine failed; recovery in progress.
    Recovering,
    /// Finished (the simulated run completed its iterations).
    Completed,
    /// Permanently failed (no recovery possible).
    Failed(String),
}

/// A submitted training task.
#[derive(Clone, Debug)]
pub struct TrainingTask {
    pub id: usize,
    pub model: ModelSpec,
    pub state: TaskState,
    /// Machines currently assigned (empty while queued).
    pub machines: Vec<usize>,
    /// Iterations completed so far (simulated progress).
    pub iterations_done: u64,
    pub iterations_target: u64,
}

impl TrainingTask {
    pub fn new(id: usize, model: ModelSpec, iterations: u64) -> TrainingTask {
        TrainingTask {
            id,
            model,
            state: TaskState::Queued,
            machines: Vec::new(),
            iterations_done: 0,
            iterations_target: iterations,
        }
    }

    pub fn is_active(&self) -> bool {
        matches!(self.state, TaskState::Running | TaskState::Recovering)
    }

    pub fn progress(&self) -> f64 {
        if self.iterations_target == 0 {
            return 1.0;
        }
        self.iterations_done as f64 / self.iterations_target as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_flags() {
        let mut t = TrainingTask::new(0, ModelSpec::bert_large(), 100);
        assert_eq!(t.state, TaskState::Queued);
        assert!(!t.is_active());
        t.state = TaskState::Running;
        assert!(t.is_active());
        t.state = TaskState::Failed("boom".into());
        assert!(!t.is_active());
    }

    #[test]
    fn progress_fraction() {
        let mut t = TrainingTask::new(1, ModelSpec::gpt2_xl(), 200);
        t.iterations_done = 50;
        assert!((t.progress() - 0.25).abs() < 1e-12);
        let z = TrainingTask::new(2, ModelSpec::gpt2_xl(), 0);
        assert_eq!(z.progress(), 1.0);
    }
}
