//! The leader event loop.
//!
//! The core (`Coordinator::handle`) is synchronous and fully testable;
//! `Coordinator::spawn` runs it on a thread behind std mpsc channels
//! (tokio is not in the offline registry — DESIGN.md §Substitutions).
//! Request routing, batching of task admissions, and failure handling all
//! happen here; GCN inference is consulted through the planner injected
//! at construction.

use std::cell::RefCell;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::cluster::{Fleet, GpuModel, Region};
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::parallel::{pipeline_cost, PipelinePlan};
use crate::scheduler::Assignment;
use crate::planner::chain_order;

use super::metrics::Metrics;
use super::recovery::{recover, RecoveryAction};
use super::scale::scale_out;
use super::tasks::{TaskState, TrainingTask};

/// Events the leader reacts to.
#[derive(Clone, Debug)]
pub enum CoordinatorEvent {
    /// Admit a new training task.
    Submit { model: ModelSpec, iterations: u64 },
    /// A machine died.
    MachineFailed { machine: usize },
    /// Fig. 6 scale-out.
    ScaleOut { region: Region, gpu: GpuModel, n_gpus: usize },
    /// Advance simulated training by `iterations` on every running task.
    Tick { iterations: u64 },
    /// Graceful stop; the thread replies with final metrics and exits.
    Shutdown,
}

/// Replies the leader emits (one per event).
#[derive(Clone, Debug)]
pub enum CoordinatorReply {
    Admitted { task_id: usize, machines: Vec<usize> },
    Queued { task_id: usize },
    Recovered { action: RecoveryAction },
    ScaledOut { machine_id: usize, joined_task: Option<usize> },
    Ticked { completed: Vec<usize> },
    Stopped { metrics_render: String },
}

/// The leader state machine.
pub struct Coordinator {
    pub fleet: Fleet,
    pub tasks: Vec<TrainingTask>,
    pub assignment: Assignment,
    pub metrics: Metrics,
    failed_machines: Vec<usize>,
    /// Memoized [`ClusterGraph`]: the leader consults the graph on
    /// every admit / recovery / iteration-estimate, but the fleet only
    /// changes on scale-out and failure. Rebuilding the O(n²)
    /// adjacency per event dominated bursty planet-scale streams;
    /// mutation sites call [`Coordinator::invalidate_graph`].
    graph_cache: RefCell<Option<Arc<ClusterGraph>>>,
}

impl Coordinator {
    pub fn new(fleet: Fleet) -> Coordinator {
        Coordinator {
            fleet,
            tasks: Vec::new(),
            assignment: Assignment::new(Vec::new()),
            metrics: Metrics::new(),
            failed_machines: Vec::new(),
            graph_cache: RefCell::new(None),
        }
    }

    fn active_models(&self) -> Vec<ModelSpec> {
        self.tasks
            .iter()
            .filter(|t| t.is_active())
            .map(|t| t.model.clone())
            .collect()
    }

    fn graph(&self) -> Arc<ClusterGraph> {
        if let Some(g) = self.graph_cache.borrow().as_ref() {
            // `fleet` is a public field: a caller mutating it directly
            // (instead of through ScaleOut/MachineFailed events) must
            // not be served a wrong-sized graph — self-heal on any
            // size drift.
            if g.n == self.fleet.len() {
                return g.clone();
            }
        }
        let mut g = ClusterGraph::from_fleet(&self.fleet);
        // Failed machines lose their edges (paper §5.2: removal = edge
        // deletion).
        for &m in &self.failed_machines {
            for j in 0..g.n {
                g.adj[m * g.n + j] = 0.0;
                g.adj[j * g.n + m] = 0.0;
            }
        }
        let g = Arc::new(g);
        *self.graph_cache.borrow_mut() = Some(g.clone());
        g
    }

    /// Drop the memoized graph; the next consumer rebuilds it. Must run
    /// after every fleet or failed-machine mutation.
    fn invalidate_graph(&self) {
        self.graph_cache.borrow_mut().take();
    }

    /// Pool of machines not assigned to an active task and not failed.
    /// Membership goes through a bool mask keyed by machine id — O(n)
    /// total instead of O(n × tasks × group) scans, which matters when
    /// the leader fronts planet-scale fleets under bursty arrivals.
    fn free_pool(&self) -> Vec<usize> {
        let n = self.fleet.len();
        let mut free = vec![true; n];
        for &m in &self.failed_machines {
            if m < n {
                free[m] = false;
            }
        }
        for task in self.tasks.iter().filter(|t| t.is_active()) {
            for &m in &task.machines {
                if m < n {
                    free[m] = false;
                }
            }
        }
        (0..n).filter(|&m| free[m]).collect()
    }

    /// Admit a task: grow a group from the free pool greedily by
    /// latency, honoring the memory threshold (the single-task special
    /// case of Algorithm 1, which the paper notes "can also be used to
    /// determine superiority if there is only one task").
    fn admit(&mut self, model: &ModelSpec) -> Option<Vec<usize>> {
        let graph = self.graph();
        let pool = self.free_pool();
        if pool.is_empty() {
            return None;
        }
        // Seed: biggest-memory machine in the pool.
        let seed = *pool.iter().max_by(|&&a, &&b| {
            self.fleet.machines[a]
                .total_memory_gb()
                .partial_cmp(&self.fleet.machines[b].total_memory_gb())
                .unwrap()
        })?;
        let mut group = vec![seed];
        let mut mem = self.fleet.machines[seed].total_memory_gb();
        while mem < model.train_gb() * 1.1 {
            let next = pool
                .iter()
                .copied()
                .filter(|m| !group.contains(m))
                .filter(|&m| group.iter().any(|&j| graph.has_edge(m, j)))
                .min_by(|&a, &b| {
                    let cost = |i: usize| -> f64 {
                        group
                            .iter()
                            .map(|&j| {
                                let w = graph.weight(i, j);
                                if w > 0.0 { w as f64 } else { 2e3 }
                            })
                            .sum()
                    };
                    cost(a).partial_cmp(&cost(b)).unwrap()
                });
            match next {
                Some(m) => {
                    mem += self.fleet.machines[m].total_memory_gb();
                    group.push(m);
                }
                None => return None, // pool exhausted / unreachable
            }
        }
        group.sort_unstable();
        Some(group)
    }

    /// Estimated per-iteration time of a task on its group (drives Tick
    /// accounting).
    pub fn task_iter_ms(&self, task: &TrainingTask) -> Option<f64> {
        if task.machines.is_empty() {
            return None;
        }
        let graph = self.graph();
        let ordered = chain_order(&graph, &task.machines);
        let stages: Vec<usize> =
            ordered.into_iter().take(task.model.layers).collect();
        let plan = PipelinePlan::proportional(&self.fleet, stages,
                                              &task.model);
        let cost = pipeline_cost(&self.fleet, &plan, &task.model);
        cost.is_feasible().then(|| cost.total_ms())
    }

    /// Synchronous event handler — the heart of the leader.
    pub fn handle(&mut self, event: CoordinatorEvent) -> CoordinatorReply {
        match event {
            CoordinatorEvent::Submit { model, iterations } => {
                let id = self.tasks.len();
                let mut task = TrainingTask::new(id, model, iterations);
                self.metrics.inc("tasks_submitted");
                match self.admit(&task.model) {
                    Some(group) => {
                        task.machines = group.clone();
                        task.state = TaskState::Running;
                        self.tasks.push(task);
                        self.sync_assignment();
                        self.metrics.inc("tasks_admitted");
                        CoordinatorReply::Admitted { task_id: id,
                                                     machines: group }
                    }
                    None => {
                        task.state = TaskState::Queued;
                        self.tasks.push(task);
                        self.metrics.inc("tasks_queued");
                        CoordinatorReply::Queued { task_id: id }
                    }
                }
            }
            CoordinatorEvent::MachineFailed { machine } => {
                self.failed_machines.push(machine);
                self.invalidate_graph();
                self.metrics.inc("machine_failures");
                let graph = self.graph();
                let models = self.active_models();
                let action = recover(&self.fleet, &graph,
                                     &mut self.assignment, &models, machine);
                // Mirror the assignment back into task state.
                self.apply_assignment(&action);
                CoordinatorReply::Recovered { action }
            }
            CoordinatorEvent::ScaleOut { region, gpu, n_gpus } => {
                let models = self.active_models();
                let (id, joined) = scale_out(&mut self.fleet,
                                             &mut self.assignment, &models,
                                             region, gpu, n_gpus);
                self.invalidate_graph();
                if let Some(t) = joined {
                    if let Some(task) =
                        self.tasks.iter_mut().filter(|t| t.is_active()).nth(t)
                    {
                        task.machines.push(id);
                        task.machines.sort_unstable();
                    }
                }
                self.metrics.inc("scale_out_events");
                CoordinatorReply::ScaledOut { machine_id: id,
                                              joined_task: joined }
            }
            CoordinatorEvent::Tick { iterations } => {
                let mut completed = Vec::new();
                for i in 0..self.tasks.len() {
                    if !matches!(self.tasks[i].state, TaskState::Running) {
                        continue;
                    }
                    self.tasks[i].iterations_done = (self.tasks[i]
                        .iterations_done
                        + iterations)
                        .min(self.tasks[i].iterations_target);
                    if self.tasks[i].iterations_done
                        >= self.tasks[i].iterations_target
                    {
                        self.tasks[i].state = TaskState::Completed;
                        completed.push(i);
                    }
                }
                self.metrics.add("iterations_ticked", iterations);
                // Completed tasks release machines → try the queue.
                if !completed.is_empty() {
                    self.retry_queue();
                }
                CoordinatorReply::Ticked { completed }
            }
            CoordinatorEvent::Shutdown => CoordinatorReply::Stopped {
                metrics_render: self.metrics.render(),
            },
        }
    }

    fn retry_queue(&mut self) {
        for i in 0..self.tasks.len() {
            if self.tasks[i].state != TaskState::Queued {
                continue;
            }
            let model = self.tasks[i].model.clone();
            if let Some(group) = self.admit(&model) {
                self.tasks[i].machines = group;
                self.tasks[i].state = TaskState::Running;
                self.metrics.inc("tasks_admitted");
            }
        }
        self.sync_assignment();
    }

    fn sync_assignment(&mut self) {
        self.assignment = Assignment::new(
            self.tasks
                .iter()
                .filter(|t| t.is_active())
                .map(|t| t.machines.clone())
                .collect(),
        );
    }

    fn apply_assignment(&mut self, action: &RecoveryAction) {
        let active: Vec<usize> = (0..self.tasks.len())
            .filter(|&i| self.tasks[i].is_active())
            .collect();
        for (slot, &task_idx) in active.iter().enumerate() {
            if slot < self.assignment.groups.len() {
                self.tasks[task_idx].machines =
                    self.assignment.groups[slot].clone();
            }
        }
        if let RecoveryAction::Requeue { task } = action {
            if let Some(&idx) = active.get(*task) {
                self.tasks[idx].state = TaskState::Queued;
                self.tasks[idx].machines.clear();
                self.sync_assignment();
            }
        }
    }

    /// Run the leader on a thread. Send events in, receive one reply per
    /// event; the thread exits after `Shutdown`.
    pub fn spawn(mut self)
        -> (Sender<CoordinatorEvent>, Receiver<CoordinatorReply>,
            JoinHandle<()>)
    {
        let (tx_in, rx_in) = channel::<CoordinatorEvent>();
        let (tx_out, rx_out) = channel::<CoordinatorReply>();
        let handle = std::thread::spawn(move || {
            while let Ok(event) = rx_in.recv() {
                let stop = matches!(event, CoordinatorEvent::Shutdown);
                let reply = self.handle(event);
                if tx_out.send(reply).is_err() {
                    break;
                }
                if stop {
                    break;
                }
            }
        });
        (tx_in, rx_out, handle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coordinator() -> Coordinator {
        Coordinator::new(Fleet::paper_evaluation(0))
    }

    #[test]
    fn submit_admits_feasible_task() {
        let mut c = coordinator();
        let reply = c.handle(CoordinatorEvent::Submit {
            model: ModelSpec::gpt2_xl(),
            iterations: 100,
        });
        match reply {
            CoordinatorReply::Admitted { task_id, machines } => {
                assert_eq!(task_id, 0);
                assert!(!machines.is_empty());
            }
            other => panic!("expected admission, got {other:?}"),
        }
        assert_eq!(c.metrics.counter("tasks_admitted"), 1);
    }

    #[test]
    fn groups_of_concurrent_tasks_are_disjoint() {
        let mut c = coordinator();
        for model in ModelSpec::paper_four() {
            c.handle(CoordinatorEvent::Submit { model, iterations: 10 });
        }
        c.assignment.validate_disjoint(c.fleet.len()).unwrap();
    }

    #[test]
    fn tick_completes_tasks_and_unblocks_queue() {
        let mut c = coordinator();
        // Fill the fleet with big tasks until one queues.
        let mut queued = None;
        for i in 0..8 {
            let reply = c.handle(CoordinatorEvent::Submit {
                model: ModelSpec::t5_11b(),
                iterations: 5,
            });
            if matches!(reply, CoordinatorReply::Queued { .. }) {
                queued = Some(i);
                break;
            }
        }
        let Some(_) = queued else {
            return; // fleet fit everything; nothing to assert
        };
        // Complete everything running.
        let reply = c.handle(CoordinatorEvent::Tick { iterations: 5 });
        match reply {
            CoordinatorReply::Ticked { completed } => {
                assert!(!completed.is_empty());
            }
            other => panic!("{other:?}"),
        }
        // The queued task should now be running.
        assert!(c.tasks.iter().any(|t| t.state == TaskState::Running));
    }

    #[test]
    fn machine_failure_triggers_recovery() {
        let mut c = coordinator();
        c.handle(CoordinatorEvent::Submit {
            model: ModelSpec::gpt2_xl(),
            iterations: 100,
        });
        let victim = c.tasks[0].machines[0];
        let reply = c.handle(CoordinatorEvent::MachineFailed {
            machine: victim });
        match reply {
            CoordinatorReply::Recovered { action } => {
                assert!(!matches!(action, RecoveryAction::NoOp),
                        "action {action:?}");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(c.metrics.counter("machine_failures"), 1);
        assert!(!c.tasks[0].machines.contains(&victim)
                || c.tasks[0].state == TaskState::Queued);
    }

    #[test]
    fn graph_cache_is_invalidated_by_failures_and_scale_out() {
        let mut c = coordinator();
        let before = c.graph();
        // A second read is the same allocation, not a rebuild.
        assert!(Arc::ptr_eq(&before, &c.graph()));
        c.handle(CoordinatorEvent::MachineFailed { machine: 3 });
        let after = c.graph();
        assert!(!Arc::ptr_eq(&before, &after), "stale graph survived");
        // The failed machine lost its edges.
        assert_eq!(after.degree(3), 0);
        assert!(before.degree(3) > 0);
        let n = after.n;
        c.handle(CoordinatorEvent::ScaleOut {
            region: Region::Rome,
            gpu: GpuModel::V100,
            n_gpus: 8,
        });
        assert_eq!(c.graph().n, n + 1, "scale-out must rebuild the graph");
    }

    #[test]
    fn spawn_roundtrip_over_channels() {
        let c = coordinator();
        let (tx, rx, handle) = c.spawn();
        tx.send(CoordinatorEvent::Submit {
            model: ModelSpec::bert_large(),
            iterations: 1,
        })
        .unwrap();
        let reply = rx.recv().unwrap();
        assert!(matches!(reply, CoordinatorReply::Admitted { .. }));
        tx.send(CoordinatorEvent::Shutdown).unwrap();
        let stopped = rx.recv().unwrap();
        assert!(matches!(stopped, CoordinatorReply::Stopped { .. }));
        handle.join().unwrap();
    }

    #[test]
    fn task_iter_ms_is_finite_for_running_tasks() {
        let mut c = coordinator();
        c.handle(CoordinatorEvent::Submit {
            model: ModelSpec::bert_large(),
            iterations: 10,
        });
        let t = &c.tasks[0];
        let ms = c.task_iter_ms(t).expect("running task has iter time");
        assert!(ms > 0.0);
    }
}
