//! The cluster graph: weighted adjacency over a fleet (paper §3, Fig. 1/7).
//!
//! The dense matrix is the ≤[`DENSE_ORACLE_MAX`]-machine **oracle**: it
//! defines the reference weights/summation order every sparse
//! representation must reproduce bit-for-bit, and construction refuses
//! larger fleets — planet-and-beyond fleets go through
//! [`CsrGraph::from_fleet_direct`](super::csr::CsrGraph::from_fleet_direct)
//! and [`HierarchicalGraph`](super::hier::HierarchicalGraph), which
//! never materialize the n×n matrix.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::cluster::Fleet;

/// Largest fleet the dense adjacency may be built for. Anything bigger
/// must use the CSR/hierarchical path; [`ClusterGraph::from_fleet`]
/// panics past this bound so an accidental dense build of a 100k-machine
/// fleet (40 GB of f32) is impossible.
pub const DENSE_ORACLE_MAX: usize = 1000;

/// High-water mark of dense builds this process performed — the debug
/// counter the no-dense-allocation scaling tests read. A monotone max
/// (not a delta count) so concurrent `cargo test` threads cannot race it
/// into a misleading value.
static MAX_DENSE_N: AtomicUsize = AtomicUsize::new(0);

/// Largest machine count any [`ClusterGraph::from_fleet`] call in this
/// process has densified (0 if none).
pub fn max_dense_n() -> usize {
    MAX_DENSE_N.load(Ordering::Relaxed)
}

/// Multiplicative spread of per-machine-pair path variation around the
/// regional latency (±10%). Two machines in the same region sit in
/// different DCs/racks, so their pairwise latencies differ slightly —
/// without this, same-region machines have *identical* adjacency rows and
/// are mathematically indistinguishable to the GCN (and to any scheduler)
/// even though the oracle must split them across groups.
const MACHINE_JITTER: f32 = 0.10;

/// Deterministic pair jitter in [1−J, 1+J], symmetric in (i, j). Keyed
/// by **global** machine ids, so any subgraph (CSR row, hierarchical
/// refinement pool) reproduces exactly the weights the dense oracle
/// would assign those machines.
pub(crate) fn pair_jitter(i: usize, j: usize) -> f32 {
    let (a, b) = if i < j { (i, j) } else { (j, i) };
    let mut h = (a as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b as u64)
        .wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 31;
    let u = (h >> 11) as f32 / (1u64 << 53) as f32; // [0, 1)
    1.0 - MACHINE_JITTER + 2.0 * MACHINE_JITTER * u
}

/// Dense weighted adjacency. `adj[i][j]` is the latency in ms per 64-byte
/// message between machines i and j; `0.0` means no edge (unreachable or
/// self). Symmetric, zero diagonal — exactly the paper's adjacency-matrix
/// representation (§3).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterGraph {
    pub n: usize,
    /// Row-major n×n.
    pub adj: Vec<f32>,
}

impl ClusterGraph {
    /// Build from a fleet: edge iff the two machines' regions can
    /// communicate; weight = regional WAN latency × per-pair path jitter.
    pub fn from_fleet(fleet: &Fleet) -> ClusterGraph {
        let n = fleet.len();
        assert!(
            n <= DENSE_ORACLE_MAX,
            "dense ClusterGraph is the ≤{DENSE_ORACLE_MAX}-machine \
             oracle; build CsrGraph::from_fleet_direct or a \
             HierarchicalGraph for {n} machines"
        );
        MAX_DENSE_N.fetch_max(n, Ordering::Relaxed);
        let mut adj = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                if let Some(lat) = fleet.latency_ms(i, j) {
                    let w = lat as f32 * pair_jitter(i, j);
                    adj[i * n + j] = w;
                    adj[j * n + i] = w;
                }
            }
        }
        ClusterGraph { n, adj }
    }

    #[inline]
    pub fn weight(&self, i: usize, j: usize) -> f32 {
        self.adj[i * self.n + j]
    }

    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.weight(i, j) > 0.0
    }

    pub fn degree(&self, i: usize) -> usize {
        (0..self.n).filter(|&j| self.has_edge(i, j)).count()
    }

    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        (0..self.n).filter(|&j| self.has_edge(i, j)).collect()
    }

    /// Mean latency of i's incident edges (∞-free: None if isolated).
    pub fn mean_latency(&self, i: usize) -> Option<f32> {
        let nbrs = self.neighbors(i);
        if nbrs.is_empty() {
            return None;
        }
        Some(nbrs.iter().map(|&j| self.weight(i, j)).sum::<f32>()
            / nbrs.len() as f32)
    }

    pub fn min_latency(&self, i: usize) -> Option<f32> {
        self.neighbors(i)
            .iter()
            .map(|&j| self.weight(i, j))
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Total edge weight inside a node subset — the objective Hulk
    /// minimizes per task group (intra-group communication cost).
    pub fn subset_cost(&self, nodes: &[usize]) -> f64 {
        let mut cost = 0.0;
        for (k, &i) in nodes.iter().enumerate() {
            for &j in &nodes[k + 1..] {
                cost += self.weight(i, j) as f64;
            }
        }
        cost
    }

    /// Is the induced subgraph on `nodes` connected? (A task group must be
    /// able to pipeline across its members.)
    pub fn subset_connected(&self, nodes: &[usize]) -> bool {
        if nodes.is_empty() {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![nodes[0]];
        seen[nodes[0]] = true;
        let in_set: Vec<bool> = {
            let mut v = vec![false; self.n];
            for &i in nodes {
                v[i] = true;
            }
            v
        };
        let mut count = 0;
        while let Some(i) = stack.pop() {
            count += 1;
            for j in 0..self.n {
                if in_set[j] && !seen[j] && self.has_edge(i, j) {
                    seen[j] = true;
                    stack.push(j);
                }
            }
        }
        count == nodes.len()
    }

    /// Pad to `slots` node slots (the GCN artifact's fixed N): returns the
    /// padded row-major adjacency. Padded slots are isolated.
    pub fn padded_adj(&self, slots: usize) -> Vec<f32> {
        assert!(slots >= self.n, "graph larger than artifact slots");
        let mut out = vec![0.0f32; slots * slots];
        for i in 0..self.n {
            for j in 0..self.n {
                out[i * slots + j] = self.adj[i * self.n + j];
            }
        }
        out
    }

    /// Node mask for `slots` slots: 1.0 for real nodes, 0.0 for padding.
    pub fn padded_mask(&self, slots: usize) -> Vec<f32> {
        assert!(slots >= self.n);
        let mut m = vec![0.0f32; slots];
        for v in &mut m[..self.n] {
            *v = 1.0;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Fleet, Machine, Region};

    #[test]
    fn from_fleet_is_symmetric_zero_diagonal() {
        let g = ClusterGraph::from_fleet(&Fleet::paper_toy(0));
        assert_eq!(g.n, 8);
        for i in 0..g.n {
            assert_eq!(g.weight(i, i), 0.0);
            for j in 0..g.n {
                assert_eq!(g.weight(i, j), g.weight(j, i));
            }
        }
    }

    #[test]
    fn blocked_pair_has_no_edge() {
        // Build a fleet with Beijing and Paris machines: Table 1 blocks
        // that pair.
        let mut fleet = Fleet::paper_toy(0);
        let paris = fleet.add_machine(
            Region::Paris,
            crate::cluster::GpuModel::V100,
            8,
        );
        let g = ClusterGraph::from_fleet(&fleet);
        assert!(!g.has_edge(0, paris)); // node0 is Beijing
        assert!(g.has_edge(1, paris)); // Nanjing–Paris measured 265.1
    }

    #[test]
    fn degree_and_neighbors_consistent() {
        let g = ClusterGraph::from_fleet(&Fleet::paper_evaluation(0));
        for i in 0..g.n {
            assert_eq!(g.degree(i), g.neighbors(i).len());
        }
    }

    #[test]
    fn subset_cost_counts_each_pair_once() {
        let g = ClusterGraph {
            n: 3,
            adj: vec![0.0, 10.0, 20.0, 10.0, 0.0, 30.0, 20.0, 30.0, 0.0],
        };
        assert_eq!(g.subset_cost(&[0, 1, 2]), 60.0);
        assert_eq!(g.subset_cost(&[0, 1]), 10.0);
        assert_eq!(g.subset_cost(&[0]), 0.0);
    }

    #[test]
    fn connectivity_detects_split_groups() {
        // 0-1 connected, 2 isolated.
        let g = ClusterGraph {
            n: 3,
            adj: vec![0.0, 5.0, 0.0, 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        };
        assert!(g.subset_connected(&[0, 1]));
        assert!(!g.subset_connected(&[0, 2]));
        assert!(g.subset_connected(&[2]));
        assert!(g.subset_connected(&[]));
    }

    #[test]
    fn padding_preserves_content_and_masks() {
        let g = ClusterGraph::from_fleet(&Fleet::paper_toy(0));
        let padded = g.padded_adj(16);
        let mask = g.padded_mask(16);
        assert_eq!(padded.len(), 256);
        assert_eq!(mask.iter().sum::<f32>(), 8.0);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(padded[i * 16 + j], g.weight(i, j));
            }
        }
        // Padded rows are all zero.
        for i in 8..16 {
            for j in 0..16 {
                assert_eq!(padded[i * 16 + j], 0.0);
            }
        }
    }

    #[test]
    #[should_panic]
    fn padding_smaller_than_graph_panics() {
        let g = ClusterGraph::from_fleet(&Fleet::paper_toy(0));
        g.padded_adj(4);
    }

    /// Symmetry + zero diagonal + CSR round-trip for one fleet — the
    /// invariants every from_fleet graph must satisfy, checked at the
    /// degenerate shapes below.
    fn check_edge_fleet(fleet: &Fleet) {
        use crate::graph::CsrGraph;
        let g = ClusterGraph::from_fleet(fleet);
        assert_eq!(g.n, fleet.len());
        for i in 0..g.n {
            assert_eq!(g.weight(i, i), 0.0, "diagonal must be zero");
            for j in 0..g.n {
                assert_eq!(g.weight(i, j).to_bits(),
                           g.weight(j, i).to_bits(), "asymmetric ({i},{j})");
            }
        }
        // CSR round-trip: direct-from-fleet CSR == dense-then-compress,
        // and both re-densify to the original matrix.
        let via_dense = CsrGraph::from_graph(&g);
        let direct = CsrGraph::from_fleet_direct(fleet);
        assert_eq!(via_dense, direct);
        assert_eq!(direct.to_dense(), g.adj);
    }

    #[test]
    fn single_machine_fleet_graph_is_empty_but_valid() {
        let machines =
            vec![Machine::new(0, Region::Rome, crate::cluster::GpuModel::V100,
                              8)];
        let fleet = Fleet::new(machines, crate::cluster::WanModel::new(0));
        check_edge_fleet(&fleet);
        let g = ClusterGraph::from_fleet(&fleet);
        assert_eq!(g.degree(0), 0);
        assert_eq!(g.mean_latency(0), None);
    }

    #[test]
    fn single_region_fleet_is_a_jittered_intra_region_clique() {
        let fleet = Fleet::synthetic(6, 1, 5);
        check_edge_fleet(&fleet);
        let g = ClusterGraph::from_fleet(&fleet);
        for i in 0..g.n {
            assert_eq!(g.degree(i), g.n - 1, "intra-region clique");
            for j in 0..g.n {
                if i != j {
                    // INTRA_REGION_MS × jitter stays within ±10%.
                    assert!((0.9..=1.1).contains(&g.weight(i, j)),
                            "({i},{j}): {}", g.weight(i, j));
                }
            }
        }
    }

    #[test]
    fn fully_policy_blocked_pair_yields_disconnected_graph() {
        // A two-machine fleet straddling the Beijing↔Paris block: the
        // graph must be valid, symmetric, and entirely edgeless.
        let machines = vec![
            Machine::new(0, Region::Beijing,
                         crate::cluster::GpuModel::A100, 8),
            Machine::new(1, Region::Paris, crate::cluster::GpuModel::V100,
                         8),
        ];
        let fleet = Fleet::new(machines, crate::cluster::WanModel::new(0));
        check_edge_fleet(&fleet);
        let g = ClusterGraph::from_fleet(&fleet);
        assert!(!g.has_edge(0, 1));
        assert_eq!(g.mean_latency(0), None);
        assert_eq!(g.mean_latency(1), None);
        assert!(!g.subset_connected(&[0, 1]));
    }

    #[test]
    #[should_panic(expected = "oracle")]
    fn dense_build_refuses_fleets_past_the_oracle_bound() {
        let fleet = Fleet::synthetic(DENSE_ORACLE_MAX + 1, 12, 0);
        ClusterGraph::from_fleet(&fleet);
    }

    #[test]
    fn max_dense_n_tracks_the_high_water_mark() {
        let before = max_dense_n();
        ClusterGraph::from_fleet(&Fleet::paper_toy(0));
        assert!(max_dense_n() >= 8.max(before));
        assert!(max_dense_n() <= DENSE_ORACLE_MAX);
    }
}
