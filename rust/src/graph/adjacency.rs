//! The cluster graph: weighted adjacency over a fleet (paper §3, Fig. 1/7).

use crate::cluster::Fleet;

/// Multiplicative spread of per-machine-pair path variation around the
/// regional latency (±10%). Two machines in the same region sit in
/// different DCs/racks, so their pairwise latencies differ slightly —
/// without this, same-region machines have *identical* adjacency rows and
/// are mathematically indistinguishable to the GCN (and to any scheduler)
/// even though the oracle must split them across groups.
const MACHINE_JITTER: f32 = 0.10;

/// Deterministic pair jitter in [1−J, 1+J], symmetric in (i, j).
fn pair_jitter(i: usize, j: usize) -> f32 {
    let (a, b) = if i < j { (i, j) } else { (j, i) };
    let mut h = (a as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b as u64)
        .wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 31;
    let u = (h >> 11) as f32 / (1u64 << 53) as f32; // [0, 1)
    1.0 - MACHINE_JITTER + 2.0 * MACHINE_JITTER * u
}

/// Dense weighted adjacency. `adj[i][j]` is the latency in ms per 64-byte
/// message between machines i and j; `0.0` means no edge (unreachable or
/// self). Symmetric, zero diagonal — exactly the paper's adjacency-matrix
/// representation (§3).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterGraph {
    pub n: usize,
    /// Row-major n×n.
    pub adj: Vec<f32>,
}

impl ClusterGraph {
    /// Build from a fleet: edge iff the two machines' regions can
    /// communicate; weight = regional WAN latency × per-pair path jitter.
    pub fn from_fleet(fleet: &Fleet) -> ClusterGraph {
        let n = fleet.len();
        let mut adj = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                if let Some(lat) = fleet.latency_ms(i, j) {
                    let w = lat as f32 * pair_jitter(i, j);
                    adj[i * n + j] = w;
                    adj[j * n + i] = w;
                }
            }
        }
        ClusterGraph { n, adj }
    }

    #[inline]
    pub fn weight(&self, i: usize, j: usize) -> f32 {
        self.adj[i * self.n + j]
    }

    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.weight(i, j) > 0.0
    }

    pub fn degree(&self, i: usize) -> usize {
        (0..self.n).filter(|&j| self.has_edge(i, j)).count()
    }

    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        (0..self.n).filter(|&j| self.has_edge(i, j)).collect()
    }

    /// Mean latency of i's incident edges (∞-free: None if isolated).
    pub fn mean_latency(&self, i: usize) -> Option<f32> {
        let nbrs = self.neighbors(i);
        if nbrs.is_empty() {
            return None;
        }
        Some(nbrs.iter().map(|&j| self.weight(i, j)).sum::<f32>()
            / nbrs.len() as f32)
    }

    pub fn min_latency(&self, i: usize) -> Option<f32> {
        self.neighbors(i)
            .iter()
            .map(|&j| self.weight(i, j))
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Total edge weight inside a node subset — the objective Hulk
    /// minimizes per task group (intra-group communication cost).
    pub fn subset_cost(&self, nodes: &[usize]) -> f64 {
        let mut cost = 0.0;
        for (k, &i) in nodes.iter().enumerate() {
            for &j in &nodes[k + 1..] {
                cost += self.weight(i, j) as f64;
            }
        }
        cost
    }

    /// Is the induced subgraph on `nodes` connected? (A task group must be
    /// able to pipeline across its members.)
    pub fn subset_connected(&self, nodes: &[usize]) -> bool {
        if nodes.is_empty() {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![nodes[0]];
        seen[nodes[0]] = true;
        let in_set: Vec<bool> = {
            let mut v = vec![false; self.n];
            for &i in nodes {
                v[i] = true;
            }
            v
        };
        let mut count = 0;
        while let Some(i) = stack.pop() {
            count += 1;
            for j in 0..self.n {
                if in_set[j] && !seen[j] && self.has_edge(i, j) {
                    seen[j] = true;
                    stack.push(j);
                }
            }
        }
        count == nodes.len()
    }

    /// Pad to `slots` node slots (the GCN artifact's fixed N): returns the
    /// padded row-major adjacency. Padded slots are isolated.
    pub fn padded_adj(&self, slots: usize) -> Vec<f32> {
        assert!(slots >= self.n, "graph larger than artifact slots");
        let mut out = vec![0.0f32; slots * slots];
        for i in 0..self.n {
            for j in 0..self.n {
                out[i * slots + j] = self.adj[i * self.n + j];
            }
        }
        out
    }

    /// Node mask for `slots` slots: 1.0 for real nodes, 0.0 for padding.
    pub fn padded_mask(&self, slots: usize) -> Vec<f32> {
        assert!(slots >= self.n);
        let mut m = vec![0.0f32; slots];
        for v in &mut m[..self.n] {
            *v = 1.0;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Fleet, Region};

    #[test]
    fn from_fleet_is_symmetric_zero_diagonal() {
        let g = ClusterGraph::from_fleet(&Fleet::paper_toy(0));
        assert_eq!(g.n, 8);
        for i in 0..g.n {
            assert_eq!(g.weight(i, i), 0.0);
            for j in 0..g.n {
                assert_eq!(g.weight(i, j), g.weight(j, i));
            }
        }
    }

    #[test]
    fn blocked_pair_has_no_edge() {
        // Build a fleet with Beijing and Paris machines: Table 1 blocks
        // that pair.
        let mut fleet = Fleet::paper_toy(0);
        let paris = fleet.add_machine(
            Region::Paris,
            crate::cluster::GpuModel::V100,
            8,
        );
        let g = ClusterGraph::from_fleet(&fleet);
        assert!(!g.has_edge(0, paris)); // node0 is Beijing
        assert!(g.has_edge(1, paris)); // Nanjing–Paris measured 265.1
    }

    #[test]
    fn degree_and_neighbors_consistent() {
        let g = ClusterGraph::from_fleet(&Fleet::paper_evaluation(0));
        for i in 0..g.n {
            assert_eq!(g.degree(i), g.neighbors(i).len());
        }
    }

    #[test]
    fn subset_cost_counts_each_pair_once() {
        let g = ClusterGraph {
            n: 3,
            adj: vec![0.0, 10.0, 20.0, 10.0, 0.0, 30.0, 20.0, 30.0, 0.0],
        };
        assert_eq!(g.subset_cost(&[0, 1, 2]), 60.0);
        assert_eq!(g.subset_cost(&[0, 1]), 10.0);
        assert_eq!(g.subset_cost(&[0]), 0.0);
    }

    #[test]
    fn connectivity_detects_split_groups() {
        // 0-1 connected, 2 isolated.
        let g = ClusterGraph {
            n: 3,
            adj: vec![0.0, 5.0, 0.0, 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        };
        assert!(g.subset_connected(&[0, 1]));
        assert!(!g.subset_connected(&[0, 2]));
        assert!(g.subset_connected(&[2]));
        assert!(g.subset_connected(&[]));
    }

    #[test]
    fn padding_preserves_content_and_masks() {
        let g = ClusterGraph::from_fleet(&Fleet::paper_toy(0));
        let padded = g.padded_adj(16);
        let mask = g.padded_mask(16);
        assert_eq!(padded.len(), 256);
        assert_eq!(mask.iter().sum::<f32>(), 8.0);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(padded[i * 16 + j], g.weight(i, j));
            }
        }
        // Padded rows are all zero.
        for i in 8..16 {
            for j in 0..16 {
                assert_eq!(padded[i * 16 + j], 0.0);
            }
        }
    }

    #[test]
    #[should_panic]
    fn padding_smaller_than_graph_panics() {
        let g = ClusterGraph::from_fleet(&Fleet::paper_toy(0));
        g.padded_adj(4);
    }
}
