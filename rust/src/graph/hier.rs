//! Two-level hierarchical fleet graph — the planning substrate for
//! 10k–100k-machine fleets (DistDGL-style coarsen-then-refine).
//!
//! Levels:
//!
//! - **Coarse**: one node per populated region (≤ the 12-region catalog),
//!   edge weight = region-pair WAN latency, no per-machine jitter. Small
//!   enough that the planner (and the GCN) can afford dense O(k²) work.
//! - **Fine**: the machine level. Below [`HIER_THRESHOLD`] machines the
//!   full CSR is built eagerly ([`CsrGraph::from_fleet_direct`] — still
//!   no dense n×n anywhere); above it the level is **lazy**: no
//!   machine-level graph is ever materialized, and pair weights are
//!   computed on demand from the two machines' regions plus the
//!   deterministic global-id [`pair_jitter`] — bit-identical to what the
//!   dense oracle would store for the same ids.
//!
//! Incremental updates (the online-scheduling seam): [`apply_failure`]
//! flips an alive bit (dead nodes become isolated — the same masking
//! semantics the coordinator uses, so global ids and therefore jitter
//! never shift), and [`apply_join`] appends machines with ids strictly
//! above every existing id (ascending-order iteration, and hence f32
//! summation order, is preserved). Both rebuild only the ≤12-node coarse
//! level.
//!
//! [`apply_failure`]: HierarchicalGraph::apply_failure
//! [`apply_join`]: HierarchicalGraph::apply_join

use std::sync::Arc;

use super::adjacency::{pair_jitter, ClusterGraph, DENSE_ORACLE_MAX};
use super::csr::CsrGraph;
use super::view::GraphView;
use crate::cluster::{Fleet, GpuModel, Machine, Region, WanModel};

/// Machine counts above this plan on the coarse level first and refine
/// lazily; at or below it the fine CSR is built eagerly and planning is
/// identical to the flat path. Matches [`DENSE_ORACLE_MAX`] so every
/// fleet the dense oracle accepts is planned exactly as before.
pub const HIER_THRESHOLD: usize = DENSE_ORACLE_MAX;

/// Per-region aggregate: the coarse level's node payload.
#[derive(Clone, Debug)]
pub struct RegionSummary {
    pub region: Region,
    /// Member machine ids, ascending. Global ids — regions need not be
    /// contiguous blocks (hetero fleets round-robin them).
    pub members: Vec<usize>,
    /// Total memory of the *alive* members, GB.
    pub total_memory_gb: f64,
}

#[derive(Clone, Debug)]
enum FineLevel {
    /// Eager machine-level CSR (fleets ≤ [`HIER_THRESHOLD`]).
    Full(CsrGraph),
    /// No machine-level graph exists; weights are computed on demand.
    Lazy,
}

/// The two-level graph. Owns its fleet snapshot (`Arc` — shared with the
/// `ScenarioWorld`) plus the join/failure deltas applied since.
#[derive(Clone, Debug)]
pub struct HierarchicalGraph {
    fleet: Arc<Fleet>,
    /// Machines appended by [`apply_join`](Self::apply_join); their ids
    /// continue the fleet's dense range (`fleet.len()..`).
    joined: Vec<Machine>,
    /// Alive mask over `fleet.len() + joined.len()` ids.
    alive: Vec<bool>,
    summaries: Vec<RegionSummary>,
    coarse: ClusterGraph,
    fine: FineLevel,
    /// Bumped on every mutation — part of [`GraphView::memo_key`] so
    /// forward-pass memos can never survive an in-place update.
    version: usize,
}

impl HierarchicalGraph {
    pub fn from_fleet(fleet: Arc<Fleet>) -> HierarchicalGraph {
        let n = fleet.len();
        let mut summaries: Vec<RegionSummary> = Vec::new();
        for m in &fleet.machines {
            match summaries.iter_mut().find(|s| s.region == m.region) {
                Some(s) => {
                    s.members.push(m.id);
                    s.total_memory_gb += m.total_memory_gb();
                }
                None => summaries.push(RegionSummary {
                    region: m.region,
                    members: vec![m.id],
                    total_memory_gb: m.total_memory_gb(),
                }),
            }
        }
        let coarse = build_coarse(&summaries, &fleet);
        let fine = if n <= HIER_THRESHOLD {
            FineLevel::Full(CsrGraph::from_fleet_direct(&fleet))
        } else {
            FineLevel::Lazy
        };
        HierarchicalGraph {
            alive: vec![true; n],
            joined: Vec::new(),
            fleet,
            summaries,
            coarse,
            fine,
            version: 0,
        }
    }

    /// Is the fine level lazy? True ⇔ the fleet is past
    /// [`HIER_THRESHOLD`] and planners must go region-first.
    pub fn is_coarse(&self) -> bool {
        matches!(self.fine, FineLevel::Lazy)
    }

    /// Region summaries, first-occurrence order (= coarse node order).
    pub fn summaries(&self) -> &[RegionSummary] {
        &self.summaries
    }

    /// The coarse inter-region graph; node k = `summaries()[k]`.
    pub fn coarse(&self) -> &ClusterGraph {
        &self.coarse
    }

    /// Machine by global id (base fleet or joined). `Machine` is `Copy`.
    pub fn machine(&self, id: usize) -> Machine {
        if id < self.fleet.len() {
            self.fleet.machines[id]
        } else {
            self.joined[id - self.fleet.len()]
        }
    }

    pub fn is_alive(&self, id: usize) -> bool {
        self.alive[id]
    }

    /// One representative pseudo-machine per coarse node, for running the
    /// GCN over the coarse graph: the summary's first alive member
    /// re-badged with the coarse node index as its id (feature extraction
    /// wants dense ids). Empty regions get a 1-GPU placeholder so the
    /// tensor stays rectangular; their coarse row is all-zero anyway.
    pub fn region_representatives(&self) -> Vec<Machine> {
        self.summaries
            .iter()
            .enumerate()
            .map(|(k, s)| match s.members.first() {
                Some(&id) => {
                    let m = self.machine(id);
                    Machine::new(k, s.region, m.gpu, m.n_gpus)
                }
                None => Machine::new(k, s.region, GpuModel::V100, 1),
            })
            .collect()
    }

    /// Mark a machine failed: it keeps its id (jitter stability) but
    /// becomes isolated — weight 0 on every incident edge — and leaves
    /// its region summary. Only the ≤12-node coarse level is rebuilt.
    pub fn apply_failure(&mut self, id: usize) {
        assert!(self.alive[id], "machine {id} already failed");
        self.alive[id] = false;
        let region = self.machine(id).region;
        let idx = self
            .summaries
            .iter()
            .position(|s| s.region == region)
            .expect("failed machine's region has a summary");
        self.summaries[idx].members.retain(|&m| m != id);
        let mem: f64 = self.summaries[idx]
            .members
            .iter()
            .map(|&m| self.machine(m).total_memory_gb())
            .sum();
        self.summaries[idx].total_memory_gb = mem;
        self.coarse = build_coarse(&self.summaries, &self.fleet);
        self.version += 1;
    }

    /// Append a machine (scale-out). Its id continues the dense range —
    /// strictly above every existing id — so ascending-order iteration
    /// (and the f32 summation order it fixes) is unchanged for old nodes.
    /// Returns the new id.
    pub fn apply_join(&mut self, region: Region, gpu: GpuModel,
                      n_gpus: usize) -> usize
    {
        let id = self.n_nodes();
        let m = Machine::new(id, region, gpu, n_gpus);
        self.joined.push(m);
        self.alive.push(true);
        match self.summaries.iter_mut().find(|s| s.region == region) {
            Some(s) => {
                s.members.push(id); // id > all existing ⇒ still ascending
                s.total_memory_gb += m.total_memory_gb();
            }
            None => self.summaries.push(RegionSummary {
                region,
                members: vec![id],
                total_memory_gb: m.total_memory_gb(),
            }),
        }
        self.coarse = build_coarse(&self.summaries, &self.fleet);
        self.version += 1;
        id
    }

    /// Swap in a new WAN model (link brownout / flap injection): every
    /// weight the graph serves — coarse region pairs, eager fine CSR,
    /// on-demand [`demand_weight`](Self::demand_weight) — reads
    /// `fleet.wan`, so the whole fleet snapshot is re-`Arc`ed with the
    /// new matrix. Machines, ids, the alive mask, and joins are
    /// untouched (jitter never shifts); only the ≤12-node coarse level
    /// and (when eager) the fine CSR are rebuilt, and the version bump
    /// invalidates every forward-pass memo.
    pub fn apply_wan(&mut self, wan: WanModel) {
        let mut fleet = (*self.fleet).clone();
        fleet.wan = wan;
        let fleet = Arc::new(fleet);
        self.coarse = build_coarse(&self.summaries, &fleet);
        if matches!(self.fine, FineLevel::Full(_)) {
            self.fine = FineLevel::Full(CsrGraph::from_fleet_direct(&fleet));
        }
        self.fleet = fleet;
        self.version += 1;
    }

    fn has_deltas(&self) -> bool {
        !self.joined.is_empty() || self.alive.iter().any(|&a| !a)
    }

    /// The weight the dense oracle would assign (i, j), honoring the
    /// alive mask: regional WAN latency × global-id pair jitter.
    fn demand_weight(&self, i: usize, j: usize) -> f32 {
        if i == j || !self.alive[i] || !self.alive[j] {
            return 0.0;
        }
        let (ra, rb) = (self.machine(i).region, self.machine(j).region);
        match self.fleet.wan.latency_ms(ra, rb) {
            Some(lat) => lat as f32 * pair_jitter(i, j),
            None => 0.0,
        }
    }
}

/// Coarse inter-region graph: weight = WAN latency between the two
/// regions (no jitter — jitter is a per-machine-pair notion). Regions
/// whose summaries are empty are isolated.
fn build_coarse(summaries: &[RegionSummary], fleet: &Fleet) -> ClusterGraph {
    let k = summaries.len();
    let mut adj = vec![0.0f32; k * k];
    for a in 0..k {
        if summaries[a].members.is_empty() {
            continue;
        }
        for b in (a + 1)..k {
            if summaries[b].members.is_empty() {
                continue;
            }
            if let Some(lat) = fleet
                .wan
                .latency_ms(summaries[a].region, summaries[b].region)
            {
                adj[a * k + b] = lat as f32;
                adj[b * k + a] = lat as f32;
            }
        }
    }
    ClusterGraph { n: k, adj }
}

impl GraphView for HierarchicalGraph {
    fn n_nodes(&self) -> usize {
        self.fleet.len() + self.joined.len()
    }

    fn weight(&self, i: usize, j: usize) -> f32 {
        if i >= self.n_nodes() || j >= self.n_nodes() {
            return 0.0;
        }
        match &self.fine {
            // Delta-free Full: the stored CSR *is* the oracle value.
            FineLevel::Full(csr) if !self.has_deltas() => {
                GraphView::weight(csr, i, j)
            }
            _ => self.demand_weight(i, j),
        }
    }

    fn mean_latency(&self, i: usize) -> Option<f32> {
        if i >= self.n_nodes() || !self.alive[i] {
            return None;
        }
        if let FineLevel::Full(csr) = &self.fine {
            if !self.has_deltas() {
                return GraphView::mean_latency(csr, i);
            }
        }
        // Ascending-j scan = the dense oracle's summation order.
        let mut sum = 0.0f32;
        let mut count = 0usize;
        for j in 0..self.n_nodes() {
            let w = self.demand_weight(i, j);
            if w > 0.0 {
                sum += w;
                count += 1;
            }
        }
        if count == 0 {
            None
        } else {
            Some(sum / count as f32)
        }
    }

    fn padded_csr(&self, slots: usize) -> CsrGraph {
        match &self.fine {
            FineLevel::Full(csr) if !self.has_deltas() => {
                csr.with_slots(slots)
            }
            FineLevel::Full(_) => {
                // Deltas present: rebuild the masked CSR on demand
                // (n ≤ HIER_THRESHOLD here, so O(n²) scan is the dense
                // oracle's own cost).
                let n = self.n_nodes();
                assert!(slots >= n, "graph larger than artifact slots");
                let mut row_ptr = Vec::with_capacity(slots + 1);
                row_ptr.push(0);
                let mut cols = Vec::new();
                let mut vals = Vec::new();
                for i in 0..n {
                    for j in 0..n {
                        let w = self.demand_weight(i, j);
                        if w > 0.0 {
                            cols.push(j);
                            vals.push(w);
                        }
                    }
                    row_ptr.push(cols.len());
                }
                row_ptr.resize(slots + 1, cols.len());
                CsrGraph { n: slots, real: n, row_ptr, cols, vals }
            }
            FineLevel::Lazy => panic!(
                "machine-level GCN tensors are not available past \
                 HIER_THRESHOLD ({HIER_THRESHOLD}) machines; run the GCN \
                 over coarse() + region_representatives() instead"
            ),
        }
    }

    fn memo_key(&self) -> (usize, usize) {
        (
            self.n_nodes(),
            (self.coarse.adj.as_ptr() as usize)
                ^ self.version.wrapping_mul(0x9E37_79B9),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::adjacency::max_dense_n;

    fn hier(fleet: Fleet) -> HierarchicalGraph {
        HierarchicalGraph::from_fleet(Arc::new(fleet))
    }

    #[test]
    fn full_level_is_bit_identical_to_the_dense_oracle() {
        for fleet in
            [Fleet::paper_toy(0), Fleet::paper_evaluation(1),
             Fleet::synthetic(60, 7, 3)]
        {
            let dense = ClusterGraph::from_fleet(&fleet);
            let h = hier(fleet);
            assert!(!h.is_coarse());
            assert_eq!(h.n_nodes(), dense.n);
            for i in 0..dense.n {
                assert_eq!(
                    GraphView::mean_latency(&h, i).map(f32::to_bits),
                    GraphView::mean_latency(&dense, i).map(f32::to_bits)
                );
                for j in 0..dense.n {
                    assert_eq!(GraphView::weight(&h, i, j).to_bits(),
                               dense.weight(i, j).to_bits());
                }
            }
            let slots = dense.n + 5;
            assert_eq!(GraphView::padded_csr(&h, slots),
                       CsrGraph::padded(&dense, slots));
            assert_eq!(GraphView::padded_mask(&h, slots),
                       dense.padded_mask(slots));
        }
    }

    #[test]
    fn coarse_level_has_region_pair_wan_weights() {
        let fleet = Fleet::synthetic(60, 7, 3);
        let wan = fleet.wan.clone();
        let h = hier(fleet);
        let coarse = h.coarse();
        assert_eq!(coarse.n, h.summaries().len());
        assert_eq!(coarse.n, 7);
        for a in 0..coarse.n {
            assert_eq!(coarse.weight(a, a), 0.0);
            for b in 0..coarse.n {
                let expect = if a == b {
                    None
                } else {
                    wan.latency_ms(h.summaries()[a].region,
                                   h.summaries()[b].region)
                };
                match expect {
                    Some(lat) => {
                        assert_eq!(coarse.weight(a, b), lat as f32)
                    }
                    None => assert_eq!(coarse.weight(a, b), 0.0),
                }
            }
        }
        // Summary members cover 0..n ascending, disjoint.
        let mut all: Vec<usize> = h
            .summaries()
            .iter()
            .flat_map(|s| {
                assert!(s.members.windows(2).all(|w| w[0] < w[1]));
                s.members.iter().copied()
            })
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..h.n_nodes()).collect::<Vec<_>>());
    }

    #[test]
    fn past_threshold_is_lazy_and_never_densifies() {
        let n = HIER_THRESHOLD + 500;
        let fleet = Fleet::synthetic(n, 12, 0);
        let h = hier(fleet.clone());
        assert!(h.is_coarse());
        // Spot-check on-demand weights against the oracle formula.
        for (i, j) in [(0usize, 1usize), (3, n - 1), (n - 2, n - 1)] {
            let expect = match fleet.latency_ms(i, j) {
                Some(lat) => lat as f32 * pair_jitter(i, j),
                None => 0.0,
            };
            assert_eq!(GraphView::weight(&h, i, j).to_bits(),
                       expect.to_bits());
            assert_eq!(GraphView::weight(&h, j, i).to_bits(),
                       expect.to_bits());
        }
        // The whole construction stayed under the dense-oracle bound.
        assert!(max_dense_n() <= DENSE_ORACLE_MAX);
    }

    #[test]
    #[should_panic(expected = "HIER_THRESHOLD")]
    fn lazy_level_refuses_machine_level_tensors() {
        let h = hier(Fleet::synthetic(HIER_THRESHOLD + 1, 12, 0));
        GraphView::padded_csr(&h, HIER_THRESHOLD + 10);
    }

    #[test]
    fn apply_failure_isolates_the_machine_and_updates_summaries() {
        let fleet = Fleet::synthetic(40, 5, 2);
        let mut h = hier(fleet.clone());
        let dead = 7usize;
        let region = fleet.machines[dead].region;
        let before_mem: f64 = h
            .summaries()
            .iter()
            .find(|s| s.region == region)
            .unwrap()
            .total_memory_gb;
        h.apply_failure(dead);
        assert!(!h.is_alive(dead));
        for j in 0..h.n_nodes() {
            assert_eq!(GraphView::weight(&h, dead, j), 0.0);
            assert_eq!(GraphView::weight(&h, j, dead), 0.0);
        }
        assert_eq!(GraphView::mean_latency(&h, dead), None);
        let s = h.summaries().iter().find(|s| s.region == region).unwrap();
        assert!(!s.members.contains(&dead));
        assert!(s.total_memory_gb < before_mem);
        // Survivor weights are untouched (ids, hence jitter, unchanged).
        let dense = ClusterGraph::from_fleet(&fleet);
        for i in 0..h.n_nodes() {
            for j in 0..h.n_nodes() {
                if i != dead && j != dead {
                    assert_eq!(GraphView::weight(&h, i, j).to_bits(),
                               dense.weight(i, j).to_bits());
                }
            }
        }
        // And the padded tensors equal a dense build with the dead row/col
        // masked out.
        let mut masked = dense.clone();
        for k in 0..masked.n {
            masked.adj[dead * masked.n + k] = 0.0;
            masked.adj[k * masked.n + dead] = 0.0;
        }
        let slots = masked.n + 3;
        assert_eq!(GraphView::padded_csr(&h, slots),
                   CsrGraph::padded(&masked, slots));
    }

    #[test]
    fn apply_join_matches_a_rebuilt_fleet_with_the_machine_appended() {
        let fleet = Fleet::synthetic(30, 4, 1);
        let mut h = hier(fleet.clone());
        let id = h.apply_join(Region::Rome, GpuModel::A100, 8);
        assert_eq!(id, 30);
        assert_eq!(h.n_nodes(), 31);
        let mut grown = fleet;
        grown.add_machine(Region::Rome, GpuModel::A100, 8);
        let rebuilt = hier(grown);
        for i in 0..h.n_nodes() {
            assert_eq!(
                GraphView::mean_latency(&h, i).map(f32::to_bits),
                GraphView::mean_latency(&rebuilt, i).map(f32::to_bits),
                "mean_latency({i})"
            );
            for j in 0..h.n_nodes() {
                assert_eq!(GraphView::weight(&h, i, j).to_bits(),
                           GraphView::weight(&rebuilt, i, j).to_bits());
            }
        }
        let s =
            h.summaries().iter().find(|s| s.region == Region::Rome).unwrap();
        assert!(s.members.contains(&id));
    }

    #[test]
    fn apply_wan_matches_a_rebuild_with_the_degraded_matrix() {
        let fleet = Fleet::synthetic(40, 5, 2);
        let degraded = fleet.wan.scaled(3.0);
        let mut h = hier(fleet.clone());
        h.apply_wan(degraded.clone());
        let rebuilt = hier(Fleet::new(fleet.machines.clone(),
                                      degraded.clone()));
        for i in 0..h.n_nodes() {
            assert_eq!(
                GraphView::mean_latency(&h, i).map(f32::to_bits),
                GraphView::mean_latency(&rebuilt, i).map(f32::to_bits)
            );
            for j in 0..h.n_nodes() {
                assert_eq!(GraphView::weight(&h, i, j).to_bits(),
                           GraphView::weight(&rebuilt, i, j).to_bits());
            }
        }
        let slots = h.n_nodes() + 3;
        assert_eq!(GraphView::padded_csr(&h, slots),
                   GraphView::padded_csr(&rebuilt, slots));
        // Coarse weights picked up the multiplier too.
        for a in 0..h.coarse().n {
            for b in 0..h.coarse().n {
                assert_eq!(h.coarse().weight(a, b),
                           rebuilt.coarse().weight(a, b));
            }
        }
    }

    #[test]
    fn apply_wan_preserves_deltas_and_restores_cleanly() {
        let fleet = Fleet::synthetic(30, 4, 1);
        let base = fleet.wan.clone();
        let mut h = hier(fleet.clone());
        h.apply_failure(3);
        let id = h.apply_join(Region::Tokyo, GpuModel::A100, 8);
        h.apply_wan(base.scaled(4.0));
        assert!(!h.is_alive(3));
        assert!(h.is_alive(id));
        // Dead rows stay isolated; alive pairs follow the new matrix.
        for j in 0..h.n_nodes() {
            assert_eq!(GraphView::weight(&h, 3, j), 0.0);
        }
        let (ra, rb) = (h.machine(0).region, h.machine(1).region);
        if let Some(lat) = base.scaled(4.0).latency_ms(ra, rb) {
            assert_eq!(GraphView::weight(&h, 0, 1).to_bits(),
                       (lat as f32 * pair_jitter(0, 1)).to_bits());
        }
        // Flap back to the pristine matrix: weights equal a graph that
        // never browned out (same failure + join applied).
        h.apply_wan(base.clone());
        let mut clean = hier(fleet);
        clean.apply_failure(3);
        clean.apply_join(Region::Tokyo, GpuModel::A100, 8);
        for i in 0..h.n_nodes() {
            for j in 0..h.n_nodes() {
                assert_eq!(GraphView::weight(&h, i, j).to_bits(),
                           GraphView::weight(&clean, i, j).to_bits());
            }
        }
    }

    #[test]
    fn mutations_change_the_memo_key() {
        let mut h = hier(Fleet::synthetic(20, 3, 0));
        let k0 = GraphView::memo_key(&h);
        h.apply_failure(5);
        let k1 = GraphView::memo_key(&h);
        assert_ne!(k0, k1);
        h.apply_join(Region::Tokyo, GpuModel::V100, 8);
        let k2 = GraphView::memo_key(&h);
        assert_ne!(k1, k2);
        let wan = Fleet::synthetic(20, 3, 0).wan.scaled(2.0);
        h.apply_wan(wan);
        let k3 = GraphView::memo_key(&h);
        assert_ne!(k2, k3);
    }

    #[test]
    fn representatives_align_with_coarse_nodes() {
        let h = hier(Fleet::synthetic(60, 7, 3));
        let reps = h.region_representatives();
        assert_eq!(reps.len(), h.coarse().n);
        for (k, (rep, s)) in reps.iter().zip(h.summaries()).enumerate() {
            assert_eq!(rep.id, k);
            assert_eq!(rep.region, s.region);
        }
    }
}
