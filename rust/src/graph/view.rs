//! [`GraphView`] — the planner-facing graph abstraction.
//!
//! Everything the scheduling stack reads from a fleet graph goes through
//! this trait: pairwise weights, per-node mean latency, and the padded
//! GCN tensors. Three implementations exist:
//!
//! - [`ClusterGraph`] — the dense O(n²) adjacency, demoted to a
//!   ≤[`DENSE_ORACLE_MAX`](super::adjacency::DENSE_ORACLE_MAX)-machine
//!   oracle (construction asserts the bound).
//! - [`CsrGraph`] — CSR built **directly from the fleet**
//!   ([`CsrGraph::from_fleet_direct`]), no dense intermediate anywhere.
//! - [`HierarchicalGraph`](super::hier::HierarchicalGraph) — the
//!   two-level substrate for 10k–100k-machine fleets.
//!
//! The contract that makes the refactor artifact-safe: for the same
//! fleet, every implementation must return **bit-identical** `weight`
//! and `mean_latency` values. CSR stores exactly the positive entries of
//! the dense row in ascending column order, so its float summation
//! visits the same addends in the same order as a dense row scan.

use super::csr::CsrGraph;

/// Read-only graph interface consumed by `grow_group`, `chain_order`,
/// `TaskSplitter`, and GCN inference. `&ClusterGraph` coerces to
/// `&dyn GraphView` at every historical call site.
pub trait GraphView {
    /// Number of machine nodes (excluding any padding slots).
    fn n_nodes(&self) -> usize;

    /// Edge weight (latency ms per 64 B) between nodes i and j;
    /// `0.0` = no edge (unreachable, self, or dead node).
    fn weight(&self, i: usize, j: usize) -> f32;

    /// Is there an edge between i and j?
    fn has_edge(&self, i: usize, j: usize) -> bool {
        self.weight(i, j) > 0.0
    }

    /// Mean latency of i's incident edges (`None` if isolated).
    /// Implementations must sum neighbors in ascending id order so the
    /// f32 reduction is bit-identical across representations.
    fn mean_latency(&self, i: usize) -> Option<f32>;

    /// The padded CSR adjacency for `slots` GCN artifact slots.
    fn padded_csr(&self, slots: usize) -> CsrGraph;

    /// Node mask for `slots` slots: 1.0 real, 0.0 padding.
    fn padded_mask(&self, slots: usize) -> Vec<f32> {
        let n = self.n_nodes();
        assert!(slots >= n, "graph larger than artifact slots");
        let mut m = vec![0.0f32; slots];
        for v in &mut m[..n] {
            *v = 1.0;
        }
        m
    }

    /// Cheap identity of the underlying storage `(node count, allocation
    /// address)` — lets forward-pass memos detect a swapped graph.
    fn memo_key(&self) -> (usize, usize);
}

impl GraphView for super::adjacency::ClusterGraph {
    fn n_nodes(&self) -> usize {
        self.n
    }

    fn weight(&self, i: usize, j: usize) -> f32 {
        super::adjacency::ClusterGraph::weight(self, i, j)
    }

    fn mean_latency(&self, i: usize) -> Option<f32> {
        super::adjacency::ClusterGraph::mean_latency(self, i)
    }

    fn padded_csr(&self, slots: usize) -> CsrGraph {
        CsrGraph::padded(self, slots)
    }

    fn padded_mask(&self, slots: usize) -> Vec<f32> {
        super::adjacency::ClusterGraph::padded_mask(self, slots)
    }

    fn memo_key(&self) -> (usize, usize) {
        (self.n, self.adj.as_ptr() as usize)
    }
}

impl GraphView for CsrGraph {
    fn n_nodes(&self) -> usize {
        self.real
    }

    fn weight(&self, i: usize, j: usize) -> f32 {
        if i >= self.real || j >= self.real {
            return 0.0;
        }
        let (cols, vals) = self.row(i);
        match cols.binary_search(&j) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    fn mean_latency(&self, i: usize) -> Option<f32> {
        if i >= self.real {
            return None;
        }
        let (_, vals) = self.row(i);
        if vals.is_empty() {
            return None;
        }
        // Ascending-column order == the dense row-scan summation order.
        Some(vals.iter().copied().sum::<f32>() / vals.len() as f32)
    }

    fn padded_csr(&self, slots: usize) -> CsrGraph {
        self.with_slots(slots)
    }

    fn memo_key(&self) -> (usize, usize) {
        // row_ptr is never empty (length real + 1 minimum), so its
        // allocation address identifies this graph.
        (self.real, self.row_ptr.as_ptr() as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::super::adjacency::ClusterGraph;
    use super::*;
    use crate::cluster::Fleet;

    fn views_agree(fleet: &Fleet) {
        let dense = ClusterGraph::from_fleet(fleet);
        let csr = CsrGraph::from_fleet_direct(fleet);
        let dv: &dyn GraphView = &dense;
        let cv: &dyn GraphView = &csr;
        assert_eq!(dv.n_nodes(), cv.n_nodes());
        for i in 0..fleet.len() {
            // Bit-identical, not approximately equal: the artifact gate
            // depends on it.
            assert_eq!(dv.mean_latency(i).map(f32::to_bits),
                       cv.mean_latency(i).map(f32::to_bits),
                       "mean_latency({i})");
            for j in 0..fleet.len() {
                assert_eq!(dv.weight(i, j).to_bits(),
                           cv.weight(i, j).to_bits(),
                           "weight({i},{j})");
                assert_eq!(dv.has_edge(i, j), cv.has_edge(i, j));
            }
        }
        let slots = fleet.len() + 9;
        assert_eq!(dv.padded_csr(slots), cv.padded_csr(slots));
        assert_eq!(dv.padded_mask(slots), cv.padded_mask(slots));
    }

    #[test]
    fn dense_and_direct_csr_views_are_bit_identical() {
        views_agree(&Fleet::paper_toy(0));
        views_agree(&Fleet::paper_evaluation(0));
        views_agree(&Fleet::synthetic(60, 7, 3));
    }

    #[test]
    fn memo_keys_distinguish_graphs() {
        let fleet = Fleet::paper_toy(0);
        let a = ClusterGraph::from_fleet(&fleet);
        let b = ClusterGraph::from_fleet(&fleet);
        assert_ne!(GraphView::memo_key(&a), GraphView::memo_key(&b));
        let c1 = CsrGraph::from_fleet_direct(&fleet);
        let c2 = CsrGraph::from_fleet_direct(&fleet);
        assert_ne!(GraphView::memo_key(&c1), GraphView::memo_key(&c2));
    }
}
