//! Graph representation of a fleet (paper §3): nodes = machines with
//! `{City, ComputeCapability, Memory}`-derived feature vectors, edges =
//! pairwise WAN latency (ms per 64-byte message), 0 = unreachable.
//!
//! This module is the single definition of the adjacency/feature encoding
//! on both sides of the PJRT boundary: the Rust coordinator builds these
//! tensors and the AOT-compiled GCN consumes them (shape contract in
//! `artifacts/manifest.kv`).

pub mod adjacency;
pub mod csr;
pub mod features;
pub mod hier;
pub mod normalize;
pub mod view;

pub use adjacency::{max_dense_n, ClusterGraph, DENSE_ORACLE_MAX};
pub use csr::{sym_normalize_csr, CsrGraph, CsrNormalized, CSR_DENSITY_MAX};
pub use features::{node_features, node_features_csr, FEATURE_DIM};
pub use hier::{HierarchicalGraph, RegionSummary, HIER_THRESHOLD};
pub use normalize::sym_normalize;
pub use view::GraphView;
