//! Compressed-sparse-row view of a [`ClusterGraph`] and the sparse
//! symmetric normalization behind the GCN's CSR forward path.
//!
//! The padded dense adjacency the GCN artifact consumes is `slots ×
//! slots` even though (a) padding rows are empty by construction and
//! (b) WAN policy blocks remove region pairs entirely. Aggregating over
//! the stored edges instead of scanning every slot pair turns the
//! forward's neighborhood work from O(slots²·F) into O(E·F)
//! (`gnn::reference::RefGcn::forward_csr`). The dense path stays as the
//! numerical oracle; [`CsrGraph::density`] drives the automatic
//! selection (`gnn::Classifier`).

use super::adjacency::{pair_jitter, ClusterGraph};
use crate::cluster::Fleet;
use crate::util::MatF32;

/// Nonzero-density ceiling below which the reference classifier
/// aggregates through the CSR path. Padding headroom (a planet-capable
/// artifact compiled for more slots than the fleet fills) and WAN policy
/// blocks keep real inputs under it; a fully occupied, fully connected
/// graph falls back to the dense oracle.
pub const CSR_DENSITY_MAX: f64 = 0.8;

/// CSR view of a (possibly padded) cluster graph. Rows `real..n` are the
/// padding slots: present in `row_ptr` but empty. Column indices are
/// strictly ascending within a row — the same visit order as a dense
/// row scan, so sparse reductions reproduce the dense float-summation
/// order exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrGraph {
    /// Row count (the artifact's slot count when built via [`padded`]).
    ///
    /// [`padded`]: CsrGraph::padded
    pub n: usize,
    /// Rows holding real machines; rows `real..n` are empty padding.
    pub real: usize,
    /// `row_ptr[i]..row_ptr[i + 1]` indexes row i's entries. Length `n + 1`.
    pub row_ptr: Vec<usize>,
    pub cols: Vec<usize>,
    /// Edge weights (latency ms), parallel to `cols`.
    pub vals: Vec<f32>,
}

impl CsrGraph {
    /// CSR of the graph at its natural size (no padding).
    pub fn from_graph(graph: &ClusterGraph) -> CsrGraph {
        CsrGraph::padded(graph, graph.n)
    }

    /// Build CSR **directly from the fleet** — no dense n×n intermediate
    /// anywhere on the path. Per row, columns are visited ascending and
    /// each weight is the same `latency × pair_jitter` expression the
    /// dense oracle evaluates, so the result is byte-identical to
    /// `CsrGraph::from_graph(&ClusterGraph::from_fleet(fleet))` without
    /// ever allocating the matrix (WAN latencies are ≥ 1 ms, so a stored
    /// entry can never be 0.0 and the `w > 0.0` compress step of the
    /// dense path drops nothing the direct path keeps).
    pub fn from_fleet_direct(fleet: &Fleet) -> CsrGraph {
        let n = fleet.len();
        let mut row_ptr = Vec::with_capacity(n + 1);
        row_ptr.push(0);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..n {
            for j in 0..n {
                if j == i {
                    continue;
                }
                if let Some(lat) = fleet.latency_ms(i, j) {
                    cols.push(j);
                    vals.push(lat as f32 * pair_jitter(i, j));
                }
            }
            row_ptr.push(cols.len());
        }
        CsrGraph { n, real: n, row_ptr, cols, vals }
    }

    /// This graph re-padded to `slots` rows — the CSR counterpart of
    /// re-deriving [`CsrGraph::padded`] at a different slot count,
    /// byte-identical to a dense-graph `padded` build of the same fleet.
    pub fn with_slots(&self, slots: usize) -> CsrGraph {
        assert!(slots >= self.real, "graph larger than artifact slots");
        let mut row_ptr = self.row_ptr[..=self.real].to_vec();
        row_ptr.resize(slots + 1, self.nnz());
        CsrGraph {
            n: slots,
            real: self.real,
            row_ptr,
            cols: self.cols.clone(),
            vals: self.vals.clone(),
        }
    }

    /// CSR of the graph padded to `slots` rows — the sparse counterpart
    /// of [`ClusterGraph::padded_adj`], without materializing the
    /// `slots²` zeros.
    pub fn padded(graph: &ClusterGraph, slots: usize) -> CsrGraph {
        assert!(slots >= graph.n, "graph larger than artifact slots");
        let mut row_ptr = Vec::with_capacity(slots + 1);
        row_ptr.push(0);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..graph.n {
            let row = &graph.adj[i * graph.n..(i + 1) * graph.n];
            for (j, &w) in row.iter().enumerate() {
                if w > 0.0 {
                    cols.push(j);
                    vals.push(w);
                }
            }
            row_ptr.push(cols.len());
        }
        row_ptr.resize(slots + 1, cols.len());
        CsrGraph { n: slots, real: graph.n, row_ptr, cols, vals }
    }

    /// Stored (nonzero) entry count.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Nonzero fraction of the padded dense matrix this view replaces.
    pub fn density(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.n * self.n) as f64
    }

    /// Row i's (columns, weights), ascending column order.
    pub fn row(&self, i: usize) -> (&[usize], &[f32]) {
        let span = self.row_ptr[i]..self.row_ptr[i + 1];
        (&self.cols[span.clone()], &self.vals[span])
    }

    /// Materialize the padded dense adjacency this view compresses —
    /// exactly [`ClusterGraph::padded_adj`]'s output (the dense-oracle
    /// fallback and the PJRT artifact consume this shape).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n * self.n];
        for i in 0..self.real {
            let (cols, vals) = self.row(i);
            for (&j, &w) in cols.iter().zip(vals) {
                out[i * self.n + j] = w;
            }
        }
        out
    }
}

/// Latency-affinity symmetric normalization Â in CSR form: the sparse
/// mirror of [`super::normalize::sym_normalize`], pattern = edges ∪
/// diagonal, columns ascending (the diagonal merged into sorted
/// position so degree sums visit addends in the dense row order).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrNormalized {
    pub n: usize,
    pub real: usize,
    pub row_ptr: Vec<usize>,
    pub cols: Vec<usize>,
    pub vals: Vec<f32>,
}

/// Compute Â = D^{-1/2} (S + I) D^{-1/2} over the CSR adjacency —
/// identical per-entry float operations (and summation order) as the
/// dense `sym_normalize`, touching only stored edges plus the diagonal.
pub fn sym_normalize_csr(adj: &CsrGraph) -> CsrNormalized {
    use super::normalize::AFFINITY_REF_LAT_MS;
    let n = adj.n;
    let mut row_ptr = Vec::with_capacity(n + 1);
    row_ptr.push(0);
    let mut cols = Vec::with_capacity(adj.nnz() + n);
    let mut vals = Vec::with_capacity(adj.nnz() + n);
    let mut deg = Vec::with_capacity(n);
    for i in 0..n {
        let (rcols, rvals) = adj.row(i);
        let mut d = 0.0f32;
        let mut diag_emitted = false;
        for (&j, &w) in rcols.iter().zip(rvals) {
            if !diag_emitted && j > i {
                cols.push(i);
                vals.push(1.0);
                d += 1.0;
                diag_emitted = true;
            }
            // The adjacency stores no self loops, so j == i cannot occur.
            let s = (AFFINITY_REF_LAT_MS / w.max(1e-6)).min(1.0);
            cols.push(j);
            vals.push(s);
            d += s;
        }
        if !diag_emitted {
            cols.push(i);
            vals.push(1.0);
            d += 1.0;
        }
        deg.push(d);
        row_ptr.push(cols.len());
    }
    let dinv: Vec<f32> =
        deg.iter().map(|&d| 1.0 / d.max(1e-12).sqrt()).collect();
    for i in 0..n {
        for k in row_ptr[i]..row_ptr[i + 1] {
            vals[k] *= dinv[i] * dinv[cols[k]];
        }
    }
    CsrNormalized { n, real: adj.real, row_ptr, cols, vals }
}

impl CsrNormalized {
    /// `Â[..real, ..real] @ x` — the sparse aggregation kernel, O(E·F).
    ///
    /// Real rows of Â only reference real columns (edges connect real
    /// machines; padding rows carry just their self loop), so the
    /// product over the `real × cols` block of `x` is exact.
    pub fn matmul_real(&self, x: &MatF32) -> MatF32 {
        assert_eq!(x.rows, self.real, "aggregation input must be real-row");
        let mut out = MatF32::zeros(self.real, x.cols);
        for i in 0..self.real {
            let orow = &mut out.data[i * x.cols..(i + 1) * x.cols];
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                let a = self.vals[k];
                let brow = x.row(self.cols[k]);
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fleet;
    use crate::graph::normalize::sym_normalize;

    fn toy() -> ClusterGraph {
        ClusterGraph::from_fleet(&Fleet::paper_toy(0))
    }

    #[test]
    fn csr_roundtrips_the_dense_adjacency() {
        let g = toy();
        let csr = CsrGraph::padded(&g, 16);
        assert_eq!(csr.n, 16);
        assert_eq!(csr.real, g.n);
        let dense = g.padded_adj(16);
        assert_eq!(csr.to_dense(), dense);
        // Columns strictly ascending per row.
        for i in 0..16 {
            let (cols, _) = csr.row(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {i}");
        }
    }

    #[test]
    fn direct_build_equals_dense_then_compress() {
        for fleet in [Fleet::paper_toy(0), Fleet::paper_evaluation(2),
                      Fleet::synthetic(60, 7, 3)]
        {
            let dense = ClusterGraph::from_fleet(&fleet);
            let direct = CsrGraph::from_fleet_direct(&fleet);
            assert_eq!(direct, CsrGraph::from_graph(&dense));
            assert_eq!(direct.to_dense(), dense.adj);
        }
    }

    #[test]
    fn with_slots_equals_padded_build_at_the_same_slot_count() {
        let fleet = Fleet::paper_toy(0);
        let dense = ClusterGraph::from_fleet(&fleet);
        let direct = CsrGraph::from_fleet_direct(&fleet);
        for slots in [fleet.len(), 16, 64] {
            assert_eq!(direct.with_slots(slots),
                       CsrGraph::padded(&dense, slots));
        }
        // Re-padding an already-padded view keeps only the real rows.
        let wide = direct.with_slots(64);
        assert_eq!(wide.with_slots(64), wide);
    }

    #[test]
    #[should_panic(expected = "slots")]
    fn with_slots_below_real_rows_panics() {
        CsrGraph::from_fleet_direct(&Fleet::paper_toy(0)).with_slots(4);
    }

    #[test]
    fn padding_rows_are_empty_and_counted_in_density() {
        let g = toy();
        let tight = CsrGraph::from_graph(&g);
        let padded = CsrGraph::padded(&g, 32);
        assert_eq!(tight.nnz(), padded.nnz());
        for i in g.n..32 {
            assert!(padded.row(i).0.is_empty());
        }
        assert!(padded.density() < tight.density());
        assert!(padded.density() <= CSR_DENSITY_MAX);
    }

    #[test]
    fn normalized_csr_matches_dense_sym_normalize() {
        let g = toy();
        let slots = 16;
        let a_dense = sym_normalize(&g.padded_adj(slots), slots);
        let a_csr = sym_normalize_csr(&CsrGraph::padded(&g, slots));
        let mut rebuilt = MatF32::zeros(slots, slots);
        for i in 0..slots {
            for k in a_csr.row_ptr[i]..a_csr.row_ptr[i + 1] {
                rebuilt.set(i, a_csr.cols[k], a_csr.vals[k]);
            }
        }
        assert_eq!(rebuilt, a_dense, "Â entries must match bitwise");
        // Diagonal present on every row — padding rows included.
        for i in 0..slots {
            let span = a_csr.row_ptr[i]..a_csr.row_ptr[i + 1];
            assert!(a_csr.cols[span].contains(&i), "row {i} lost its diag");
        }
    }

    #[test]
    fn sparse_aggregation_matches_dense_matmul_on_real_rows() {
        let g = toy();
        let slots = 16;
        let a_dense = sym_normalize(&g.padded_adj(slots), slots);
        let a_csr = sym_normalize_csr(&CsrGraph::padded(&g, slots));
        let x_full = MatF32::from_vec(
            slots,
            3,
            (0..slots * 3).map(|v| (v as f32 * 0.37).sin()).collect(),
        );
        // Zero the padding rows, as masked GCN activations are.
        let mut x_full = x_full;
        for r in g.n..slots {
            for c in 0..3 {
                x_full.set(r, c, 0.0);
            }
        }
        let dense = a_dense.matmul(&x_full);
        let x_real =
            MatF32::from_vec(g.n, 3, x_full.data[..g.n * 3].to_vec());
        let sparse = a_csr.matmul_real(&x_real);
        for i in 0..g.n {
            for c in 0..3 {
                assert!((dense.at(i, c) - sparse.at(i, c)).abs() < 1e-6,
                        "({i},{c}): {} vs {}", dense.at(i, c),
                        sparse.at(i, c));
            }
        }
    }
}
