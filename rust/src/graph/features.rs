//! Node feature embedding (paper §3, Eq. 2): machine → f32 vector.
//!
//! This is the ONLY definition of the feature encoding — Python receives
//! `feats[N, F]` as data and never re-derives it, so Rust and the GCN
//! artifact cannot drift. Layout (F = 18):
//!
//! | idx   | feature                                             |
//! |-------|-----------------------------------------------------|
//! | 0–11  | region one-hot (`Region::index`)                    |
//! | 12    | compute capability / 10                             |
//! | 13    | log2(total GPU memory GB) / 10                      |
//! | 14    | degree / n                                          |
//! | 15    | mean incident latency / 1000 (0 if isolated)        |
//! | 16    | min incident latency / 1000 (0 if isolated)         |
//! | 17    | constant 1.0 (bias channel)                         |
//!
//! Scalings keep every channel O(1) so the GCN's Glorot init sees a
//! well-conditioned input.

use super::adjacency::ClusterGraph;
use super::csr::CsrGraph;
use crate::cluster::Machine;

/// Feature dimension; must equal `f` in artifacts/manifest.kv.
/// 12 region one-hots + 5 scalar channels + 1 bias channel.
pub const FEATURE_DIM: usize = N_REGION_CHANNELS + 6;

/// One-hot width reserved for regions — tracks the catalog by
/// construction, so adding a region cannot silently corrupt rows.
const N_REGION_CHANNELS: usize = crate::cluster::Region::ALL.len();

/// Graph-derived channels of node i: (degree, mean latency, min latency)
/// reduced in one ascending-neighbor pass. The summation/compare order is
/// exactly the one `ClusterGraph::{degree, mean_latency, min_latency}`
/// visit, so the channel values are bit-identical to the historical
/// three-scan build.
fn latency_channels(weights: impl Iterator<Item = f32>)
    -> (usize, f32, f32)
{
    let mut deg = 0usize;
    let mut sum = 0.0f32;
    let mut min = f32::INFINITY;
    for w in weights {
        if w > 0.0 {
            deg += 1;
            sum += w;
            if w < min {
                min = w;
            }
        }
    }
    if deg == 0 {
        (0, 0.0, 0.0)
    } else {
        (deg, sum / deg as f32, min)
    }
}

fn feature_row(row: &mut [f32], m: &Machine, n: usize, deg: usize,
               mean: f32, min: f32)
{
    row[m.region.index()] = 1.0;
    row[12] = (m.compute_capability() / 10.0) as f32;
    row[13] = (m.total_memory_gb().max(1.0).log2() / 10.0) as f32;
    row[14] = deg as f32 / n.max(1) as f32;
    row[15] = mean / 1000.0;
    row[16] = min / 1000.0;
    row[17] = 1.0;
}

/// Features for every machine, padded to `slots` rows (row-major
/// `[slots, FEATURE_DIM]`). Padded rows are all-zero.
pub fn node_features(machines: &[Machine], graph: &ClusterGraph,
                     slots: usize) -> Vec<f32>
{
    assert_eq!(machines.len(), graph.n, "fleet/graph size mismatch");
    assert!(slots >= graph.n);
    let mut out = vec![0.0f32; slots * FEATURE_DIM];
    for (i, m) in machines.iter().enumerate() {
        let adj_row = &graph.adj[i * graph.n..(i + 1) * graph.n];
        let (deg, mean, min) = latency_channels(adj_row.iter().copied());
        let row = &mut out[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
        feature_row(row, m, graph.n, deg, mean, min);
    }
    out
}

/// [`node_features`] from a CSR view — O(E) instead of O(n²), identical
/// values. `csr.n` is the slot count; padded rows stay all-zero.
pub fn node_features_csr(machines: &[Machine], csr: &CsrGraph)
    -> Vec<f32>
{
    assert_eq!(machines.len(), csr.real, "fleet/graph size mismatch");
    let mut out = vec![0.0f32; csr.n * FEATURE_DIM];
    for (i, m) in machines.iter().enumerate() {
        let (_, vals) = csr.row(i);
        let (deg, mean, min) = latency_channels(vals.iter().copied());
        let row = &mut out[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
        feature_row(row, m, csr.real, deg, mean, min);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Fleet, GpuModel, Region};

    fn toy() -> (Fleet, ClusterGraph) {
        let fleet = Fleet::paper_toy(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        (fleet, graph)
    }

    #[test]
    fn shape_and_padding() {
        let (fleet, graph) = toy();
        let f = node_features(&fleet.machines, &graph, 16);
        assert_eq!(f.len(), 16 * FEATURE_DIM);
        // Padded rows all-zero.
        for i in 8..16 {
            assert!(f[i * FEATURE_DIM..(i + 1) * FEATURE_DIM]
                .iter()
                .all(|&v| v == 0.0));
        }
    }

    #[test]
    fn one_hot_region_is_exclusive() {
        let (fleet, graph) = toy();
        let f = node_features(&fleet.machines, &graph, 8);
        for (i, m) in fleet.machines.iter().enumerate() {
            let row = &f[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
            let ones: Vec<usize> = (0..N_REGION_CHANNELS)
                .filter(|&k| row[k] == 1.0)
                .collect();
            assert_eq!(ones, vec![m.region.index()]);
        }
    }

    #[test]
    fn channels_are_order_one() {
        let (fleet, graph) = toy();
        let f = node_features(&fleet.machines, &graph, 8);
        for (i, _) in fleet.machines.iter().enumerate() {
            let row = &f[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
            for (k, &v) in row.iter().enumerate() {
                assert!((0.0..=1.5).contains(&v), "feature {k} = {v}");
            }
            assert_eq!(row[17], 1.0);
        }
    }

    #[test]
    fn compute_and_memory_channels_differ_between_machines() {
        let (fleet, graph) = toy();
        let f = node_features(&fleet.machines, &graph, 8);
        // node2 is 8×A100 (640 GB), node6 is 8×1080Ti (88 GB).
        let mem2 = f[2 * FEATURE_DIM + 13];
        let mem6 = f[6 * FEATURE_DIM + 13];
        assert!(mem2 > mem6);
        let cc2 = f[2 * FEATURE_DIM + 12];
        assert!((cc2 - 0.8).abs() < 1e-6);
    }

    #[test]
    fn isolated_node_gets_zero_latency_channels() {
        let machines = vec![Machine::new(0, Region::Rome, GpuModel::V100, 8)];
        let graph = ClusterGraph { n: 1, adj: vec![0.0] };
        let f = node_features(&machines, &graph, 4);
        assert_eq!(f[15], 0.0);
        assert_eq!(f[16], 0.0);
        assert_eq!(f[14], 0.0);
    }

    #[test]
    fn csr_features_match_dense_features_bitwise() {
        for fleet in [Fleet::paper_toy(0), Fleet::paper_evaluation(3)] {
            let graph = ClusterGraph::from_fleet(&fleet);
            let slots = graph.n + 7;
            let dense = node_features(&fleet.machines, &graph, slots);
            let csr = crate::graph::CsrGraph::padded(&graph, slots);
            let sparse = node_features_csr(&fleet.machines, &csr);
            assert_eq!(dense, sparse);
        }
    }

    #[test]
    fn region_channel_width_matches_region_catalog() {
        assert_eq!(N_REGION_CHANNELS, Region::ALL.len());
        assert_eq!(FEATURE_DIM, Region::ALL.len() + 6);
    }
}
