//! Node feature embedding (paper §3, Eq. 2): machine → f32 vector.
//!
//! This is the ONLY definition of the feature encoding — Python receives
//! `feats[N, F]` as data and never re-derives it, so Rust and the GCN
//! artifact cannot drift. Layout (F = 18):
//!
//! | idx   | feature                                             |
//! |-------|-----------------------------------------------------|
//! | 0–11  | region one-hot (`Region::index`)                    |
//! | 12    | compute capability / 10                             |
//! | 13    | log2(total GPU memory GB) / 10                      |
//! | 14    | degree / n                                          |
//! | 15    | mean incident latency / 1000 (0 if isolated)        |
//! | 16    | min incident latency / 1000 (0 if isolated)         |
//! | 17    | constant 1.0 (bias channel)                         |
//!
//! Scalings keep every channel O(1) so the GCN's Glorot init sees a
//! well-conditioned input.

use super::adjacency::ClusterGraph;
use crate::cluster::Machine;

/// Feature dimension; must equal `f` in artifacts/manifest.kv.
/// 12 region one-hots + 5 scalar channels + 1 bias channel.
pub const FEATURE_DIM: usize = N_REGION_CHANNELS + 6;

/// One-hot width reserved for regions — tracks the catalog by
/// construction, so adding a region cannot silently corrupt rows.
const N_REGION_CHANNELS: usize = crate::cluster::Region::ALL.len();

/// Features for every machine, padded to `slots` rows (row-major
/// `[slots, FEATURE_DIM]`). Padded rows are all-zero.
pub fn node_features(machines: &[Machine], graph: &ClusterGraph,
                     slots: usize) -> Vec<f32>
{
    assert_eq!(machines.len(), graph.n, "fleet/graph size mismatch");
    assert!(slots >= graph.n);
    let mut out = vec![0.0f32; slots * FEATURE_DIM];
    for (i, m) in machines.iter().enumerate() {
        let row = &mut out[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
        row[m.region.index()] = 1.0;
        row[12] = (m.compute_capability() / 10.0) as f32;
        row[13] = (m.total_memory_gb().max(1.0).log2() / 10.0) as f32;
        row[14] = graph.degree(i) as f32 / graph.n.max(1) as f32;
        row[15] = graph.mean_latency(i).unwrap_or(0.0) / 1000.0;
        row[16] = graph.min_latency(i).unwrap_or(0.0) / 1000.0;
        row[17] = 1.0;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Fleet, GpuModel, Region};

    fn toy() -> (Fleet, ClusterGraph) {
        let fleet = Fleet::paper_toy(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        (fleet, graph)
    }

    #[test]
    fn shape_and_padding() {
        let (fleet, graph) = toy();
        let f = node_features(&fleet.machines, &graph, 16);
        assert_eq!(f.len(), 16 * FEATURE_DIM);
        // Padded rows all-zero.
        for i in 8..16 {
            assert!(f[i * FEATURE_DIM..(i + 1) * FEATURE_DIM]
                .iter()
                .all(|&v| v == 0.0));
        }
    }

    #[test]
    fn one_hot_region_is_exclusive() {
        let (fleet, graph) = toy();
        let f = node_features(&fleet.machines, &graph, 8);
        for (i, m) in fleet.machines.iter().enumerate() {
            let row = &f[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
            let ones: Vec<usize> = (0..N_REGION_CHANNELS)
                .filter(|&k| row[k] == 1.0)
                .collect();
            assert_eq!(ones, vec![m.region.index()]);
        }
    }

    #[test]
    fn channels_are_order_one() {
        let (fleet, graph) = toy();
        let f = node_features(&fleet.machines, &graph, 8);
        for (i, _) in fleet.machines.iter().enumerate() {
            let row = &f[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
            for (k, &v) in row.iter().enumerate() {
                assert!((0.0..=1.5).contains(&v), "feature {k} = {v}");
            }
            assert_eq!(row[17], 1.0);
        }
    }

    #[test]
    fn compute_and_memory_channels_differ_between_machines() {
        let (fleet, graph) = toy();
        let f = node_features(&fleet.machines, &graph, 8);
        // node2 is 8×A100 (640 GB), node6 is 8×1080Ti (88 GB).
        let mem2 = f[2 * FEATURE_DIM + 13];
        let mem6 = f[6 * FEATURE_DIM + 13];
        assert!(mem2 > mem6);
        let cc2 = f[2 * FEATURE_DIM + 12];
        assert!((cc2 - 0.8).abs() < 1e-6);
    }

    #[test]
    fn isolated_node_gets_zero_latency_channels() {
        let machines = vec![Machine::new(0, Region::Rome, GpuModel::V100, 8)];
        let graph = ClusterGraph { n: 1, adj: vec![0.0] };
        let f = node_features(&machines, &graph, 4);
        assert_eq!(f[15], 0.0);
        assert_eq!(f[16], 0.0);
        assert_eq!(f[14], 0.0);
    }

    #[test]
    fn region_channel_width_matches_region_catalog() {
        assert_eq!(N_REGION_CHANNELS, Region::ALL.len());
        assert_eq!(FEATURE_DIM, Region::ALL.len() + 6);
    }
}
