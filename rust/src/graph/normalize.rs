//! Latency-affinity symmetric normalization — the Rust mirror of
//! `python/compile/kernels/ref.py::sym_normalize_ref`, used by the
//! pure-Rust reference GCN (`gnn::reference`) and its parity tests.
//!
//! Â = D^{-1/2} (S + I) D^{-1/2},  S_uv = REF_LAT / latency_uv on edges.
//!
//! Aggregation weight decays with latency so low-latency neighbors
//! dominate; a binary connectivity matrix would oversmooth dense graphs
//! (identical Â rows on a complete graph) — see ref.py for the discussion.

use crate::util::MatF32;

/// Must equal `AFFINITY_REF_LAT_MS` in ref.py.
pub const AFFINITY_REF_LAT_MS: f32 = 10.0;

/// Compute Â from a row-major weighted adjacency (`0` = no edge).
pub fn sym_normalize(adj: &[f32], n: usize) -> MatF32 {
    assert_eq!(adj.len(), n * n);
    let mut s = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            let w = adj[i * n + j];
            s[i * n + j] = if i == j {
                1.0
            } else if w > 0.0 {
                // Clamp at the self-loop weight: a 1 ms intra-region link
                // must not out-weigh self 10:1 (oversmoothing; ref.py).
                (AFFINITY_REF_LAT_MS / w.max(1e-6)).min(1.0)
            } else {
                0.0
            };
        }
    }
    let deg: Vec<f32> = (0..n)
        .map(|i| s[i * n..(i + 1) * n].iter().sum::<f32>())
        .collect();
    let dinv: Vec<f32> =
        deg.iter().map(|&d| 1.0 / d.max(1e-12).sqrt()).collect();
    for i in 0..n {
        for j in 0..n {
            s[i * n + j] *= dinv[i] * dinv[j];
        }
    }
    MatF32::from_vec(n, n, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isolated_node_keeps_identity() {
        let a = sym_normalize(&[0.0; 9], 3);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((a.at(i, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn symmetric_output() {
        let adj = vec![0.0, 30.0, 300.0, 30.0, 0.0, 0.0, 300.0, 0.0, 0.0];
        let a = sym_normalize(&adj, 3);
        for i in 0..3 {
            for j in 0..3 {
                assert!((a.at(i, j) - a.at(j, i)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn low_latency_neighbor_weighs_more() {
        // node0 connects to node1 (30 ms) and node2 (300 ms).
        let adj = vec![0.0, 30.0, 300.0, 30.0, 0.0, 0.0, 300.0, 0.0, 0.0];
        let a = sym_normalize(&adj, 3);
        assert!(a.at(0, 1) > a.at(0, 2));
        assert!(a.at(0, 0) > a.at(0, 1)); // self dominates
    }

    #[test]
    fn rows_do_not_collapse_on_complete_graph() {
        // Two latency cliques inside a complete graph: rows must differ
        // (this is the degeneracy the affinity weighting exists to avoid).
        let n = 4;
        let mut adj = vec![0.0f32; n * n];
        let w = |i: usize, j: usize| -> f32 {
            if (i < 2) == (j < 2) { 30.0 } else { 300.0 }
        };
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    adj[i * n + j] = w(i, j);
                }
            }
        }
        let a = sym_normalize(&adj, n);
        let row0: Vec<f32> = (0..n).map(|j| a.at(0, j)).collect();
        let row2: Vec<f32> = (0..n).map(|j| a.at(2, j)).collect();
        let diff: f32 =
            row0.iter().zip(&row2).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 0.05, "rows collapsed: {row0:?} vs {row2:?}");
    }

    #[test]
    fn spectral_radius_at_most_one() {
        // Power iteration on a random-ish symmetric normalized matrix.
        let adj = vec![
            0.0, 30.0, 0.0, 120.0,
            30.0, 0.0, 55.0, 0.0,
            0.0, 55.0, 0.0, 200.0,
            120.0, 0.0, 200.0, 0.0,
        ];
        let a = sym_normalize(&adj, 4);
        let mut v = vec![1.0f32, 0.5, -0.5, 0.25];
        let mut lambda = 0.0f32;
        for _ in 0..200 {
            let mut w = vec![0.0f32; 4];
            for i in 0..4 {
                for j in 0..4 {
                    w[i] += a.at(i, j) * v[j];
                }
            }
            lambda = w.iter().map(|x| x * x).sum::<f32>().sqrt();
            if lambda > 0.0 {
                for x in &mut w {
                    *x /= lambda;
                }
            }
            v = w;
        }
        assert!(lambda <= 1.0 + 1e-4, "spectral radius {lambda}");
    }
}
