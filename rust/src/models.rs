//! Specifications of the language models the paper trains (§6.3, Fig. 9):
//! OPT-175B, T5-11B, GPT-2 (1.5B), BERT-large, RoBERTa, XLNet.
//!
//! The parallelism cost models (`parallel::*`) need, per model: parameter
//! count, transformer layer count, hidden width, tokens per iteration, and
//! derived quantities (FLOPs/iter, activation bytes at a pipeline cut,
//! training memory footprint).

/// A trainable model in the multi-task workload.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Total parameters.
    pub params: f64,
    /// Transformer blocks (pipeline-partitionable units).
    pub layers: usize,
    pub hidden: usize,
    pub seq_len: usize,
    /// Sequences per global batch.
    pub batch: usize,
}

/// Bytes per parameter during mixed-precision training: fp16 weights +
/// fp16 grads + fp32 master + fp32 Adam m/v  (2+2+4+4+4).
pub const TRAIN_BYTES_PER_PARAM: f64 = 16.0;

/// Dense-transformer FLOPs per token ≈ 6 × params (fwd 2× + bwd 4×).
pub const FLOPS_PER_TOKEN_FACTOR: f64 = 6.0;

impl ModelSpec {
    pub fn tokens_per_iter(&self) -> f64 {
        (self.batch * self.seq_len) as f64
    }

    /// FLOPs for one optimizer iteration over the global batch.
    pub fn flops_per_iter(&self) -> f64 {
        FLOPS_PER_TOKEN_FACTOR * self.params * self.tokens_per_iter()
    }

    /// Training-state footprint of a full replica, bytes.
    pub fn train_bytes(&self) -> f64 {
        self.params * TRAIN_BYTES_PER_PARAM
    }

    pub fn train_gb(&self) -> f64 {
        self.train_bytes() / 1e9
    }

    /// Gradient all-reduce volume per iteration (fp16), bytes.
    pub fn grad_bytes(&self) -> f64 {
        self.params * 2.0
    }

    /// Activation tensor crossing a pipeline cut for `micro_batch`
    /// sequences (fp16), bytes.
    pub fn activation_bytes(&self, micro_batch: usize) -> f64 {
        (micro_batch * self.seq_len * self.hidden) as f64 * 2.0
    }

    // ---------------------------------------------------------- catalog --
    pub fn opt_175b() -> ModelSpec {
        ModelSpec { name: "OPT (175B)", params: 175e9, layers: 96,
                    hidden: 12288, seq_len: 2048, batch: 256 }
    }

    pub fn t5_11b() -> ModelSpec {
        // 24 encoder + 24 decoder blocks.
        ModelSpec { name: "T5 (11B)", params: 11e9, layers: 48,
                    hidden: 1024, seq_len: 512, batch: 128 }
    }

    pub fn gpt2_xl() -> ModelSpec {
        ModelSpec { name: "GPT-2 (1.5B)", params: 1.5e9, layers: 48,
                    hidden: 1600, seq_len: 1024, batch: 64 }
    }

    pub fn bert_large() -> ModelSpec {
        ModelSpec { name: "BERT-large (340M)", params: 340e6, layers: 24,
                    hidden: 1024, seq_len: 512, batch: 256 }
    }

    pub fn roberta_large() -> ModelSpec {
        ModelSpec { name: "RoBERTa (355M)", params: 355e6, layers: 24,
                    hidden: 1024, seq_len: 512, batch: 256 }
    }

    pub fn xlnet_large() -> ModelSpec {
        ModelSpec { name: "XLNet (340M)", params: 340e6, layers: 24,
                    hidden: 1024, seq_len: 512, batch: 256 }
    }

    /// Canonical workload order: parameters descending (Algorithm 1
    /// feeds tasks largest-first), name ascending as the tie-breaker.
    /// `f64::total_cmp` makes the sort total (no NaN panic) and the name
    /// tie-break makes it fully deterministic across equal-sized models
    /// (e.g. BERT-large vs XLNet, both 340M).
    pub fn sort_largest_first(tasks: &mut [ModelSpec]) {
        tasks.sort_by(|a, b| {
            b.params
                .total_cmp(&a.params)
                .then_with(|| a.name.cmp(b.name))
        });
    }

    /// Wire-protocol slug for this catalog model (`hulk serve` Place
    /// requests name models by slug, not display name). Non-catalog
    /// specs fall back to the display name.
    pub fn slug(&self) -> &'static str {
        match self.name {
            "OPT (175B)" => "opt_175b",
            "T5 (11B)" => "t5_11b",
            "GPT-2 (1.5B)" => "gpt2_xl",
            "BERT-large (340M)" => "bert_large",
            "RoBERTa (355M)" => "roberta_large",
            "XLNet (340M)" => "xlnet_large",
            other => other,
        }
    }

    /// Inverse of [`ModelSpec::slug`]: resolve a wire slug to the
    /// catalog entry. Unknown slugs return `None` (the daemon turns
    /// that into a typed `Error` reply rather than a panic).
    pub fn from_slug(slug: &str) -> Option<ModelSpec> {
        match slug {
            "opt_175b" => Some(ModelSpec::opt_175b()),
            "t5_11b" => Some(ModelSpec::t5_11b()),
            "gpt2_xl" => Some(ModelSpec::gpt2_xl()),
            "bert_large" => Some(ModelSpec::bert_large()),
            "roberta_large" => Some(ModelSpec::roberta_large()),
            "xlnet_large" => Some(ModelSpec::xlnet_large()),
            _ => None,
        }
    }

    /// Fig. 8 workload: the four-model task set of §6.3.
    pub fn paper_four() -> Vec<ModelSpec> {
        vec![
            ModelSpec::opt_175b(),
            ModelSpec::t5_11b(),
            ModelSpec::gpt2_xl(),
            ModelSpec::bert_large(),
        ]
    }

    /// Fig. 10 workload: six models (adds RoBERTa and XLNet; the paper
    /// substitutes OPT-175B for the closed GPT-3).
    pub fn paper_six() -> Vec<ModelSpec> {
        vec![
            ModelSpec::opt_175b(),
            ModelSpec::t5_11b(),
            ModelSpec::gpt2_xl(),
            ModelSpec::bert_large(),
            ModelSpec::roberta_large(),
            ModelSpec::xlnet_large(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_paper_parameter_counts() {
        // Fig. 9 parameter chart.
        assert_eq!(ModelSpec::opt_175b().params, 175e9);
        assert_eq!(ModelSpec::t5_11b().params, 11e9);
        assert_eq!(ModelSpec::gpt2_xl().params, 1.5e9);
        assert_eq!(ModelSpec::bert_large().params, 340e6);
        assert_eq!(ModelSpec::roberta_large().params, 355e6);
        assert_eq!(ModelSpec::xlnet_large().params, 340e6);
    }

    #[test]
    fn gpt2_to_bert_ratio_is_paperlike() {
        // Paper §5.1: "approximately 4.4:1".
        let ratio = ModelSpec::gpt2_xl().params / ModelSpec::bert_large().params;
        assert!((ratio - 4.4).abs() < 0.05, "{ratio}");
    }

    #[test]
    fn derived_quantities_positive_and_ordered() {
        let opt = ModelSpec::opt_175b();
        let bert = ModelSpec::bert_large();
        assert!(opt.flops_per_iter() > bert.flops_per_iter());
        assert!(opt.train_gb() > 1000.0); // 2.8 TB
        assert!(bert.train_gb() < 10.0);
        assert!(opt.activation_bytes(1) > 0.0);
    }

    #[test]
    fn sort_largest_first_is_total_and_tie_stable() {
        // BERT-large and XLNet are both 340M: params alone cannot order
        // them, and a NaN must not panic the comparator.
        let mut tasks = vec![
            ModelSpec::xlnet_large(),
            ModelSpec::bert_large(),
            ModelSpec { params: f64::NAN, ..ModelSpec::gpt2_xl() },
            ModelSpec::opt_175b(),
        ];
        ModelSpec::sort_largest_first(&mut tasks);
        // NaN sorts above every finite value under total_cmp descending.
        assert!(tasks[0].params.is_nan());
        assert_eq!(tasks[1].name, "OPT (175B)");
        // The 340M tie breaks by name, deterministically.
        assert_eq!(tasks[2].name, "BERT-large (340M)");
        assert_eq!(tasks[3].name, "XLNet (340M)");

        // Shuffled input reaches the same order.
        let mut a = ModelSpec::paper_six();
        let mut b = ModelSpec::paper_six();
        b.reverse();
        ModelSpec::sort_largest_first(&mut a);
        ModelSpec::sort_largest_first(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn slug_roundtrips_the_whole_catalog() {
        for m in ModelSpec::paper_six() {
            let back = ModelSpec::from_slug(m.slug()).expect("catalog slug");
            assert_eq!(back, m, "{}", m.slug());
        }
        assert!(ModelSpec::from_slug("gpt5").is_none());
    }

    #[test]
    fn workload_sets_match_paper() {
        assert_eq!(ModelSpec::paper_four().len(), 4);
        assert_eq!(ModelSpec::paper_six().len(), 6);
        // Fig 8/10 order starts with the largest model.
        assert_eq!(ModelSpec::paper_four()[0].name, "OPT (175B)");
    }
}
