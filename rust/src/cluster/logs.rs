//! Synthetic communication-log generator — the paper's measurement input:
//! "we collected all communication logs between the three machines and
//! the eight servers over a three-month period" (§1). We do not have the
//! authors' logs (DESIGN.md §Substitutions); this generator produces a
//! deterministic per-pair time series whose 3-month mean equals the WAN
//! model's value (i.e., Table 1 where the paper measured), with the
//! structure real WAN probes show: diurnal load swing, lognormal jitter,
//! and rare congestion spikes.
//!
//! `hulk bench table1 --from-logs` derives Table 1 by averaging these
//! samples, closing the loop from raw logs → table exactly as the paper
//! did.

use super::region::Region;
use super::wan::WanModel;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// One probe: `at_hour` hours into the collection window, latency in ms
/// per 64-byte message.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LogSample {
    pub at_hour: f64,
    pub latency_ms: f64,
}

/// Diurnal swing amplitude (±12% around the mean over a 24 h cycle).
const DIURNAL_AMPLITUDE: f64 = 0.12;
/// Per-sample lognormal jitter sigma.
const SAMPLE_SIGMA: f64 = 0.06;
/// Probability of a congestion spike, and its multiplier range.
const SPIKE_PROB: f64 = 0.01;
const SPIKE_MAX: f64 = 3.0;

/// Generate `count` samples spread uniformly over `days` days for the
/// (a, b) pair. Deterministic in the WAN seed + pair. `None` if the pair
/// cannot communicate.
pub fn generate_logs(wan: &WanModel, a: Region, b: Region, days: usize,
                     count: usize) -> Option<Vec<LogSample>>
{
    let base = wan.latency_ms(a, b)?;
    let tag = ((a.index() as u64) << 32) | (b.index() as u64);
    let mut rng = Rng::new(wan.seed() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ 0x4C4F_4753); // "LOGS"
    let hours = days as f64 * 24.0;
    let mut out = Vec::with_capacity(count);
    for k in 0..count {
        let at_hour = hours * (k as f64 + rng.f64()) / count as f64;
        let diurnal =
            1.0 + DIURNAL_AMPLITUDE * (at_hour / 24.0 * std::f64::consts::TAU).sin();
        let jitter = rng.lognormal(0.0, SAMPLE_SIGMA);
        let spike = if rng.chance(SPIKE_PROB) {
            rng.uniform(1.5, SPIKE_MAX)
        } else {
            1.0
        };
        out.push(LogSample { at_hour, latency_ms: base * diurnal * jitter * spike });
    }
    Some(out)
}

/// Robust per-pair estimate from logs: the paper "calculated the
/// average"; we use the trimmed mean (drop the top 5% — congestion
/// spikes) so the estimate converges to the WAN model's base value.
pub fn estimate_latency(samples: &[LogSample]) -> f64 {
    assert!(!samples.is_empty());
    let mut v: Vec<f64> = samples.iter().map(|s| s.latency_ms).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let keep = ((v.len() as f64) * 0.95).ceil() as usize;
    let kept = &v[..keep.max(1)];
    kept.iter().sum::<f64>() / kept.len() as f64
}

/// Summary statistics over a pair's logs (for the logs bench output).
pub fn log_summary(samples: &[LogSample]) -> Summary {
    let v: Vec<f64> = samples.iter().map(|s| s.latency_ms).collect();
    Summary::of(&v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wan() -> WanModel {
        WanModel::new(0)
    }

    #[test]
    fn deterministic_per_pair() {
        let w = wan();
        let a = generate_logs(&w, Region::Beijing, Region::Tokyo, 90, 500)
            .unwrap();
        let b = generate_logs(&w, Region::Beijing, Region::Tokyo, 90, 500)
            .unwrap();
        assert_eq!(a, b);
        let c = generate_logs(&w, Region::Beijing, Region::Berlin, 90, 500)
            .unwrap();
        assert_ne!(a[0].latency_ms, c[0].latency_ms);
    }

    #[test]
    fn blocked_pair_has_no_logs() {
        assert!(generate_logs(&wan(), Region::Beijing, Region::Paris, 90, 10)
            .is_none());
    }

    #[test]
    fn trimmed_mean_recovers_table1_value() {
        // 3 months of probes → estimate within 3% of the measured mean.
        let w = wan();
        let logs = generate_logs(&w, Region::Beijing, Region::California,
                                 90, 2_000)
            .unwrap();
        let est = estimate_latency(&logs);
        let base = w
            .latency_ms(Region::Beijing, Region::California)
            .unwrap(); // 89.1 from Table 1
        assert!((est / base - 1.0).abs() < 0.03,
                "estimate {est:.1} vs base {base}");
    }

    #[test]
    fn samples_cover_the_window_in_order_of_hours() {
        let logs = generate_logs(&wan(), Region::Tokyo, Region::Berlin,
                                 90, 300)
            .unwrap();
        assert_eq!(logs.len(), 300);
        assert!(logs.first().unwrap().at_hour >= 0.0);
        assert!(logs.last().unwrap().at_hour <= 90.0 * 24.0);
        // Monotone non-decreasing sample times (uniform strided draw).
        for w in logs.windows(2) {
            assert!(w[1].at_hour >= w[0].at_hour - 24.0 / 300.0);
        }
    }

    #[test]
    fn spikes_exist_but_are_rare() {
        let w = wan();
        let logs = generate_logs(&w, Region::Nanjing, Region::London,
                                 90, 5_000)
            .unwrap();
        let base = w.latency_ms(Region::Nanjing, Region::London).unwrap();
        let spikes =
            logs.iter().filter(|s| s.latency_ms > base * 1.45).count();
        assert!(spikes > 0, "no spikes in 5000 samples");
        assert!((spikes as f64) < 0.05 * logs.len() as f64,
                "{spikes} spikes is too many");
    }

    #[test]
    fn summary_mean_above_min_below_max() {
        let logs = generate_logs(&wan(), Region::Rome, Region::Brasilia,
                                 30, 200)
            .unwrap();
        let s = log_summary(&logs);
        assert!(s.min < s.mean && s.mean < s.max);
        assert_eq!(s.n, 200);
    }
}
