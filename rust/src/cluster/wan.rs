//! WAN latency / bandwidth / reachability model.
//!
//! The paper measured 3 months of communication logs between its sites
//! (Table 1, ms per 64-byte message). We reproduce Table 1's values
//! verbatim and synthesize every pair the paper did not measure from
//! great-circle distance, with a deterministic per-pair jitter factor —
//! the calibration constants below put the synthetic values in the same
//! range as the measured ones (DESIGN.md §Substitutions).
//!
//! Reachability: Table 1's `-` (Beijing↔Paris) is preserved; the model can
//! also inject extra policy blocks for robustness experiments.


use super::paper_data::table1_lookup;
use super::region::Region;
use crate::util::rng::Rng;

/// Latency floor within one region (same metro, different DC), ms per 64 B.
pub const INTRA_REGION_MS: f64 = 1.0;

/// Propagation model for unmeasured pairs: `BASE + MS_PER_KM * distance`,
/// scaled by a per-pair lognormal jitter (routing detours, policy paths).
/// Calibrated against Table 1: Beijing→California (9,490 km) measured
/// 89.1 ms; model base gives ≈ 100 ms before jitter.
const BASE_MS: f64 = 15.0;
const MS_PER_KM: f64 = 0.009;
const JITTER_SIGMA: f64 = 0.30;

/// Bandwidth model: intra-region links are fat (10 Gbit/s); inter-region
/// bandwidth shrinks with latency (long paths cross more contended
/// transit), clamped to [0.1, 10] Gbit/s.
pub const INTRA_REGION_GBPS: f64 = 10.0;

/// WAN model over the full region catalog. Symmetric: we use the max of the two
/// directed Table 1 measurements when both exist (TCP pays the slower
/// direction).
#[derive(Clone, Debug)]
pub struct WanModel {
    /// latency[a][b] in ms per 64-byte message; `None` = unreachable.
    latency: Vec<Vec<Option<f64>>>,
    seed: u64,
}

impl WanModel {
    /// Build the model: Table 1 seeds + synthesized remainder.
    pub fn new(seed: u64) -> WanModel {
        let n = Region::ALL.len();
        let mut latency = vec![vec![None; n]; n];
        let mut rng = Rng::new(seed ^ WAN_SEED_TAG);
        for (i, &a) in Region::ALL.iter().enumerate() {
            for (j, &b) in Region::ALL.iter().enumerate() {
                if j < i {
                    latency[i][j] = latency[j][i];
                    continue;
                }
                latency[i][j] = if i == j {
                    Some(INTRA_REGION_MS)
                } else {
                    Self::pair_latency(a, b, &mut rng)
                };
            }
        }
        WanModel { latency, seed }
    }

    /// Measured value if the paper has one (either direction; max when
    /// both); otherwise distance-based synthesis. Beijing↔Paris stays
    /// blocked per Table 1.
    fn pair_latency(a: Region, b: Region, rng: &mut Rng) -> Option<f64> {
        let fwd = table1_lookup(a, b);
        let rev = table1_lookup(b, a);
        match (fwd, rev) {
            (Some(None), _) | (_, Some(None)) => None, // policy block
            (Some(Some(x)), Some(Some(y))) => Some(x.max(y)),
            (Some(Some(x)), _) | (_, Some(Some(x))) => Some(x),
            (None, None) => {
                // Deterministic per-pair jitter: fork the rng on the pair id
                // so the value is independent of iteration order.
                let tag = (a.index() as u64) << 8 | b.index() as u64;
                let mut r = rng.fork(tag);
                let dist = a.distance_km(b);
                let jitter = r.lognormal(0.0, JITTER_SIGMA);
                Some((BASE_MS + MS_PER_KM * dist) * jitter)
            }
        }
    }

    /// Latency in ms per 64-byte message, `None` if unreachable.
    pub fn latency_ms(&self, a: Region, b: Region) -> Option<f64> {
        self.latency[a.index()][b.index()]
    }

    /// Bandwidth in Gbit/s for a reachable pair.
    pub fn bandwidth_gbps(&self, a: Region, b: Region) -> Option<f64> {
        let lat = self.latency_ms(a, b)?;
        if a == b {
            return Some(INTRA_REGION_GBPS);
        }
        Some((100.0 / lat).clamp(0.1, INTRA_REGION_GBPS))
    }

    /// Time in ms to move `bytes` over the (a, b) link: latency + transfer.
    pub fn transfer_ms(&self, a: Region, b: Region, bytes: f64) -> Option<f64> {
        let lat = self.latency_ms(a, b)?;
        let bw = self.bandwidth_gbps(a, b)?;
        let transfer_ms = bytes * 8.0 / (bw * 1e9) * 1e3;
        Some(lat + transfer_ms)
    }

    pub fn is_reachable(&self, a: Region, b: Region) -> bool {
        self.latency[a.index()][b.index()].is_some()
    }

    /// A copy with every *inter-region* latency scaled by `factor`
    /// (WAN-degradation sweeps; intra-region latencies are local fabric
    /// and unaffected).
    pub fn scaled(&self, factor: f64) -> WanModel {
        assert!(factor >= 1.0, "degradation factor must be >= 1");
        let mut m = self.clone();
        for (i, row) in m.latency.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                if i != j {
                    if let Some(v) = cell.as_mut() {
                        *v *= factor;
                    }
                }
            }
        }
        m
    }

    /// A copy with additional policy blocks between `pairs` (robustness /
    /// failure-injection experiments).
    pub fn with_blocks(&self, pairs: &[(Region, Region)]) -> WanModel {
        let mut m = self.clone();
        for &(a, b) in pairs {
            m.latency[a.index()][b.index()] = None;
            m.latency[b.index()][a.index()] = None;
        }
        m
    }

    /// Sample a jittered measurement of the (a, b) latency — used by the
    /// Table 1 bench to emulate the paper's "average of 10 communications".
    pub fn sample_latency_ms(&self, a: Region, b: Region, trial: u64)
        -> Option<f64>
    {
        let base = self.latency_ms(a, b)?;
        let tag = ((a.index() as u64) << 16)
            | ((b.index() as u64) << 8)
            | (trial & 0xff);
        let mut r = Rng::new(self.seed ^ tag.wrapping_mul(0x2545F4914F6CDD1D));
        // ±8% measurement noise around the modelled mean.
        Some(base * r.lognormal(0.0, 0.08))
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

/// Domain-separation tag for the WAN model's rng stream ("WAN_MODL").
const WAN_SEED_TAG: u64 = 0x5741_4E5F_4D4F_444C;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values_reproduced() {
        let wan = WanModel::new(0);
        // Beijing–California: max(89.1, 144.8-is-not-this-pair) — the
        // reverse direction (California→"Beijing") is not in Table 1's
        // receiver columns, so the measured 89.1 stands.
        assert_eq!(wan.latency_ms(Region::Beijing, Region::California),
                   Some(89.1));
        assert_eq!(wan.latency_ms(Region::Nanjing, Region::Rome),
                   Some(741.3));
    }

    #[test]
    fn beijing_paris_unreachable() {
        let wan = WanModel::new(0);
        assert!(!wan.is_reachable(Region::Beijing, Region::Paris));
        assert!(wan.is_reachable(Region::Nanjing, Region::Paris));
    }

    #[test]
    fn symmetric_and_deterministic() {
        let a = WanModel::new(7);
        let b = WanModel::new(7);
        for &x in &Region::ALL {
            for &y in &Region::ALL {
                assert_eq!(a.latency_ms(x, y), a.latency_ms(y, x));
                assert_eq!(a.latency_ms(x, y), b.latency_ms(x, y));
            }
        }
    }

    #[test]
    fn intra_region_is_fast() {
        let wan = WanModel::new(0);
        for &r in &Region::ALL {
            if r == Region::California {
                continue; // Table 1 measured 1.0 for California–California
            }
            assert_eq!(wan.latency_ms(r, r), Some(INTRA_REGION_MS));
        }
    }

    #[test]
    fn synthesized_pairs_in_plausible_range() {
        let wan = WanModel::new(0);
        // Tokyo–Berlin is not in Table 1 → synthesized.
        let lat = wan.latency_ms(Region::Tokyo, Region::Berlin).unwrap();
        assert!((30.0..600.0).contains(&lat), "{lat}");
    }

    #[test]
    fn bandwidth_shrinks_with_latency() {
        let wan = WanModel::new(0);
        let near = wan
            .bandwidth_gbps(Region::Beijing, Region::Tokyo)
            .unwrap();
        let far = wan
            .bandwidth_gbps(Region::Nanjing, Region::Rome)
            .unwrap();
        assert!(near > far, "near {near} vs far {far}");
    }

    #[test]
    fn transfer_time_monotone_in_bytes() {
        let wan = WanModel::new(0);
        let t1 = wan
            .transfer_ms(Region::Beijing, Region::Tokyo, 1e6)
            .unwrap();
        let t2 = wan
            .transfer_ms(Region::Beijing, Region::Tokyo, 1e9)
            .unwrap();
        assert!(t2 > t1);
        // Latency term dominates tiny messages.
        let t0 = wan.transfer_ms(Region::Beijing, Region::Tokyo, 64.0)
            .unwrap();
        assert!((t0 - 74.3).abs() < 1.0, "{t0}");
    }

    #[test]
    fn blocks_apply_symmetrically() {
        let wan = WanModel::new(0)
            .with_blocks(&[(Region::Tokyo, Region::Berlin)]);
        assert!(!wan.is_reachable(Region::Tokyo, Region::Berlin));
        assert!(!wan.is_reachable(Region::Berlin, Region::Tokyo));
    }

    #[test]
    fn sampled_latency_close_to_mean() {
        let wan = WanModel::new(0);
        let base = wan.latency_ms(Region::Beijing, Region::Tokyo).unwrap();
        let mean: f64 = (0..10)
            .map(|t| {
                wan.sample_latency_ms(Region::Beijing, Region::Tokyo, t)
                    .unwrap()
            })
            .sum::<f64>()
            / 10.0;
        assert!((mean / base - 1.0).abs() < 0.15, "mean {mean} base {base}");
    }
}
