//! Constants reproduced verbatim from the paper.
//!
//! - Table 1: measured communication time (ms per 64-byte message) between
//!   three sender sites and eight receiver regions; `None` is the paper's
//!   `-` (Beijing→Paris blocked by network policy).
//! - Fig. 1: the eight-node toy graph used throughout §3–§5.
//! - Fig. 6: node 45 `{Rome, 7, 384}` joined during the scalability demo.

use super::gpu::GpuModel;
use super::machine::Machine;
use super::region::Region;

/// Sender sites of Table 1, in row order.
pub const TABLE1_SENDERS: [Region; 3] =
    [Region::Beijing, Region::Nanjing, Region::California];

/// Receiver regions of Table 1, in column order.
pub const TABLE1_RECEIVERS: [Region; 8] = [
    Region::California,
    Region::Tokyo,
    Region::Berlin,
    Region::London,
    Region::NewDelhi,
    Region::Paris,
    Region::Rome,
    Region::Brasilia,
];

/// Table 1 cells: ms to send 64 bytes; `None` = unreachable (`-`).
pub const TABLE1_MS: [[Option<f64>; 8]; 3] = [
    // Beijing
    [Some(89.1), Some(74.3), Some(250.5), Some(229.8), Some(341.9), None,
     Some(296.0), Some(341.8)],
    // Nanjing
    [Some(97.9), Some(173.8), Some(213.7), Some(176.7), Some(236.3),
     Some(265.1), Some(741.3), Some(351.3)],
    // California (1 ms to itself: intra-region hop)
    [Some(1.0), Some(118.8), Some(144.8), Some(132.3), Some(197.0),
     Some(133.9), Some(158.6), Some(158.6)],
];

/// Look up a Table 1 measurement for an ordered (sender, receiver) pair.
pub fn table1_lookup(a: Region, b: Region) -> Option<Option<f64>> {
    let row = TABLE1_SENDERS.iter().position(|&r| r == a)?;
    let col = TABLE1_RECEIVERS.iter().position(|&r| r == b)?;
    Some(TABLE1_MS[row][col])
}

/// The Fig. 1 eight-node toy graph. The paper gives node 0 as
/// `{'Beijing', 8.6, 152}` and leaves the rest to the figure; we
/// instantiate a concrete fleet with the same regions/feature ranges
/// (DESIGN.md §Substitutions).
pub fn fig1_toy_fleet() -> Vec<Machine> {
    vec![
        Machine::new(0, Region::Beijing, GpuModel::A40, 4),
        Machine::new(1, Region::Nanjing, GpuModel::V100, 8),
        Machine::new(2, Region::California, GpuModel::A100, 8),
        Machine::new(3, Region::Tokyo, GpuModel::Rtx3090, 8),
        Machine::new(4, Region::Berlin, GpuModel::RtxA5000, 8),
        Machine::new(5, Region::London, GpuModel::V100, 4),
        Machine::new(6, Region::NewDelhi, GpuModel::Gtx1080Ti, 8),
        Machine::new(7, Region::Rome, GpuModel::TitanXp, 8),
    ]
}

/// Fig. 6: "the machine with id 45 {Rome, 7, 384}" added to the system.
pub fn fig6_node_45() -> Machine {
    Machine::new(45, Region::Rome, GpuModel::V100, 12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_dimensions() {
        assert_eq!(TABLE1_MS.len(), 3);
        for row in &TABLE1_MS {
            assert_eq!(row.len(), 8);
        }
    }

    #[test]
    fn beijing_paris_is_blocked() {
        assert_eq!(table1_lookup(Region::Beijing, Region::Paris), Some(None));
    }

    #[test]
    fn spot_values_match_paper() {
        assert_eq!(
            table1_lookup(Region::Beijing, Region::California),
            Some(Some(89.1))
        );
        assert_eq!(
            table1_lookup(Region::Nanjing, Region::Rome),
            Some(Some(741.3))
        );
        assert_eq!(
            table1_lookup(Region::California, Region::California),
            Some(Some(1.0))
        );
    }

    #[test]
    fn non_sender_rows_absent() {
        assert_eq!(table1_lookup(Region::Rome, Region::Paris), None);
    }

    #[test]
    fn toy_fleet_shape() {
        let fleet = fig1_toy_fleet();
        assert_eq!(fleet.len(), 8);
        assert_eq!(fleet[0].label(), "{Beijing, 8.6, 192}");
        // ids are dense 0..8
        for (i, m) in fleet.iter().enumerate() {
            assert_eq!(m.id, i);
        }
    }

    #[test]
    fn node_45_matches_figure() {
        let m = fig6_node_45();
        assert_eq!(m.id, 45);
        assert_eq!(m.label(), "{Rome, 7, 384}");
    }
}
