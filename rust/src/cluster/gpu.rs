//! The paper's GPU catalog (§6.1): NVIDIA A100, A40, V100, RTX A5000,
//! GeForce GTX 1080 Ti, GeForce RTX 3090, TITAN Xp.
//!
//! Compute capability values follow NVIDIA's CUDA GPUs table (the paper's
//! footnote 6); memory is the per-board memory; throughput is the dense
//! mixed-precision training throughput used by the computation-time model
//! (tensor-core FP16 where the part has tensor cores, FP32 otherwise —
//! pre-Volta parts gain nothing from FP16 math for training).

/// GPU model in the fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GpuModel {
    A100,
    A40,
    V100,
    RtxA5000,
    Gtx1080Ti,
    Rtx3090,
    TitanXp,
}

impl GpuModel {
    pub const ALL: [GpuModel; 7] = [
        GpuModel::A100,
        GpuModel::A40,
        GpuModel::V100,
        GpuModel::RtxA5000,
        GpuModel::Gtx1080Ti,
        GpuModel::Rtx3090,
        GpuModel::TitanXp,
    ];

    pub fn name(self) -> &'static str {
        match self {
            GpuModel::A100 => "NVIDIA A100",
            GpuModel::A40 => "NVIDIA A40",
            GpuModel::V100 => "NVIDIA V100",
            GpuModel::RtxA5000 => "RTX A5000",
            GpuModel::Gtx1080Ti => "GeForce GTX 1080 Ti",
            GpuModel::Rtx3090 => "GeForce RTX 3090",
            GpuModel::TitanXp => "NVIDIA TITAN Xp",
        }
    }

    /// NVIDIA compute capability (paper Fig. 1 node feature).
    pub fn compute_capability(self) -> f64 {
        match self {
            GpuModel::A100 => 8.0,
            GpuModel::A40 => 8.6,
            GpuModel::V100 => 7.0,
            GpuModel::RtxA5000 => 8.6,
            GpuModel::Gtx1080Ti => 6.1,
            GpuModel::Rtx3090 => 8.6,
            GpuModel::TitanXp => 6.1,
        }
    }

    /// Per-board memory in GB.
    pub fn memory_gb(self) -> f64 {
        match self {
            GpuModel::A100 => 80.0,
            GpuModel::A40 => 48.0,
            GpuModel::V100 => 32.0,
            GpuModel::RtxA5000 => 24.0,
            GpuModel::Gtx1080Ti => 11.0,
            GpuModel::Rtx3090 => 24.0,
            GpuModel::TitanXp => 12.0,
        }
    }

    /// Effective dense training throughput in TFLOP/s (tensor-core FP16
    /// for Volta+, FP32 otherwise). These feed the computation-time model;
    /// only ratios matter for the reproduced figures.
    pub fn tflops(self) -> f64 {
        match self {
            GpuModel::A100 => 312.0,
            GpuModel::A40 => 150.0,
            GpuModel::V100 => 125.0,
            GpuModel::RtxA5000 => 111.0,
            GpuModel::Gtx1080Ti => 11.3,
            GpuModel::Rtx3090 => 142.0,
            GpuModel::TitanXp => 12.1,
        }
    }
}

impl std::fmt::Display for GpuModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_paper_section_6_1() {
        assert_eq!(GpuModel::ALL.len(), 7);
    }

    #[test]
    fn compute_capabilities_match_nvidia_table() {
        assert_eq!(GpuModel::A100.compute_capability(), 8.0);
        assert_eq!(GpuModel::A40.compute_capability(), 8.6);
        assert_eq!(GpuModel::V100.compute_capability(), 7.0);
        assert_eq!(GpuModel::TitanXp.compute_capability(), 6.1);
    }

    #[test]
    fn throughput_ordering_is_sane() {
        // Datacenter parts beat consumer parts of the same era.
        assert!(GpuModel::A100.tflops() > GpuModel::A40.tflops());
        assert!(GpuModel::V100.tflops() > GpuModel::Gtx1080Ti.tflops());
        for g in GpuModel::ALL {
            assert!(g.tflops() > 0.0 && g.memory_gb() > 0.0);
        }
    }
}
