//! The regions machines can live in: the ten appearing in paper
//! Table 1 / §6 plus two planet-scale extensions (Singapore, São Paulo)
//! used by synthetic fleets. Coordinates drive great-circle latency
//! synthesis of pairs the paper did not measure.
//!
//! The first ten indices are the paper's regions in Table 1 order and
//! must stay stable — they are part of the one-hot feature contract with
//! the GCN artifact (`graph::features`). New regions append at the end.

/// A geographic region hosting machines. The paper's node feature vector is
/// `{City, ComputeCapability, Memory}`; `Region` is the city component.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Region {
    Beijing,
    Nanjing,
    California,
    Tokyo,
    Berlin,
    London,
    NewDelhi,
    Paris,
    Rome,
    Brasilia,
    Singapore,
    SaoPaulo,
}

impl Region {
    pub const ALL: [Region; 12] = [
        Region::Beijing,
        Region::Nanjing,
        Region::California,
        Region::Tokyo,
        Region::Berlin,
        Region::London,
        Region::NewDelhi,
        Region::Paris,
        Region::Rome,
        Region::Brasilia,
        Region::Singapore,
        Region::SaoPaulo,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Region::Beijing => "Beijing",
            Region::Nanjing => "Nanjing",
            Region::California => "California",
            Region::Tokyo => "Tokyo",
            Region::Berlin => "Berlin",
            Region::London => "London",
            Region::NewDelhi => "New Delhi",
            Region::Paris => "Paris",
            Region::Rome => "Rome",
            Region::Brasilia => "Brasilia",
            Region::Singapore => "Singapore",
            Region::SaoPaulo => "São Paulo",
        }
    }

    /// Index into one-hot feature encodings (graph::features) — stable,
    /// part of the artifact contract with the GCN.
    pub fn index(self) -> usize {
        Region::ALL.iter().position(|&r| r == self).unwrap()
    }

    pub fn from_index(i: usize) -> Option<Region> {
        Region::ALL.get(i).copied()
    }

    /// (latitude, longitude) in degrees — representative city centers.
    pub fn coords(self) -> (f64, f64) {
        match self {
            Region::Beijing => (39.90, 116.41),
            Region::Nanjing => (32.06, 118.80),
            Region::California => (37.39, -122.08),
            Region::Tokyo => (35.68, 139.69),
            Region::Berlin => (52.52, 13.40),
            Region::London => (51.51, -0.13),
            Region::NewDelhi => (28.61, 77.21),
            Region::Paris => (48.86, 2.35),
            Region::Rome => (41.90, 12.50),
            Region::Brasilia => (-15.79, -47.88),
            Region::Singapore => (1.35, 103.82),
            Region::SaoPaulo => (-23.55, -46.63),
        }
    }

    /// Great-circle distance to `other` in kilometres (haversine).
    pub fn distance_km(self, other: Region) -> f64 {
        if self == other {
            return 0.0;
        }
        let (la1, lo1) = self.coords();
        let (la2, lo2) = other.coords();
        let (la1, lo1, la2, lo2) = (
            la1.to_radians(),
            lo1.to_radians(),
            la2.to_radians(),
            lo2.to_radians(),
        );
        let dla = la2 - la1;
        let dlo = lo2 - lo1;
        let a = (dla / 2.0).sin().powi(2)
            + la1.cos() * la2.cos() * (dlo / 2.0).sin().powi(2);
        2.0 * 6371.0 * a.sqrt().asin()
    }
}

impl std::fmt::Display for Region {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_are_dense_and_stable() {
        for (i, r) in Region::ALL.iter().enumerate() {
            assert_eq!(r.index(), i);
            assert_eq!(Region::from_index(i), Some(*r));
        }
        assert_eq!(Region::from_index(12), None);
        // The paper's ten regions keep their Table 1 indices (artifact
        // contract); extensions append after them.
        assert_eq!(Region::Brasilia.index(), 9);
        assert_eq!(Region::Singapore.index(), 10);
        assert_eq!(Region::SaoPaulo.index(), 11);
    }

    #[test]
    fn distance_is_symmetric_and_zero_on_diagonal() {
        for &a in &Region::ALL {
            assert_eq!(a.distance_km(a), 0.0);
            for &b in &Region::ALL {
                let d1 = a.distance_km(b);
                let d2 = b.distance_km(a);
                assert!((d1 - d2).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn known_distances_roughly_correct() {
        // Beijing–Tokyo ≈ 2,100 km; Beijing–California ≈ 9,500 km;
        // London–Paris ≈ 340 km.
        let bt = Region::Beijing.distance_km(Region::Tokyo);
        assert!((1_900.0..2_300.0).contains(&bt), "{bt}");
        let bc = Region::Beijing.distance_km(Region::California);
        assert!((9_000.0..10_100.0).contains(&bc), "{bc}");
        let lp = Region::London.distance_km(Region::Paris);
        assert!((300.0..400.0).contains(&lp), "{lp}");
    }

    #[test]
    fn display_matches_name() {
        assert_eq!(Region::NewDelhi.to_string(), "New Delhi");
    }
}
