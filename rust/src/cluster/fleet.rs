//! Fleet construction: the paper's 46-server / 368-GPU evaluation fleet
//! (§6.1), planet-scale synthetic fleets for scaling scenarios, and
//! randomized fleets for GNN training-data generation.

use super::gpu::GpuModel;
use super::machine::Machine;
use super::paper_data::fig1_toy_fleet;
use super::region::Region;
use super::wan::WanModel;
use crate::util::rng::Rng;

/// A fleet: machines + the WAN connecting their regions.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub machines: Vec<Machine>,
    pub wan: WanModel,
}

impl Fleet {
    pub fn new(machines: Vec<Machine>, wan: WanModel) -> Fleet {
        // ids must be dense 0..n so they can index matrices directly.
        for (i, m) in machines.iter().enumerate() {
            assert_eq!(m.id, i, "machine ids must be dense");
        }
        Fleet { machines, wan }
    }

    pub fn len(&self) -> usize {
        self.machines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.machines.is_empty()
    }

    pub fn total_gpus(&self) -> usize {
        self.machines.iter().map(|m| m.n_gpus).sum()
    }

    pub fn total_memory_gb(&self) -> f64 {
        self.machines.iter().map(|m| m.total_memory_gb()).sum()
    }

    /// Latency between two machines (ms per 64 B); `None` if their regions
    /// cannot communicate.
    pub fn latency_ms(&self, a: usize, b: usize) -> Option<f64> {
        self.wan
            .latency_ms(self.machines[a].region, self.machines[b].region)
    }

    /// Append a machine (Fig. 6 scale-out); returns its id.
    pub fn add_machine(&mut self, region: Region, gpu: GpuModel,
                       n_gpus: usize) -> usize
    {
        let id = self.len();
        self.machines.push(Machine::new(id, region, gpu, n_gpus));
        id
    }

    /// Remove a machine by id (failure / scale-in). Remaining ids are
    /// re-densified; returns the removed machine.
    pub fn remove_machine(&mut self, id: usize) -> Machine {
        let removed = self.machines.remove(id);
        for (i, m) in self.machines.iter_mut().enumerate() {
            m.id = i;
        }
        removed
    }

    /// A copy with the WAN degraded by `factor` (scenarios::sweep).
    pub fn with_wan_scaled(&self, factor: f64) -> Fleet {
        Fleet { machines: self.machines.clone(),
                wan: self.wan.scaled(factor) }
    }

    /// The Fig. 1 eight-node toy fleet.
    pub fn paper_toy(seed: u64) -> Fleet {
        Fleet::new(fig1_toy_fleet(), WanModel::new(seed))
    }

    /// The §6.1 evaluation fleet: 46 servers, 8 GPUs each = 368 GPUs,
    /// spread over all ten regions with a region-correlated GPU mix
    /// (datacenter parts cluster in the large regions, consumer parts in
    /// the long tail — matching the paper's mixed inventory).
    pub fn paper_evaluation(seed: u64) -> Fleet {
        let mut rng = Rng::new(seed ^ 0x464C_4545_5421); // "FLEET!"
        // (region, #servers): totals 46.
        let plan: [(Region, usize); 10] = [
            (Region::Beijing, 6),
            (Region::Nanjing, 5),
            (Region::California, 8),
            (Region::Tokyo, 5),
            (Region::Berlin, 4),
            (Region::London, 4),
            (Region::NewDelhi, 4),
            (Region::Paris, 4),
            (Region::Rome, 3),
            (Region::Brasilia, 3),
        ];
        // Region-weighted GPU pools.
        let rich: &[GpuModel] = &[
            GpuModel::A100,
            GpuModel::A100,
            GpuModel::A40,
            GpuModel::V100,
            GpuModel::Rtx3090,
        ];
        let mixed: &[GpuModel] = &[
            GpuModel::A40,
            GpuModel::V100,
            GpuModel::RtxA5000,
            GpuModel::Rtx3090,
            GpuModel::Gtx1080Ti,
        ];
        let lean: &[GpuModel] = &[
            GpuModel::V100,
            GpuModel::RtxA5000,
            GpuModel::Gtx1080Ti,
            GpuModel::TitanXp,
        ];
        let mut machines = Vec::new();
        for (region, count) in plan {
            let pool = match region {
                Region::California | Region::Beijing | Region::Tokyo => rich,
                Region::Nanjing | Region::Berlin | Region::London
                | Region::Paris => mixed,
                _ => lean,
            };
            for _ in 0..count {
                let gpu = *rng.choice(pool);
                machines.push(Machine::new(machines.len(), region, gpu, 8));
            }
        }
        assert_eq!(machines.len(), 46);
        Fleet::new(machines, WanModel::new(seed))
    }

    /// Synthetic planet-scale fleet: `n_servers` spread over `n_regions`
    /// distinct regions (seed-sampled from the catalog), with the same
    /// region-correlated GPU inventory shape as the paper fleet. The WAN
    /// reuses the existing model: Table 1 values where measured,
    /// great-circle synthesis with deterministic per-pair jitter
    /// everywhere else — so a 200-server fleet is a pure function of
    /// `(n_servers, n_regions, seed)`.
    ///
    /// Degenerate shapes are valid: `n_servers = 0` yields an empty
    /// fleet, and `n_regions > n_servers` collapses to one region per
    /// server (so a 1-server fleet is single-region), never an empty
    /// region block.
    pub fn synthetic(n_servers: usize, n_regions: usize, seed: u64)
        -> Fleet
    {
        assert!(
            (1..=Region::ALL.len()).contains(&n_regions),
            "n_regions must be in 1..={}, got {n_regions}",
            Region::ALL.len()
        );
        if n_servers == 0 {
            return Fleet::new(Vec::new(), WanModel::new(seed));
        }
        let n_regions = n_regions.min(n_servers);
        let mut rng = Rng::new(seed ^ 0x504C_414E_4554); // "PLANET"
        // Sampled regions kept in catalog order, and machines emitted in
        // contiguous per-region blocks — the same layout as
        // `paper_evaluation`. The block layout matters: baseline systems
        // ring-allreduce in id order, so no two *cyclically adjacent*
        // blocks may be the policy-blocked Beijing↔Paris pair. Catalog
        // order alone does not guarantee that for subsets (e.g. a sample
        // with nothing between or after the two), so when both are drawn
        // and would touch, Paris is re-seated mid-cycle. With fewer than
        // 4 regions no separator can exist on both sides; such fleets may
        // be genuinely partitioned and the cost models report the
        // affected rings infeasible.
        let mut region_idx = rng.sample_indices(Region::ALL.len(), n_regions);
        region_idx.sort_unstable();
        let mut regions: Vec<Region> =
            region_idx.iter().map(|&i| Region::ALL[i]).collect();
        let blocked = (Region::Beijing, Region::Paris);
        let bj = regions.iter().position(|&r| r == blocked.0);
        let pa = regions.iter().position(|&r| r == blocked.1);
        if let (Some(bi), Some(pi)) = (bj, pa) {
            let k = regions.len();
            let touching = (bi + 1) % k == pi || (pi + 1) % k == bi;
            if touching && k >= 4 {
                let others: Vec<Region> = regions
                    .iter()
                    .copied()
                    .filter(|&r| r != blocked.0 && r != blocked.1)
                    .collect();
                let mid = others.len().div_ceil(2); // ≥ 1 on each side
                let mut order = vec![blocked.0];
                order.extend(&others[..mid]);
                order.push(blocked.1);
                order.extend(&others[mid..]);
                regions = order;
            }
        }
        // Every region hosts at least one server; the rest land by
        // seeded draw, so large fleets are unevenly loaded like real
        // estates.
        let mut counts = vec![1usize; n_regions];
        for _ in n_regions..n_servers {
            counts[rng.below(n_regions)] += 1;
        }
        // Datacenter-grade parts dominate; consumer parts form the tail
        // (same inventory shape as `paper_evaluation`).
        let pool: &[GpuModel] = &[
            GpuModel::A100,
            GpuModel::A100,
            GpuModel::A40,
            GpuModel::V100,
            GpuModel::V100,
            GpuModel::RtxA5000,
            GpuModel::Rtx3090,
            GpuModel::Gtx1080Ti,
        ];
        let mut machines = Vec::with_capacity(n_servers);
        for (&region, &count) in regions.iter().zip(&counts) {
            for _ in 0..count {
                let gpu = *rng.choice(pool);
                let n_gpus = [4, 8, 8, 8, 12][rng.below(5)];
                machines.push(Machine::new(machines.len(), region, gpu,
                                           n_gpus));
            }
        }
        Fleet::new(machines, WanModel::new(seed))
    }

    /// Random fleet for GNN training-set generation: `n` servers over a
    /// random subset of regions, 4–12 GPUs each.
    pub fn random(n: usize, seed: u64) -> Fleet {
        let mut rng = Rng::new(seed ^ 0x524E_444F_4D46); // "RNDOMF"
        let n_regions = 2 + rng.below(Region::ALL.len() - 1);
        let region_idx = rng.sample_indices(Region::ALL.len(), n_regions);
        let regions: Vec<Region> =
            region_idx.iter().map(|&i| Region::ALL[i]).collect();
        let mut machines = Vec::new();
        for id in 0..n {
            let region = *rng.choice(&regions);
            let gpu = *rng.choice(&GpuModel::ALL);
            let n_gpus = [4, 8, 8, 8, 12][rng.below(5)];
            machines.push(Machine::new(id, region, gpu, n_gpus));
        }
        Fleet::new(machines, WanModel::new(seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluation_fleet_matches_paper_inventory() {
        let fleet = Fleet::paper_evaluation(0);
        assert_eq!(fleet.len(), 46);
        assert_eq!(fleet.total_gpus(), 368); // 46 servers × 8 GPUs (§6.1)
    }

    #[test]
    fn evaluation_fleet_is_deterministic() {
        let a = Fleet::paper_evaluation(3);
        let b = Fleet::paper_evaluation(3);
        assert_eq!(a.machines, b.machines);
    }

    #[test]
    fn toy_fleet_is_fig1() {
        let fleet = Fleet::paper_toy(0);
        assert_eq!(fleet.len(), 8);
        assert_eq!(fleet.machines[0].region, Region::Beijing);
    }

    #[test]
    fn latency_uses_machine_regions() {
        let fleet = Fleet::paper_toy(0);
        // node0 Beijing, node2 California → Table 1 measured value.
        assert_eq!(fleet.latency_ms(0, 2), Some(89.1));
    }

    #[test]
    fn add_and_remove_keep_ids_dense() {
        let mut fleet = Fleet::paper_toy(0);
        let id = fleet.add_machine(Region::Rome, GpuModel::V100, 12);
        assert_eq!(id, 8);
        assert_eq!(fleet.len(), 9);
        let removed = fleet.remove_machine(3);
        assert_eq!(removed.region, Region::Tokyo);
        for (i, m) in fleet.machines.iter().enumerate() {
            assert_eq!(m.id, i);
        }
    }

    #[test]
    fn synthetic_fleet_has_requested_shape() {
        let fleet = Fleet::synthetic(220, 12, 0);
        assert_eq!(fleet.len(), 220);
        let mut regions: Vec<Region> =
            fleet.machines.iter().map(|m| m.region).collect();
        regions.sort_unstable();
        regions.dedup();
        assert_eq!(regions.len(), 12, "every region must be populated");
        for (i, m) in fleet.machines.iter().enumerate() {
            assert_eq!(m.id, i);
        }
        // Machines form contiguous per-region blocks — the layout the
        // id-order baseline rings rely on. (Block *order* is catalog
        // order except when the Beijing/Paris re-seat fires, so assert
        // contiguity, not monotonicity.)
        let mut seen: Vec<Region> = Vec::new();
        for m in &fleet.machines {
            if seen.last() != Some(&m.region) {
                assert!(!seen.contains(&m.region),
                        "region {} split into non-contiguous blocks",
                        m.region);
                seen.push(m.region);
            }
        }
        assert!(fleet.total_memory_gb() > 10_000.0,
                "planet fleet should hold tens of TB");
    }

    #[test]
    fn synthetic_fleet_is_deterministic_and_seed_sensitive() {
        let a = Fleet::synthetic(64, 8, 3);
        let b = Fleet::synthetic(64, 8, 3);
        let c = Fleet::synthetic(64, 8, 4);
        assert_eq!(a.machines, b.machines);
        assert_ne!(a.machines, c.machines);
    }

    #[test]
    #[should_panic(expected = "n_regions")]
    fn synthetic_rejects_too_many_regions() {
        Fleet::synthetic(10, Region::ALL.len() + 1, 0);
    }

    #[test]
    fn synthetic_degenerate_shapes_are_valid_fleets() {
        // Zero servers: an empty fleet, not a panic.
        let empty = Fleet::synthetic(0, 5, 7);
        assert!(empty.is_empty());
        // One server: a valid single-region fleet even when more regions
        // were requested.
        let one = Fleet::synthetic(1, Region::ALL.len(), 7);
        assert_eq!(one.len(), 1);
        assert_eq!(one.machines[0].id, 0);
        // Fewer servers than regions: clamps to one region per server —
        // every region block is non-empty.
        let few = Fleet::synthetic(3, Region::ALL.len(), 7);
        assert_eq!(few.len(), 3);
        let mut regions: Vec<Region> =
            few.machines.iter().map(|m| m.region).collect();
        regions.sort_unstable();
        regions.dedup();
        assert_eq!(regions.len(), 3, "one region per server when clamped");
        // Still deterministic.
        assert_eq!(Fleet::synthetic(3, Region::ALL.len(), 7).machines,
                   few.machines);
    }

    #[test]
    fn synthetic_id_ring_edges_always_reachable() {
        // The invariant baseline rings rely on: with ≥ 4 regions, no
        // id-adjacent (or wrap-around) machine pair may straddle the
        // policy-blocked Beijing↔Paris link, whatever the seed draws.
        for seed in 0..16 {
            for n_regions in [4usize, 6, 8, 12] {
                let fleet = Fleet::synthetic(40, n_regions, seed);
                let n = fleet.len();
                for i in 0..n {
                    let j = (i + 1) % n;
                    assert!(
                        fleet.latency_ms(i, j).is_some(),
                        "seed {seed} / {n_regions} regions: ring edge \
                         {i}-{j} ({} ↔ {}) unreachable",
                        fleet.machines[i].region,
                        fleet.machines[j].region
                    );
                }
            }
        }
    }

    #[test]
    fn random_fleets_vary_with_seed() {
        let a = Fleet::random(12, 1);
        let b = Fleet::random(12, 2);
        assert_eq!(a.len(), 12);
        assert_ne!(a.machines, b.machines);
    }

    #[test]
    #[should_panic(expected = "dense")]
    fn non_dense_ids_rejected() {
        let machines = vec![Machine::new(1, Region::Rome, GpuModel::V100, 8)];
        Fleet::new(machines, WanModel::new(0));
    }
}
