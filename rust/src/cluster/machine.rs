//! A machine (server) in the fleet: region + homogeneous GPU complement.
//! The paper's node representation is `v = {City, ComputeCapability,
//! Memory}` (Fig. 1); `Machine` carries the underlying inventory those
//! features derive from.

use super::gpu::GpuModel;
use super::region::Region;

/// One server. GPUs within a machine are homogeneous (as in the paper's
/// fleet: "eight servers … 368 GPUs of various models").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Machine {
    pub id: usize,
    pub region: Region,
    pub gpu: GpuModel,
    pub n_gpus: usize,
}

impl Machine {
    pub fn new(id: usize, region: Region, gpu: GpuModel, n_gpus: usize)
        -> Machine
    {
        assert!(n_gpus > 0, "machine {id} with zero GPUs");
        Machine { id, region, gpu, n_gpus }
    }

    /// Paper feature: NVIDIA compute capability of the machine's GPUs.
    pub fn compute_capability(&self) -> f64 {
        self.gpu.compute_capability()
    }

    /// Paper feature: "memory refers to the total memory across all GPUs
    /// on each machine" (Fig. 1 caption).
    pub fn total_memory_gb(&self) -> f64 {
        self.gpu.memory_gb() * self.n_gpus as f64
    }

    /// Aggregate training throughput (TFLOP/s) across the machine's GPUs,
    /// derated for intra-machine scaling inefficiency.
    pub fn total_tflops(&self) -> f64 {
        const INTRA_MACHINE_SCALING: f64 = 0.9; // NVLink/PCIe sync overhead
        self.gpu.tflops() * self.n_gpus as f64 * INTRA_MACHINE_SCALING
    }

    /// Paper Fig. 1 node label, e.g. `{'Beijing', 8.6, 152}`.
    pub fn label(&self) -> String {
        format!(
            "{{{}, {}, {}}}",
            self.region.name(),
            self.compute_capability(),
            self.total_memory_gb() as i64
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_scale_with_gpu_count() {
        let m = Machine::new(0, Region::Beijing, GpuModel::A40, 4);
        assert_eq!(m.total_memory_gb(), 192.0);
        let m8 = Machine::new(1, Region::Beijing, GpuModel::A40, 8);
        assert_eq!(m8.total_memory_gb(), 384.0);
        assert!(m8.total_tflops() > m.total_tflops());
    }

    #[test]
    fn label_matches_paper_format() {
        let m = Machine::new(45, Region::Rome, GpuModel::V100, 12);
        assert_eq!(m.label(), "{Rome, 7, 384}");
    }

    #[test]
    #[should_panic]
    fn zero_gpus_rejected() {
        Machine::new(0, Region::Rome, GpuModel::V100, 0);
    }
}
