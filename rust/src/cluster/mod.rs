//! Cluster substrate: the paper's 46-server / 368-GPU geo-distributed
//! testbed, rebuilt as a deterministic model (DESIGN.md §Substitutions).
//!
//! - [`region`] — the ten regions of paper Table 1 plus two planet-scale
//!   extensions, with coordinates.
//! - [`gpu`] — the paper's GPU catalog (§6.1) with NVIDIA compute
//!   capability, per-GPU memory and throughput.
//! - [`machine`] — a server: region + GPU model + count.
//! - [`wan`] — inter-region latency/bandwidth model seeded with Table 1's
//!   measured values; unmeasured pairs synthesized from great-circle
//!   distance; policy blocks (the `-` entries) preserved.
//! - [`fleet`] — fleet construction: the 46-server evaluation fleet,
//!   planet-scale synthetic fleets, random fleets for GNN training data.
//! - [`paper_data`] — verbatim constants from the paper (Table 1 matrix,
//!   the Fig. 1 eight-node toy graph, Fig. 6's node 45).

pub mod fleet;
pub mod logs;
pub mod gpu;
pub mod machine;
pub mod paper_data;
pub mod region;
pub mod wan;

pub use fleet::Fleet;
pub use gpu::GpuModel;
pub use machine::Machine;
pub use region::Region;
pub use wan::WanModel;
