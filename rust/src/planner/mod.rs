//! The planning seam: every placement strategy — the paper's baseline
//! Systems A/B/C, Hulk itself, and any future ablation or hybrid — is a
//! [`Planner`] that turns a [`PlanContext`] into a typed [`Placement`]
//! and prices it as an [`IterCost`](crate::parallel::IterCost) per task.
//!
//! Before this module existed the four systems exposed four incompatible
//! free-function APIs (`system_a::cost(fleet, model)`, `system_b::plan`,
//! `system_c::cost`, `hulk_plan(fleet, graph, workload, splitter)`), and
//! every consumer hand-rolled a 4-way `match SystemKind`. Now:
//!
//! - [`Planner`] — the trait: `name`/`slug`/`kind`, `plan(ctx)`, and
//!   `cost(ctx, placement, task_idx)` (default: derived purely from the
//!   placement IR, so two planners emitting the same placement always
//!   price identically).
//! - [`PlanContext`] — the bundled inputs `{fleet, cluster graph,
//!   canonically sorted workload, Hulk splitter config}`.
//! - [`Placement`] — the typed IR ([`placement`]): per task one of
//!   `Replicated`, `PipelineStages`, `TensorSharded`, `Grouped`. It
//!   replaces the ad-hoc `HulkPlan` / `PipelinePlan` / participant-vec
//!   trio that each system used to return.
//! - [`PlannerRegistry`] — slug → `Box<dyn Planner>`, insertion-ordered
//!   ([`registry`]): [`PlannerRegistry::standard`] is the paper's four
//!   (the default everywhere), [`PlannerRegistry::catalog`] adds the
//!   registered ablations (`hulk_no_gcn`), and
//!   [`PlannerRegistry::resolve`] answers the CLI's `--systems a,b,hulk`
//!   filter.
//!
//! The scenario subsystem ([`crate::scenarios`]) iterates the registry —
//! `evaluate`, the runner's cell decomposition (scenario × registered
//! planner), the named scenarios and the sweeps — so adding a fifth
//! strategy is one `register` call, not four edited `match` arms.
//!
//! To add a planner: implement [`Planner`] (emit one of the existing
//! [`TaskPlacement`] variants and the default `cost` comes for free),
//! pick a unique slug, and add it to [`PlannerRegistry::catalog`]. See
//! DESIGN.md §Planner architecture.

pub mod baselines;
pub mod cost_model;
pub mod hulk;
pub mod placement;
pub mod registry;

use anyhow::Result;

use crate::cluster::Fleet;
use crate::graph::{GraphView, HierarchicalGraph};
use crate::models::ModelSpec;
use crate::parallel::IterCost;

pub use baselines::{SystemAPlanner, SystemBPlanner, SystemCPlanner};
pub use cost_model::{CostBackend, ExecReport, LinkUse, PricedPlacement};
pub use hulk::{chain_order, HulkNoGcnPlanner, HulkPlanner, HulkSplitterKind};
pub use placement::{Placement, PlacementSummary, TaskPlacement};
pub use registry::PlannerRegistry;

/// Everything a planner may consult. `workload` must be in canonical
/// order — [`ModelSpec::sort_largest_first`] — because Algorithm 1
/// consumes tasks largest-first and task indices into the resulting
/// [`Placement`] follow this order ([`is_canonical`] checks it).
pub struct PlanContext<'a> {
    pub fleet: &'a Fleet,
    /// Any [`GraphView`]: the dense ≤1k-machine oracle, a direct-built
    /// CSR, or a [`HierarchicalGraph`] — `&ClusterGraph` coerces here at
    /// every historical call site.
    pub graph: &'a dyn GraphView,
    pub workload: &'a [ModelSpec],
    /// Which splitter `F` Hulk-family planners drive Algorithm 1 with
    /// (baselines ignore it).
    pub splitter: HulkSplitterKind<'a>,
    /// How placements are priced ([`Planner::price`]): closed-form
    /// per-task formulas, or whole-placement discrete-event execution
    /// with shared WAN-link contention. `new` defaults to `Analytic`,
    /// keeping every pre-backend call site and artifact byte-identical.
    pub backend: CostBackend,
    /// The two-level graph, when the caller has one. Hulk-family
    /// planners go region-first **only** when this is set *and* lazy
    /// (fleet past `HIER_THRESHOLD`) — every ≤220-machine scenario keeps
    /// the flat plan path and its byte-identical artifacts.
    pub hier: Option<&'a HierarchicalGraph>,
}

impl<'a> PlanContext<'a> {
    pub fn new(fleet: &'a Fleet, graph: &'a dyn GraphView,
               workload: &'a [ModelSpec], splitter: HulkSplitterKind<'a>)
        -> PlanContext<'a>
    {
        PlanContext { fleet, graph, workload, splitter,
                      backend: CostBackend::Analytic, hier: None }
    }

    /// The same context priced by `backend` instead of the default
    /// analytic formulas.
    pub fn with_backend(mut self, backend: CostBackend) -> PlanContext<'a> {
        self.backend = backend;
        self
    }

    /// The same context carrying a hierarchical graph for region-first
    /// planning at scale.
    pub fn with_hier(mut self, hier: &'a HierarchicalGraph)
        -> PlanContext<'a>
    {
        self.hier = Some(hier);
        self
    }
}

/// Is `workload` in the canonical order `sort_largest_first` produces?
pub fn is_canonical(workload: &[ModelSpec]) -> bool {
    workload.windows(2).all(|w| {
        w[1].params
            .total_cmp(&w[0].params)
            .then_with(|| w[0].name.cmp(w[1].name))
            != std::cmp::Ordering::Greater
    })
}

/// What role a planner plays in reports: baselines are what Hulk's
/// headline improvement is measured against; ablations are neither.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannerKind {
    Baseline,
    Hulk,
    Ablation,
}

/// Display/reporting metadata of one registered planner — the column
/// header of an evaluation table or `BENCH_*.json` entry name.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SystemMeta {
    pub name: &'static str,
    pub slug: &'static str,
    pub kind: PlannerKind,
}

/// A placement strategy. Implementations are stateless and shareable
/// across the runner's worker threads (`Send + Sync`).
pub trait Planner: Send + Sync {
    /// Human-readable column name, e.g. `"System B (GPipe)"`.
    fn name(&self) -> &'static str;

    /// Stable machine-readable id used in `BENCH_*.json` entry names and
    /// the `--systems` CLI filter, e.g. `"system_b"`.
    fn slug(&self) -> &'static str;

    /// Baseline / Hulk / Ablation (drives improvement accounting).
    fn kind(&self) -> PlannerKind;

    /// Decide where every task of `ctx.workload` runs.
    fn plan(&self, ctx: &PlanContext) -> Result<Placement>;

    /// Per-iteration cost of task `task_idx` under `placement`, priced
    /// by the **analytic** closed forms. The default prices the
    /// placement IR itself, so identical placements cost identically no
    /// matter which planner emitted them.
    fn cost(&self, ctx: &PlanContext, placement: &Placement,
            task_idx: usize) -> IterCost
    {
        placement.cost(ctx.fleet, &ctx.workload[task_idx], task_idx)
    }

    /// Price the whole placement with the context's
    /// [`CostBackend`]: the analytic arm routes through [`Self::cost`]
    /// task by task (so per-task overrides are honored and the output is
    /// byte-identical to the historical loop); the simulated arm
    /// executes every task concurrently on the discrete-event engine
    /// ([`crate::sim::cluster`]) and additionally returns the
    /// [`ExecReport`] contention digest.
    fn price(&self, ctx: &PlanContext, placement: &Placement)
        -> PricedPlacement
    {
        match ctx.backend {
            CostBackend::Analytic => PricedPlacement {
                per_task: (0..ctx.workload.len())
                    .map(|t| self.cost(ctx, placement, t))
                    .collect(),
                exec: None,
            },
            CostBackend::Simulated => CostBackend::Simulated
                .price(ctx.fleet, ctx.workload, placement),
        }
    }

    /// Reporting metadata bundle.
    fn meta(&self) -> SystemMeta {
        SystemMeta { name: self.name(), slug: self.slug(),
                     kind: self.kind() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ClusterGraph;

    #[test]
    fn canonical_order_check_matches_sorter() {
        // paper_four is strictly descending; paper_six is NOT (BERT-large
        // 340M precedes RoBERTa 355M) until sorted.
        assert!(is_canonical(&ModelSpec::paper_four()));
        let mut wl = ModelSpec::paper_six();
        assert!(!is_canonical(&wl));
        ModelSpec::sort_largest_first(&mut wl);
        assert!(is_canonical(&wl));
        // Ties (BERT-large vs XLNet, both 340M) break by name.
        let tie = vec![ModelSpec::bert_large(), ModelSpec::xlnet_large()];
        assert!(is_canonical(&tie));
        let tie_rev = vec![ModelSpec::xlnet_large(), ModelSpec::bert_large()];
        assert!(!is_canonical(&tie_rev));
    }

    #[test]
    fn default_cost_is_placement_derived() {
        // Two different planners returning the same placement must price
        // it identically (the default cost path).
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let wl = vec![ModelSpec::bert_large()];
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let b = SystemBPlanner;
        let placement = b.plan(&ctx).unwrap();
        let via_trait = b.cost(&ctx, &placement, 0);
        let via_ir = placement.cost(&fleet, &wl[0], 0);
        assert_eq!(via_trait, via_ir);
    }

    #[test]
    fn price_follows_the_context_backend() {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let wl = vec![ModelSpec::bert_large()];
        let analytic_ctx = PlanContext::new(&fleet, &graph, &wl,
                                            HulkSplitterKind::Oracle);
        let b = SystemBPlanner;
        let placement = b.plan(&analytic_ctx).unwrap();
        // Analytic arm == the historical per-task cost loop, no report.
        let priced = b.price(&analytic_ctx, &placement);
        assert!(priced.exec.is_none());
        assert_eq!(priced.per_task,
                   vec![b.cost(&analytic_ctx, &placement, 0)]);
        // Simulated arm carries the execution digest.
        let sim_ctx = PlanContext::new(&fleet, &graph, &wl,
                                       HulkSplitterKind::Oracle)
            .with_backend(CostBackend::Simulated);
        let priced = b.price(&sim_ctx, &placement);
        let exec = priced.exec.expect("sim pricing has a report");
        assert!(exec.makespan_ms.is_finite());
        assert!(priced.per_task[0].is_feasible());
    }
}
