//! The Hulk system as a [`Planner`], plus its natural ablation.
//!
//! Hulk (paper §5–§6): GCN (or oracle) grouping via Algorithm 1, then
//! GPipe inside each group with a locality-aware stage order ("we utilize
//! Gpipe to train the model in parallel [within each class]; depending on
//! the computational power and memory of each node, we determine which
//! part of the model it will handle").
//!
//! - [`HulkPlanner`] — the full system; Algorithm 1 is driven by the
//!   splitter the [`PlanContext`] carries (trained GCN in production,
//!   oracle for artifact-free runs).
//! - [`HulkNoGcnPlanner`] — the `hulk_no_gcn` ablation: identical
//!   grouping pipeline but the splitter is pinned to the labeling
//!   oracle, whatever the context asks for. Any gap between `hulk` (GNN
//!   splitter) and `hulk_no_gcn` isolates the learned model's
//!   contribution from the grouping policy's; under an oracle-configured
//!   context the two match exactly (the seam's identity check).

use anyhow::Result;

use crate::cluster::{Fleet, Machine};
use crate::gnn::inference::GnnSplitter;
use crate::gnn::Classifier;
use crate::graph::{CsrGraph, GraphView, HierarchicalGraph};
use crate::models::ModelSpec;
use crate::parallel::PipelinePlan;
use crate::scheduler::oracle::grow_group;
use crate::scheduler::{algorithm1_pool, Algorithm1Error, Assignment,
                       TaskSplitter};

use super::{is_canonical, PlanContext, Placement, Planner, PlannerKind,
            TaskPlacement};

/// Which splitter `F` drives Algorithm 1.
pub enum HulkSplitterKind<'a> {
    /// The trained GCN (production path). A fresh [`GnnSplitter`] is
    /// built per plan call — one forward pass per call.
    Gnn { classifier: &'a Classifier, params: &'a [f32] },
    /// A caller-owned [`GnnSplitter`] shared across plan calls against
    /// the **same frozen (fleet, graph)** — the serve batcher's seam:
    /// one forward pass serves a whole batch of `Place` requests.
    /// Placements are byte-identical to [`HulkSplitterKind::Gnn`] with
    /// the same classifier/params (the probabilities are the same
    /// memoized forward either way).
    SharedGnn { splitter: &'a GnnSplitter<'a> },
    /// The oracle partitioner (ablation / artifact-free path).
    Oracle,
}

/// Oracle-backed splitter for Algorithm 1.
struct OracleSplitter;

impl TaskSplitter for OracleSplitter {
    fn split(&self, fleet: &Fleet, graph: &dyn GraphView,
             remaining: &[usize], task: &ModelSpec, _class: usize)
        -> Vec<usize>
    {
        grow_group(&fleet.machines, graph, remaining, task, GROUP_HEADROOM)
    }
}

/// Order a group's machines into a pipeline chain by greedy
/// nearest-neighbor on latency: adjacent stages end up in the same or
/// nearby regions.
pub fn chain_order(graph: &dyn GraphView, group: &[usize]) -> Vec<usize> {
    if group.len() <= 2 {
        return group.to_vec();
    }
    // Start from the member with the lowest total latency to the rest.
    let start = *group
        .iter()
        .min_by(|&&a, &&b| {
            let cost = |i: usize| -> f32 {
                group
                    .iter()
                    .map(|&j| {
                        let w = graph.weight(i, j);
                        if j != i && w == 0.0 { 2e3 } else { w }
                    })
                    .sum()
            };
            cost(a).partial_cmp(&cost(b)).unwrap()
        })
        .unwrap();
    let mut chain = vec![start];
    let mut rest: Vec<usize> =
        group.iter().copied().filter(|&m| m != start).collect();
    while !rest.is_empty() {
        let last = *chain.last().unwrap();
        let (k, _) = rest
            .iter()
            .enumerate()
            .min_by(|(_, &a), (_, &b)| {
                let cost = |i: usize| -> f32 {
                    let w = graph.weight(last, i);
                    if w == 0.0 { 2e3 } else { w }
                };
                cost(a).partial_cmp(&cost(b)).unwrap()
            })
            .unwrap();
        chain.push(rest.remove(k));
    }
    chain
}

fn run_algorithm1(fleet: &Fleet, graph: &dyn GraphView, tasks: &[ModelSpec],
                  f: &dyn TaskSplitter, pool: &[usize]) -> Result<Assignment>
{
    match algorithm1_pool(fleet, graph, tasks, f, pool) {
        Ok(a) => Ok(a),
        Err(Algorithm1Error::MustWait { partial, deferred }) => {
            // The coordinator queues deferred tasks; for planning we
            // surface the partial assignment only if nothing is missing
            // entirely.
            anyhow::bail!(
                "Algorithm 1 deferred tasks {:?} (partial groups: {:?})",
                deferred,
                partial.groups.iter().map(Vec::len).collect::<Vec<_>>()
            )
        }
        Err(e) => anyhow::bail!("Algorithm 1 failed: {e}"),
    }
}

/// Headroom factor the oracle splitter (and the two-phase refinement)
/// grows groups to.
const GROUP_HEADROOM: f64 = 1.3;

/// Candidate-pool cap for in-region refinement, as a multiple of the
/// task's memory need: enough slack for grow_group to be choosy, small
/// enough that refinement cost is independent of fleet size.
const CANDIDATE_POOL_FACTOR: f64 = 2.0;

/// Phase 1 of the two-phase plan: rank/accumulate regions for one task
/// until their free memory covers `need` GB. Oracle flavor — greedy on
/// the coarse graph, mirroring [`grow_group`]'s seed + min-added-latency
/// policy (region indices into `hier.summaries()`).
fn rank_regions_oracle(hier: &HierarchicalGraph, free_mem: &[f64],
                       need: f64) -> Vec<usize>
{
    let coarse = hier.coarse();
    let avail: Vec<usize> =
        (0..coarse.n).filter(|&r| free_mem[r] > 0.0).collect();
    if avail.is_empty() {
        return Vec::new();
    }
    let seed = *avail
        .iter()
        .max_by(|&&a, &&b| {
            let score = |r: usize| {
                let loc = coarse.mean_latency(r).unwrap_or(1e4) as f64;
                free_mem[r] / loc.max(1.0)
            };
            score(a).partial_cmp(&score(b)).unwrap()
        })
        .unwrap();
    let mut chosen = vec![seed];
    let mut mem = free_mem[seed];
    while mem < need {
        let next = avail
            .iter()
            .copied()
            .filter(|r| !chosen.contains(r))
            .filter(|&r| chosen.iter().any(|&j| coarse.has_edge(r, j)))
            .min_by(|&a, &b| {
                let cost = |r: usize| -> f64 {
                    chosen
                        .iter()
                        .map(|&j| {
                            let w = coarse.weight(r, j);
                            if w > 0.0 { w as f64 } else { 2e3 }
                        })
                        .sum()
                };
                cost(a).partial_cmp(&cost(b)).unwrap()
            });
        match next {
            Some(r) => {
                mem += free_mem[r];
                chosen.push(r);
            }
            None => break,
        }
    }
    chosen
}

/// Phase 1, GCN flavor: regions ranked by the coarse forward's class
/// probability (descending, index-ascending ties — the same `total_cmp`
/// convention as the flat [`GnnSplitter`]), accumulated until `need` GB.
fn rank_regions_gnn(probs: &[f32], c: usize, class_idx: usize,
                    free_mem: &[f64], need: f64) -> Vec<usize>
{
    let mut ranked: Vec<usize> =
        (0..free_mem.len()).filter(|&r| free_mem[r] > 0.0).collect();
    ranked.sort_by(|&a, &b| {
        probs[b * c + class_idx]
            .total_cmp(&probs[a * c + class_idx])
            .then_with(|| a.cmp(&b))
    });
    let mut chosen = Vec::new();
    let mut mem = 0.0;
    for r in ranked {
        chosen.push(r);
        mem += free_mem[r];
        if mem >= need {
            break;
        }
    }
    chosen
}

/// The two-phase Hulk plan for coarse (past-`HIER_THRESHOLD`) fleets:
/// per task largest-first, (1) choose regions on the ~12-node coarse
/// graph, (2) refine inside them — a capped candidate pool, a subset CSR
/// whose weights come from **global** machine ids (so they equal what
/// the dense oracle would assign those machines), then the same
/// [`grow_group`] + [`chain_order`] pipeline as the flat path. Planning
/// cost per task is O(candidates²), independent of fleet size.
fn plan_two_phase(ctx: &PlanContext, hier: &HierarchicalGraph,
                  splitter: &HulkSplitterKind) -> Result<Placement>
{
    // Coarse GCN forward: once per plan call, over one node per region.
    let gnn_config = match splitter {
        HulkSplitterKind::Gnn { classifier, params } => {
            Some((*classifier, *params))
        }
        HulkSplitterKind::SharedGnn { splitter } => {
            // The shared splitter memoizes the *fine* forward; the
            // coarse (≤12-node) forward is cheap enough to run per call.
            Some((splitter.classifier, splitter.params))
        }
        HulkSplitterKind::Oracle => None,
    };
    let coarse_probs: Option<(Vec<f32>, usize)> = match gnn_config {
        Some((classifier, params)) => {
            let reps = hier.region_representatives();
            let probs =
                classifier.probs_for_graph(params, &reps, hier.coarse())?;
            Some((probs, classifier.n_classes()))
        }
        None => None,
    };

    // Line-2 feasibility over the alive fleet.
    let alive_gb: f64 = (0..hier.n_nodes())
        .filter(|&m| hier.is_alive(m))
        .map(|m| hier.machine(m).total_memory_gb())
        .sum();
    let required: f64 = ctx.workload.iter().map(|t| t.train_gb()).sum();
    anyhow::ensure!(
        alive_gb >= required,
        "graph does not meet task requirements: need {required:.0} GB, \
         have {alive_gb:.0} GB"
    );

    let mut used = vec![false; hier.n_nodes()];
    let mut per_task = Vec::with_capacity(ctx.workload.len());
    for (t, task) in ctx.workload.iter().enumerate() {
        // Free members / free memory per region under the global used set.
        let free: Vec<Vec<usize>> = hier
            .summaries()
            .iter()
            .map(|s| {
                s.members
                    .iter()
                    .copied()
                    .filter(|&m| hier.is_alive(m) && !used[m])
                    .collect()
            })
            .collect();
        let free_mem: Vec<f64> = free
            .iter()
            .map(|ms| {
                ms.iter().map(|&m| hier.machine(m).total_memory_gb()).sum()
            })
            .collect();
        let need = task.train_gb() * GROUP_HEADROOM;
        let regions = match &coarse_probs {
            Some((probs, c)) => {
                rank_regions_gnn(probs, *c, t, &free_mem, need)
            }
            None => rank_regions_oracle(hier, &free_mem, need),
        };
        anyhow::ensure!(!regions.is_empty(),
                        "task {} found no candidate regions", task.name);

        // Capped candidate pool: per chosen region, biggest-memory
        // machines first (id-ascending ties), until ~2× the task's need.
        let mut cands: Vec<usize> = Vec::new();
        let mut cand_gb = 0.0;
        'fill: for &r in &regions {
            let mut members = free[r].clone();
            members.sort_by(|&a, &b| {
                hier.machine(b)
                    .total_memory_gb()
                    .total_cmp(&hier.machine(a).total_memory_gb())
                    .then_with(|| a.cmp(&b))
            });
            for m in members {
                cand_gb += hier.machine(m).total_memory_gb();
                cands.push(m);
                if cand_gb >= need * CANDIDATE_POOL_FACTOR
                    && cands.len() >= 2
                {
                    break 'fill;
                }
            }
        }

        // Subset CSR over the candidates: weights looked up by global id
        // (region latency × global-id jitter), local node k = cands[k].
        let k = cands.len();
        let machines: Vec<Machine> =
            cands.iter().map(|&g| hier.machine(g)).collect();
        let mut row_ptr = Vec::with_capacity(k + 1);
        row_ptr.push(0);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for a in 0..k {
            for b in 0..k {
                if a == b {
                    continue;
                }
                let w = hier.weight(cands[a], cands[b]);
                if w > 0.0 {
                    cols.push(b);
                    vals.push(w);
                }
            }
            row_ptr.push(cols.len());
        }
        let sub = CsrGraph { n: k, real: k, row_ptr, cols, vals };

        let pool: Vec<usize> = (0..k).collect();
        let local =
            grow_group(&machines, &sub, &pool, task, GROUP_HEADROOM);
        let got: f64 =
            local.iter().map(|&l| machines[l].total_memory_gb()).sum();
        anyhow::ensure!(
            !local.is_empty() && got >= task.train_gb(),
            "task {} refinement under-provisioned: {got:.0} GB of \
             {:.0} GB from {k} candidates",
            task.name,
            task.train_gb()
        );

        let ordered_local = chain_order(&sub, &local);
        let mut group: Vec<usize> =
            local.iter().map(|&l| cands[l]).collect();
        group.sort_unstable();
        for &g in &group {
            used[g] = true;
        }
        let ordered: Vec<usize> =
            ordered_local.into_iter().map(|l| cands[l]).collect();
        let n_stages = ordered.len().min(task.layers);
        let stages: Vec<usize> =
            ordered.into_iter().take(n_stages).collect();
        let pipe = PipelinePlan::proportional(ctx.fleet, stages, task);
        per_task.push(TaskPlacement::Grouped {
            group,
            chain: pipe.stages,
            layers: pipe.layers,
            microbatches: pipe.microbatches,
        });
    }
    Ok(Placement { per_task })
}

/// The shared Hulk planning pipeline: Algorithm 1 with `splitter`, then a
/// locality-ordered proportional GPipe plan inside every group. Contexts
/// carrying a **coarse** hierarchical graph (fleet past `HIER_THRESHOLD`)
/// take the region-first two-phase route instead; at or below the
/// threshold the flat path runs unchanged, keeping every existing
/// scenario's placements byte-identical.
fn plan_with_splitter(ctx: &PlanContext, splitter: &HulkSplitterKind)
    -> Result<Placement>
{
    anyhow::ensure!(
        is_canonical(ctx.workload),
        "PlanContext workload must be in canonical order \
         (ModelSpec::sort_largest_first): Algorithm 1 consumes tasks \
         largest-first"
    );
    if let Some(hier) = ctx.hier {
        if hier.is_coarse() {
            return plan_two_phase(ctx, hier, splitter);
        }
    }
    // The flat path's machine pool: everything, unless the context
    // carries a hierarchical graph with liveness deltas — then failed
    // machines are excluded up front, matching plan_two_phase's
    // `is_alive` filter. All-alive contexts build the identity pool, so
    // every historical placement is byte-identical.
    let pool: Vec<usize> = match ctx.hier {
        Some(h) => {
            (0..ctx.fleet.len()).filter(|&m| h.is_alive(m)).collect()
        }
        None => (0..ctx.fleet.len()).collect(),
    };
    let assignment = match splitter {
        HulkSplitterKind::Gnn { classifier, params } => {
            let f = GnnSplitter::new(classifier, params);
            run_algorithm1(ctx.fleet, ctx.graph, ctx.workload, &f, &pool)?
        }
        HulkSplitterKind::SharedGnn { splitter } => {
            run_algorithm1(ctx.fleet, ctx.graph, ctx.workload, *splitter,
                           &pool)?
        }
        HulkSplitterKind::Oracle => {
            run_algorithm1(ctx.fleet, ctx.graph, ctx.workload,
                           &OracleSplitter, &pool)?
        }
    };

    let mut per_task = Vec::with_capacity(ctx.workload.len());
    for (t, task) in ctx.workload.iter().enumerate() {
        let group = assignment.group(t);
        anyhow::ensure!(!group.is_empty(), "task {} got no machines",
                        task.name);
        let ordered = chain_order(ctx.graph, group);
        let n_stages = ordered.len().min(task.layers);
        let stages: Vec<usize> = ordered.into_iter().take(n_stages).collect();
        let pipe = PipelinePlan::proportional(ctx.fleet, stages, task);
        per_task.push(TaskPlacement::Grouped {
            group: group.to_vec(),
            chain: pipe.stages,
            layers: pipe.layers,
            microbatches: pipe.microbatches,
        });
    }
    Ok(Placement { per_task })
}

/// The full Hulk system (splitter chosen by the context).
pub struct HulkPlanner;

impl Planner for HulkPlanner {
    fn name(&self) -> &'static str {
        "Hulk"
    }

    fn slug(&self) -> &'static str {
        "hulk"
    }

    fn kind(&self) -> PlannerKind {
        PlannerKind::Hulk
    }

    fn plan(&self, ctx: &PlanContext) -> Result<Placement> {
        plan_with_splitter(ctx, &ctx.splitter)
    }
}

/// The `hulk_no_gcn` ablation: Algorithm-1 grouping with oracle labels
/// only, ignoring any GNN the context carries.
pub struct HulkNoGcnPlanner;

impl Planner for HulkNoGcnPlanner {
    fn name(&self) -> &'static str {
        "Hulk (no GCN)"
    }

    fn slug(&self) -> &'static str {
        "hulk_no_gcn"
    }

    fn kind(&self) -> PlannerKind {
        PlannerKind::Ablation
    }

    fn plan(&self, ctx: &PlanContext) -> Result<Placement> {
        plan_with_splitter(ctx, &HulkSplitterKind::Oracle)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::graph::ClusterGraph;

    fn setup() -> (Fleet, ClusterGraph) {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        (fleet, graph)
    }

    fn sorted(workload: Vec<ModelSpec>) -> Vec<ModelSpec> {
        let mut wl = workload;
        ModelSpec::sort_largest_first(&mut wl);
        wl
    }

    #[test]
    fn oracle_plan_covers_paper_workload() {
        let (fleet, graph) = setup();
        let wl = sorted(ModelSpec::paper_four());
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let p = HulkPlanner.plan(&ctx).unwrap();
        assert_eq!(p.n_tasks(), 4);
        assert_eq!(wl[0].name, "OPT (175B)"); // sorted desc
        let a = p.to_assignment();
        a.validate_disjoint(fleet.len()).unwrap();
        a.validate_memory(&fleet, &wl).unwrap();
        for t in 0..4 {
            let c = HulkPlanner.cost(&ctx, &p, t);
            assert!(c.is_feasible(), "{} infeasible", wl[t].name);
        }
    }

    #[test]
    fn chain_order_is_a_permutation_and_locality_aware() {
        let (_fleet, graph) = setup();
        let group: Vec<usize> = (0..12).collect();
        let chain = chain_order(&graph, &group);
        let mut sorted = chain.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, group);
        // Adjacent chain latency must not exceed a random order's by
        // construction (greedy NN): compare against identity order.
        let adj_cost = |order: &[usize]| -> f32 {
            order
                .windows(2)
                .map(|w| {
                    let x = graph.weight(w[0], w[1]);
                    if x == 0.0 { 2e3 } else { x }
                })
                .sum()
        };
        assert!(adj_cost(&chain) <= adj_cost(&group) * 1.01,
                "chain {} vs id {}", adj_cost(&chain), adj_cost(&group));
    }

    #[test]
    fn hulk_beats_system_b_on_comm() {
        let (fleet, graph) = setup();
        let wl = sorted(ModelSpec::paper_four());
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let hulk = HulkPlanner.plan(&ctx).unwrap();
        let b = super::super::SystemBPlanner.plan(&ctx).unwrap();
        for (t, task) in wl.iter().enumerate() {
            let hulk_c = HulkPlanner.cost(&ctx, &hulk, t);
            let b_c = super::super::SystemBPlanner.cost(&ctx, &b, t);
            assert!(hulk_c.comm_ms < b_c.comm_ms,
                    "{}: hulk {} vs B {}", task.name, hulk_c.comm_ms,
                    b_c.comm_ms);
        }
    }

    #[test]
    fn infeasible_workload_errors() {
        let fleet = Fleet::paper_toy(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let wl = vec![ModelSpec::opt_175b()];
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        assert!(HulkPlanner.plan(&ctx).is_err());
    }

    #[test]
    fn non_canonical_workload_rejected() {
        let (fleet, graph) = setup();
        let wl = vec![ModelSpec::bert_large(), ModelSpec::opt_175b()];
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let err = HulkPlanner.plan(&ctx).unwrap_err();
        assert!(err.to_string().contains("canonical order"), "{err}");
    }

    #[test]
    fn hier_context_below_threshold_keeps_flat_placements() {
        // The parity pin: attaching a (non-coarse) hierarchical graph to
        // the context must not change a single placement — the two-phase
        // route only engages past HIER_THRESHOLD.
        let fleet = Fleet::synthetic(220, 12, 0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let wl = sorted(ModelSpec::paper_four());
        let flat_ctx = PlanContext::new(&fleet, &graph, &wl,
                                        HulkSplitterKind::Oracle);
        let flat = HulkPlanner.plan(&flat_ctx).unwrap();
        let hier = HierarchicalGraph::from_fleet(Arc::new(fleet.clone()));
        assert!(!hier.is_coarse());
        let ctx = PlanContext::new(&fleet, &hier, &wl,
                                   HulkSplitterKind::Oracle)
            .with_hier(&hier);
        assert_eq!(flat, HulkPlanner.plan(&ctx).unwrap());
    }

    #[test]
    fn two_phase_plans_a_coarse_fleet_without_densifying() {
        let fleet = Fleet::synthetic(1200, 12, 0);
        let hier = HierarchicalGraph::from_fleet(Arc::new(fleet.clone()));
        assert!(hier.is_coarse());
        let wl = sorted(ModelSpec::paper_four());
        let ctx = PlanContext::new(&fleet, &hier, &wl,
                                   HulkSplitterKind::Oracle)
            .with_hier(&hier);
        let p = HulkPlanner.plan(&ctx).unwrap();
        assert_eq!(p.n_tasks(), 4);
        let a = p.to_assignment();
        a.validate_disjoint(fleet.len()).unwrap();
        a.validate_memory(&fleet, &wl).unwrap();
        for g in &a.groups {
            assert!(!g.is_empty());
        }
        // Deterministic.
        assert_eq!(p, HulkPlanner.plan(&ctx).unwrap());
        // The whole plan ran without any dense n×n build of this fleet.
        assert!(crate::graph::max_dense_n()
                    <= crate::graph::DENSE_ORACLE_MAX);
    }

    #[test]
    fn no_gcn_ablation_matches_hulk_under_oracle_context() {
        let (fleet, graph) = setup();
        let wl = sorted(ModelSpec::paper_four());
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let hulk = HulkPlanner.plan(&ctx).unwrap();
        let ablation = HulkNoGcnPlanner.plan(&ctx).unwrap();
        assert_eq!(hulk, ablation,
                   "oracle-context hulk and hulk_no_gcn must coincide");
        for t in 0..wl.len() {
            assert_eq!(HulkPlanner.cost(&ctx, &hulk, t),
                       HulkNoGcnPlanner.cost(&ctx, &ablation, t));
        }
    }
}
