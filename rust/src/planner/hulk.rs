//! The Hulk system as a [`Planner`], plus its natural ablation.
//!
//! Hulk (paper §5–§6): GCN (or oracle) grouping via Algorithm 1, then
//! GPipe inside each group with a locality-aware stage order ("we utilize
//! Gpipe to train the model in parallel [within each class]; depending on
//! the computational power and memory of each node, we determine which
//! part of the model it will handle").
//!
//! - [`HulkPlanner`] — the full system; Algorithm 1 is driven by the
//!   splitter the [`PlanContext`] carries (trained GCN in production,
//!   oracle for artifact-free runs).
//! - [`HulkNoGcnPlanner`] — the `hulk_no_gcn` ablation: identical
//!   grouping pipeline but the splitter is pinned to the labeling
//!   oracle, whatever the context asks for. Any gap between `hulk` (GNN
//!   splitter) and `hulk_no_gcn` isolates the learned model's
//!   contribution from the grouping policy's; under an oracle-configured
//!   context the two match exactly (the seam's identity check).

use anyhow::Result;

use crate::cluster::Fleet;
use crate::gnn::inference::GnnSplitter;
use crate::gnn::Classifier;
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::parallel::PipelinePlan;
use crate::scheduler::{algorithm1, Algorithm1Error, Assignment,
                       TaskSplitter};

use super::{is_canonical, PlanContext, Placement, Planner, PlannerKind,
            TaskPlacement};

/// Which splitter `F` drives Algorithm 1.
pub enum HulkSplitterKind<'a> {
    /// The trained GCN (production path).
    Gnn { classifier: &'a Classifier, params: &'a [f32] },
    /// The oracle partitioner (ablation / artifact-free path).
    Oracle,
}

/// Oracle-backed splitter for Algorithm 1.
struct OracleSplitter;

impl TaskSplitter for OracleSplitter {
    fn split(&self, fleet: &Fleet, graph: &ClusterGraph,
             remaining: &[usize], task: &ModelSpec, _class: usize)
        -> Vec<usize>
    {
        crate::scheduler::oracle::grow_group(fleet, graph, remaining, task,
                                             1.3)
    }
}

/// Order a group's machines into a pipeline chain by greedy
/// nearest-neighbor on latency: adjacent stages end up in the same or
/// nearby regions.
pub fn chain_order(graph: &ClusterGraph, group: &[usize]) -> Vec<usize> {
    if group.len() <= 2 {
        return group.to_vec();
    }
    // Start from the member with the lowest total latency to the rest.
    let start = *group
        .iter()
        .min_by(|&&a, &&b| {
            let cost = |i: usize| -> f32 {
                group
                    .iter()
                    .map(|&j| {
                        let w = graph.weight(i, j);
                        if j != i && w == 0.0 { 2e3 } else { w }
                    })
                    .sum()
            };
            cost(a).partial_cmp(&cost(b)).unwrap()
        })
        .unwrap();
    let mut chain = vec![start];
    let mut rest: Vec<usize> =
        group.iter().copied().filter(|&m| m != start).collect();
    while !rest.is_empty() {
        let last = *chain.last().unwrap();
        let (k, _) = rest
            .iter()
            .enumerate()
            .min_by(|(_, &a), (_, &b)| {
                let cost = |i: usize| -> f32 {
                    let w = graph.weight(last, i);
                    if w == 0.0 { 2e3 } else { w }
                };
                cost(a).partial_cmp(&cost(b)).unwrap()
            })
            .unwrap();
        chain.push(rest.remove(k));
    }
    chain
}

fn run_algorithm1(fleet: &Fleet, graph: &ClusterGraph, tasks: &[ModelSpec],
                  f: &dyn TaskSplitter) -> Result<Assignment>
{
    match algorithm1(fleet, graph, tasks, f) {
        Ok(a) => Ok(a),
        Err(Algorithm1Error::MustWait { partial, deferred }) => {
            // The coordinator queues deferred tasks; for planning we
            // surface the partial assignment only if nothing is missing
            // entirely.
            anyhow::bail!(
                "Algorithm 1 deferred tasks {:?} (partial groups: {:?})",
                deferred,
                partial.groups.iter().map(Vec::len).collect::<Vec<_>>()
            )
        }
        Err(e) => anyhow::bail!("Algorithm 1 failed: {e}"),
    }
}

/// The shared Hulk planning pipeline: Algorithm 1 with `splitter`, then a
/// locality-ordered proportional GPipe plan inside every group.
fn plan_with_splitter(ctx: &PlanContext, splitter: &HulkSplitterKind)
    -> Result<Placement>
{
    anyhow::ensure!(
        is_canonical(ctx.workload),
        "PlanContext workload must be in canonical order \
         (ModelSpec::sort_largest_first): Algorithm 1 consumes tasks \
         largest-first"
    );
    let assignment = match splitter {
        HulkSplitterKind::Gnn { classifier, params } => {
            let f = GnnSplitter::new(classifier, params);
            run_algorithm1(ctx.fleet, ctx.graph, ctx.workload, &f)?
        }
        HulkSplitterKind::Oracle => {
            run_algorithm1(ctx.fleet, ctx.graph, ctx.workload,
                           &OracleSplitter)?
        }
    };

    let mut per_task = Vec::with_capacity(ctx.workload.len());
    for (t, task) in ctx.workload.iter().enumerate() {
        let group = assignment.group(t);
        anyhow::ensure!(!group.is_empty(), "task {} got no machines",
                        task.name);
        let ordered = chain_order(ctx.graph, group);
        let n_stages = ordered.len().min(task.layers);
        let stages: Vec<usize> = ordered.into_iter().take(n_stages).collect();
        let pipe = PipelinePlan::proportional(ctx.fleet, stages, task);
        per_task.push(TaskPlacement::Grouped {
            group: group.to_vec(),
            chain: pipe.stages,
            layers: pipe.layers,
            microbatches: pipe.microbatches,
        });
    }
    Ok(Placement { per_task })
}

/// The full Hulk system (splitter chosen by the context).
pub struct HulkPlanner;

impl Planner for HulkPlanner {
    fn name(&self) -> &'static str {
        "Hulk"
    }

    fn slug(&self) -> &'static str {
        "hulk"
    }

    fn kind(&self) -> PlannerKind {
        PlannerKind::Hulk
    }

    fn plan(&self, ctx: &PlanContext) -> Result<Placement> {
        plan_with_splitter(ctx, &ctx.splitter)
    }
}

/// The `hulk_no_gcn` ablation: Algorithm-1 grouping with oracle labels
/// only, ignoring any GNN the context carries.
pub struct HulkNoGcnPlanner;

impl Planner for HulkNoGcnPlanner {
    fn name(&self) -> &'static str {
        "Hulk (no GCN)"
    }

    fn slug(&self) -> &'static str {
        "hulk_no_gcn"
    }

    fn kind(&self) -> PlannerKind {
        PlannerKind::Ablation
    }

    fn plan(&self, ctx: &PlanContext) -> Result<Placement> {
        plan_with_splitter(ctx, &HulkSplitterKind::Oracle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Fleet, ClusterGraph) {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        (fleet, graph)
    }

    fn sorted(workload: Vec<ModelSpec>) -> Vec<ModelSpec> {
        let mut wl = workload;
        ModelSpec::sort_largest_first(&mut wl);
        wl
    }

    #[test]
    fn oracle_plan_covers_paper_workload() {
        let (fleet, graph) = setup();
        let wl = sorted(ModelSpec::paper_four());
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let p = HulkPlanner.plan(&ctx).unwrap();
        assert_eq!(p.n_tasks(), 4);
        assert_eq!(wl[0].name, "OPT (175B)"); // sorted desc
        let a = p.to_assignment();
        a.validate_disjoint(fleet.len()).unwrap();
        a.validate_memory(&fleet, &wl).unwrap();
        for t in 0..4 {
            let c = HulkPlanner.cost(&ctx, &p, t);
            assert!(c.is_feasible(), "{} infeasible", wl[t].name);
        }
    }

    #[test]
    fn chain_order_is_a_permutation_and_locality_aware() {
        let (_fleet, graph) = setup();
        let group: Vec<usize> = (0..12).collect();
        let chain = chain_order(&graph, &group);
        let mut sorted = chain.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, group);
        // Adjacent chain latency must not exceed a random order's by
        // construction (greedy NN): compare against identity order.
        let adj_cost = |order: &[usize]| -> f32 {
            order
                .windows(2)
                .map(|w| {
                    let x = graph.weight(w[0], w[1]);
                    if x == 0.0 { 2e3 } else { x }
                })
                .sum()
        };
        assert!(adj_cost(&chain) <= adj_cost(&group) * 1.01,
                "chain {} vs id {}", adj_cost(&chain), adj_cost(&group));
    }

    #[test]
    fn hulk_beats_system_b_on_comm() {
        let (fleet, graph) = setup();
        let wl = sorted(ModelSpec::paper_four());
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let hulk = HulkPlanner.plan(&ctx).unwrap();
        let b = super::super::SystemBPlanner.plan(&ctx).unwrap();
        for (t, task) in wl.iter().enumerate() {
            let hulk_c = HulkPlanner.cost(&ctx, &hulk, t);
            let b_c = super::super::SystemBPlanner.cost(&ctx, &b, t);
            assert!(hulk_c.comm_ms < b_c.comm_ms,
                    "{}: hulk {} vs B {}", task.name, hulk_c.comm_ms,
                    b_c.comm_ms);
        }
    }

    #[test]
    fn infeasible_workload_errors() {
        let fleet = Fleet::paper_toy(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let wl = vec![ModelSpec::opt_175b()];
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        assert!(HulkPlanner.plan(&ctx).is_err());
    }

    #[test]
    fn non_canonical_workload_rejected() {
        let (fleet, graph) = setup();
        let wl = vec![ModelSpec::bert_large(), ModelSpec::opt_175b()];
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let err = HulkPlanner.plan(&ctx).unwrap_err();
        assert!(err.to_string().contains("canonical order"), "{err}");
    }

    #[test]
    fn no_gcn_ablation_matches_hulk_under_oracle_context() {
        let (fleet, graph) = setup();
        let wl = sorted(ModelSpec::paper_four());
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let hulk = HulkPlanner.plan(&ctx).unwrap();
        let ablation = HulkNoGcnPlanner.plan(&ctx).unwrap();
        assert_eq!(hulk, ablation,
                   "oracle-context hulk and hulk_no_gcn must coincide");
        for t in 0..wl.len() {
            assert_eq!(HulkPlanner.cost(&ctx, &hulk, t),
                       HulkNoGcnPlanner.cost(&ctx, &ablation, t));
        }
    }
}
