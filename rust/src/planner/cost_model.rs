//! Pluggable cost backends: *how* a [`Placement`](super::Placement) is
//! priced, decoupled from *who* produced it.
//!
//! Two backends exist:
//!
//! - [`CostBackend::Analytic`] — the closed-form per-task models in
//!   [`crate::parallel`] (`data_parallel_cost`, `pipeline_cost`,
//!   `tensor_parallel_cost`), dispatched through [`Placement::cost`].
//!   This is the historical pricing path, byte-identical to every
//!   pre-backend artifact, and the default everywhere.
//! - [`CostBackend::Simulated`] — whole-placement execution on the
//!   discrete-event engine ([`crate::sim::cluster`]): every task of the
//!   placement runs concurrently, contending for shared inter-region WAN
//!   links and machines. Pricing by execution sees the cross-task
//!   interference the closed forms cannot, and returns an
//!   [`ExecReport`] (makespan, per-link utilization, straggler wait)
//!   alongside the per-task [`IterCost`] columns.
//!
//! The backend travels in [`PlanContext::backend`](super::PlanContext)
//! and surfaces on the CLI as `hulk scenarios run … --cost analytic|sim`.
//! Both backends always agree on *feasibility* (the simulated backend
//! gates on the analytic verdict before lowering), so infeasible cells
//! stay infeasible no matter how they are priced.

use anyhow::Result;

use crate::cluster::Fleet;
use crate::models::ModelSpec;
use crate::parallel::IterCost;
use crate::sim::cluster::execute_placement;
pub use crate::sim::cluster::{ExecReport, LinkUse};

use super::Placement;

/// Which pricing engine a plan/evaluate run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CostBackend {
    /// Closed-form per-task formulas (`parallel::*`) — no interference.
    #[default]
    Analytic,
    /// Whole-placement discrete-event execution with shared WAN-link and
    /// machine contention (`sim::cluster`).
    Simulated,
}

/// What a backend returns for one placement: the per-task cost columns,
/// plus the execution digest when pricing ran on the simulator.
#[derive(Clone, Debug)]
pub struct PricedPlacement {
    /// One [`IterCost`] per workload task, placement order.
    pub per_task: Vec<IterCost>,
    /// Present iff the backend executed the placement
    /// ([`CostBackend::Simulated`]).
    pub exec: Option<ExecReport>,
}

impl CostBackend {
    pub const ALL: [CostBackend; 2] =
        [CostBackend::Analytic, CostBackend::Simulated];

    /// Stable id used in CLI flags and artifact suite names.
    pub fn slug(self) -> &'static str {
        match self {
            CostBackend::Analytic => "analytic",
            CostBackend::Simulated => "sim",
        }
    }

    /// Human-readable description for reports.
    pub fn name(self) -> &'static str {
        match self {
            CostBackend::Analytic => "analytic (closed-form)",
            CostBackend::Simulated => "sim (discrete-event, contended)",
        }
    }

    /// Parse the `--cost` CLI value. Accepts the slugs plus the obvious
    /// long form; anything else errors listing the valid names.
    pub fn parse(s: &str) -> Result<CostBackend> {
        match s.trim() {
            "analytic" => Ok(CostBackend::Analytic),
            "sim" | "simulated" => Ok(CostBackend::Simulated),
            other => anyhow::bail!(
                "unknown cost backend {other:?}; valid: analytic, sim"
            ),
        }
    }

    /// Price `placement` for `workload` on `fleet` with this backend.
    /// (Planners route their default [`Planner::price`](super::Planner)
    /// through their own `cost` for the analytic arm so per-task
    /// overrides are honored; this standalone entry point prices the IR
    /// directly.)
    pub fn price(self, fleet: &Fleet, workload: &[ModelSpec],
                 placement: &Placement) -> PricedPlacement
    {
        match self {
            CostBackend::Analytic => PricedPlacement {
                per_task: (0..workload.len())
                    .map(|t| placement.cost(fleet, &workload[t], t))
                    .collect(),
                exec: None,
            },
            CostBackend::Simulated => {
                let run = execute_placement(fleet, workload, placement);
                PricedPlacement {
                    per_task: run.per_task_costs(),
                    exec: Some(run.report),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ClusterGraph;
    use crate::planner::{HulkSplitterKind, PlanContext, Planner,
                         SystemAPlanner};

    #[test]
    fn parse_accepts_slugs_and_rejects_garbage() {
        assert_eq!(CostBackend::parse("analytic").unwrap(),
                   CostBackend::Analytic);
        assert_eq!(CostBackend::parse("sim").unwrap(),
                   CostBackend::Simulated);
        assert_eq!(CostBackend::parse(" simulated ").unwrap(),
                   CostBackend::Simulated);
        let err = CostBackend::parse("exact").unwrap_err();
        assert!(err.to_string().contains("analytic"), "{err}");
        assert_eq!(CostBackend::default(), CostBackend::Analytic);
    }

    #[test]
    fn analytic_backend_is_byte_identical_to_placement_cost() {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let mut wl = ModelSpec::paper_four();
        ModelSpec::sort_largest_first(&mut wl);
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let placement = SystemAPlanner.plan(&ctx).unwrap();
        let priced =
            CostBackend::Analytic.price(&fleet, &wl, &placement);
        assert!(priced.exec.is_none());
        for (t, model) in wl.iter().enumerate() {
            assert_eq!(priced.per_task[t],
                       placement.cost(&fleet, model, t));
        }
    }

    #[test]
    fn simulated_backend_returns_an_exec_report_and_same_feasibility() {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let mut wl = ModelSpec::paper_four();
        ModelSpec::sort_largest_first(&mut wl);
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let placement = SystemAPlanner.plan(&ctx).unwrap();
        let analytic =
            CostBackend::Analytic.price(&fleet, &wl, &placement);
        let sim = CostBackend::Simulated.price(&fleet, &wl, &placement);
        let exec = sim.exec.expect("simulated pricing carries a report");
        assert!(exec.makespan_ms.is_finite());
        assert!(exec.events_processed > 0);
        for t in 0..wl.len() {
            assert_eq!(analytic.per_task[t].is_feasible(),
                       sim.per_task[t].is_feasible(),
                       "backend feasibility disagrees on task {t}");
        }
        // System A gives every task the whole (replica-capable) fleet:
        // under execution the tasks contend, so no simulated total may
        // undercut its analytic counterpart.
        for t in 0..wl.len() {
            if analytic.per_task[t].is_feasible() {
                assert!(sim.per_task[t].total_ms()
                            >= analytic.per_task[t].total_ms() * 0.99,
                        "task {t}: sim {} vs analytic {}",
                        sim.per_task[t].total_ms(),
                        analytic.per_task[t].total_ms());
            }
        }
    }
}
