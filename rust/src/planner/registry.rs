//! The pluggable planner registry: slug → `Box<dyn Planner>`, strictly
//! insertion-ordered (report columns, runner cells and `BENCH_*.json`
//! entry order all follow it).
//!
//! Three constructors matter:
//! - [`PlannerRegistry::standard`] — the paper's four systems in their
//!   canonical order (`system_a`, `system_b`, `system_c`, `hulk`). This
//!   is the default everywhere, which is what keeps
//!   `hulk scenarios run all --json` byte-identical to the
//!   pre-planner-seam artifacts.
//! - [`PlannerRegistry::catalog`] — every known planner: the standard
//!   four plus registered ablations (`hulk_no_gcn`).
//! - [`PlannerRegistry::resolve`] — the `--systems a,b,hulk` CLI filter:
//!   picks a subset of the catalog by slug (or `system_`-less shorthand),
//!   preserving catalog order so filtered artifacts stay column-subsets
//!   of full runs.

use anyhow::Result;

use super::baselines::{SystemAPlanner, SystemBPlanner, SystemCPlanner};
use super::hulk::{HulkNoGcnPlanner, HulkPlanner};
use super::{Planner, PlannerKind, SystemMeta};

/// An insertion-ordered set of planners keyed by slug.
pub struct PlannerRegistry {
    planners: Vec<Box<dyn Planner>>,
}

impl PlannerRegistry {
    /// An empty registry (build your own planner lineup).
    pub fn empty() -> PlannerRegistry {
        PlannerRegistry { planners: Vec::new() }
    }

    /// Append a planner; duplicate slugs are rejected (a slug is an
    /// artifact column name — two planners writing the same column would
    /// corrupt every report).
    pub fn register(&mut self, planner: Box<dyn Planner>) -> Result<()> {
        anyhow::ensure!(
            self.find(planner.slug()).is_none(),
            "planner slug {:?} already registered",
            planner.slug()
        );
        self.planners.push(planner);
        Ok(())
    }

    /// The paper's four systems, canonical order preserved.
    pub fn standard() -> PlannerRegistry {
        let mut r = PlannerRegistry::empty();
        r.register(Box::new(SystemAPlanner)).expect("fresh registry");
        r.register(Box::new(SystemBPlanner)).expect("fresh registry");
        r.register(Box::new(SystemCPlanner)).expect("fresh registry");
        r.register(Box::new(HulkPlanner)).expect("fresh registry");
        r
    }

    /// Every known planner: the standard four plus ablations.
    pub fn catalog() -> PlannerRegistry {
        let mut r = PlannerRegistry::standard();
        r.register(Box::new(HulkNoGcnPlanner)).expect("unique slug");
        r
    }

    /// Resolve a comma-separated `--systems` filter against the catalog.
    /// Accepts full slugs (`system_a`, `hulk_no_gcn`) and the
    /// `system_`-less shorthand (`a`, `b`, `c`); unknown names error
    /// listing the valid ones. Selection keeps **catalog order** (not
    /// user order) and ignores duplicates, so a filtered run's artifact
    /// columns are always an ordered subset of the catalog's.
    pub fn resolve(csv: &str) -> Result<PlannerRegistry> {
        let requested: Vec<&str> = csv
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        anyhow::ensure!(!requested.is_empty(),
                        "--systems got an empty planner list");
        let catalog = PlannerRegistry::catalog();
        let unknown: Vec<&str> = requested
            .iter()
            .copied()
            .filter(|name| {
                !catalog.planners.iter().any(|p| slug_matches(p.slug(), name))
            })
            .collect();
        if !unknown.is_empty() {
            let valid: Vec<&'static str> =
                catalog.planners.iter().map(|p| p.slug()).collect();
            anyhow::bail!(
                "unknown planner{} {unknown:?}; valid slugs: {} \
                 (system_a/b/c may be shortened to a/b/c)",
                if unknown.len() > 1 { "s" } else { "" },
                valid.join(", ")
            );
        }
        let planners: Vec<Box<dyn Planner>> = catalog
            .planners
            .into_iter()
            .filter(|p| requested.iter().any(|n| slug_matches(p.slug(), n)))
            .collect();
        Ok(PlannerRegistry { planners })
    }

    pub fn len(&self) -> usize {
        self.planners.len()
    }

    pub fn is_empty(&self) -> bool {
        self.planners.is_empty()
    }

    pub fn get(&self, idx: usize) -> &dyn Planner {
        &*self.planners[idx]
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Planner> {
        self.planners.iter().map(|p| &**p)
    }

    /// The registered baselines, in order (improvement denominators).
    pub fn baselines(&self) -> impl Iterator<Item = &dyn Planner> {
        self.iter().filter(|p| p.kind() == PlannerKind::Baseline)
    }

    pub fn find(&self, slug: &str) -> Option<&dyn Planner> {
        self.iter().find(|p| p.slug() == slug)
    }

    /// Column metadata, in insertion order.
    pub fn metas(&self) -> Vec<SystemMeta> {
        self.iter().map(|p| p.meta()).collect()
    }

    pub fn slugs(&self) -> Vec<&'static str> {
        self.iter().map(|p| p.slug()).collect()
    }
}

fn slug_matches(slug: &str, requested: &str) -> bool {
    slug == requested
        || slug.strip_prefix("system_") == Some(requested)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_is_the_canonical_four() {
        let r = PlannerRegistry::standard();
        assert_eq!(r.slugs(),
                   vec!["system_a", "system_b", "system_c", "hulk"]);
        assert_eq!(r.baselines().count(), 3);
        assert_eq!(r.find("hulk").unwrap().kind(), PlannerKind::Hulk);
    }

    #[test]
    fn catalog_appends_the_ablation() {
        let r = PlannerRegistry::catalog();
        assert_eq!(
            r.slugs(),
            vec!["system_a", "system_b", "system_c", "hulk", "hulk_no_gcn"]
        );
        assert_eq!(r.find("hulk_no_gcn").unwrap().kind(),
                   PlannerKind::Ablation);
        // Names and slugs are unique.
        let mut slugs = r.slugs();
        slugs.sort_unstable();
        slugs.dedup();
        assert_eq!(slugs.len(), r.len());
    }

    #[test]
    fn duplicate_slugs_rejected() {
        let mut r = PlannerRegistry::standard();
        let err = r.register(Box::new(HulkPlanner)).unwrap_err();
        assert!(err.to_string().contains("hulk"), "{err}");
    }

    #[test]
    fn resolve_accepts_slugs_and_shorthand_in_catalog_order() {
        let r = PlannerRegistry::resolve("hulk,a,system_b").unwrap();
        // Catalog order, not user order.
        assert_eq!(r.slugs(), vec!["system_a", "system_b", "hulk"]);
        let r = PlannerRegistry::resolve("hulk_no_gcn").unwrap();
        assert_eq!(r.slugs(), vec!["hulk_no_gcn"]);
        // Duplicates collapse.
        let r = PlannerRegistry::resolve("a, a ,system_a").unwrap();
        assert_eq!(r.slugs(), vec!["system_a"]);
    }

    #[test]
    fn resolve_rejects_unknown_and_empty() {
        let err = PlannerRegistry::resolve("a,bogus").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("bogus"), "{msg}");
        assert!(msg.contains("hulk_no_gcn"), "{msg} lists valid slugs");
        assert!(PlannerRegistry::resolve("").is_err());
        assert!(PlannerRegistry::resolve(" , ").is_err());
    }
}
