//! The paper's three baseline systems (§6.4) as [`Planner`]s.
//!
//! - **System A** ([`SystemAPlanner`]) — pure data parallelism; machines
//!   that cannot hold a full replica are discarded. When *no* machine
//!   fits (OPT-175B on the evaluation fleet) the task is genuinely
//!   untrainable and prices infeasible.
//! - **System B** ([`SystemBPlanner`]) — GPipe across the fleet, layers
//!   assigned in machine-id order until the model is distributed.
//!   Topology-oblivious: stages routinely straddle continents, which is
//!   the pathology Hulk's grouping removes.
//! - **System C** ([`SystemCPlanner`]) — Megatron-LM tensor parallelism
//!   across the entire fleet ("requiring all machines to be utilized").

use anyhow::Result;

use crate::models::ModelSpec;
use crate::parallel::data_parallel::replica_capable;
use crate::parallel::PipelinePlan;

use super::{PlanContext, Placement, Planner, PlannerKind, TaskPlacement};

/// System A: data parallelism over every replica-capable machine.
pub struct SystemAPlanner;

impl Planner for SystemAPlanner {
    fn name(&self) -> &'static str {
        "System A (DP)"
    }

    fn slug(&self) -> &'static str {
        "system_a"
    }

    fn kind(&self) -> PlannerKind {
        PlannerKind::Baseline
    }

    fn plan(&self, ctx: &PlanContext) -> Result<Placement> {
        Ok(Placement {
            per_task: ctx
                .workload
                .iter()
                .map(|model| TaskPlacement::Replicated {
                    participants: replica_capable(ctx.fleet, model),
                })
                .collect(),
        })
    }
}

/// System B: one GPipe pipeline over the first `min(layers, n)` machines
/// in id order, layer split proportional to throughput.
pub struct SystemBPlanner;

fn id_order_pipeline(ctx: &PlanContext, model: &ModelSpec) -> TaskPlacement {
    let n_stages = ctx.fleet.len().min(model.layers);
    let stages: Vec<usize> = (0..n_stages).collect();
    let plan = PipelinePlan::proportional(ctx.fleet, stages, model);
    TaskPlacement::PipelineStages {
        stages: plan.stages,
        layers: plan.layers,
        microbatches: plan.microbatches,
    }
}

impl Planner for SystemBPlanner {
    fn name(&self) -> &'static str {
        "System B (GPipe)"
    }

    fn slug(&self) -> &'static str {
        "system_b"
    }

    fn kind(&self) -> PlannerKind {
        PlannerKind::Baseline
    }

    fn plan(&self, ctx: &PlanContext) -> Result<Placement> {
        Ok(Placement {
            per_task: ctx
                .workload
                .iter()
                .map(|model| id_order_pipeline(ctx, model))
                .collect(),
        })
    }
}

/// System C: Megatron tensor parallelism over the whole fleet.
pub struct SystemCPlanner;

impl Planner for SystemCPlanner {
    fn name(&self) -> &'static str {
        "System C (Megatron)"
    }

    fn slug(&self) -> &'static str {
        "system_c"
    }

    fn kind(&self) -> PlannerKind {
        PlannerKind::Baseline
    }

    fn plan(&self, ctx: &PlanContext) -> Result<Placement> {
        let all: Vec<usize> = (0..ctx.fleet.len()).collect();
        Ok(Placement {
            per_task: ctx
                .workload
                .iter()
                .map(|_| TaskPlacement::TensorSharded { group: all.clone() })
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fleet;
    use crate::graph::ClusterGraph;
    use crate::planner::HulkSplitterKind;

    fn ctx_parts(workload: Vec<ModelSpec>)
        -> (Fleet, ClusterGraph, Vec<ModelSpec>)
    {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let mut wl = workload;
        ModelSpec::sort_largest_first(&mut wl);
        (fleet, graph, wl)
    }

    #[test]
    fn system_a_bert_uses_whole_fleet_and_opt_is_infeasible() {
        let (fleet, graph, wl) =
            ctx_parts(vec![ModelSpec::opt_175b(), ModelSpec::bert_large()]);
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let p = SystemAPlanner.plan(&ctx).unwrap();
        // wl sorted: OPT first, BERT second.
        assert!(p.machines(0).is_empty(), "no machine fits OPT-175B");
        assert!(!SystemAPlanner.cost(&ctx, &p, 0).is_feasible());
        assert_eq!(p.machines(1).len(), 46, "BERT replicates everywhere");
        assert!(SystemAPlanner.cost(&ctx, &p, 1).is_feasible());
    }

    #[test]
    fn system_a_t5_uses_a_strict_subset() {
        let (fleet, graph, wl) = ctx_parts(vec![ModelSpec::t5_11b()]);
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let p = SystemAPlanner.plan(&ctx).unwrap();
        let n = p.machines(0).len();
        assert!(n > 0 && n < 46, "expected a strict subset, got {n}");
    }

    #[test]
    fn system_b_uses_all_machines_up_to_layer_count() {
        let (fleet, graph, wl) =
            ctx_parts(vec![ModelSpec::opt_175b(), ModelSpec::bert_large()]);
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let p = SystemBPlanner.plan(&ctx).unwrap();
        assert_eq!(p.pipeline(0).unwrap().n_stages(), 46); // 96 layers > 46
        assert_eq!(p.pipeline(1).unwrap().n_stages(), 24); // 24 layers < 46
    }

    #[test]
    fn system_b_feasible_but_comm_heavy_for_all_paper_models() {
        let (fleet, graph, wl) = ctx_parts(ModelSpec::paper_six());
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let p = SystemBPlanner.plan(&ctx).unwrap();
        for (t, model) in wl.iter().enumerate() {
            let c = SystemBPlanner.cost(&ctx, &p, t);
            assert!(c.is_feasible(), "{} infeasible under B", model.name);
            if model.name == "GPT-2 (1.5B)" {
                // id-order stages cross regions constantly: comm must
                // dominate compute for a model this small.
                assert!(c.comm_ms > c.comp_ms, "comm {} comp {}",
                        c.comm_ms, c.comp_ms);
            }
        }
    }

    #[test]
    fn system_c_feasible_but_comm_bound_for_every_model() {
        let (fleet, graph, wl) = ctx_parts(ModelSpec::paper_six());
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let p = SystemCPlanner.plan(&ctx).unwrap();
        for (t, model) in wl.iter().enumerate() {
            assert_eq!(p.machines(t).len(), fleet.len());
            let c = SystemCPlanner.cost(&ctx, &p, t);
            assert!(c.is_feasible(), "{}", model.name);
            assert!(c.comm_ms > c.comp_ms,
                    "{}: TP over WAN must be comm-bound", model.name);
        }
    }
}
