//! The typed placement IR every [`Planner`](super::Planner) emits.
//!
//! A [`Placement`] holds one [`TaskPlacement`] per workload task (same
//! index as the canonically sorted `PlanContext::workload`). The IR is
//! *priceable on its own* — [`Placement::cost`] dispatches to the
//! analytic cost models in [`crate::parallel`] — which is what lets the
//! `Planner` trait ship a default `cost` and what guarantees that two
//! planners emitting the same placement report the same numbers.

use crate::cluster::Fleet;
use crate::models::ModelSpec;
use crate::parallel::{data_parallel_cost, pipeline_cost,
                      tensor_parallel_cost, IterCost, PipelinePlan};
use crate::scheduler::Assignment;

/// Where and how one task runs.
#[derive(Clone, Debug, PartialEq)]
pub enum TaskPlacement {
    /// Synchronous data parallelism: every participant holds a full
    /// replica and all-reduces gradients (System A). Empty participants
    /// = the task fits no machine (priced infeasible).
    Replicated { participants: Vec<usize> },
    /// A GPipe pipeline: stage `s` runs on machine `stages[s]` hosting
    /// `layers[s]` contiguous layers (System B).
    PipelineStages {
        stages: Vec<usize>,
        layers: Vec<usize>,
        microbatches: usize,
    },
    /// Megatron-style tensor parallelism across `group` (System C).
    TensorSharded { group: Vec<usize> },
    /// Hulk: an Algorithm-1 group plus the locality-aware chain order a
    /// pipeline runs over. `chain` is the stage order (truncated to the
    /// model's layer count, so possibly a strict subset of `group`);
    /// `layers` is the per-stage split.
    Grouped {
        group: Vec<usize>,
        chain: Vec<usize>,
        layers: Vec<usize>,
        microbatches: usize,
    },
}

/// A complete deployment decision for a workload.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    /// One strategy per task, indexed like the context workload.
    pub per_task: Vec<TaskPlacement>,
}

/// The per-system placement digest reported in `BENCH_placements.json`:
/// how many tasks got machines, how many pipeline stages exist in
/// total, and how many adjacent communication edges cross a region
/// boundary (the quantity Hulk's grouping minimizes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementSummary {
    pub groups: usize,
    pub stages: usize,
    pub cross_region_edges: usize,
}

impl Placement {
    pub fn n_tasks(&self) -> usize {
        self.per_task.len()
    }

    /// The machines task `task` runs on (participants / stages / group).
    pub fn machines(&self, task: usize) -> &[usize] {
        match &self.per_task[task] {
            TaskPlacement::Replicated { participants } => participants,
            TaskPlacement::PipelineStages { stages, .. } => stages,
            TaskPlacement::TensorSharded { group } => group,
            TaskPlacement::Grouped { group, .. } => group,
        }
    }

    /// The concrete pipeline plan of a pipelined task (`None` for
    /// replicated / tensor-sharded tasks).
    pub fn pipeline(&self, task: usize) -> Option<PipelinePlan> {
        match &self.per_task[task] {
            TaskPlacement::PipelineStages { stages, layers, microbatches }
            | TaskPlacement::Grouped { chain: stages, layers,
                                       microbatches, .. } => {
                Some(PipelinePlan {
                    stages: stages.clone(),
                    layers: layers.clone(),
                    microbatches: *microbatches,
                })
            }
            _ => None,
        }
    }

    /// Per-iteration cost of task `task` (which must be `model`) under
    /// this placement — the single pricing path behind every planner's
    /// default `cost`.
    pub fn cost(&self, fleet: &Fleet, model: &ModelSpec, task: usize)
        -> IterCost
    {
        match &self.per_task[task] {
            TaskPlacement::Replicated { participants } => {
                data_parallel_cost(fleet, participants, model)
            }
            TaskPlacement::TensorSharded { group } => {
                tensor_parallel_cost(fleet, group, model)
            }
            TaskPlacement::PipelineStages { .. }
            | TaskPlacement::Grouped { .. } => {
                let plan = self.pipeline(task).expect("pipelined variant");
                pipeline_cost(fleet, &plan, model)
            }
        }
    }

    /// Structural validity against a concrete fleet: every machine id a
    /// task references (group members and pipeline chains alike) must
    /// exist in `fleet`, and no list may name the same machine twice.
    /// This is the "lands on live machines" floor the property harness
    /// checks for every planner — and the guard that makes pricing safe,
    /// since [`Placement::cost`] indexes `fleet.machines` directly.
    /// Capacity and connectivity are the cost models' job.
    pub fn validate_machines(&self, fleet: &Fleet)
        -> Result<(), String>
    {
        let check = |task: usize, what: &str, ids: &[usize]|
            -> Result<(), String>
        {
            let mut seen = vec![false; fleet.len()];
            for &m in ids {
                if m >= fleet.len() {
                    return Err(format!(
                        "task {task}: {what} names machine {m} but the \
                         fleet has machines 0..{}", fleet.len()));
                }
                if seen[m] {
                    return Err(format!(
                        "task {task}: {what} lists machine {m} twice"));
                }
                seen[m] = true;
            }
            Ok(())
        };
        for (t, p) in self.per_task.iter().enumerate() {
            check(t, "group", self.machines(t))?;
            if let TaskPlacement::Grouped { chain, .. } = p {
                check(t, "chain", chain)?;
            }
        }
        Ok(())
    }

    /// The machine groups as a scheduler [`Assignment`] (task order
    /// preserved) — for validation helpers and quality metrics.
    pub fn to_assignment(&self) -> Assignment {
        Assignment::new(
            (0..self.n_tasks())
                .map(|t| self.machines(t).to_vec())
                .collect(),
        )
    }

    /// Reporting digest; see [`PlacementSummary`].
    pub fn summary(&self, fleet: &Fleet) -> PlacementSummary {
        let groups = (0..self.n_tasks())
            .filter(|&t| !self.machines(t).is_empty())
            .count();
        let stages = self
            .per_task
            .iter()
            .map(|p| match p {
                TaskPlacement::PipelineStages { stages, .. } => stages.len(),
                TaskPlacement::Grouped { chain, .. } => chain.len(),
                _ => 0,
            })
            .sum();
        let cross_region_edges = self
            .per_task
            .iter()
            .map(|p| match p {
                // Ring collectives in id order: every ring edge,
                // wraparound included.
                TaskPlacement::Replicated { participants: m }
                | TaskPlacement::TensorSharded { group: m } => {
                    ring_cross_region(fleet, m)
                }
                // Pipelines: each stage boundary once.
                TaskPlacement::PipelineStages { stages, .. } => {
                    chain_cross_region(fleet, stages)
                }
                TaskPlacement::Grouped { chain, .. } => {
                    chain_cross_region(fleet, chain)
                }
            })
            .sum();
        PlacementSummary { groups, stages, cross_region_edges }
    }
}

fn differs(fleet: &Fleet, a: usize, b: usize) -> bool {
    fleet.machines[a].region != fleet.machines[b].region
}

fn chain_cross_region(fleet: &Fleet, order: &[usize]) -> usize {
    order
        .windows(2)
        .filter(|w| differs(fleet, w[0], w[1]))
        .count()
}

fn ring_cross_region(fleet: &Fleet, members: &[usize]) -> usize {
    let n = members.len();
    if n <= 1 {
        return 0;
    }
    (0..n)
        .filter(|&k| differs(fleet, members[k], members[(k + 1) % n]))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn machines_and_pipeline_per_variant() {
        let p = Placement {
            per_task: vec![
                TaskPlacement::Replicated { participants: vec![0, 1] },
                TaskPlacement::PipelineStages {
                    stages: vec![2, 3],
                    layers: vec![12, 12],
                    microbatches: 8,
                },
                TaskPlacement::TensorSharded { group: vec![4] },
                TaskPlacement::Grouped {
                    group: vec![5, 6, 7],
                    chain: vec![6, 5],
                    layers: vec![10, 14],
                    microbatches: 8,
                },
            ],
        };
        assert_eq!(p.machines(0), &[0, 1]);
        assert_eq!(p.machines(3), &[5, 6, 7]);
        assert!(p.pipeline(0).is_none());
        assert!(p.pipeline(2).is_none());
        let pipe = p.pipeline(3).unwrap();
        assert_eq!(pipe.stages, vec![6, 5]);
        assert_eq!(pipe.layers, vec![10, 14]);
        let a = p.to_assignment();
        assert_eq!(a.group(1), &[2, 3]);
        assert_eq!(a.group(3), &[5, 6, 7]);
    }

    #[test]
    fn cost_matches_the_underlying_models() {
        let fleet = Fleet::paper_toy(0);
        let model = ModelSpec::bert_large();
        let pipe = PipelinePlan::proportional(&fleet, vec![0, 1, 3], &model);
        let p = Placement {
            per_task: vec![
                TaskPlacement::Replicated { participants: vec![0, 1] },
                TaskPlacement::PipelineStages {
                    stages: pipe.stages.clone(),
                    layers: pipe.layers.clone(),
                    microbatches: pipe.microbatches,
                },
                TaskPlacement::TensorSharded { group: vec![0, 1, 2] },
            ],
        };
        assert_eq!(p.cost(&fleet, &model, 0),
                   data_parallel_cost(&fleet, &[0, 1], &model));
        assert_eq!(p.cost(&fleet, &model, 1),
                   pipeline_cost(&fleet, &pipe, &model));
        assert_eq!(p.cost(&fleet, &model, 2),
                   tensor_parallel_cost(&fleet, &[0, 1, 2], &model));
        // Empty replica set prices infeasible, exactly like System A on
        // an oversized model.
        let none = Placement {
            per_task: vec![TaskPlacement::Replicated {
                participants: vec![],
            }],
        };
        assert!(!none.cost(&fleet, &model, 0).is_feasible());
    }

    #[test]
    fn validate_machines_rejects_dead_ids_and_duplicates() {
        let fleet = Fleet::paper_toy(0);
        let ok = Placement {
            per_task: vec![
                TaskPlacement::Replicated { participants: vec![0, 3] },
                TaskPlacement::Grouped {
                    group: vec![1, 2, 4],
                    chain: vec![2, 1],
                    layers: vec![12, 12],
                    microbatches: 8,
                },
            ],
        };
        assert!(ok.validate_machines(&fleet).is_ok());
        let dead = Placement {
            per_task: vec![TaskPlacement::Replicated {
                participants: vec![0, fleet.len()],
            }],
        };
        let err = dead.validate_machines(&fleet).unwrap_err();
        assert!(err.contains("machines 0..8"), "{err}");
        let dup = Placement {
            per_task: vec![TaskPlacement::TensorSharded {
                group: vec![2, 2],
            }],
        };
        assert!(dup.validate_machines(&fleet).unwrap_err()
                   .contains("twice"));
        // The pipeline chain is validated too, not just the group.
        let bad_chain = Placement {
            per_task: vec![TaskPlacement::Grouped {
                group: vec![0, 1],
                chain: vec![0, 9],
                layers: vec![12, 12],
                microbatches: 8,
            }],
        };
        assert!(bad_chain.validate_machines(&fleet).unwrap_err()
                        .contains("chain"));
    }

    #[test]
    fn summary_counts_groups_stages_and_region_crossings() {
        // paper_toy: nodes 0,1 Beijing; 2,3 California; … (regions vary
        // by id) — rely only on "same id ⇒ same region".
        let fleet = Fleet::paper_toy(0);
        let same = fleet.machines[0].region == fleet.machines[1].region;
        let p = Placement {
            per_task: vec![
                TaskPlacement::Grouped {
                    group: vec![0, 1],
                    chain: vec![0, 1],
                    layers: vec![12, 12],
                    microbatches: 8,
                },
                TaskPlacement::Replicated { participants: vec![] },
            ],
        };
        let s = p.summary(&fleet);
        assert_eq!(s.groups, 1);
        assert_eq!(s.stages, 2);
        assert_eq!(s.cross_region_edges, usize::from(!same));
        // A single-member ring has no edges.
        let solo = Placement {
            per_task: vec![TaskPlacement::TensorSharded { group: vec![3] }],
        };
        assert_eq!(solo.summary(&fleet).cross_region_edges, 0);
    }
}
