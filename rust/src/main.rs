//! `hulk` — the Layer-3 coordinator binary.
//!
//! Subcommands (full grammar: `hulk help` / `cli::usage`):
//! - `info`      — fleet inventory + model catalog.
//! - `assign`    — run Hulk task assignment (Table 2), oracle or GNN.
//! - `train-gnn` — train the GCN from Rust through PJRT (Fig. 4).
//! - `simulate`  — multi-task leader-loop simulation with failures.
//! - `bench`     — regenerate any paper table/figure (see benches/).
//! - `scenarios` — list/run the named-scenario registry (`--json` emits
//!   `BENCH_scenarios.json` through the benchkit reporting layer), or
//!   `generate` seeded random property-test cases (`--check` runs the
//!   planner invariants with shrinking-on-failure).
//! - `serve`     — the placement-as-a-service daemon (batched GCN
//!   forwards, live fleet updates over the wire).
//! - `loadgen`   — drive a running daemon; writes `BENCH_serve.json`.
//! - `chaos`     — seeded fault injection against a running daemon
//!   (region outage, revocation wave, link flap, join storm) with
//!   recovery probing; writes `BENCH_serve_chaos.json`.
//! - `help`      — print the CLI grammar.

use std::path::PathBuf;

use anyhow::Result;

use hulk::benchkit::BenchReport;
use hulk::cli::Cli;
use hulk::cluster::Fleet;
use hulk::coordinator::{Coordinator, CoordinatorEvent, CoordinatorReply};
use hulk::gnn::{make_dataset, train_gcn, TrainerOptions};
use hulk::models::ModelSpec;
use hulk::planner::{CostBackend, HulkSplitterKind, PlannerRegistry};
use hulk::runtime::{GcnRuntime, Manifest};
use hulk::runtime::client::TrainState;
use hulk::scenarios::evaluate_all;
use hulk::util::rng::Rng;
use hulk::util::table::{fmt_params, Table};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(&args)?;
    match cli.command.as_str() {
        "info" => cmd_info(&cli),
        "assign" => cmd_assign(&cli),
        "train-gnn" => cmd_train_gnn(&cli),
        "simulate" => cmd_simulate(&cli),
        "bench" => hulk::scenarios::bench::run(&cli.positional, &cli),
        "scenarios" => cmd_scenarios(&cli),
        "serve" => hulk::serve::run_serve(&cli),
        "loadgen" => hulk::serve::run_loadgen(&cli),
        "chaos" => hulk::serve::run_chaos(&cli),
        "help" | "--help" | "-h" => {
            println!("{}", hulk::cli::usage());
            Ok(())
        }
        other => anyhow::bail!(
            "unknown subcommand {other:?} (see `hulk help`)"),
    }
}

/// `hulk scenarios list` / `hulk scenarios run <name…|all>`.
fn cmd_scenarios(cli: &Cli) -> Result<()> {
    match cli.positional.first().map(String::as_str) {
        Some("list") => {
            let mut t = hulk::util::table::Table::new(
                &["scenario", "description"]);
            for s in hulk::scenarios::all_scenarios() {
                let name = if s.sim_only {
                    format!("{} (sim-only)", s.name)
                } else {
                    s.name.to_string()
                };
                t.row(&[name, s.description.to_string()]);
            }
            println!("{}", t.render());
            let catalog = PlannerRegistry::catalog();
            println!("registered planners: {} (default: the paper's \
                      four; filter with --systems)",
                     catalog.slugs().join(", "));
            println!("cost backends: analytic (closed-form, default), \
                      sim (discrete-event with shared WAN contention; \
                      sim-only scenarios need it)");
            println!("run with: hulk scenarios run <name…|all> \
                      [--seed S] [--systems a,b,hulk] \
                      [--cost analytic|sim] [--json] [--out DIR] \
                      [--parallel] [--threads N]");
            Ok(())
        }
        Some("run") => {
            let seed = cli.flag_u64("seed", 0)?;
            let names = &cli.positional[1..];
            let backend = match cli.flag("cost") {
                Some(v) => CostBackend::parse(v)?,
                None => CostBackend::Analytic,
            };
            // Every name is validated before anything runs: an unknown
            // scenario (or planner slug) exits non-zero listing the
            // valid names instead of silently running the wrong suite.
            let (specs, ran_all) =
                hulk::scenarios::resolve_scenarios(names, backend)?;
            let planners = match cli.flag("systems") {
                Some(csv) => PlannerRegistry::resolve(csv)?,
                None => PlannerRegistry::standard(),
            };
            let threads = scenario_threads(cli)?;
            let started = std::time::Instant::now();
            let results = hulk::scenarios::run_specs(&specs, seed,
                                                     threads, &planners,
                                                     backend)?;
            let wall = started.elapsed().as_secs_f64();
            for r in &results {
                println!("\n================ {} (seed {seed}) \
                          ================",
                         r.scenario);
                println!("{}", r.rendered);
            }
            // Wall-clock is logged to stdout only — the JSON report
            // stays free of timing so parallel and serial runs diff
            // byte-identical.
            println!("ran {} scenario(s) × {} planner(s) on {} \
                      thread(s), {} pricing, in {:.2}s",
                     results.len(), planners.len(), threads,
                     backend.name(), wall);
            if cli.flag_bool("json") {
                let out = PathBuf::from(cli.flag("out").unwrap_or("."));
                // A subset run gets its own file name so it cannot
                // silently overwrite the full-suite report; likewise a
                // planner-filtered or sim-priced run.
                let mut suite = if ran_all {
                    "scenarios".to_string()
                } else {
                    let picked: Vec<&str> =
                        results.iter().map(|r| r.scenario).collect();
                    format!("scenarios_{}", picked.join("_"))
                };
                if cli.flag("systems").is_some() {
                    suite =
                        format!("{suite}_systems_{}",
                                planners.slugs().join("_"));
                }
                if backend != CostBackend::Analytic {
                    suite = format!("{suite}_cost_{}", backend.slug());
                }
                let mut report = BenchReport::new(&suite);
                // The placement digests go to a sibling file so the
                // scenarios artifact keeps its pre-planner-seam shape
                // byte-for-byte.
                let mut placements = BenchReport::new(
                    &suite.replacen("scenarios", "placements", 1));
                for r in results {
                    report.extend(r.entries);
                    placements.extend(r.placements);
                }
                let path = report.write(&out)?;
                println!("wrote {} ({} entries)", path.display(),
                         report.entries.len());
                let path = placements.write(&out)?;
                println!("wrote {} ({} entries)", path.display(),
                         placements.entries.len());
            }
            Ok(())
        }
        Some("generate") => {
            let seed = cli.flag_u64("seed", 0)?;
            let count = cli.flag_u64("count", 20)? as usize;
            anyhow::ensure!(count >= 1, "--count must be at least 1");
            // The property run covers every registered planner by
            // default (ablations included) — `--systems` narrows it.
            let planners = match cli.flag("systems") {
                Some(csv) => PlannerRegistry::resolve(csv)?,
                None => PlannerRegistry::catalog(),
            };
            let mut t = hulk::util::table::Table::new(
                &["case", "machines", "regions", "tasks", "failures"]);
            for index in 0..count {
                let shape =
                    hulk::scenarios::generate_case(seed, index).shape();
                t.row(&[format!("{index:02}"),
                        shape.machines.to_string(),
                        shape.regions.to_string(),
                        shape.tasks.to_string(),
                        shape.failures.to_string()]);
            }
            println!("{}", t.render());
            println!("generated {count} case(s) from seed {seed} \
                      (deterministic: case K alone reproduces as \
                      --seed {seed} --count K+1)");
            if cli.flag_bool("check") {
                let started = std::time::Instant::now();
                let run = hulk::scenarios::run_generated(
                    seed, count, &planners,
                    &hulk::scenarios::CheckOptions::default());
                let wall = started.elapsed().as_secs_f64();
                if let Some(report) = run.failure {
                    eprintln!("{report}");
                    anyhow::bail!(
                        "generated-case property check failed after \
                         {} case(s) (seed {seed})", run.cases);
                }
                println!("checked {} case(s) × {} planner(s): {} \
                          fully planned, 0 violations, in {wall:.2}s",
                         run.cases, planners.len(), run.fully_planned);
            }
            Ok(())
        }
        _ => anyhow::bail!(
            "usage: hulk scenarios <list|run|generate> … \
             (see `hulk help`)"),
    }
}

/// Worker-pool width for `scenarios run`: `--threads N` pins it (and
/// implies parallel execution); bare `--parallel` uses the machine's
/// available parallelism; default is serial.
fn scenario_threads(cli: &Cli) -> Result<usize> {
    if cli.flag("threads").is_some() {
        let n = cli.flag_u64("threads", 1)?;
        anyhow::ensure!(n >= 1, "--threads must be >= 1, got {n}");
        return Ok(n as usize);
    }
    if cli.flag_bool("parallel") {
        return Ok(std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(4));
    }
    Ok(1)
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let seed = cli.flag_u64("seed", 0)?;
    let fleet = Fleet::paper_evaluation(seed);
    println!("Hulk evaluation fleet (seed {seed}): {} servers, {} GPUs, \
              {:.1} TB total GPU memory",
             fleet.len(), fleet.total_gpus(),
             fleet.total_memory_gb() / 1e3);
    let mut t = Table::new(&["id", "region", "gpu", "n", "mem GB",
                             "TFLOP/s"]);
    for m in &fleet.machines {
        t.row(&[
            m.id.to_string(),
            m.region.name().to_string(),
            m.gpu.name().to_string(),
            m.n_gpus.to_string(),
            format!("{:.0}", m.total_memory_gb()),
            format!("{:.0}", m.total_tflops()),
        ]);
    }
    println!("{}", t.render());
    println!("Model catalog:");
    let mut t = Table::new(&["model", "params", "layers", "train GB"]);
    for m in ModelSpec::paper_six() {
        t.row(&[
            m.name.to_string(),
            fmt_params(m.params),
            m.layers.to_string(),
            format!("{:.0}", m.train_gb()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_assign(cli: &Cli) -> Result<()> {
    let seed = cli.flag_u64("seed", 0)?;
    let n_tasks = cli.flag_u64("tasks", 4)?;
    let fleet = Fleet::paper_evaluation(seed);
    let workload = match n_tasks {
        4 => ModelSpec::paper_four(),
        6 => ModelSpec::paper_six(),
        n => anyhow::bail!("--tasks must be 4 or 6, got {n}"),
    };
    let eval = if cli.flag_bool("gnn") {
        let rt = GcnRuntime::load(&Manifest::default_dir())?;
        let params = load_or_train_params(&rt, cli)?;
        let classifier = hulk::gnn::Classifier::Runtime(rt);
        evaluate_all(&fleet, &workload,
                     HulkSplitterKind::Gnn { classifier: &classifier,
                                             params: &params })?
    } else {
        evaluate_all(&fleet, &workload, HulkSplitterKind::Oracle)?
    };
    println!("{}", eval.render());
    println!("Hulk total-time improvement over best baseline: {:.1}%",
             eval.hulk_improvement() * 100.0);
    Ok(())
}

/// Train briefly (or reuse `--params <path>`): the GNN splitter needs
/// trained weights to produce meaningful groups.
fn load_or_train_params(rt: &GcnRuntime, cli: &Cli) -> Result<Vec<f32>> {
    let steps = cli.flag_u64("gnn-steps", 60)? as u32;
    let mut state = TrainState::fresh(rt.manifest.load_init_params()?);
    let dataset = make_dataset(16, rt.manifest.n, cli.flag_u64("seed", 0)?);
    let opts = TrainerOptions { steps, lr: 0.01, log_every: 0 };
    train_gcn(rt, &mut state, &dataset, &opts)?;
    Ok(state.params)
}

fn cmd_train_gnn(cli: &Cli) -> Result<()> {
    let steps = cli.flag_u64("steps", 10)? as u32;
    let lr = cli.flag_f64("lr", 0.01)? as f32;
    let n_graphs = cli.flag_u64("dataset", 16)? as usize;
    let seed = cli.flag_u64("seed", 0)?;
    let rt = GcnRuntime::load(&Manifest::default_dir())?;
    println!("PJRT platform: {}; params: {}", rt.platform(),
             rt.manifest.p);
    let dataset = make_dataset(n_graphs, rt.manifest.n, seed);
    let mut state = TrainState::fresh(rt.manifest.load_init_params()?);
    let opts = TrainerOptions { steps, lr, log_every: 1 };
    let curve = train_gcn(&rt, &mut state, &dataset, &opts)?;
    let best = curve
        .iter()
        .map(|p| p.acc)
        .fold(0.0f32, f32::max);
    println!("best accuracy over {steps} steps: {best:.3}");
    Ok(())
}

fn cmd_simulate(cli: &Cli) -> Result<()> {
    let seed = cli.flag_u64("seed", 0)?;
    let failures = cli.flag_u64("failures", 2)?;
    let fleet = Fleet::paper_evaluation(seed);
    let n = fleet.len();
    let mut coordinator = Coordinator::new(fleet);
    let mut rng = Rng::new(seed ^ 0x5349_4D55); // "SIMU"

    println!("submitting paper workload…");
    for model in ModelSpec::paper_four() {
        let reply = coordinator.handle(CoordinatorEvent::Submit {
            model: model.clone(),
            iterations: 50,
        });
        match reply {
            CoordinatorReply::Admitted { task_id, machines } => {
                println!("  task {task_id} ({}) → {} machines",
                         model.name, machines.len());
            }
            CoordinatorReply::Queued { task_id } => {
                println!("  task {task_id} ({}) queued", model.name);
            }
            _ => {}
        }
    }
    for _ in 0..failures {
        let victim = rng.below(n);
        let reply = coordinator
            .handle(CoordinatorEvent::MachineFailed { machine: victim });
        if let CoordinatorReply::Recovered { action } = reply {
            println!("machine {victim} failed → {action:?}");
        }
    }
    coordinator.handle(CoordinatorEvent::Tick { iterations: 50 });
    println!("\nleader metrics:\n{}", coordinator.metrics.render());
    coordinator.assignment.validate_disjoint(coordinator.fleet.len())
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("final assignment valid ✓");
    Ok(())
}

