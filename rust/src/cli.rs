//! Hand-rolled CLI parsing (clap is not in the offline registry).
//! [`usage`] is the single source of the grammar, printed by
//! `hulk help` and documented in README.md.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// The full CLI grammar (printed by `hulk help`).
pub fn usage() -> &'static str {
    "\
usage: hulk <subcommand> [flags]

  info       [--seed S]
             Fleet inventory + model catalog.
  assign     [--seed S] [--tasks 4|6] [--gnn] [--gnn-steps N]
             Run Hulk task assignment (Table 2), oracle or GNN splitter.
  train-gnn  [--steps N] [--lr F] [--dataset N] [--seed S]
             Train the GCN from Rust through PJRT (Fig. 4); needs
             `make artifacts` and the real xla crate.
  simulate   [--failures N] [--seed S]
             Multi-task leader-loop simulation with machine failures.
  bench      <table1|logs|table2|fig4|fig5|fig6|fig8|fig9|fig10|
              ablation|sweep|micro|all>… [--seed S] [--json] [--out DIR]
             Regenerate paper tables/figures; `micro --json` writes
             BENCH_micro.json.
  scenarios  list
  scenarios  run <name…|all> [--seed S] [--systems a,b,hulk]
                 [--cost analytic|sim] [--json] [--out DIR]
                 [--parallel] [--threads N]
             Run named scenarios deterministically from the seed.
             The heavy scale scenarios (continent_scale 10k machines,
             global_scale 100k) are excluded from `all` — run them by
             name. `--systems` filters which planners run (slugs from the
             planner registry: system_a|a, system_b|b, system_c|c,
             hulk, hulk_no_gcn; default = the paper's four). `--cost`
             picks the pricing backend: `analytic` (default, the
             closed-form per-task formulas) or `sim` (whole-placement
             discrete-event execution where concurrent tasks contend
             for shared WAN links and machines; adds per-system
             makespan/straggler/link-utilization rows and unlocks the
             sim-only scenarios contended_links, sim_vs_analytic and
             generated_sweep).
             `--json` writes BENCH_scenarios.json in the
             customSmallerIsBetter shape plus BENCH_placements.json
             (per-system placement digests: group/stage counts,
             cross-region edges); a sim-priced run writes
             BENCH_scenarios_cost_sim.json instead. `--parallel`
             executes (scenario × planner) cells on a worker pool
             (`--threads N` pins the width; default = the machine's
             available parallelism). Output is byte-identical to a
             serial run, for either backend.
  scenarios  generate [--seed S] [--count N] [--check]
                 [--systems a,b,hulk]
             Deterministically generate N (default 20) randomized
             (fleet, workload, failure script) cases from the seed —
             skewed regions, mixed GPUs, degraded/blocked WAN links,
             spot revocations — and print their shapes. With --check,
             run every registered planner over each case and verify
             the property invariants (feasible machine ids + capacity,
             plan determinism, self-pricing vs evaluate_world,
             analytic/sim winner agreement, the exhaustive oracle
             bound on ≤8-machine fleets, survivor replanning); a
             failure is shrunk by halving fleet/workload and reported
             as a minimal seed+shape with the exact repro command,
             exiting non-zero.
  serve      [--addr HOST:PORT] [--uds PATH] [--cost analytic|sim]
                 [--batch-window-ms N] [--seed S] [--workers N]
                 [--read-timeout-ms N] [--shards N]
                 [--cache-capacity N] [--queue-depth N]
                 [--fault-injection]
             Long-lived placement-as-a-service daemon on the
             planet-scale fleet (default tcp://127.0.0.1:7711;
             --uds serves a unix socket instead/in addition).
             Length-prefixed JSON requests: Place (workload → placement
             + predicted cost; requests are digest-routed across
             --shards batcher shards — default 0 = min(4, cores) — and
             concurrent requests within a shard's batch window share
             one GCN forward), Admin join/fail/revoke/fail_region/wan
             (live fleet updates through the incremental graph seam —
             never a world rebuild; every mutation invalidates the
             per-shard placement caches, --cache-capacity entries each,
             0 = off), Stats, Shutdown. Replies are byte-identical
             across shard counts and cache settings. Workers and
             batcher shards are panic-supervised (restarts counted in
             worker_restarts); past --queue-depth waiting connections
             (default 1024) new arrivals are shed with a typed
             `overloaded` reply; --fault-injection arms the `panic`
             admin op for the chaos harness.
  loadgen    [--addr HOST:PORT] --rps N --duration-s S [--seed K]
                 [--connections C] [--systems a,b,hulk] [--out DIR]
                 [--repeat-mix F] [--max-error-rate F] [--shutdown]
             Drive a running serve daemon with seeded request mixes;
             --repeat-mix F resends an earlier workload with
             probability F (cache-hit traffic). Connects retry with
             capped backoff (failed attempts count as errors);
             --max-error-rate F exits non-zero when
             errors/(ok+errors) exceeds F. Writes
             BENCH_serve.json (serve/p50_place_us, serve/p99_place_us,
             serve/throughput_rps, serve/batched_forward_speedup,
             serve/cache_hit_rate, serve/p50_cached_place_us,
             serve/p50_uncached_place_us). --shutdown stops the
             daemon afterwards.
  chaos      --script region_outage|revocation_wave|link_flap|
                 join_storm [--addr HOST:PORT] [--seed S] [--out DIR]
                 [--probe-interval-ms N] [--recovery-timeout-ms N]
             Seeded fault injection against a RUNNING serve daemon via
             its admin surface, with continuous place probes. First
             proves supervision (one worker + one shard panic, skipped
             unless the daemon runs --fault-injection), then runs the
             script and measures recovery: time from injection to the
             first placement excluding every failed machine. Fails if
             recovery times out or any post-recovery placement uses a
             dead machine. Writes BENCH_serve_chaos.json
             (serve/availability_pct, serve/error_rate,
             serve/recovery_ms).
  help       Print this grammar.

Flags are `--key value`, `--key=value`, or bare `--key` for booleans."
}

/// Parsed command line: subcommand + flags.
#[derive(Clone, Debug, PartialEq)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

/// Flags that are always boolean: they never consume the following
/// argument, so `hulk scenarios run --json table1_fleet` keeps
/// `table1_fleet` as a positional instead of treating it as the value
/// of `--json`. (Use `--flag=value` to force a value for one of these.)
const BOOL_FLAGS: [&str; 6] =
    ["gnn", "json", "parallel", "check", "shutdown", "fault-injection"];

impl Cli {
    /// Parse `args` (without argv[0]). Flags are `--key value` or
    /// `--key=value`; bare `--key` (and every [`BOOL_FLAGS`] name) is a
    /// boolean `true`.
    pub fn parse(args: &[String]) -> Result<Cli> {
        let Some(command) = args.first() else {
            bail!("usage: hulk <info|assign|train-gnn|simulate|bench|\
                   scenarios|serve|loadgen|chaos|help> … \
                   (see `hulk help`)");
        };
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if BOOL_FLAGS.contains(&key) {
                    flags.insert(key.to_string(), "true".to_string());
                } else if i + 1 < args.len()
                    && !args[i + 1].starts_with("--")
                {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Cli { command: command.clone(), positional, flags })
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn flag_bool(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn flag_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, \
                                              got {v:?}")),
        }
    }

    pub fn flag_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, \
                                              got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let cli = Cli::parse(&argv("assign --seed 7 --tasks=6 --gnn")).unwrap();
        assert_eq!(cli.command, "assign");
        assert_eq!(cli.flag("seed"), Some("7"));
        assert_eq!(cli.flag("tasks"), Some("6"));
        assert!(cli.flag_bool("gnn"));
        assert!(!cli.flag_bool("missing"));
    }

    #[test]
    fn positional_arguments_collected() {
        let cli = Cli::parse(&argv("bench fig8 fig10 --seed 1")).unwrap();
        assert_eq!(cli.positional, vec!["fig8", "fig10"]);
        assert_eq!(cli.flag_u64("seed", 0).unwrap(), 1);
    }

    #[test]
    fn typed_flags_validate() {
        let cli = Cli::parse(&argv("train-gnn --steps ten")).unwrap();
        assert!(cli.flag_u64("steps", 10).is_err());
        let cli = Cli::parse(&argv("train-gnn --lr 0.01")).unwrap();
        assert_eq!(cli.flag_f64("lr", 0.1).unwrap(), 0.01);
        assert_eq!(cli.flag_f64("other", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn empty_args_error() {
        assert!(Cli::parse(&[]).is_err());
    }

    #[test]
    fn boolean_flags_do_not_swallow_positionals() {
        let cli =
            Cli::parse(&argv("scenarios run --json table1_fleet")).unwrap();
        assert_eq!(cli.positional, vec!["run", "table1_fleet"]);
        assert!(cli.flag_bool("json"));
        // --parallel is boolean too: it must not eat a scenario name.
        let cli =
            Cli::parse(&argv("scenarios run --parallel all --threads 4"))
                .unwrap();
        assert_eq!(cli.positional, vec!["run", "all"]);
        assert!(cli.flag_bool("parallel"));
        assert_eq!(cli.flag_u64("threads", 1).unwrap(), 4);
        // --gnn mid-argument-list likewise leaves positionals alone.
        let cli = Cli::parse(&argv("bench --gnn fig8")).unwrap();
        assert_eq!(cli.positional, vec!["fig8"]);
        assert!(cli.flag_bool("gnn"));
        // --check is boolean: `generate --check --seed 3` must keep
        // the seed flag intact and the subcommand positional.
        let cli =
            Cli::parse(&argv("scenarios generate --check --seed 3"))
                .unwrap();
        assert_eq!(cli.positional, vec!["generate"]);
        assert!(cli.flag_bool("check"));
        assert_eq!(cli.flag_u64("seed", 0).unwrap(), 3);
    }

    #[test]
    fn usage_covers_every_subcommand() {
        let text = usage();
        for sub in ["info", "assign", "train-gnn", "simulate", "bench",
                    "scenarios", "serve", "loadgen", "chaos", "help"] {
            assert!(text.contains(sub), "usage() missing {sub}");
        }
        assert!(text.contains("BENCH_scenarios.json"));
        assert!(text.contains("BENCH_placements.json"));
        assert!(text.contains("--parallel") && text.contains("--threads"));
        assert!(text.contains("--systems") && text.contains("hulk_no_gcn"));
        assert!(text.contains("--cost") && text.contains("analytic|sim"));
        assert!(text.contains("contended_links")
            && text.contains("sim_vs_analytic"));
        assert!(text.contains("generate") && text.contains("--check"),
                "usage() missing the generate grammar");
        assert!(text.contains("generated_sweep"));
        // The serve/loadgen grammar.
        assert!(text.contains("--batch-window-ms")
            && text.contains("--uds"),
                "usage() missing the serve grammar");
        assert!(text.contains("--rps") && text.contains("--duration-s")
            && text.contains("--shutdown"),
                "usage() missing the loadgen grammar");
        assert!(text.contains("BENCH_serve.json"));
        // The sharded-batcher + placement-cache grammar.
        assert!(text.contains("--shards")
            && text.contains("--cache-capacity"),
                "usage() missing the serve sharding grammar");
        assert!(text.contains("--repeat-mix")
            && text.contains("serve/cache_hit_rate")
            && text.contains("serve/p50_cached_place_us"),
                "usage() missing the loadgen cache grammar");
        // The self-healing + chaos grammar.
        assert!(text.contains("--queue-depth")
            && text.contains("--fault-injection")
            && text.contains("worker_restarts"),
                "usage() missing the serve supervision grammar");
        assert!(text.contains("--max-error-rate"),
                "usage() missing the loadgen error-rate gate");
        assert!(text.contains("--script")
            && text.contains("region_outage")
            && text.contains("revocation_wave")
            && text.contains("link_flap")
            && text.contains("join_storm"),
                "usage() missing the chaos script catalog");
        assert!(text.contains("--probe-interval-ms")
            && text.contains("--recovery-timeout-ms"),
                "usage() missing the chaos probe knobs");
        assert!(text.contains("BENCH_serve_chaos.json")
            && text.contains("serve/availability_pct")
            && text.contains("serve/error_rate")
            && text.contains("serve/recovery_ms"),
                "usage() missing the chaos SLO rows");
    }

    #[test]
    fn fault_injection_is_boolean_and_does_not_swallow_flags() {
        let cli = Cli::parse(&argv(
            "serve --fault-injection --workers 2 --shards 1")).unwrap();
        assert!(cli.flag_bool("fault-injection"));
        assert_eq!(cli.flag_u64("workers", 0).unwrap(), 2);
        assert_eq!(cli.flag_u64("shards", 0).unwrap(), 1);
    }

    #[test]
    fn shutdown_is_boolean_and_does_not_swallow_flags() {
        let cli = Cli::parse(&argv(
            "loadgen --shutdown --rps 200 --duration-s 5")).unwrap();
        assert!(cli.flag_bool("shutdown"));
        assert_eq!(cli.flag_u64("rps", 0).unwrap(), 200);
        assert_eq!(cli.flag_u64("duration-s", 0).unwrap(), 5);
    }
}
