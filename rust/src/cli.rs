//! Hand-rolled CLI parsing (clap is not in the offline registry).
//!
//! ```text
//! hulk info                         fleet + model inventory
//! hulk assign [--seed S] [--tasks 4|6] [--gnn]
//! hulk train-gnn [--steps N] [--lr F] [--dataset N]
//! hulk simulate [--failures N] [--seed S]
//! hulk bench <table1|table2|fig4|fig5|fig6|fig8|fig9|fig10|ablation|micro|all>
//! ```

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed command line: subcommand + flags.
#[derive(Clone, Debug, PartialEq)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Cli {
    /// Parse `args` (without argv[0]). Flags are `--key value` or
    /// `--key=value`; bare `--key` is a boolean `true`.
    pub fn parse(args: &[String]) -> Result<Cli> {
        let Some(command) = args.first() else {
            bail!("usage: hulk <info|assign|train-gnn|simulate|bench> …");
        };
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < args.len()
                    && !args[i + 1].starts_with("--")
                {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Cli { command: command.clone(), positional, flags })
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn flag_bool(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn flag_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, \
                                              got {v:?}")),
        }
    }

    pub fn flag_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, \
                                              got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let cli = Cli::parse(&argv("assign --seed 7 --tasks=6 --gnn")).unwrap();
        assert_eq!(cli.command, "assign");
        assert_eq!(cli.flag("seed"), Some("7"));
        assert_eq!(cli.flag("tasks"), Some("6"));
        assert!(cli.flag_bool("gnn"));
        assert!(!cli.flag_bool("missing"));
    }

    #[test]
    fn positional_arguments_collected() {
        let cli = Cli::parse(&argv("bench fig8 fig10 --seed 1")).unwrap();
        assert_eq!(cli.positional, vec!["fig8", "fig10"]);
        assert_eq!(cli.flag_u64("seed", 0).unwrap(), 1);
    }

    #[test]
    fn typed_flags_validate() {
        let cli = Cli::parse(&argv("train-gnn --steps ten")).unwrap();
        assert!(cli.flag_u64("steps", 10).is_err());
        let cli = Cli::parse(&argv("train-gnn --lr 0.01")).unwrap();
        assert_eq!(cli.flag_f64("lr", 0.1).unwrap(), 0.01);
        assert_eq!(cli.flag_f64("other", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn empty_args_error() {
        assert!(Cli::parse(&[]).is_err());
    }
}
