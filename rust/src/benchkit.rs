//! Criterion-substitute micro/macro benchmark harness (the offline vendored
//! registry has no criterion). Same discipline: warmup, fixed sample count,
//! mean/p50/p95/stddev, and a one-line-per-benchmark report. Used by
//! `rust/benches/bench_main.rs` (`cargo bench`) and the `hulk bench` CLI.

use std::time::Instant;

use crate::util::stats::Summary;
use crate::util::table::Table;

/// Configuration for a measurement run.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Inner iterations per sample for fast functions (amortizes timer
    /// overhead; per-op time is reported).
    pub iters_per_sample: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, samples: 20, iters_per_sample: 1 }
    }
}

/// One benchmark result (times in milliseconds per operation).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>10.4} ms  p50 {:>10.4}  p95 {:>10.4}  sd {:>8.4}  (n={})",
            self.name,
            self.summary.mean,
            self.summary.p50,
            self.summary.p95,
            self.summary.stddev,
            self.summary.n
        )
    }
}

/// Collects results; renders a criterion-like report at the end.
#[derive(Default)]
pub struct Bencher {
    pub config: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(config: BenchConfig) -> Bencher {
        Bencher { config, results: Vec::new() }
    }

    /// Measure `f`, which must do one unit of work per call. The return
    /// value is folded into a black-box sink so the optimizer cannot elide
    /// the work.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.config.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let start = Instant::now();
            for _ in 0..self.config.iters_per_sample {
                std::hint::black_box(f());
            }
            let elapsed = start.elapsed().as_secs_f64() * 1_000.0;
            samples.push(elapsed / self.config.iters_per_sample as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Render all collected results as a table (for report files).
    pub fn report(&self) -> String {
        let mut t = Table::new(&["benchmark", "mean_ms", "p50_ms", "p95_ms",
                                 "stddev_ms", "n"]);
        for r in &self.results {
            t.row(&[
                r.name.clone(),
                format!("{:.4}", r.summary.mean),
                format!("{:.4}", r.summary.p50),
                format!("{:.4}", r.summary.p95),
                format!("{:.4}", r.summary.stddev),
                r.summary.n.to_string(),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_positive_times() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 1,
            samples: 5,
            iters_per_sample: 10,
        });
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
        assert_eq!(r.summary.n, 5);
    }

    #[test]
    fn report_contains_all_rows() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 0,
            samples: 3,
            iters_per_sample: 1,
        });
        b.bench("a", || 1);
        b.bench("b", || 2);
        let rep = b.report();
        assert!(rep.contains("a") && rep.contains("b"));
    }
}
