//! Criterion-substitute micro/macro benchmark harness (the offline vendored
//! registry has no criterion). Same discipline: warmup, fixed sample count,
//! mean/p50/p95/stddev, and a one-line-per-benchmark report. Used by
//! `rust/benches/bench_main.rs` (`cargo bench`) and the `hulk bench` CLI.
//!
//! Also the machine-readable reporting layer: [`BenchEntry`] rows in
//! github-action-benchmark's `customSmallerIsBetter` shape, collected by
//! [`BenchReport`] into `BENCH_<suite>.json` files whose outer structure
//! mirrors `window.BENCHMARK_DATA` (so runs can accumulate into a perf
//! trajectory; see DESIGN.md §Reporting).

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::Table;

/// Configuration for a measurement run.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Inner iterations per sample for fast functions (amortizes timer
    /// overhead; per-op time is reported).
    pub iters_per_sample: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, samples: 20, iters_per_sample: 1 }
    }
}

/// One benchmark result (times in milliseconds per operation).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>10.4} ms  p50 {:>10.4}  p95 {:>10.4}  sd {:>8.4}  (n={})",
            self.name,
            self.summary.mean,
            self.summary.p50,
            self.summary.p95,
            self.summary.stddev,
            self.summary.n
        )
    }
}

/// Collects results; renders a criterion-like report at the end.
#[derive(Default)]
pub struct Bencher {
    pub config: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(config: BenchConfig) -> Bencher {
        Bencher { config, results: Vec::new() }
    }

    /// Measure `f`, which must do one unit of work per call. The return
    /// value is folded into a black-box sink so the optimizer cannot elide
    /// the work.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.config.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let start = Instant::now();
            for _ in 0..self.config.iters_per_sample {
                std::hint::black_box(f());
            }
            let elapsed = start.elapsed().as_secs_f64() * 1_000.0;
            samples.push(elapsed / self.config.iters_per_sample as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Collected results as machine-readable entries (mean ms per op),
    /// names prefixed `"<prefix>/"` when `prefix` is non-empty.
    pub fn entries(&self, prefix: &str) -> Vec<BenchEntry> {
        self.results
            .iter()
            .map(|r| {
                let name = if prefix.is_empty() {
                    r.name.clone()
                } else {
                    format!("{prefix}/{}", r.name)
                };
                BenchEntry::new(name, r.summary.mean, "ms")
            })
            .collect()
    }

    /// Render all collected results as a table (for report files).
    pub fn report(&self) -> String {
        let mut t = Table::new(&["benchmark", "mean_ms", "p50_ms", "p95_ms",
                                 "stddev_ms", "n"]);
        for r in &self.results {
            t.row(&[
                r.name.clone(),
                format!("{:.4}", r.summary.mean),
                format!("{:.4}", r.summary.p50),
                format!("{:.4}", r.summary.p95),
                format!("{:.4}", r.summary.stddev),
                r.summary.n.to_string(),
            ]);
        }
        t.render()
    }
}

/// One benchmark datum in github-action-benchmark's
/// `customSmallerIsBetter` row shape: `{name, value, unit}`.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchEntry {
    /// Hierarchical name, e.g. `table1_fleet/hulk/opt_175b/iter_ms`.
    pub name: String,
    pub value: f64,
    /// `"ms"`, `"count"`, `"%"`, …; entries whose unit is `%` are
    /// informational (bigger-is-better) rather than tracked regressions.
    pub unit: String,
}

impl BenchEntry {
    pub fn new(name: impl Into<String>, value: f64,
               unit: impl Into<String>) -> BenchEntry
    {
        BenchEntry { name: name.into(), value, unit: unit.into() }
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("name", self.name.as_str().into());
        obj.set("value", self.value.into());
        obj.set("unit", self.unit.as_str().into());
        obj
    }
}

/// A named collection of [`BenchEntry`] rows, serialized as
/// `BENCH_<suite>.json`. The outer object follows the
/// `window.BENCHMARK_DATA` layout (`entries.<suite>[0].benches` holds the
/// `customSmallerIsBetter` rows) so files concatenate directly into a
/// benchmark-action dashboard. Output contains no wall-clock fields: two
/// runs of a deterministic suite produce byte-identical files.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub suite: String,
    pub entries: Vec<BenchEntry>,
}

impl BenchReport {
    pub fn new(suite: &str) -> BenchReport {
        BenchReport { suite: suite.to_string(), entries: Vec::new() }
    }

    pub fn push(&mut self, entry: BenchEntry) {
        self.entries.push(entry);
    }

    pub fn extend(&mut self, entries: impl IntoIterator<Item = BenchEntry>) {
        self.entries.extend(entries);
    }

    /// `BENCH_<suite>.json`.
    pub fn file_name(&self) -> String {
        format!("BENCH_{}.json", self.suite)
    }

    pub fn to_json(&self) -> Json {
        let mut benches = Json::arr();
        for e in &self.entries {
            benches.push(e.to_json());
        }
        let mut run = Json::obj();
        let mut commit = Json::obj();
        commit.set("id", "workspace".into());
        commit.set("message", self.suite.as_str().into());
        run.set("commit", commit);
        run.set("date", 0usize.into());
        run.set("tool", "customSmallerIsBetter".into());
        run.set("benches", benches);
        let mut series = Json::arr();
        series.push(run);
        let mut entries = Json::obj();
        entries.set(&self.suite, series);
        let mut root = Json::obj();
        root.set("lastUpdate", 0usize.into());
        root.set("repoUrl", "".into());
        root.set("entries", entries);
        root
    }

    /// Write `BENCH_<suite>.json` under `dir` (created if missing);
    /// returns the file path.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join(self.file_name());
        let mut text = self.to_json().render();
        text.push('\n');
        std::fs::write(&path, text)
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_positive_times() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 1,
            samples: 5,
            iters_per_sample: 10,
        });
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
        assert_eq!(r.summary.n, 5);
    }

    #[test]
    fn report_contains_all_rows() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 0,
            samples: 3,
            iters_per_sample: 1,
        });
        b.bench("a", || 1);
        b.bench("b", || 2);
        let rep = b.report();
        assert!(rep.contains("a") && rep.contains("b"));
    }

    #[test]
    fn bencher_entries_carry_prefix_and_unit() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 0,
            samples: 2,
            iters_per_sample: 1,
        });
        b.bench("spin", || 1);
        let entries = b.entries("micro");
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].name, "micro/spin");
        assert_eq!(entries[0].unit, "ms");
        assert!(entries[0].value >= 0.0);
        assert_eq!(b.entries("")[0].name, "spin");
    }

    #[test]
    fn report_json_has_benchmark_data_shape() {
        let mut report = BenchReport::new("scenarios");
        report.push(BenchEntry::new("s/hulk/m/iter_ms", 12.5, "ms"));
        report.push(BenchEntry::new("s/system_a/m/iter_ms", 20.0, "ms"));
        let text = report.to_json().render();
        assert!(text.contains("\"entries\":{\"scenarios\":["));
        assert!(text.contains("\"tool\":\"customSmallerIsBetter\""));
        assert!(text.contains(
            "{\"name\":\"s/hulk/m/iter_ms\",\"value\":12.5,\"unit\":\"ms\"}"
        ));
        assert_eq!(report.file_name(), "BENCH_scenarios.json");
    }

    #[test]
    fn report_write_roundtrip() {
        let mut report = BenchReport::new("benchkit_test");
        report.push(BenchEntry::new("x", 1.0, "ms"));
        let dir = std::env::temp_dir().join("hulk_benchkit_report_test");
        let path = report.write(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"name\":\"x\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}
