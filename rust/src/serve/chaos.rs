//! `hulk chaos` — a seeded fault-script driver for a **live** serve
//! daemon. Where `tests/serve_roundtrip.rs` proves the state machine
//! and `hulk loadgen` proves throughput, chaos proves *recovery*: it
//! injects faults through the admin surface (the same wire ops an
//! operator would use), keeps probing the request plane throughout,
//! and reports SLOs over exactly the fault window.
//!
//! Scripts (`--script`), all seeded (`--seed`) and reusing the
//! scenario generator's failure-script machinery:
//!
//! - `region_outage` — one correlated whole-region kill (a single
//!   `fail_region` admin op: one epoch, no half-dead region ever
//!   visible), then probe until placements exclude every dead machine.
//! - `revocation_wave` — a staggered spot-revocation wave
//!   ([`sample_failure_wave`]): seeded machine picks revoked one by
//!   one on the wave's cadence.
//! - `link_flap` — WAN brownout (`wan` admin op with a seeded factor)
//!   probed under degradation, then flapped back to `1.0`; the world
//!   is restored bit-for-bit, so post-flap replies match a daemon that
//!   never degraded.
//! - `join_storm` — a burst of seeded `join` ops; recovery is the
//!   first successful placement on the grown fleet.
//!
//! Before the script, chaos attempts a supervision proof: inject one
//! worker panic and one shard panic (`panic` admin op — requires the
//! daemon to be started with `--fault-injection`) and verify via
//! `stats` that `worker_restarts` advanced while `uptime_s` kept
//! climbing — the crash was recovered *in place*, not respawned. An
//! unarmed daemon declines the op and the proof is skipped, never
//! failed.
//!
//! SLOs are measured as stats-counter deltas over the run
//! ([`SloWindow`]) and written as `BENCH_serve_chaos.json` rows
//! (`serve/availability_pct`, `serve/error_rate`,
//! `serve/recovery_ms`) — a separate file from loadgen's
//! `BENCH_serve.json` so a concurrent background load run can't
//! clobber them. `recovery_ms` is the time from fault injection to the
//! first placement that excludes every failed machine (for the outage
//! scripts) or the first healthy reply after restore (flap/storm).

use std::collections::BTreeSet;
use std::net::TcpStream;
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::benchkit::{BenchEntry, BenchReport};
use crate::cli::Cli;
use crate::cluster::{GpuModel, Region};
use crate::coordinator::{Metrics, SloWindow};
use crate::scenarios::{sample_failure_wave, sample_workload};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::framing::roundtrip;
use super::loadgen::place_request;

/// Which fault script to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosScript {
    RegionOutage,
    RevocationWave,
    LinkFlap,
    JoinStorm,
}

impl ChaosScript {
    pub const ALL: [ChaosScript; 4] = [
        ChaosScript::RegionOutage,
        ChaosScript::RevocationWave,
        ChaosScript::LinkFlap,
        ChaosScript::JoinStorm,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ChaosScript::RegionOutage => "region_outage",
            ChaosScript::RevocationWave => "revocation_wave",
            ChaosScript::LinkFlap => "link_flap",
            ChaosScript::JoinStorm => "join_storm",
        }
    }

    pub fn parse(name: &str) -> Result<ChaosScript> {
        ChaosScript::ALL
            .into_iter()
            .find(|s| s.name() == name)
            .with_context(|| {
                let known: Vec<&str> =
                    ChaosScript::ALL.iter().map(|s| s.name()).collect();
                format!("unknown chaos script {name:?} (known: {})",
                        known.join(", "))
            })
    }
}

/// Chaos-run configuration (CLI: `hulk chaos`).
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    pub addr: String,
    pub script: ChaosScript,
    pub seed: u64,
    /// Directory `BENCH_serve_chaos.json` is written to.
    pub out: PathBuf,
    /// Sleep between recovery probes.
    pub probe_interval_ms: u64,
    /// Hard deadline for recovery; exceeding it fails the run.
    pub recovery_timeout_ms: u64,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            addr: "127.0.0.1:7711".to_string(),
            script: ChaosScript::RegionOutage,
            seed: 0,
            out: PathBuf::from("."),
            probe_interval_ms: 25,
            recovery_timeout_ms: 20_000,
        }
    }
}

/// What one chaos run measured.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub script: &'static str,
    /// Admin mutations the script landed (machines failed/revoked,
    /// joins accepted, wan ops applied).
    pub injected: usize,
    /// Injection → first recovered placement, milliseconds.
    pub recovery_ms: f64,
    /// Post-recovery placements re-verified to exclude every failed
    /// machine (0 for scripts where exclusion doesn't apply).
    pub exclusion_checks: usize,
    pub availability_pct: f64,
    pub error_rate: f64,
    pub probes_ok: u64,
    pub probes_err: u64,
    /// `worker_restarts` from the final stats reply.
    pub worker_restarts: u64,
    /// `Some(n)` when the supervision proof ran (n = restarts seen);
    /// `None` when the daemon wasn't started with `--fault-injection`.
    pub supervision_proof: Option<u64>,
}

/// One admin/stats/probe connection to the daemon, with a single
/// reconnect retry per call — an injected worker panic legitimately
/// drops the connection right after its reply.
struct Daemon {
    addr: String,
    stream: TcpStream,
}

impl Daemon {
    fn connect(addr: &str) -> Result<Daemon> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to hulk serve at {addr}"))?;
        Ok(Daemon { addr: addr.to_string(), stream })
    }

    fn call(&mut self, payload: &str) -> Result<Json> {
        for attempt in 0..2 {
            match roundtrip(&mut self.stream, payload.as_bytes()) {
                Ok(reply) => {
                    let text = String::from_utf8(reply)
                        .context("daemon reply is not UTF-8")?;
                    return Json::parse(&text).map_err(|e| {
                        anyhow::anyhow!("daemon reply unparsable: {e}")
                    });
                }
                Err(_) if attempt == 0 => {
                    // The connection died (e.g. the worker we were
                    // pinned to took an injected panic). Reconnect
                    // once; a daemon that's actually down fails the
                    // retry too.
                    self.stream = TcpStream::connect(&self.addr)
                        .with_context(|| format!(
                            "reconnecting to hulk serve at {}", self.addr))?;
                }
                Err(e) => {
                    anyhow::bail!("daemon round-trip failed: {e:?}");
                }
            }
        }
        unreachable!("the retry loop always returns")
    }

    fn stats(&mut self) -> Result<Json> {
        let reply = self.call("{\"op\":\"stats\"}")?;
        anyhow::ensure!(is_ok(&reply), "stats reply not ok: {}",
                        reply.render());
        Ok(reply)
    }
}

fn is_ok(reply: &Json) -> bool {
    reply.get("ok").and_then(Json::as_bool) == Some(true)
}

/// Rebuild the SLO-relevant counters from a wire stats reply, so
/// [`SloWindow`] can diff two of them.
fn counters_from_stats(stats: &Json) -> Metrics {
    let mut m = Metrics::new();
    if let Some(counters) =
        stats.get("metrics").and_then(|x| x.get("counters"))
    {
        for name in ["place_requests", "place_errors",
                     "connections_shed"]
        {
            if let Some(v) = counters.get(name).and_then(Json::as_f64) {
                m.add(name, v as u64);
            }
        }
    }
    m
}

fn stat_f64(stats: &Json, field: &str) -> f64 {
    stats.get(field).and_then(Json::as_f64).unwrap_or(0.0)
}

/// All machine ids referenced by successful per-system placements in a
/// place reply; `None` when no system produced a placement (the reply
/// can be top-level ok while every system declined).
fn placed_machines(reply: &Json) -> Option<BTreeSet<usize>> {
    let results = reply.get("results").and_then(Json::as_arr)?;
    let mut machines = BTreeSet::new();
    let mut any_ok = false;
    for entry in results {
        if entry.get("ok").and_then(Json::as_bool) != Some(true) {
            continue;
        }
        any_ok = true;
        let tasks = entry.get("tasks").and_then(Json::as_arr);
        for task in tasks.into_iter().flatten() {
            let ids = task.get("machines").and_then(Json::as_arr);
            for m in ids.into_iter().flatten() {
                if let Some(id) = m.as_usize() {
                    machines.insert(id);
                }
            }
        }
    }
    any_ok.then_some(machines)
}

/// Seeded place-probe generator: every probe draws a fresh workload
/// (distinct digests, so cache hits can't mask a stale epoch) against
/// a conservative memory budget — half the healthy fleet's, so probes
/// stay plannable even after a region dies.
struct Prober {
    rng: Rng,
    budget_gb: f64,
    ok: u64,
    err: u64,
}

impl Prober {
    fn new(rng: Rng, fleet_memory_gb: f64) -> Prober {
        Prober { rng, budget_gb: fleet_memory_gb * 0.5, ok: 0, err: 0 }
    }

    /// One place probe; returns the reply plus the machines a
    /// successful placement used (`None` = no system placed).
    fn place(&mut self, daemon: &mut Daemon)
        -> Result<(Json, Option<BTreeSet<usize>>)>
    {
        let workload = sample_workload(&mut self.rng, self.budget_gb);
        let request = place_request(&workload, Some("hulk"));
        let reply = daemon.call(&request)?;
        let machines =
            if is_ok(&reply) { placed_machines(&reply) } else { None };
        if machines.is_some() {
            self.ok += 1;
        } else {
            self.err += 1;
        }
        Ok((reply, machines))
    }
}

/// What a script injected and how fast the daemon recovered.
struct ScriptOutcome {
    injected: usize,
    recovery_ms: f64,
    exclusion_checks: usize,
}

/// Poll probes until a placement excludes every machine in `failed`;
/// returns injection-to-recovery milliseconds.
fn await_exclusion(daemon: &mut Daemon, probe: &mut Prober,
                   failed: &BTreeSet<usize>, t0: Instant,
                   config: &ChaosConfig) -> Result<f64>
{
    let deadline = t0 + Duration::from_millis(config.recovery_timeout_ms);
    loop {
        let (_, machines) = probe.place(daemon)?;
        if let Some(machines) = machines {
            if machines.is_disjoint(failed) {
                return Ok(t0.elapsed().as_secs_f64() * 1000.0);
            }
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "recovery timed out after {}ms: placements still include \
             failed machines (or no system can place)",
            config.recovery_timeout_ms);
        thread::sleep(Duration::from_millis(config.probe_interval_ms));
    }
}

/// Poll probes until any successful placement appears (scripts where
/// machine exclusion doesn't apply); returns t0-to-recovery ms.
fn await_placement(daemon: &mut Daemon, probe: &mut Prober, t0: Instant,
                   config: &ChaosConfig) -> Result<f64>
{
    await_exclusion(daemon, probe, &BTreeSet::new(), t0, config)
}

/// Re-probe `n` times post-recovery: **every** successful placement
/// must exclude the failed machines — recovery that flickers back to
/// placing on dead machines is a cache-invalidation bug, and this is
/// where it would surface.
fn verify_exclusion(daemon: &mut Daemon, probe: &mut Prober,
                    failed: &BTreeSet<usize>, n: usize) -> Result<usize>
{
    let mut checked = 0;
    for _ in 0..n {
        let (reply, machines) = probe.place(daemon)?;
        if let Some(machines) = machines {
            anyhow::ensure!(
                machines.is_disjoint(failed),
                "post-recovery placement used failed machines {:?}: {}",
                machines.intersection(failed).collect::<Vec<_>>(),
                reply.render());
            checked += 1;
        }
    }
    anyhow::ensure!(checked > 0,
                    "no post-recovery probe produced a placement");
    Ok(checked)
}

fn script_region_outage(daemon: &mut Daemon, rng: &mut Rng,
                        probe: &mut Prober, config: &ChaosConfig)
    -> Result<ScriptOutcome>
{
    // Seeded region pick; the daemon declines regions that are empty
    // or whose loss would kill the whole fleet, so walk a shuffled
    // order until one lands.
    let mut order: Vec<usize> = (0..Region::ALL.len()).collect();
    rng.shuffle(&mut order);
    for idx in order {
        let name = Region::ALL[idx].name();
        let request = format!(
            "{{\"op\":\"admin\",\"action\":\"fail_region\",\
             \"region\":\"{name}\"}}");
        let t0 = Instant::now();
        let reply = daemon.call(&request)?;
        if !is_ok(&reply) {
            continue;
        }
        let failed: BTreeSet<usize> = reply
            .get("machines")
            .and_then(Json::as_arr)
            .map(|arr| arr.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        anyhow::ensure!(!failed.is_empty(),
                        "fail_region reply listed no machines: {}",
                        reply.render());
        println!("chaos: region {name} down ({} machines, epoch {})",
                 failed.len(), stat_f64(&reply, "epoch"));
        let recovery_ms =
            await_exclusion(daemon, probe, &failed, t0, config)?;
        let exclusion_checks =
            verify_exclusion(daemon, probe, &failed, 5)?;
        return Ok(ScriptOutcome { injected: failed.len(), recovery_ms,
                                  exclusion_checks });
    }
    anyhow::bail!("no region could be failed (fleet already too \
                   degraded for a correlated outage)")
}

fn script_revocation_wave(daemon: &mut Daemon, rng: &mut Rng,
                          probe: &mut Prober, config: &ChaosConfig)
    -> Result<ScriptOutcome>
{
    let stats = daemon.stats()?;
    let n_machines = stats
        .get("fleet_machines")
        .and_then(Json::as_usize)
        .context("stats reply missing fleet_machines")?;
    // A seeded, staggered wave on the generator's canonical cadence.
    let gap_ms = (config.probe_interval_ms * 2) as f64;
    let wave = sample_failure_wave(rng, n_machines, 8, 0.0, gap_ms);
    let t0 = Instant::now();
    let mut revoked = BTreeSet::new();
    let mut last_at = 0.0;
    for plan in &wave {
        let wait_ms = plan.at_ms - last_at;
        last_at = plan.at_ms;
        if wait_ms > 0.0 {
            thread::sleep(Duration::from_secs_f64(wait_ms / 1000.0));
        }
        let request = format!(
            "{{\"op\":\"admin\",\"action\":\"revoke\",\
             \"machine\":{}}}", plan.machine);
        let reply = daemon.call(&request)?;
        // Declines (machine already dead from an earlier script) are
        // fine — chaos runs compose against one daemon.
        if is_ok(&reply) {
            revoked.insert(plan.machine);
        }
    }
    anyhow::ensure!(!revoked.is_empty(),
                    "revocation wave: every revoke was declined");
    println!("chaos: revoked {} of {} targeted machines",
             revoked.len(), wave.len());
    let recovery_ms = await_exclusion(daemon, probe, &revoked, t0,
                                      config)?;
    let exclusion_checks = verify_exclusion(daemon, probe, &revoked, 5)?;
    Ok(ScriptOutcome { injected: revoked.len(), recovery_ms,
                       exclusion_checks })
}

fn script_link_flap(daemon: &mut Daemon, rng: &mut Rng,
                    probe: &mut Prober, config: &ChaosConfig)
    -> Result<ScriptOutcome>
{
    // Brownout at a seeded factor, probe under degradation, then flap
    // back to 1.0. Recovery is the first placement on the restored
    // matrix (which state.rs guarantees is bit-for-bit pristine).
    let factor = [2.0, 4.0, 8.0, 16.0][rng.below(4)];
    let brown = daemon.call(&format!(
        "{{\"op\":\"admin\",\"action\":\"wan\",\"factor\":{factor}}}"))?;
    anyhow::ensure!(is_ok(&brown), "wan brownout declined: {}",
                    brown.render());
    println!("chaos: wan brownout x{factor} (epoch {})",
             stat_f64(&brown, "epoch"));
    // The daemon must keep placing *through* the brownout.
    let browned = Instant::now();
    await_placement(daemon, probe, browned, config)?;
    let restore = daemon.call(
        "{\"op\":\"admin\",\"action\":\"wan\",\"factor\":1.0}")?;
    anyhow::ensure!(is_ok(&restore), "wan restore declined: {}",
                    restore.render());
    let t0 = Instant::now();
    let recovery_ms = await_placement(daemon, probe, t0, config)?;
    Ok(ScriptOutcome { injected: 2, recovery_ms, exclusion_checks: 0 })
}

fn script_join_storm(daemon: &mut Daemon, rng: &mut Rng,
                     probe: &mut Prober, config: &ChaosConfig)
    -> Result<ScriptOutcome>
{
    let t0 = Instant::now();
    let mut joined = 0usize;
    for _ in 0..6 {
        let region = Region::ALL[rng.below(Region::ALL.len())].name();
        let gpu = GpuModel::ALL[rng.below(GpuModel::ALL.len())].name();
        let n_gpus = 1usize << rng.below(4); // 1, 2, 4 or 8
        let request = format!(
            "{{\"op\":\"admin\",\"action\":\"join\",\
             \"region\":\"{region}\",\"gpu\":\"{gpu}\",\
             \"n_gpus\":{n_gpus}}}");
        let reply = daemon.call(&request)?;
        // A capacity decline is legal; the storm keeps going.
        if is_ok(&reply) {
            joined += 1;
        }
    }
    anyhow::ensure!(joined >= 1,
                    "join storm: every join was declined");
    println!("chaos: join storm landed {joined} machines");
    let recovery_ms = await_placement(daemon, probe, t0, config)?;
    Ok(ScriptOutcome { injected: joined, recovery_ms,
                       exclusion_checks: 0 })
}

/// Inject one worker and one shard panic and verify supervision
/// recovered both: `worker_restarts` advances while `uptime_s` keeps
/// climbing (same process took the hit — not a silent respawn).
/// Returns `Ok(None)` (a skip, not a failure) when the daemon isn't
/// armed with `--fault-injection`.
fn prove_supervision(daemon: &mut Daemon) -> Result<Option<u64>> {
    let before = daemon.stats()?;
    let restarts0 = stat_f64(&before, "worker_restarts");
    let uptime0 = stat_f64(&before, "uptime_s");
    let worker = daemon.call(
        "{\"op\":\"admin\",\"action\":\"panic\",\"scope\":\"worker\"}")?;
    if !is_ok(&worker) {
        println!("chaos: supervision proof skipped (daemon not started \
                  with --fault-injection)");
        return Ok(None);
    }
    let shard = daemon.call(
        "{\"op\":\"admin\",\"action\":\"panic\",\"scope\":\"shard\"}")?;
    anyhow::ensure!(is_ok(&shard), "shard panic injection declined: {}",
                    shard.render());
    // Both crashes land asynchronously; poll until the supervisor has
    // logged both restarts.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let stats = daemon.stats()?;
        let restarts = stat_f64(&stats, "worker_restarts");
        let uptime = stat_f64(&stats, "uptime_s");
        anyhow::ensure!(
            uptime >= uptime0,
            "uptime went backwards ({uptime0}s -> {uptime}s): the \
             daemon was restarted from outside, not supervised");
        if restarts >= restarts0 + 2.0 {
            println!("chaos: supervision proof ok — {} restarts \
                      recovered in place", restarts - restarts0);
            return Ok(Some(restarts as u64));
        }
        anyhow::ensure!(Instant::now() < deadline,
                        "supervision proof timed out: worker_restarts \
                         stuck at {restarts} (started at {restarts0})");
        thread::sleep(Duration::from_millis(50));
    }
}

/// Run one chaos script against a live daemon and write the SLO rows.
pub fn run(config: &ChaosConfig) -> Result<ChaosReport> {
    let mut daemon = Daemon::connect(&config.addr)?;
    let stats0 = daemon.stats()?;
    let budget_gb = stats0
        .get("fleet_memory_gb")
        .and_then(Json::as_f64)
        .context("stats reply missing fleet_memory_gb")?;
    let uptime0 = stat_f64(&stats0, "uptime_s");
    let window = SloWindow::begin(&counters_from_stats(&stats0));

    let mut rng = Rng::new(config.seed ^ 0x4348_414F); // "CHAO"
    let mut probe = Prober::new(rng.fork(1), budget_gb);

    let supervision_proof = prove_supervision(&mut daemon)?;

    let outcome = match config.script {
        ChaosScript::RegionOutage => {
            script_region_outage(&mut daemon, &mut rng, &mut probe,
                                 config)?
        }
        ChaosScript::RevocationWave => {
            script_revocation_wave(&mut daemon, &mut rng, &mut probe,
                                   config)?
        }
        ChaosScript::LinkFlap => {
            script_link_flap(&mut daemon, &mut rng, &mut probe, config)?
        }
        ChaosScript::JoinStorm => {
            script_join_storm(&mut daemon, &mut rng, &mut probe,
                              config)?
        }
    };

    let stats1 = daemon.stats()?;
    anyhow::ensure!(
        stat_f64(&stats1, "uptime_s") >= uptime0,
        "uptime went backwards across the run: the daemon process was \
         replaced, so the SLO window spans two daemons");
    let slo = window.close(&counters_from_stats(&stats1));
    let worker_restarts = stat_f64(&stats1, "worker_restarts") as u64;

    let mut bench = BenchReport::new("serve_chaos");
    bench.push(BenchEntry::new("serve/availability_pct",
                               slo.availability_pct(), "%"));
    bench.push(BenchEntry::new("serve/error_rate", slo.error_rate(),
                               "ratio"));
    bench.push(BenchEntry::new("serve/recovery_ms", outcome.recovery_ms,
                               "ms"));
    let path = bench.write(&config.out)?;
    println!("wrote {} ({} entries)", path.display(),
             bench.entries.len());

    Ok(ChaosReport {
        script: config.script.name(),
        injected: outcome.injected,
        recovery_ms: outcome.recovery_ms,
        exclusion_checks: outcome.exclusion_checks,
        availability_pct: slo.availability_pct(),
        error_rate: slo.error_rate(),
        probes_ok: probe.ok,
        probes_err: probe.err,
        worker_restarts,
        supervision_proof,
    })
}

/// `hulk chaos` CLI entry.
pub fn run_chaos(cli: &Cli) -> Result<()> {
    let script = ChaosScript::parse(cli.flag("script").context(
        "--script is required \
         (region_outage|revocation_wave|link_flap|join_storm)")?)?;
    let config = ChaosConfig {
        addr: cli.flag("addr").unwrap_or("127.0.0.1:7711").to_string(),
        script,
        seed: cli.flag_u64("seed", 0)?,
        out: PathBuf::from(cli.flag("out").unwrap_or(".")),
        probe_interval_ms: cli.flag_u64("probe-interval-ms", 25)?,
        recovery_timeout_ms: cli.flag_u64("recovery-timeout-ms",
                                          20_000)?,
    };
    let r = run(&config)?;
    println!(
        "chaos {}: {} injected, recovered in {:.0}ms, {} exclusion \
         checks",
        r.script, r.injected, r.recovery_ms, r.exclusion_checks);
    println!(
        "  window SLO: {:.3}% available, error rate {:.4} \
         ({} probes ok, {} err)",
        r.availability_pct, r.error_rate, r.probes_ok, r.probes_err);
    match r.supervision_proof {
        Some(n) => println!(
            "  supervision: proven ({n} total worker_restarts, all \
             recovered)"),
        None => println!(
            "  supervision: not proven (daemon unarmed; start it with \
             --fault-injection)"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripts_parse_by_name_and_reject_unknowns() {
        for script in ChaosScript::ALL {
            assert_eq!(ChaosScript::parse(script.name()).unwrap(),
                       script);
        }
        let err = ChaosScript::parse("meteor_strike").unwrap_err();
        assert!(err.to_string().contains("region_outage"),
                "error should list known scripts: {err}");
    }

    #[test]
    fn placed_machines_reads_successful_systems_only() {
        let reply = Json::parse(
            r#"{"ok":true,"type":"place","results":[
                {"system":"hulk","ok":true,"tasks":[
                    {"model":"bert_large","machines":[3,4]},
                    {"model":"resnet152","machines":[9]}]},
                {"system":"system_a","ok":false,"error":"nope"}]}"#)
            .unwrap();
        let machines = placed_machines(&reply).unwrap();
        assert_eq!(machines.into_iter().collect::<Vec<_>>(),
                   vec![3, 4, 9]);
        // All systems failing -> None, even though the envelope is ok.
        let none = Json::parse(
            r#"{"ok":true,"results":[{"ok":false,"error":"x"}]}"#)
            .unwrap();
        assert!(placed_machines(&none).is_none());
        // No results field at all -> None.
        assert!(placed_machines(&Json::parse("{\"ok\":true}").unwrap())
                    .is_none());
    }

    #[test]
    fn slo_counters_rebuild_from_a_stats_reply() {
        let stats = Json::parse(
            r#"{"ok":true,"metrics":{"counters":{
                "place_requests":120,"place_errors":3,
                "connections_shed":2,"unrelated":9}}}"#)
            .unwrap();
        let m = counters_from_stats(&stats);
        assert_eq!(m.counter("place_requests"), 120);
        assert_eq!(m.counter("place_errors"), 3);
        assert_eq!(m.counter("connections_shed"), 2);
        assert_eq!(m.counter("unrelated"), 0, "only SLO counters copy");
        // Degenerate stats (no metrics) -> all-zero counters.
        let empty = counters_from_stats(&Json::parse("{}").unwrap());
        assert_eq!(empty.counter("place_requests"), 0);
    }
}
