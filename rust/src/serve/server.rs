//! The `hulk serve` daemon: accept loop, worker pool, and N batcher
//! shards that coalesce concurrent `Place` requests onto shared GCN
//! forwards and serve repeated workloads from per-shard placement
//! caches.
//!
//! Threading (std only — no async runtime in the offline registry):
//!
//! ```text
//!   accept loop (per listener, nonblocking + shutdown poll)
//!        │ pushes accepted connections
//!        ▼
//!   Mutex<VecDeque<Conn>> + Condvar ──► N workers
//!        each worker owns one connection at a time, frames requests,
//!        answers Admin (WorldCell::mutate) / Stats (epoch snapshot) /
//!        Shutdown inline, and routes Place jobs by workload digest:
//!            shard = digest % n_shards ──mpsc──► batcher shard k
//!                                          │ drains its channel for one
//!                                          │ batch window, snapshots the
//!                                          │ world (Arc clone, no lock
//!                                          │ held), answers cache hits
//!                                          │ from its PlacementCache and
//!                                          │ plans misses against its
//!                                          │ own GnnSplitter
//!                                          ▼
//!                               per-job reply channel back to the worker
//! ```
//!
//! Sharding semantics: each shard owns a private classifier (identical
//! weights — [`default_classifier`] is deterministic in the seed), a
//! private batch-shared [`GnnSplitter`], and a private
//! [`PlacementCache`]. Requests are hash-routed by
//! [`PlaceRequest::digest`], so identical workloads always land on the
//! same shard — its cache needs no cross-shard coherence, and a burst
//! of identical requests still pays **one** GCN forward on one shard.
//! Because planning is deterministic in the world snapshot and cached
//! replies are stored bytes, a sharded + cached daemon answers
//! byte-identically to the single-shard uncached daemon (pinned by
//! `tests/serve_roundtrip.rs`).
//!
//! The world is read through epoch snapshots ([`WorldCell`]): `place`
//! and `stats` clone an `Arc` instead of holding a state mutex, so
//! admin mutations never stall the request plane. Every successful
//! mutation publishes a new generation, which re-keys each shard's
//! splitter memo ([`LiveWorld::graph_key`]) and invalidates its cache
//! scope ([`LiveWorld::cache_scope`]) — a quiet fleet pays one forward
//! per shard per mutation, not one per window.
//!
//! A stalled client cannot pin a worker: every connection carries a
//! read timeout, and a timeout (like any framing-fatal error) drops the
//! connection. Parse-level garbage gets a typed error reply and the
//! connection lives on — see [`super::framing`] for the taxonomy.
//!
//! Self-healing (chaos hardening): worker and shard threads run under a
//! panic supervisor ([`supervised`]) — a panicking iteration is counted
//! (`worker_restarts`) and the loop restarted, so no single bad request
//! or injected fault permanently shrinks the pool. The accept queue is
//! depth-bounded: past [`ServeConfig::queue_depth`] waiting
//! connections, new arrivals are shed with a typed
//! `{"ok":false,"error":"overloaded"}` reply instead of queueing into a
//! hang. Admin mutations publish optimistically
//! ([`WorldCell::publish_if_current`]) and retry epoch-race losses with
//! capped exponential backoff + seeded jitter. `--fault-injection` arms
//! the `panic` admin op so the chaos harness can prove the supervisor
//! recovers, not merely that nothing happened to die.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cli::Cli;
use crate::coordinator::{Metrics, ShardedMetrics, SharedMetrics};
use crate::gnn::GnnSplitter;
use crate::graph::max_dense_n;
use crate::planner::CostBackend;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::framing::{read_frame, write_frame, FrameError, MAX_FRAME};
use super::protocol::{error_reply, parse_request, AdminOp, PanicScope,
                      PlaceRequest, Request};
use super::state::{default_classifier, LiveWorld, PlacementCache,
                   WorldCell};

/// Daemon configuration (CLI: `hulk serve`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// TCP listen address; `None` disables TCP (UDS-only daemon).
    pub addr: Option<String>,
    /// Unix-domain-socket path (unix only); stale socket files are
    /// replaced on bind and removed on shutdown.
    pub uds: Option<String>,
    pub backend: CostBackend,
    /// How long a shard waits after the first `Place` of a batch for
    /// more to coalesce. `0` disables batching (every request plans
    /// alone — the parity baseline the tests compare against).
    pub batch_window_ms: u64,
    /// Seeds the fleet and the classifier weights (every shard builds
    /// the same classifier from it — replies cannot depend on routing).
    pub seed: u64,
    pub workers: usize,
    /// Per-connection read timeout; a connection idle past it is
    /// dropped so stalled clients cannot pin workers.
    pub read_timeout_ms: u64,
    /// Batcher shards; `0` = auto (`min(4, available cores)`).
    pub shards: usize,
    /// Per-shard placement-cache entries; `0` disables caching (the
    /// uncached parity baseline).
    pub cache_capacity: usize,
    /// Accept-queue depth bound: connections arriving while this many
    /// are already waiting for a worker are shed with a typed
    /// `overloaded` reply and closed — overload degrades to fast
    /// refusals, never to an unbounded queue.
    pub queue_depth: usize,
    /// Arms the `panic` admin op (worker/shard crash injection) for
    /// the chaos harness. Off by default: an unarmed daemon declines
    /// the op with a typed error.
    pub fault_injection: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: Some("127.0.0.1:0".to_string()),
            uds: None,
            backend: CostBackend::Analytic,
            batch_window_ms: 2,
            seed: 0,
            workers: 8,
            read_timeout_ms: 2000,
            shards: 0,
            cache_capacity: 1024,
            queue_depth: 1024,
            fault_injection: false,
        }
    }
}

impl ServeConfig {
    /// The shard count `spawn` will actually use: `shards` verbatim, or
    /// `min(4, available cores)` (at least 1) for the `0` auto default.
    pub fn resolved_shards(&self) -> usize {
        if self.shards > 0 {
            self.shards
        } else {
            thread::available_parallelism()
                .map(std::num::NonZeroUsize::get)
                .unwrap_or(1)
                .clamp(1, 4)
        }
    }
}

/// State shared by every daemon thread.
struct Shared {
    world: WorldCell,
    metrics: ShardedMetrics,
    shutdown: AtomicBool,
    queue: Mutex<VecDeque<Conn>>,
    queue_cv: Condvar,
    read_timeout: Duration,
    /// Accept-queue bound; see [`ServeConfig::queue_depth`].
    queue_depth: usize,
    /// Whether the `panic` admin op is armed.
    fault_injection: bool,
    /// Daemon start time — `uptime_s` in the `Stats` reply.
    started: Instant,
    /// Config seed: de-correlates the admin-retry jitter streams.
    seed: u64,
    /// Per-admin-call nonce: seeds each call's jitter rng distinctly
    /// and round-robins shard-scope panic injection.
    admin_seq: AtomicU64,
}

/// One `Place` awaiting a batcher shard. The digest rides along so the
/// shard's cache lookup doesn't recompute what routing already hashed.
struct PlaceJob {
    req: PlaceRequest,
    digest: u64,
    reply: mpsc::Sender<String>,
}

/// What rides a shard channel: real work, or an injected fault.
enum ShardJob {
    Place(PlaceJob),
    /// Fault injection: the shard panics on receipt, so the supervisor
    /// restart path gets exercised by a genuine mid-batch crash.
    Poison,
}

/// Unwrap a shard job at a receive site; poison is the injected fault.
fn open_job(job: ShardJob) -> PlaceJob {
    match job {
        ShardJob::Place(job) => job,
        ShardJob::Poison => panic!("injected fault: shard poison"),
    }
}

/// A running daemon. `spawn` is the in-process entry point the tests
/// use; [`run_serve`] is the CLI wrapper that blocks until shutdown.
pub struct Server {
    addr: Option<SocketAddr>,
    shared: Arc<Shared>,
    threads: Vec<thread::JoinHandle<()>>,
    uds_path: Option<String>,
    n_shards: usize,
}

impl Server {
    pub fn spawn(config: &ServeConfig) -> Result<Server> {
        anyhow::ensure!(config.workers >= 1, "serve needs >= 1 worker");
        anyhow::ensure!(config.queue_depth >= 1,
                        "serve needs --queue-depth >= 1");
        anyhow::ensure!(config.addr.is_some() || config.uds.is_some(),
                        "serve needs --addr or --uds");
        let n_shards = config.resolved_shards();
        let world = LiveWorld::planet(config.seed, config.backend);
        let shared = Arc::new(Shared {
            world: WorldCell::new(world),
            metrics: ShardedMetrics::new(n_shards),
            shutdown: AtomicBool::new(false),
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            read_timeout: Duration::from_millis(config.read_timeout_ms),
            queue_depth: config.queue_depth,
            fault_injection: config.fault_injection,
            started: Instant::now(),
            seed: config.seed,
            admin_seq: AtomicU64::new(0),
        });
        let mut threads = Vec::new();

        // Listeners first: a bind failure must not leak threads.
        let mut acceptors = Vec::new();
        let mut addr = None;
        if let Some(a) = &config.addr {
            let listener = TcpListener::bind(a)?;
            listener.set_nonblocking(true)?;
            addr = Some(listener.local_addr()?);
            acceptors.push(Acceptor::Tcp(listener));
        }
        let uds_path = config.uds.clone();
        if let Some(path) = &uds_path {
            acceptors.push(bind_uds(path)?);
        }

        let mut shard_txs = Vec::with_capacity(n_shards);
        for shard_idx in 0..n_shards {
            let (tx, rx) = mpsc::channel::<ShardJob>();
            shard_txs.push(tx);
            let shared = Arc::clone(&shared);
            let window = config.batch_window_ms;
            let seed = config.seed;
            let cache_capacity = config.cache_capacity;
            threads.push(thread::spawn(move || {
                // `rx` lives out here, outside the supervised scope: a
                // panicking shard drops its in-flight batch (those
                // workers get typed errors) but never its receiver, so
                // the workers' senders stay valid across restarts.
                supervised(&shared, "shard", || {
                    shard_loop(&shared, shard_idx, &rx, window, seed,
                               cache_capacity);
                });
            }));
        }
        for _ in 0..config.workers {
            let shared = Arc::clone(&shared);
            let shard_txs = shard_txs.clone();
            threads.push(thread::spawn(move || {
                supervised(&shared, "worker", || {
                    worker_loop(&shared, &shard_txs);
                });
            }));
        }
        // Workers hold the only senders now: when they exit, every
        // shard's receiver disconnects and the shards exit too.
        drop(shard_txs);
        for acceptor in acceptors {
            let shared = Arc::clone(&shared);
            threads.push(thread::spawn(move || {
                accept_loop(&shared, &acceptor);
            }));
        }
        Ok(Server { addr, shared, threads, uds_path, n_shards })
    }

    /// The bound TCP address (the ephemeral port for `127.0.0.1:0`).
    pub fn addr(&self) -> Option<SocketAddr> {
        self.addr
    }

    /// The shard count this daemon is actually running.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// A merged point-in-time metrics view (global + every shard) —
    /// what a wire `Stats` request renders.
    pub fn metrics(&self) -> Metrics {
        self.shared.metrics.merged()
    }

    /// Ask every thread to wind down (same effect as a wire
    /// `Shutdown` request).
    pub fn stop(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue_cv.notify_all();
    }

    /// Block until every daemon thread has exited.
    pub fn join(self) {
        for t in self.threads {
            let _ = t.join();
        }
        if let Some(path) = &self.uds_path {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Panic supervision for worker and shard threads: a panicking
/// iteration is counted and the loop restarted; a clean return is a
/// deliberate exit (shutdown, channel disconnect) and ends the thread.
///
/// `AssertUnwindSafe` is sound here because everything `body` shares
/// lives behind mutexes whose lock sites recover from poisoning
/// (`PoisonError::into_inner`) or behind channels, and everything else
/// (connections, batches, splitters, caches) is thread-local state the
/// restarted iteration rebuilds from scratch.
fn supervised(shared: &Shared, role: &str, body: impl Fn()) {
    loop {
        if panic::catch_unwind(AssertUnwindSafe(&body)).is_ok() {
            return;
        }
        // `worker_restarts` is the total the stats reply and the chaos
        // gate read; the per-role counter says *what* restarted.
        shared.metrics.global().inc("worker_restarts");
        shared.metrics.global().inc(&format!("restarts_{role}"));
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
    }
}

#[cfg(unix)]
fn bind_uds(path: &str) -> Result<Acceptor> {
    use std::os::unix::net::{UnixListener, UnixStream};
    // A leftover socket file is only removable if it is actually
    // stale: probe-connect first, and refuse to evict a live daemon —
    // silently unlinking its socket would strand it serving a path no
    // client can reach.
    if std::fs::metadata(path).is_ok() {
        match UnixStream::connect(path) {
            Ok(_) => anyhow::bail!(
                "refusing to bind {path}: a live daemon is answering on \
                 it; shut it down first or pick another --uds path"),
            // Nothing answered (connection refused / not a socket):
            // stale file from a crashed daemon, safe to replace.
            Err(_) => {
                let _ = std::fs::remove_file(path);
            }
        }
    }
    let listener = UnixListener::bind(path)?;
    listener.set_nonblocking(true)?;
    Ok(Acceptor::Uds(listener))
}

#[cfg(not(unix))]
fn bind_uds(_path: &str) -> Result<Acceptor> {
    anyhow::bail!("--uds is only supported on unix platforms")
}

/// A listener of either flavor, nonblocking so the accept loop can
/// poll the shutdown flag.
enum Acceptor {
    Tcp(TcpListener),
    #[cfg(unix)]
    Uds(std::os::unix::net::UnixListener),
}

impl Acceptor {
    fn accept(&self) -> io::Result<Conn> {
        match self {
            Acceptor::Tcp(l) => {
                let (stream, _) = l.accept()?;
                // The listener is nonblocking; the worker wants
                // blocking reads bounded by the read timeout.
                stream.set_nonblocking(false)?;
                Ok(Conn::Tcp(stream))
            }
            #[cfg(unix)]
            Acceptor::Uds(l) => {
                let (stream, _) = l.accept()?;
                stream.set_nonblocking(false)?;
                Ok(Conn::Uds(stream))
            }
        }
    }
}

/// An accepted connection of either flavor.
enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Uds(std::os::unix::net::UnixStream),
}

impl Conn {
    fn set_read_timeout(&self, dur: Duration) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(Some(dur)),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_read_timeout(Some(dur)),
        }
    }

    fn set_write_timeout(&self, dur: Duration) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_write_timeout(Some(dur)),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_write_timeout(Some(dur)),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Uds(s) => s.flush(),
        }
    }
}

fn accept_loop(shared: &Shared, acceptor: &Acceptor) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            shared.queue_cv.notify_all();
            return;
        }
        match acceptor.accept() {
            Ok(conn) => {
                let mut q = shared
                    .queue
                    .lock()
                    .unwrap_or_else(|p| p.into_inner());
                if q.len() >= shared.queue_depth {
                    // Bounded queue: overload degrades to a fast typed
                    // refusal at the door, never an unbounded backlog
                    // that turns into client hangs.
                    drop(q);
                    shared.metrics.global().inc("connections_shed");
                    shed_connection(conn);
                } else {
                    q.push_back(conn);
                    drop(q);
                    shared.queue_cv.notify_one();
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Best-effort `overloaded` reply, then drop (close) the connection.
/// The short write timeout keeps a slow client from pinning the accept
/// loop — the reply is a courtesy, the close is the contract.
fn shed_connection(mut conn: Conn) {
    let _ = conn.set_write_timeout(Duration::from_millis(100));
    let _ = write_frame(&mut conn, error_reply("overloaded").as_bytes());
}

fn worker_loop(shared: &Shared, shard_txs: &[mpsc::Sender<ShardJob>]) {
    loop {
        let conn = {
            let mut q = shared
                .queue
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            loop {
                if let Some(c) = q.pop_front() {
                    break Some(c);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared
                    .queue_cv
                    .wait_timeout(q, Duration::from_millis(100))
                    .unwrap_or_else(|p| p.into_inner())
                    .0;
            }
        };
        let Some(mut conn) = conn else { return };
        serve_connection(&mut conn, shared, shard_txs);
    }
}

/// What the worker does with the connection after writing a reply.
enum Disposition {
    /// Keep framing requests off this connection.
    Keep,
    /// Close the connection (shutdown, desynced stream).
    Close,
    /// Fault injection accepted: reply first, then panic this worker
    /// so the supervisor has a genuine crash to recover from.
    PanicAfterReply,
}

/// Frame requests off one connection until it closes, times out, or a
/// framing-fatal error desynchronizes the stream.
fn serve_connection(conn: &mut Conn, shared: &Shared,
                    shard_txs: &[mpsc::Sender<ShardJob>])
{
    shared.metrics.global().inc("connections");
    let _ = conn.set_read_timeout(shared.read_timeout);
    loop {
        match read_frame(conn) {
            Ok(None) => return, // clean EOF
            Ok(Some(payload)) => {
                let (reply, disposition) =
                    handle_payload(&payload, shared, shard_txs);
                if write_frame(conn, reply.as_bytes()).is_err() {
                    return;
                }
                match disposition {
                    Disposition::Keep => {}
                    Disposition::Close => return,
                    Disposition::PanicAfterReply => {
                        panic!("injected fault: worker panic")
                    }
                }
            }
            Err(FrameError::Oversized(len)) => {
                // The payload was never read; the stream cannot be
                // resynchronized. One typed error, then close.
                shared.metrics.global().inc("protocol_errors");
                let reply = error_reply(&format!(
                    "frame of {len} bytes exceeds the {MAX_FRAME}-byte \
                     maximum; closing connection"));
                let _ = write_frame(conn, reply.as_bytes());
                return;
            }
            // Timeout (stalled client), mid-frame close, io error:
            // nothing sensible to say on a desynced stream.
            Err(_) => return,
        }
    }
}

fn handle_payload(payload: &[u8], shared: &Shared,
                  shard_txs: &[mpsc::Sender<ShardJob>])
    -> (String, Disposition)
{
    let request = match parse_request(payload) {
        Ok(r) => r,
        Err(msg) => {
            // Parse-level garbage: typed error, keep the connection.
            shared.metrics.global().inc("protocol_errors");
            return (error_reply(&msg), Disposition::Keep);
        }
    };
    match request {
        Request::Place(req) => {
            let started = Instant::now();
            let digest = req.digest();
            // Digest routing: identical workloads always hit the same
            // shard (its cache + splitter), distinct workloads spread.
            let shard = (digest % shard_txs.len() as u64) as usize;
            let (tx, rx) = mpsc::channel();
            let job = ShardJob::Place(PlaceJob { req, digest, reply: tx });
            if shard_txs[shard].send(job).is_err() {
                // Receivers outlive shard panics (they sit outside the
                // supervised scope) — a dead channel is real teardown.
                return (error_reply("daemon is shutting down"),
                        Disposition::Close);
            }
            match rx.recv() {
                Ok(reply) => {
                    // Wall-clock lives in metrics only — the reply
                    // bytes stay deterministic. The shard's instance,
                    // not a daemon-global lock: place observations only
                    // contend within their own shard.
                    shared.metrics.shard(shard).observe(
                        "place_latency_us",
                        started.elapsed().as_micros() as f64);
                    (reply, Disposition::Keep)
                }
                Err(_) if shared.shutdown.load(Ordering::SeqCst) => {
                    (error_reply("daemon is shutting down"),
                     Disposition::Close)
                }
                Err(_) => {
                    // The shard panicked mid-batch and dropped our
                    // reply sender; the supervisor is already
                    // restarting it. The connection stays usable — a
                    // retried request will land on the fresh shard.
                    shared.metrics.global().inc("place_errors");
                    (error_reply("batcher restarted; retry"),
                     Disposition::Keep)
                }
            }
        }
        Request::Admin(AdminOp::Panic { scope }) => {
            handle_panic_op(scope, shared, shard_txs)
        }
        Request::Admin(op) => {
            (handle_admin(op, shared), Disposition::Keep)
        }
        Request::Stats => {
            shared.metrics.global().inc("stats_requests");
            (stats_reply(shared), Disposition::Keep)
        }
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.queue_cv.notify_all();
            let mut reply = Json::obj();
            reply.set("ok", Json::Bool(true));
            reply.set("type", Json::from("shutdown"));
            (reply.render(), Disposition::Close)
        }
    }
}

/// The `panic` admin op: gated behind `--fault-injection`, never
/// touches the world. Worker scope panics *this* thread after the
/// reply is on the wire; shard scope poisons a batcher channel
/// (round-robin) so the crash lands mid-batch on the far side.
fn handle_panic_op(scope: PanicScope, shared: &Shared,
                   shard_txs: &[mpsc::Sender<ShardJob>])
    -> (String, Disposition)
{
    if !shared.fault_injection {
        shared.metrics.global().inc("admin_errors");
        return (error_reply(
            "fault injection is disabled; start the daemon with \
             --fault-injection"), Disposition::Keep);
    }
    let mut reply = Json::obj();
    reply.set("ok", Json::Bool(true));
    reply.set("type", Json::from("admin"));
    reply.set("op", Json::from("panic"));
    match scope {
        PanicScope::Worker => {
            shared.metrics.global().inc("admin_panics");
            reply.set("scope", Json::from("worker"));
            (reply.render(), Disposition::PanicAfterReply)
        }
        PanicScope::Shard => {
            let shard = shared.admin_seq.fetch_add(1, Ordering::Relaxed)
                as usize
                % shard_txs.len();
            if shard_txs[shard].send(ShardJob::Poison).is_err() {
                return (error_reply("daemon is shutting down"),
                        Disposition::Close);
            }
            shared.metrics.global().inc("admin_panics");
            reply.set("scope", Json::from("shard"));
            reply.set("shard", Json::from(shard));
            (reply.render(), Disposition::Keep)
        }
    }
}

/// Optimistic-publish attempts before an admin op reports contention.
/// With the capped backoff below this bounds a call to ~100ms of
/// retrying under pathological contention.
const MAX_ADMIN_ATTEMPTS: u32 = 32;

/// Outcome payload of a successful admin mutation (shapes the reply:
/// `machine` for join/fail/revoke — the pre-chaos wire bytes,
/// unchanged — `machines` for fail_region, `wan_factor` for wan).
enum AdminDetail {
    Machine(usize),
    Machines(Vec<usize>),
    WanFactor(f64),
}

fn apply_admin(op: AdminOp, world: &mut LiveWorld)
    -> (&'static str, Result<AdminDetail, String>)
{
    match op {
        AdminOp::Join { region, gpu, n_gpus } => {
            ("join",
             world.join(region, gpu, n_gpus).map(AdminDetail::Machine))
        }
        AdminOp::Fail { machine } => {
            ("fail",
             world.fail(machine).map(|()| AdminDetail::Machine(machine)))
        }
        AdminOp::Revoke { machine } => {
            ("revoke",
             world.fail(machine).map(|()| AdminDetail::Machine(machine)))
        }
        AdminOp::FailRegion { region } => {
            ("fail_region",
             world.fail_region(region).map(AdminDetail::Machines))
        }
        AdminOp::Wan { factor } => {
            ("wan",
             world.set_wan_factor(factor).map(AdminDetail::WanFactor))
        }
        AdminOp::Panic { .. } => {
            unreachable!("panic ops never reach the world path")
        }
    }
}

fn handle_admin(op: AdminOp, shared: &Shared) -> String {
    // Optimistic clone-mutate-publish: snapshot, mutate a clone, and
    // publish only if nothing else published first
    // ([`WorldCell::publish_if_current`]). The request plane keeps
    // reading the old generation until the new one is swapped in
    // whole. Losing the epoch race costs a retry against the winner's
    // world, with capped exponential backoff and seeded jitter so two
    // racing admins don't re-collide in lockstep.
    let mut rng = Rng::new(
        shared.seed ^ shared.admin_seq.fetch_add(1, Ordering::Relaxed));
    for attempt in 0..MAX_ADMIN_ATTEMPTS {
        let snapshot = shared.world.snapshot();
        let mut next = (*snapshot).clone();
        let (op_name, outcome) = apply_admin(op, &mut next);
        let detail = match outcome {
            Ok(detail) => detail,
            Err(msg) => {
                // Declines are deterministic in the snapshot the op
                // validated against; retrying cannot change them.
                shared.metrics.global().inc("admin_errors");
                return error_reply(&msg);
            }
        };
        let fleet_machines = next.fleet.len();
        let alive_machines = next.alive_machines();
        let epoch = next.epoch();
        if shared.world.publish_if_current(&snapshot, next) {
            shared.metrics.global().inc(&format!("admin_{op_name}s"));
            if attempt > 0 {
                shared.metrics.global().add("admin_retries",
                                            u64::from(attempt));
            }
            let mut reply = Json::obj();
            reply.set("ok", Json::Bool(true));
            reply.set("type", Json::from("admin"));
            reply.set("op", Json::from(op_name));
            match detail {
                AdminDetail::Machine(machine) => {
                    reply.set("machine", Json::from(machine));
                }
                AdminDetail::Machines(machines) => {
                    let mut arr = Json::arr();
                    for m in machines {
                        arr.push(Json::from(m));
                    }
                    reply.set("machines", arr);
                }
                AdminDetail::WanFactor(factor) => {
                    reply.set("wan_factor", Json::Num(factor));
                }
            }
            reply.set("fleet_machines", Json::from(fleet_machines));
            reply.set("alive_machines", Json::from(alive_machines));
            reply.set("epoch", Json::from(epoch as f64));
            return reply.render();
        }
        // Lost the publish race: another mutation landed first. Sleep
        // a jittered slice of an exponentially growing (capped) window
        // and re-validate against the new world.
        let cap_us = 200usize << attempt.min(5); // 200µs .. 6.4ms
        let jitter_us = rng.below(cap_us + 1) as u64;
        thread::sleep(Duration::from_micros(jitter_us));
    }
    shared.metrics.global().inc("admin_errors");
    error_reply("admin contention: publish retries exhausted; retry")
}

fn stats_reply(shared: &Shared) -> String {
    // The epoch snapshot, not a world lock: stats never contends with
    // admin traffic (the only shared lock is the Arc swap itself).
    let world = shared.world.snapshot();
    let mut reply = Json::obj();
    reply.set("ok", Json::Bool(true));
    reply.set("type", Json::from("stats"));
    reply.set("fleet_machines", Json::from(world.fleet.len()));
    reply.set("alive_machines", Json::from(world.alive_machines()));
    reply.set("fleet_memory_gb",
              Json::from(world.fleet.total_memory_gb()));
    reply.set("epoch", Json::from(world.epoch() as f64));
    reply.set("shards", Json::from(shared.metrics.n_shards()));
    // The incremental-update proof: no admin mutation may ever rebuild
    // the world or grow a dense adjacency past the oracle ceiling.
    reply.set("dense_rebuilds", Json::from(world.dense_rebuilds as f64));
    reply.set("max_dense_n", Json::from(max_dense_n()));
    drop(world);
    // The self-healing proof pair: a restart that happened is visible
    // (`worker_restarts` > 0) *and* the daemon that reports it is the
    // same process that took the hit (`uptime_s` never reset) — so the
    // chaos gate can distinguish recovered-from from never-crashed and
    // from silently-respawned.
    reply.set("uptime_s",
              Json::Num(shared.started.elapsed().as_secs_f64()));
    let merged = shared.metrics.merged();
    reply.set("worker_restarts",
              Json::from(merged.counter("worker_restarts") as f64));
    // `metrics` keeps the pre-sharding wire shape (merged view);
    // `per_shard` adds the breakdown, shard order.
    reply.set("metrics", merged.to_json());
    let mut per_shard = Json::arr();
    for m in shared.metrics.shard_snapshots() {
        per_shard.push(m.to_json());
    }
    reply.set("per_shard", per_shard);
    reply.render()
}

/// One batcher shard: owns a private classifier, batch-shared splitter,
/// and placement cache.
///
/// One iteration = one batch: block for the first job, drain the
/// channel until the window closes, snapshot the world (an `Arc`
/// clone — no lock held while planning), answer cache hits from the
/// shard's [`PlacementCache`] and plan misses through the shared
/// splitter. The splitter survives across batches until a mutation
/// publishes a re-keyed generation, so `gcn_forwards` counts actual
/// forward passes — the denominator of the
/// `serve/batched_forward_speedup` loadgen row.
///
/// Per-request latency here is *shard-side handling time* (cache
/// lookup or planning + reply send), deliberately excluding queue and
/// batch-window wait — that is what makes `place_cached_us` vs
/// `place_uncached_us` a meaningful cache-speedup comparison. The
/// client-observed round trip (window included) lands in
/// `place_latency_us` at the worker.
fn shard_loop(shared: &Shared, shard_idx: usize,
              rx: &mpsc::Receiver<ShardJob>, window_ms: u64, seed: u64,
              cache_capacity: usize)
{
    let metrics: SharedMetrics = shared.metrics.shard(shard_idx).clone();
    let (classifier, params) = default_classifier(seed);
    let mut splitter = GnnSplitter::new(&classifier, &params);
    let mut splitter_key = None;
    let mut forward_counted = false;
    let mut cache = PlacementCache::new(cache_capacity);
    let window = Duration::from_millis(window_ms);
    loop {
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(job) => open_job(job),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + window;
        loop {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => batch.push(open_job(job)),
                Err(_) => break,
            }
        }
        let world = shared.world.snapshot();
        let key = world.graph_key();
        if splitter_key != Some(key) {
            // A mutation published a re-keyed generation: fresh memo,
            // fresh forward. (GnnSplitter pins one graph per instance.)
            splitter = GnnSplitter::new(&classifier, &params);
            splitter_key = Some(key);
            forward_counted = false;
        }
        let scope = world.cache_scope();
        for job in &batch {
            let t0 = Instant::now();
            match cache.get(scope, job.digest) {
                Some(reply) => {
                    // Stored bytes verbatim: byte-identity for free.
                    let _ = job.reply.send(reply);
                    metrics.inc("cache_hits");
                    metrics.observe("place_cached_us",
                                    t0.elapsed().as_micros() as f64);
                }
                None => {
                    let reply = world.plan_place(&job.req, &splitter);
                    let ok = reply.starts_with("{\"ok\":true");
                    if !ok {
                        metrics.inc("place_errors");
                    } else if reply.contains("\"degraded\":true") {
                        // Oracle-fallback replies are successes for
                        // the SLO, but the degradation is observable.
                        metrics.inc("degraded_replies");
                    }
                    // Only deterministic ok replies are worth pinning;
                    // error replies are cheap to recompute.
                    if ok && cache.insert(scope, job.digest, &reply) {
                        metrics.inc("cache_evictions");
                    }
                    let _ = job.reply.send(reply);
                    metrics.inc("cache_misses");
                    metrics.observe("place_uncached_us",
                                    t0.elapsed().as_micros() as f64);
                }
            }
        }
        drop(world);
        if splitter.forward_ran() && !forward_counted {
            metrics.inc("gcn_forwards");
            forward_counted = true;
        }
        metrics.add("place_requests", batch.len() as u64);
        metrics.inc("batches");
        metrics.observe("batch_size", batch.len() as f64);
        metrics.set_gauge("cache_entries", cache.len() as f64);
    }
}

/// `hulk serve` CLI entry: spawn, announce, block until shutdown.
pub fn run_serve(cli: &Cli) -> Result<()> {
    let uds = cli.flag("uds").map(str::to_string);
    let addr = match cli.flag("addr") {
        Some(a) => Some(a.to_string()),
        // Default TCP endpoint unless the daemon is UDS-only.
        None if uds.is_none() => Some("127.0.0.1:7711".to_string()),
        None => None,
    };
    let config = ServeConfig {
        addr,
        uds,
        backend: match cli.flag("cost") {
            Some(v) => CostBackend::parse(v)?,
            None => CostBackend::Analytic,
        },
        batch_window_ms: cli.flag_u64("batch-window-ms", 2)?,
        seed: cli.flag_u64("seed", 0)?,
        workers: cli.flag_u64("workers", 8)? as usize,
        read_timeout_ms: cli.flag_u64("read-timeout-ms", 2000)?,
        shards: cli.flag_u64("shards", 0)? as usize,
        cache_capacity: cli.flag_u64("cache-capacity", 1024)? as usize,
        queue_depth: cli.flag_u64("queue-depth", 1024)? as usize,
        fault_injection: cli.flag_bool("fault-injection"),
    };
    let server = Server::spawn(&config)?;
    {
        let world = server.shared.world.snapshot();
        println!(
            "hulk serve: {} machines alive, {} backend, {}ms batch \
             window, {} workers, {} shard{}, cache {}",
            world.alive_machines(), config.backend.name(),
            config.batch_window_ms, config.workers, server.n_shards(),
            if server.n_shards() == 1 { "" } else { "s" },
            if config.cache_capacity == 0 {
                "off".to_string()
            } else {
                format!("{} entries/shard", config.cache_capacity)
            });
    }
    if config.fault_injection {
        println!("fault injection ARMED: admin panic ops will crash \
                  (and supervision will restart) daemon threads");
    }
    if let Some(a) = server.addr() {
        println!("listening on tcp://{a}");
    }
    if let Some(p) = &server.uds_path {
        println!("listening on unix://{p}");
    }
    println!("send {{\"op\":\"shutdown\"}} (or run hulk loadgen \
              --shutdown) to stop");
    server.join();
    println!("hulk serve: shut down cleanly");
    Ok(())
}
