//! The `hulk serve` daemon: accept loop, worker pool, and the request
//! batcher that coalesces concurrent `Place` requests into one shared
//! GCN forward.
//!
//! Threading (std only — no async runtime in the offline registry):
//!
//! ```text
//!   accept loop (per listener, nonblocking + shutdown poll)
//!        │ pushes accepted connections
//!        ▼
//!   Mutex<VecDeque<Conn>> + Condvar ──► N workers
//!        each worker owns one connection at a time, frames requests,
//!        answers Admin/Stats/Shutdown inline (short world lock) and
//!        forwards Place jobs ──mpsc──► the batcher thread
//!                                          │ drains the channel for one
//!                                          │ batch window, locks the
//!                                          │ world once, plans every job
//!                                          │ against one GnnSplitter
//!                                          ▼
//!                               per-job reply channel back to the worker
//! ```
//!
//! Batching semantics: all `Place` jobs collected within one
//! `batch_window_ms` window plan against the same frozen world through
//! one [`GnnSplitter`] (`HulkSplitterKind::SharedGnn`), so the batch
//! pays **one** GCN forward no matter how many requests coalesced.
//! Because class probabilities depend only on (graph, params) — never
//! the workload — and replies carry only deterministic predicted costs,
//! a batched answer is byte-identical to the unbatched answer for the
//! same request (pinned by `tests/serve_roundtrip.rs`). The splitter is
//! even reused *across* batches until an admin mutation re-keys the
//! graph ([`LiveWorld::graph_key`]), so a quiet fleet pays one forward
//! per mutation, not one per window.
//!
//! A stalled client cannot pin a worker: every connection carries a
//! read timeout, and a timeout (like any framing-fatal error) drops the
//! connection. Parse-level garbage gets a typed error reply and the
//! connection lives on — see [`super::framing`] for the taxonomy.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cli::Cli;
use crate::coordinator::SharedMetrics;
use crate::gnn::GnnSplitter;
use crate::graph::max_dense_n;
use crate::planner::CostBackend;
use crate::util::json::Json;

use super::framing::{read_frame, write_frame, FrameError, MAX_FRAME};
use super::protocol::{error_reply, parse_request, AdminOp, PlaceRequest,
                      Request};
use super::state::{default_classifier, LiveWorld};

/// Daemon configuration (CLI: `hulk serve`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// TCP listen address; `None` disables TCP (UDS-only daemon).
    pub addr: Option<String>,
    /// Unix-domain-socket path (unix only); stale socket files are
    /// replaced on bind and removed on shutdown.
    pub uds: Option<String>,
    pub backend: CostBackend,
    /// How long the batcher waits after the first `Place` of a batch
    /// for more to coalesce. `0` disables batching (every request
    /// plans alone — the parity baseline the tests compare against).
    pub batch_window_ms: u64,
    /// Seeds the fleet and the classifier weights.
    pub seed: u64,
    pub workers: usize,
    /// Per-connection read timeout; a connection idle past it is
    /// dropped so stalled clients cannot pin workers.
    pub read_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: Some("127.0.0.1:0".to_string()),
            uds: None,
            backend: CostBackend::Analytic,
            batch_window_ms: 2,
            seed: 0,
            workers: 8,
            read_timeout_ms: 2000,
        }
    }
}

/// State shared by every daemon thread.
struct Shared {
    world: Mutex<LiveWorld>,
    metrics: SharedMetrics,
    shutdown: AtomicBool,
    queue: Mutex<VecDeque<Conn>>,
    queue_cv: Condvar,
    read_timeout: Duration,
}

impl Shared {
    fn world(&self) -> MutexGuard<'_, LiveWorld> {
        // A poisoned world lock means a planner panicked; the state
        // itself is append-only counters + the graph seam, safe to
        // keep serving.
        self.world.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// One `Place` awaiting the batcher.
struct PlaceJob {
    req: PlaceRequest,
    reply: mpsc::Sender<String>,
}

/// A running daemon. `spawn` is the in-process entry point the tests
/// use; [`run_serve`] is the CLI wrapper that blocks until shutdown.
pub struct Server {
    addr: Option<SocketAddr>,
    shared: Arc<Shared>,
    threads: Vec<thread::JoinHandle<()>>,
    uds_path: Option<String>,
}

impl Server {
    pub fn spawn(config: &ServeConfig) -> Result<Server> {
        anyhow::ensure!(config.workers >= 1, "serve needs >= 1 worker");
        anyhow::ensure!(config.addr.is_some() || config.uds.is_some(),
                        "serve needs --addr or --uds");
        let world = LiveWorld::planet(config.seed, config.backend);
        let shared = Arc::new(Shared {
            world: Mutex::new(world),
            metrics: SharedMetrics::new(),
            shutdown: AtomicBool::new(false),
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            read_timeout: Duration::from_millis(config.read_timeout_ms),
        });
        let mut threads = Vec::new();

        // Listeners first: a bind failure must not leak threads.
        let mut acceptors = Vec::new();
        let mut addr = None;
        if let Some(a) = &config.addr {
            let listener = TcpListener::bind(a)?;
            listener.set_nonblocking(true)?;
            addr = Some(listener.local_addr()?);
            acceptors.push(Acceptor::Tcp(listener));
        }
        let uds_path = config.uds.clone();
        if let Some(path) = &uds_path {
            acceptors.push(bind_uds(path)?);
        }

        let (place_tx, place_rx) = mpsc::channel::<PlaceJob>();
        {
            let shared = Arc::clone(&shared);
            let window = config.batch_window_ms;
            let seed = config.seed;
            threads.push(thread::spawn(move || {
                batcher_loop(&shared, &place_rx, window, seed);
            }));
        }
        for _ in 0..config.workers {
            let shared = Arc::clone(&shared);
            let place_tx = place_tx.clone();
            threads.push(thread::spawn(move || {
                worker_loop(&shared, &place_tx);
            }));
        }
        // Workers hold the only senders now: when they exit, the
        // batcher's receiver disconnects and it exits too.
        drop(place_tx);
        for acceptor in acceptors {
            let shared = Arc::clone(&shared);
            threads.push(thread::spawn(move || {
                accept_loop(&shared, &acceptor);
            }));
        }
        Ok(Server { addr, shared, threads, uds_path })
    }

    /// The bound TCP address (the ephemeral port for `127.0.0.1:0`).
    pub fn addr(&self) -> Option<SocketAddr> {
        self.addr
    }

    pub fn metrics(&self) -> &SharedMetrics {
        &self.shared.metrics
    }

    /// Ask every thread to wind down (same effect as a wire
    /// `Shutdown` request).
    pub fn stop(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue_cv.notify_all();
    }

    /// Block until every daemon thread has exited.
    pub fn join(self) {
        for t in self.threads {
            let _ = t.join();
        }
        if let Some(path) = &self.uds_path {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(unix)]
fn bind_uds(path: &str) -> Result<Acceptor> {
    // Replace a stale socket file from a crashed daemon.
    let _ = std::fs::remove_file(path);
    let listener = std::os::unix::net::UnixListener::bind(path)?;
    listener.set_nonblocking(true)?;
    Ok(Acceptor::Uds(listener))
}

#[cfg(not(unix))]
fn bind_uds(_path: &str) -> Result<Acceptor> {
    anyhow::bail!("--uds is only supported on unix platforms")
}

/// A listener of either flavor, nonblocking so the accept loop can
/// poll the shutdown flag.
enum Acceptor {
    Tcp(TcpListener),
    #[cfg(unix)]
    Uds(std::os::unix::net::UnixListener),
}

impl Acceptor {
    fn accept(&self) -> io::Result<Conn> {
        match self {
            Acceptor::Tcp(l) => {
                let (stream, _) = l.accept()?;
                // The listener is nonblocking; the worker wants
                // blocking reads bounded by the read timeout.
                stream.set_nonblocking(false)?;
                Ok(Conn::Tcp(stream))
            }
            #[cfg(unix)]
            Acceptor::Uds(l) => {
                let (stream, _) = l.accept()?;
                stream.set_nonblocking(false)?;
                Ok(Conn::Uds(stream))
            }
        }
    }
}

/// An accepted connection of either flavor.
enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Uds(std::os::unix::net::UnixStream),
}

impl Conn {
    fn set_read_timeout(&self, dur: Duration) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(Some(dur)),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_read_timeout(Some(dur)),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Uds(s) => s.flush(),
        }
    }
}

fn accept_loop(shared: &Shared, acceptor: &Acceptor) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            shared.queue_cv.notify_all();
            return;
        }
        match acceptor.accept() {
            Ok(conn) => {
                let mut q = shared
                    .queue
                    .lock()
                    .unwrap_or_else(|p| p.into_inner());
                q.push_back(conn);
                drop(q);
                shared.queue_cv.notify_one();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn worker_loop(shared: &Shared, place_tx: &mpsc::Sender<PlaceJob>) {
    loop {
        let conn = {
            let mut q = shared
                .queue
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            loop {
                if let Some(c) = q.pop_front() {
                    break Some(c);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared
                    .queue_cv
                    .wait_timeout(q, Duration::from_millis(100))
                    .unwrap_or_else(|p| p.into_inner())
                    .0;
            }
        };
        let Some(mut conn) = conn else { return };
        serve_connection(&mut conn, shared, place_tx);
    }
}

/// Frame requests off one connection until it closes, times out, or a
/// framing-fatal error desynchronizes the stream.
fn serve_connection(conn: &mut Conn, shared: &Shared,
                    place_tx: &mpsc::Sender<PlaceJob>)
{
    shared.metrics.inc("connections");
    let _ = conn.set_read_timeout(shared.read_timeout);
    loop {
        match read_frame(conn) {
            Ok(None) => return, // clean EOF
            Ok(Some(payload)) => {
                let (reply, close) =
                    handle_payload(&payload, shared, place_tx);
                if write_frame(conn, reply.as_bytes()).is_err() {
                    return;
                }
                if close {
                    return;
                }
            }
            Err(FrameError::Oversized(len)) => {
                // The payload was never read; the stream cannot be
                // resynchronized. One typed error, then close.
                shared.metrics.inc("protocol_errors");
                let reply = error_reply(&format!(
                    "frame of {len} bytes exceeds the {MAX_FRAME}-byte \
                     maximum; closing connection"));
                let _ = write_frame(conn, reply.as_bytes());
                return;
            }
            // Timeout (stalled client), mid-frame close, io error:
            // nothing sensible to say on a desynced stream.
            Err(_) => return,
        }
    }
}

/// Returns `(reply, close_connection)`.
fn handle_payload(payload: &[u8], shared: &Shared,
                  place_tx: &mpsc::Sender<PlaceJob>) -> (String, bool)
{
    let request = match parse_request(payload) {
        Ok(r) => r,
        Err(msg) => {
            // Parse-level garbage: typed error, keep the connection.
            shared.metrics.inc("protocol_errors");
            return (error_reply(&msg), false);
        }
    };
    match request {
        Request::Place(req) => {
            let started = Instant::now();
            let (tx, rx) = mpsc::channel();
            if place_tx.send(PlaceJob { req, reply: tx }).is_err() {
                return (error_reply("daemon is shutting down"), true);
            }
            match rx.recv() {
                Ok(reply) => {
                    // Wall-clock lives in metrics only — the reply
                    // bytes stay deterministic.
                    shared.metrics.observe(
                        "place_latency_us",
                        started.elapsed().as_micros() as f64);
                    (reply, false)
                }
                Err(_) => (error_reply("daemon is shutting down"), true),
            }
        }
        Request::Admin(op) => (handle_admin(op, shared), false),
        Request::Stats => {
            shared.metrics.inc("stats_requests");
            (stats_reply(shared), false)
        }
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.queue_cv.notify_all();
            let mut reply = Json::obj();
            reply.set("ok", Json::Bool(true));
            reply.set("type", Json::from("shutdown"));
            (reply.render(), true)
        }
    }
}

fn handle_admin(op: AdminOp, shared: &Shared) -> String {
    let mut world = shared.world();
    let (op_name, outcome) = match op {
        AdminOp::Join { region, gpu, n_gpus } => {
            ("join", world.join(region, gpu, n_gpus))
        }
        AdminOp::Fail { machine } => {
            ("fail", world.fail(machine).map(|()| machine))
        }
        AdminOp::Revoke { machine } => {
            ("revoke", world.fail(machine).map(|()| machine))
        }
    };
    match outcome {
        Ok(machine) => {
            shared.metrics.inc(&format!("admin_{op_name}s"));
            let mut reply = Json::obj();
            reply.set("ok", Json::Bool(true));
            reply.set("type", Json::from("admin"));
            reply.set("op", Json::from(op_name));
            reply.set("machine", Json::from(machine));
            reply.set("fleet_machines", Json::from(world.fleet.len()));
            reply.set("alive_machines",
                      Json::from(world.alive_machines()));
            reply.render()
        }
        Err(msg) => {
            shared.metrics.inc("admin_errors");
            error_reply(&msg)
        }
    }
}

fn stats_reply(shared: &Shared) -> String {
    let world = shared.world();
    let mut reply = Json::obj();
    reply.set("ok", Json::Bool(true));
    reply.set("type", Json::from("stats"));
    reply.set("fleet_machines", Json::from(world.fleet.len()));
    reply.set("alive_machines", Json::from(world.alive_machines()));
    reply.set("fleet_memory_gb",
              Json::from(world.fleet.total_memory_gb()));
    // The incremental-update proof: no admin mutation may ever rebuild
    // the world or grow a dense adjacency past the oracle ceiling.
    reply.set("dense_rebuilds", Json::from(world.dense_rebuilds as f64));
    reply.set("max_dense_n", Json::from(max_dense_n()));
    drop(world);
    reply.set("metrics", shared.metrics.snapshot().to_json());
    reply.render()
}

/// The batcher: owns the classifier and the batch-shared splitter.
///
/// One iteration = one batch: block for the first job, drain the
/// channel until the window closes, lock the world once, answer every
/// job through the shared splitter. The splitter survives across
/// batches until the world's graph key changes, so `gcn_forwards`
/// counts actual forward passes — the denominator of the
/// `serve/batched_forward_speedup` loadgen row.
fn batcher_loop(shared: &Shared, rx: &mpsc::Receiver<PlaceJob>,
                window_ms: u64, seed: u64)
{
    let (classifier, params) = default_classifier(seed);
    let mut splitter = GnnSplitter::new(&classifier, &params);
    let mut splitter_key = None;
    let mut forward_counted = false;
    let window = Duration::from_millis(window_ms);
    loop {
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(job) => job,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + window;
        loop {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => batch.push(job),
                Err(_) => break,
            }
        }
        let world = shared.world();
        let key = world.graph_key();
        if splitter_key != Some(key) {
            // An admin mutation re-keyed the graph: fresh memo, fresh
            // forward. (GnnSplitter pins one graph per instance.)
            splitter = GnnSplitter::new(&classifier, &params);
            splitter_key = Some(key);
            forward_counted = false;
        }
        for job in &batch {
            let reply = world.plan_place(&job.req, &splitter);
            let _ = job.reply.send(reply);
        }
        drop(world);
        if splitter.forward_ran() && !forward_counted {
            shared.metrics.inc("gcn_forwards");
            forward_counted = true;
        }
        shared.metrics.add("place_requests", batch.len() as u64);
        shared.metrics.inc("batches");
        shared.metrics.observe("batch_size", batch.len() as f64);
    }
}

/// `hulk serve` CLI entry: spawn, announce, block until shutdown.
pub fn run_serve(cli: &Cli) -> Result<()> {
    let uds = cli.flag("uds").map(str::to_string);
    let addr = match cli.flag("addr") {
        Some(a) => Some(a.to_string()),
        // Default TCP endpoint unless the daemon is UDS-only.
        None if uds.is_none() => Some("127.0.0.1:7711".to_string()),
        None => None,
    };
    let config = ServeConfig {
        addr,
        uds,
        backend: match cli.flag("cost") {
            Some(v) => CostBackend::parse(v)?,
            None => CostBackend::Analytic,
        },
        batch_window_ms: cli.flag_u64("batch-window-ms", 2)?,
        seed: cli.flag_u64("seed", 0)?,
        workers: cli.flag_u64("workers", 8)? as usize,
        read_timeout_ms: cli.flag_u64("read-timeout-ms", 2000)?,
    };
    let server = Server::spawn(&config)?;
    {
        let world = server.shared.world();
        println!(
            "hulk serve: {} machines alive, {} backend, {}ms batch \
             window, {} workers",
            world.alive_machines(), config.backend.name(),
            config.batch_window_ms, config.workers);
    }
    if let Some(a) = server.addr() {
        println!("listening on tcp://{a}");
    }
    if let Some(p) = &server.uds_path {
        println!("listening on unix://{p}");
    }
    println!("send {{\"op\":\"shutdown\"}} (or run hulk loadgen \
              --shutdown) to stop");
    server.join();
    println!("hulk serve: shut down cleanly");
    Ok(())
}
