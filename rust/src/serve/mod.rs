//! `hulk serve` — placement-as-a-service: a long-lived daemon that owns
//! one live fleet world and answers placement queries over a
//! length-prefixed JSON protocol, with sharded request batching (one
//! GCN forward per shard per batch window), per-shard placement caches
//! keyed on canonical workload digests, and live fleet updates through
//! the incremental graph seam published as epoch snapshots.
//!
//! - [`framing`]  — 4-byte big-endian length prefix + JSON payload;
//!   the recoverable-vs-fatal error taxonomy.
//! - [`protocol`] — `Place` / `Admin{Join,Fail,Revoke}` / `Stats` /
//!   `Shutdown` parsing, the typed error reply, and the canonical
//!   workload digest ([`PlaceRequest::digest`]) that both routes a
//!   request to its shard and keys that shard's cache.
//! - [`state`]    — [`LiveWorld`]: fleet + [`HierarchicalGraph`]
//!   mutated only through `apply_join`/`apply_failure` (never rebuilt)
//!   and epoch-stamped per mutation; [`WorldCell`], the
//!   clone-mutate-publish cell the request plane reads as `Arc`
//!   snapshots; [`PlacementCache`], the LRU reply cache whose
//!   [`CacheScope`] dies with every fleet mutation; and the
//!   deterministic `Place` reply builder.
//! - [`server`]   — accept loop, worker pool, and N batcher shards
//!   (`--shards`), each coalescing digest-routed `Place` requests onto
//!   its own shared [`GnnSplitter`] forward and its own cache.
//! - [`loadgen`]  — `hulk loadgen`: seeded request mixes with a
//!   `--repeat-mix` knob for cache-hit traffic, µs latency
//!   percentiles, `BENCH_serve.json`.
//!
//! The contract the round-trip tests pin: replies are deterministic in
//! the world state (wall-clock lives only in metrics), so a batched
//! answer is byte-identical to the unbatched answer, a sharded+cached
//! answer is byte-identical to the single-shard uncached answer, and a
//! single served answer is byte-identical to calling the planner
//! directly on an equal world.
//!
//! [`HierarchicalGraph`]: crate::graph::HierarchicalGraph
//! [`GnnSplitter`]: crate::gnn::GnnSplitter

pub mod framing;
pub mod loadgen;
pub mod protocol;
pub mod server;
pub mod state;

pub use framing::{read_frame, roundtrip, write_frame, FrameError,
                  MAX_FRAME};
pub use loadgen::{run_loadgen, LoadgenConfig, LoadgenReport};
pub use protocol::{error_reply, parse_request, AdminOp, PlaceRequest,
                   Request};
pub use server::{run_serve, ServeConfig, Server};
pub use state::{default_classifier, CacheScope, LiveWorld,
                PlacementCache, WorldCell, SERVE_SLOTS};
