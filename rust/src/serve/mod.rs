//! `hulk serve` — placement-as-a-service: a long-lived daemon that owns
//! one live fleet world and answers placement queries over a
//! length-prefixed JSON protocol, with sharded request batching (one
//! GCN forward per shard per batch window), per-shard placement caches
//! keyed on canonical workload digests, and live fleet updates through
//! the incremental graph seam published as epoch snapshots.
//!
//! - [`framing`]  — 4-byte big-endian length prefix + JSON payload;
//!   the recoverable-vs-fatal error taxonomy.
//! - [`protocol`] — `Place` / `Admin{Join,Fail,Revoke}` / `Stats` /
//!   `Shutdown` parsing, the typed error reply, and the canonical
//!   workload digest ([`PlaceRequest::digest`]) that both routes a
//!   request to its shard and keys that shard's cache.
//! - [`state`]    — [`LiveWorld`]: fleet + [`HierarchicalGraph`]
//!   mutated only through `apply_join`/`apply_failure` (never rebuilt)
//!   and epoch-stamped per mutation; [`WorldCell`], the
//!   clone-mutate-publish cell the request plane reads as `Arc`
//!   snapshots; [`PlacementCache`], the LRU reply cache whose
//!   [`CacheScope`] dies with every fleet mutation; and the
//!   deterministic `Place` reply builder.
//! - [`server`]   — accept loop, worker pool, and N batcher shards
//!   (`--shards`), each coalescing digest-routed `Place` requests onto
//!   its own shared [`GnnSplitter`] forward and its own cache.
//! - [`loadgen`]  — `hulk loadgen`: seeded request mixes with a
//!   `--repeat-mix` knob for cache-hit traffic, µs latency
//!   percentiles, `BENCH_serve.json`; connects retry with capped
//!   backoff and `--max-error-rate` turns the observed error rate
//!   into a CI gate.
//! - [`chaos`]    — `hulk chaos`: seeded fault scripts (correlated
//!   region outage, staggered revocation wave, WAN brownout/flap,
//!   join storm) injected through the admin surface of a *live*
//!   daemon, with recovery probing, a supervision proof (panic
//!   injection behind `--fault-injection`), and SLO rows
//!   (`serve/availability_pct`, `serve/error_rate`,
//!   `serve/recovery_ms`) in `BENCH_serve_chaos.json`.
//!
//! The contract the round-trip tests pin: replies are deterministic in
//! the world state (wall-clock lives only in metrics), so a batched
//! answer is byte-identical to the unbatched answer, a sharded+cached
//! answer is byte-identical to the single-shard uncached answer, and a
//! single served answer is byte-identical to calling the planner
//! directly on an equal world.
//!
//! Degradation ladder (chaos hardening, DESIGN.md §Degradation): a
//! healthy daemon answers everything; under overload it sheds at the
//! accept queue with typed `overloaded` replies; when the GCN path
//! cannot plan the surviving fleet it falls back to the oracle
//! splitter and flags the reply `degraded`; only when even that fails
//! does a request get a typed planning error. Worker/shard panics are
//! supervised-and-restarted (`worker_restarts`), never fatal.
//!
//! [`HierarchicalGraph`]: crate::graph::HierarchicalGraph
//! [`GnnSplitter`]: crate::gnn::GnnSplitter

pub mod chaos;
pub mod framing;
pub mod loadgen;
pub mod protocol;
pub mod server;
pub mod state;

pub use chaos::{run_chaos, ChaosConfig, ChaosReport, ChaosScript};
pub use framing::{read_frame, roundtrip, write_frame, FrameError,
                  MAX_FRAME};
pub use loadgen::{run_loadgen, LoadgenConfig, LoadgenReport};
pub use protocol::{error_reply, parse_request, AdminOp, PanicScope,
                   PlaceRequest, Request, MAX_WAN_FACTOR};
pub use server::{run_serve, ServeConfig, Server};
pub use state::{default_classifier, CacheScope, LiveWorld,
                PlacementCache, WorldCell, SERVE_SLOTS};
