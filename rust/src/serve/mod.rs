//! `hulk serve` — placement-as-a-service: a long-lived daemon that owns
//! one live fleet world and answers placement queries over a
//! length-prefixed JSON protocol, with request batching (one GCN
//! forward per batch window) and live fleet updates through the
//! incremental graph seam.
//!
//! - [`framing`]  — 4-byte big-endian length prefix + JSON payload;
//!   the recoverable-vs-fatal error taxonomy.
//! - [`protocol`] — `Place` / `Admin{Join,Fail,Revoke}` / `Stats` /
//!   `Shutdown` parsing and the typed error reply.
//! - [`state`]    — [`LiveWorld`]: fleet + [`HierarchicalGraph`]
//!   mutated only through `apply_join`/`apply_failure` (never rebuilt),
//!   and the deterministic `Place` reply builder.
//! - [`server`]   — accept loop, worker pool, and the batcher thread
//!   that coalesces concurrent `Place` requests onto one shared
//!   [`GnnSplitter`] forward (`HulkSplitterKind::SharedGnn`).
//! - [`loadgen`]  — `hulk loadgen`: seeded request mixes, µs latency
//!   percentiles, `BENCH_serve.json`.
//!
//! The contract the round-trip tests pin: replies are deterministic in
//! the world state (wall-clock lives only in metrics), so a batched
//! answer is byte-identical to the unbatched answer, and a single
//! served answer is byte-identical to calling the planner directly on
//! an equal world.
//!
//! [`HierarchicalGraph`]: crate::graph::HierarchicalGraph
//! [`GnnSplitter`]: crate::gnn::GnnSplitter

pub mod framing;
pub mod loadgen;
pub mod protocol;
pub mod server;
pub mod state;

pub use framing::{read_frame, roundtrip, write_frame, FrameError,
                  MAX_FRAME};
pub use loadgen::{run_loadgen, LoadgenConfig, LoadgenReport};
pub use protocol::{error_reply, parse_request, AdminOp, PlaceRequest,
                   Request};
pub use server::{run_serve, ServeConfig, Server};
pub use state::{default_classifier, LiveWorld, SERVE_SLOTS};
