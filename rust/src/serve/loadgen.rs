//! `hulk loadgen` — a seeded closed-loop load generator for the serve
//! daemon, reporting latency/throughput rows in the standard benchkit
//! shape (`BENCH_serve.json`).
//!
//! Request mix: each connection thread forks the seed and draws
//! workloads from the same seeded sampler the scenario generator uses
//! ([`sample_workload`]), budgeted by the daemon's actual fleet memory
//! (probed via `Stats` up front) — so the mix scales with whatever
//! fleet the daemon is serving. `--repeat-mix F` makes each request
//! resend an earlier workload from the connection's history with
//! probability `F` instead of drawing fresh — the knob that exercises
//! the daemon's placement cache (repeats share a digest, so they hash
//! to the same shard and hit its cache).
//!
//! Pacing is open-ish: each thread targets `rps / connections` and
//! sleeps to its schedule, but never skips a request — if the daemon
//! falls behind, measured throughput drops below the target instead of
//! silently thinning the load.
//!
//! Fault tolerance (chaos harness): connects — initial and mid-run
//! reconnects after a dropped connection — retry with capped
//! exponential backoff, and every failed attempt counts as an error,
//! so a flapping daemon's unavailability stays visible in the totals.
//! `--max-error-rate F` turns the observed `errors / (ok + errors)`
//! into a nonzero exit for CI gating.
//!
//! Reported rows:
//! - `serve/p50_place_us`, `serve/p99_place_us` — client-observed
//!   round-trip latency (includes the batch window by design: that is
//!   the price of coalescing).
//! - `serve/throughput_rps` — successful replies / wall-clock.
//! - `serve/batched_forward_speedup` — `place_requests / gcn_forwards`
//!   from the daemon's own counters: how many placements each GCN
//!   forward amortized over (1.0 = no coalescing benefit).
//! - `serve/cache_hit_rate` — `cache_hits / (cache_hits +
//!   cache_misses)` from the daemon's counters (0.0 when the cache is
//!   disabled or the mix never repeats).
//! - `serve/p50_cached_place_us`, `serve/p50_uncached_place_us` —
//!   shard-side handling time for hits vs misses (daemon histograms;
//!   excludes queue + batch-window wait so the pair isolates what the
//!   cache actually saves).

use std::net::TcpStream;
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::benchkit::{BenchEntry, BenchReport};
use crate::cli::Cli;
use crate::models::ModelSpec;
use crate::scenarios::sample_workload;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;

use super::framing::roundtrip;

/// Each connection remembers this many past workloads for
/// `--repeat-mix` resends (bounded so long runs don't grow without
/// limit — and a bounded pool keeps repeats actually repeating).
const REPEAT_HISTORY: usize = 64;

/// Connect attempts per [`connect_with_retry`] call; backoff doubles
/// from 10ms and caps at 160ms (~310ms worst case per call).
const CONNECT_ATTEMPTS: u32 = 6;

/// Connect with capped exponential backoff. Every failed attempt is
/// counted in `errors` — the client experienced it, so a flapping
/// daemon cannot launder unavailability through silent retries.
fn connect_with_retry(addr: &str, errors: &mut u64) -> Option<TcpStream> {
    for attempt in 0..CONNECT_ATTEMPTS {
        match TcpStream::connect(addr) {
            Ok(stream) => return Some(stream),
            Err(_) => {
                *errors += 1;
                if attempt + 1 < CONNECT_ATTEMPTS {
                    thread::sleep(Duration::from_millis(
                        10 << attempt.min(4)));
                }
            }
        }
    }
    None
}

/// Load-generator configuration (CLI: `hulk loadgen`).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub addr: String,
    pub rps: u64,
    pub duration_s: u64,
    pub seed: u64,
    /// Directory `BENCH_serve.json` is written to.
    pub out: PathBuf,
    /// `--systems` CSV forwarded in every Place request (`None` = the
    /// daemon default, hulk only).
    pub systems: Option<String>,
    /// Send `{"op":"shutdown"}` after the run (CI smoke uses this to
    /// stop the background daemon).
    pub shutdown: bool,
    /// Client connections; `0` = auto (scales with rps, capped at 8).
    pub connections: usize,
    /// Probability in `[0, 1]` that a request repeats an earlier
    /// workload from this connection instead of drawing fresh. `0.0`
    /// (default) keeps the all-fresh mix; higher values manufacture
    /// cache-hit traffic.
    pub repeat_mix: f64,
    /// `--max-error-rate`: if set, `run_loadgen` exits nonzero when
    /// `errors / (ok + errors)` exceeds it — the chaos-smoke SLO gate.
    /// `None` keeps the old behavior (only all-errors fails).
    pub max_error_rate: Option<f64>,
}

/// What one run measured; every field also lands in the JSON rows or
/// the stdout summary.
#[derive(Clone, Copy, Debug)]
pub struct LoadgenReport {
    pub sent: u64,
    pub ok: u64,
    pub errors: u64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub throughput_rps: f64,
    pub place_requests: f64,
    pub gcn_forwards: f64,
    pub batched_forward_speedup: f64,
    pub cache_hits: f64,
    pub cache_misses: f64,
    pub cache_hit_rate: f64,
    pub p50_cached_us: f64,
    pub p50_uncached_us: f64,
}

/// Drive the daemon at `config.addr` and write `BENCH_serve.json`.
pub fn run(config: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(config.rps >= 1, "--rps must be >= 1");
    anyhow::ensure!(config.duration_s >= 1, "--duration-s must be >= 1");
    anyhow::ensure!(
        (0.0..=1.0).contains(&config.repeat_mix),
        "--repeat-mix must be in [0, 1], got {}", config.repeat_mix);

    // Probe the daemon: fleet memory budgets the workload sampler.
    let stats = fetch_stats(&config.addr)?;
    let budget_gb = stats
        .get("fleet_memory_gb")
        .and_then(Json::as_f64)
        .context("stats reply missing fleet_memory_gb")?;

    let connections = if config.connections > 0 {
        config.connections
    } else {
        ((config.rps / 200) as usize + 1).min(8)
    };
    let interval =
        Duration::from_secs_f64(connections as f64 / config.rps as f64);
    let duration = Duration::from_secs(config.duration_s);
    let started = Instant::now();
    let mut handles = Vec::new();
    for c in 0..connections {
        let addr = config.addr.clone();
        let systems = config.systems.clone();
        let seed = config.seed;
        let repeat_mix = config.repeat_mix;
        handles.push(thread::spawn(move || -> (Vec<f64>, u64, u64) {
            let mut rng = Rng::new(seed ^ 0x4C4F_4144) // "LOAD"
                .fork(c as u64);
            let mut latencies = Vec::new();
            let (mut sent, mut errors) = (0u64, 0u64);
            let Some(mut stream) = connect_with_retry(&addr, &mut errors)
            else {
                return (Vec::new(), sent, errors);
            };
            let mut history: Vec<Vec<ModelSpec>> = Vec::new();
            let thread_start = Instant::now();
            let mut next = thread_start;
            while thread_start.elapsed() < duration {
                // Repeat an earlier workload (cache-hit traffic) or
                // draw fresh and remember it for later repeats.
                let workload = if !history.is_empty()
                    && rng.f64() < repeat_mix
                {
                    history[rng.below(history.len())].clone()
                } else {
                    let fresh = sample_workload(&mut rng, budget_gb);
                    if history.len() < REPEAT_HISTORY {
                        history.push(fresh.clone());
                    }
                    fresh
                };
                let request = place_request(&workload, systems.as_deref());
                let t0 = Instant::now();
                sent += 1;
                match roundtrip(&mut stream, request.as_bytes()) {
                    Ok(reply) if reply.starts_with(b"{\"ok\":true") => {
                        latencies.push(t0.elapsed().as_micros() as f64);
                    }
                    Ok(_) => errors += 1,
                    Err(_) => {
                        errors += 1;
                        // Connection gone — the daemon may be
                        // mid-recovery (restarted worker, brief accept
                        // stall). Reconnect with backoff instead of
                        // abandoning this thread's share of the load;
                        // only a daemon that stays down kills it.
                        match connect_with_retry(&addr, &mut errors) {
                            Some(s) => stream = s,
                            None => break,
                        }
                    }
                }
                next += interval;
                let now = Instant::now();
                if next > now {
                    thread::sleep(next - now);
                } else {
                    next = now; // behind schedule: don't burst to catch up
                }
            }
            (latencies, sent, errors)
        }));
    }

    let mut latencies = Vec::new();
    let (mut sent, mut errors) = (0u64, 0u64);
    for h in handles {
        let (lat, s, e) = h.join().expect("loadgen thread panicked");
        latencies.extend(lat);
        sent += s;
        errors += e;
    }
    let elapsed = started.elapsed().as_secs_f64();
    let ok = latencies.len() as u64;
    latencies.sort_by(f64::total_cmp);
    let (p50_us, p99_us) = if latencies.is_empty() {
        (0.0, 0.0)
    } else {
        (percentile_sorted(&latencies, 50.0),
         percentile_sorted(&latencies, 99.0))
    };
    let throughput_rps = ok as f64 / elapsed.max(1e-9);

    // The daemon's own counters give the coalescing ratio and the
    // cache economics (merged across shards in the stats reply).
    let stats = fetch_stats(&config.addr)?;
    let counter = |name: &str| -> f64 {
        stats
            .get("metrics")
            .and_then(|m| m.get("counters"))
            .and_then(|c| c.get(name))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let histogram_p50 = |name: &str| -> f64 {
        stats
            .get("metrics")
            .and_then(|m| m.get("histograms"))
            .and_then(|h| h.get(name))
            .and_then(|h| h.get("p50"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let place_requests = counter("place_requests");
    let gcn_forwards = counter("gcn_forwards");
    let batched_forward_speedup =
        place_requests / gcn_forwards.max(1.0);
    let cache_hits = counter("cache_hits");
    let cache_misses = counter("cache_misses");
    let cache_hit_rate =
        cache_hits / (cache_hits + cache_misses).max(1.0);
    let p50_cached_us = histogram_p50("place_cached_us");
    let p50_uncached_us = histogram_p50("place_uncached_us");

    if config.shutdown {
        let mut stream = TcpStream::connect(&config.addr)?;
        let _ = roundtrip(&mut stream, b"{\"op\":\"shutdown\"}");
    }

    let mut report = BenchReport::new("serve");
    report.push(BenchEntry::new("serve/p50_place_us", p50_us, "us"));
    report.push(BenchEntry::new("serve/p99_place_us", p99_us, "us"));
    report.push(BenchEntry::new("serve/throughput_rps", throughput_rps,
                                "req/s"));
    report.push(BenchEntry::new("serve/batched_forward_speedup",
                                batched_forward_speedup, "x"));
    report.push(BenchEntry::new("serve/cache_hit_rate", cache_hit_rate,
                                "ratio"));
    report.push(BenchEntry::new("serve/p50_cached_place_us",
                                p50_cached_us, "us"));
    report.push(BenchEntry::new("serve/p50_uncached_place_us",
                                p50_uncached_us, "us"));
    let path = report.write(&config.out)?;
    println!("wrote {} ({} entries)", path.display(),
             report.entries.len());

    Ok(LoadgenReport { sent, ok, errors, p50_us, p99_us,
                       throughput_rps, place_requests, gcn_forwards,
                       batched_forward_speedup, cache_hits,
                       cache_misses, cache_hit_rate, p50_cached_us,
                       p50_uncached_us })
}

fn fetch_stats(addr: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to hulk serve at {addr}"))?;
    let reply = roundtrip(&mut stream, b"{\"op\":\"stats\"}")
        .map_err(|e| anyhow::anyhow!("stats round-trip failed: {e:?}"))?;
    let text = String::from_utf8(reply)
        .context("stats reply is not UTF-8")?;
    Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("stats reply unparsable: {e}"))
}

/// Render one Place request for `workload` (always shipping explicit
/// batch sizes so the daemon replans exactly what the sampler drew).
/// Shared with the chaos harness's recovery probes.
pub(crate) fn place_request(workload: &[ModelSpec], systems: Option<&str>)
    -> String
{
    let mut req = Json::obj();
    req.set("op", Json::from("place"));
    let mut wl = Json::arr();
    for m in workload {
        let mut item = Json::obj();
        item.set("model", Json::from(m.slug()));
        item.set("batch", Json::from(m.batch));
        wl.push(item);
    }
    req.set("workload", wl);
    if let Some(csv) = systems {
        let mut arr = Json::arr();
        for s in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            arr.push(Json::from(s));
        }
        req.set("systems", arr);
    }
    req.render()
}

/// `hulk loadgen` CLI entry.
pub fn run_loadgen(cli: &Cli) -> Result<()> {
    let config = LoadgenConfig {
        addr: cli.flag("addr").unwrap_or("127.0.0.1:7711").to_string(),
        rps: cli.flag_u64("rps", 200)?,
        duration_s: cli.flag_u64("duration-s", 5)?,
        seed: cli.flag_u64("seed", 0)?,
        out: PathBuf::from(cli.flag("out").unwrap_or(".")),
        systems: cli.flag("systems").map(str::to_string),
        shutdown: cli.flag_bool("shutdown"),
        connections: cli.flag_u64("connections", 0)? as usize,
        repeat_mix: cli.flag_f64("repeat-mix", 0.0)?,
        max_error_rate: match cli.flag("max-error-rate") {
            Some(_) => Some(cli.flag_f64("max-error-rate", 0.0)?),
            None => None,
        },
    };
    let r = run(&config)?;
    println!(
        "loadgen: {} sent, {} ok, {} errors over {}s at target {} rps \
         ({} connections, repeat-mix {:.2})",
        r.sent, r.ok, r.errors, config.duration_s, config.rps,
        if config.connections > 0 {
            config.connections
        } else {
            ((config.rps / 200) as usize + 1).min(8)
        },
        config.repeat_mix);
    println!("  p50 {:.0}us  p99 {:.0}us  throughput {:.0} req/s",
             r.p50_us, r.p99_us, r.throughput_rps);
    println!("  daemon counters: {} placements / {} GCN forwards = \
              {:.1}x batched-forward amortization",
             r.place_requests, r.gcn_forwards, r.batched_forward_speedup);
    println!("  cache: {} hits / {} misses = {:.2} hit rate \
              (shard-side p50: {:.0}us cached vs {:.0}us uncached)",
             r.cache_hits, r.cache_misses, r.cache_hit_rate,
             r.p50_cached_us, r.p50_uncached_us);
    if r.ok == 0 {
        anyhow::bail!("loadgen got zero successful replies");
    }
    if let Some(max) = config.max_error_rate {
        anyhow::ensure!((0.0..=1.0).contains(&max),
                        "--max-error-rate must be in [0, 1], got {max}");
        let rate = r.errors as f64 / (r.ok + r.errors).max(1) as f64;
        println!("  error rate {rate:.4} (gate: <= {max})");
        anyhow::ensure!(
            rate <= max,
            "error rate {rate:.4} exceeds --max-error-rate {max}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn place_request_renders_slugs_batches_and_systems() {
        let wl = vec![ModelSpec::t5_11b(), ModelSpec::bert_large()];
        let req = place_request(&wl, Some("hulk, a"));
        let parsed = Json::parse(&req).unwrap();
        assert_eq!(parsed.get("op").and_then(Json::as_str), Some("place"));
        let items = parsed.get("workload").and_then(Json::as_arr).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].get("model").and_then(Json::as_str),
                   Some("t5_11b"));
        assert_eq!(items[0].get("batch").and_then(Json::as_usize),
                   Some(128));
        let systems = parsed.get("systems").and_then(Json::as_arr).unwrap();
        assert_eq!(systems.len(), 2);
        assert_eq!(systems[1].as_str(), Some("a"));
        // No systems field when not requested.
        let req = place_request(&wl, None);
        assert!(Json::parse(&req).unwrap().get("systems").is_none());
    }
}
