//! Length-prefixed frame I/O for the `hulk serve` wire protocol.
//!
//! Every message — request or reply — is one frame: a 4-byte big-endian
//! `u32` payload length followed by that many bytes of UTF-8 JSON. The
//! framing layer is deliberately dumb: it moves byte buffers and
//! classifies failures; what the bytes *mean* is [`super::protocol`]'s
//! job.
//!
//! Failure taxonomy (drives the daemon's keep-alive policy):
//! - A frame that *arrives* but doesn't parse (empty payload, bad UTF-8,
//!   malformed JSON, unknown op) is the client's problem, not the
//!   stream's — the daemon answers with a typed `Error` reply and keeps
//!   the connection open.
//! - [`FrameError::Oversized`] means the declared length exceeds
//!   [`MAX_FRAME`]. The payload is never read, so the stream position is
//!   no longer trustworthy: the daemon sends one `Error` reply and
//!   closes.
//! - [`FrameError::Closed`] / [`FrameError::Timeout`] / io errors are
//!   stream-fatal: close without a reply (there may be nobody listening,
//!   and a half-read frame can't be resynchronized anyway).

use std::io::{self, Read, Write};

/// Largest accepted payload (1 MiB). Wire requests are small (a Place
/// is a few hundred bytes); the cap exists so a corrupt or hostile
/// length prefix cannot make the daemon allocate gigabytes.
pub const MAX_FRAME: u32 = 1 << 20;

/// Why a frame could not be read. See the module docs for which
/// variants are recoverable (none — all four close the connection; the
/// recoverable failures are *parse* failures, which yield a frame).
#[derive(Debug)]
pub enum FrameError {
    /// Peer closed the stream mid-frame (clean EOF between frames is
    /// `Ok(None)`, not an error).
    Closed,
    /// The read timed out — a stalled client must not pin a worker.
    Timeout,
    /// Declared payload length exceeds [`MAX_FRAME`]; the stream is
    /// desynchronized from here on.
    Oversized(u32),
    Io(io::Error),
}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> FrameError {
        match e.kind() {
            io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
                FrameError::Timeout
            }
            _ => FrameError::Io(e),
        }
    }
}

/// Read one frame. `Ok(None)` is a clean end-of-stream (the peer closed
/// between frames); a zero-length frame is `Ok(Some(vec![]))` — it
/// arrives intact, so the protocol layer answers it with a typed error
/// instead of dropping the connection. Partial reads (TCP segmentation,
/// a client that writes the header and payload separately) are
/// reassembled here.
pub fn read_frame(stream: &mut impl Read)
    -> Result<Option<Vec<u8>>, FrameError>
{
    let mut header = [0u8; 4];
    let mut got = 0;
    while got < header.len() {
        match stream.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(FrameError::Closed),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_be_bytes(header);
    if len > MAX_FRAME {
        return Err(FrameError::Oversized(len));
    }
    let mut payload = vec![0u8; len as usize];
    let mut got = 0;
    while got < payload.len() {
        match stream.read(&mut payload[got..]) {
            Ok(0) => return Err(FrameError::Closed),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(payload))
}

/// Write one frame (length prefix + payload) and flush.
pub fn write_frame(stream: &mut impl Write, payload: &[u8])
    -> io::Result<()>
{
    debug_assert!(payload.len() as u64 <= MAX_FRAME as u64,
                  "daemon-built frames always fit MAX_FRAME");
    stream.write_all(&(payload.len() as u32).to_be_bytes())?;
    stream.write_all(payload)?;
    stream.flush()
}

/// Client-side convenience: send one frame, wait for the reply frame.
/// Used by `hulk loadgen` and the round-trip tests.
pub fn roundtrip(stream: &mut (impl Read + Write), payload: &[u8])
    -> Result<Vec<u8>, FrameError>
{
    write_frame(stream, payload)?;
    match read_frame(stream)? {
        Some(reply) => Ok(reply),
        None => Err(FrameError::Closed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip_through_a_buffer() {
        let mut buf = Cursor::new(Vec::new());
        write_frame(&mut buf, b"{\"op\":\"stats\"}").unwrap();
        write_frame(&mut buf, b"").unwrap();
        buf.set_position(0);
        assert_eq!(read_frame(&mut buf).unwrap().unwrap(),
                   b"{\"op\":\"stats\"}");
        // Zero-length frames arrive intact (protocol-level error, not
        // a framing error).
        assert_eq!(read_frame(&mut buf).unwrap().unwrap(), b"");
        // Clean EOF between frames.
        assert!(read_frame(&mut buf).unwrap().is_none());
    }

    #[test]
    fn oversized_header_is_rejected_without_allocating() {
        let mut bytes = (MAX_FRAME + 1).to_be_bytes().to_vec();
        bytes.extend_from_slice(b"xx");
        let mut buf = Cursor::new(bytes);
        match read_frame(&mut buf) {
            Err(FrameError::Oversized(n)) => assert_eq!(n, MAX_FRAME + 1),
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn truncated_header_and_payload_are_closed_not_panics() {
        // Two bytes of a four-byte header.
        let mut buf = Cursor::new(vec![0u8, 0]);
        assert!(matches!(read_frame(&mut buf), Err(FrameError::Closed)));
        // Full header declaring 8 bytes, only 3 present.
        let mut bytes = 8u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(b"abc");
        let mut buf = Cursor::new(bytes);
        assert!(matches!(read_frame(&mut buf), Err(FrameError::Closed)));
    }
}
