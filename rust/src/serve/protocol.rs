//! The `hulk serve` wire protocol: JSON request parsing and typed
//! replies.
//!
//! A request is one JSON object with an `"op"` field:
//!
//! | op         | fields                                               |
//! |------------|------------------------------------------------------|
//! | `place`    | `workload`: `[{"model": slug, "batch"?: N}]`, `systems`?: `[slug]` (default `["hulk"]`) |
//! | `admin`    | `action`: `join` (`region`, `gpu`, `n_gpus`) \| `fail` / `revoke` (`machine`) \| `fail_region` (`region`) \| `wan` (`factor`) \| `panic` (`scope`) |
//! | `stats`    | —                                                    |
//! | `shutdown` | —                                                    |
//!
//! `fail_region` is the chaos harness's correlated-outage injection
//! (every alive machine of the region dies in one epoch); `wan` swaps
//! in a degraded WAN multiplier (`factor` ≥ 1, `1.0` restores the
//! pristine matrix); `panic` deliberately crashes one worker or
//! batcher shard to exercise supervision and is refused unless the
//! daemon was started with `--fault-injection`.
//!
//! Model slugs come from [`ModelSpec::slug`]; region and GPU names are
//! the display names `hulk info` prints. Every parse failure is a
//! `String` the daemon wraps into the typed error reply
//! ([`error_reply`]) — the connection stays open, the daemon never
//! panics on wire input.

use crate::cluster::{GpuModel, Region};
use crate::models::ModelSpec;
use crate::util::json::Json;

/// One parsed request.
#[derive(Clone, Debug)]
pub enum Request {
    Place(PlaceRequest),
    Admin(AdminOp),
    Stats,
    Shutdown,
}

/// A placement query. The workload is already canonicalized
/// ([`ModelSpec::sort_largest_first`]) so `PlanContext` accepts it
/// as-is and task indices in the reply follow canonical order.
#[derive(Clone, Debug)]
pub struct PlaceRequest {
    pub workload: Vec<ModelSpec>,
    /// Planner slugs to answer with, catalog order (the registry
    /// resolves shorthand like `a` for `system_a`).
    pub systems: Vec<String>,
}

impl PlaceRequest {
    /// Canonical request digest: FNV-1a over the (already
    /// largest-first-sorted) workload's `(slug, batch)` pairs and the
    /// systems list, with separators so field boundaries can't alias.
    ///
    /// Two requests digest equal iff they plan identically against any
    /// given world, which is what makes the digest double duty safe:
    /// it is both the shard-routing hash (identical workloads land on
    /// the same batcher shard) and the placement-cache key (a hit
    /// returns the byte-identical reply the planner would render).
    pub fn digest(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        for m in &self.workload {
            eat(m.slug().as_bytes());
            eat(&[0x00]);
            eat(&(m.batch as u64).to_le_bytes());
        }
        eat(&[0xff]);
        for s in &self.systems {
            eat(s.as_bytes());
            eat(&[0x00]);
        }
        h
    }
}

/// A live fleet mutation. `Revoke` is a spot-instance revocation —
/// operationally identical to `Fail` (the machine keeps its id, drops
/// out of every weight and pool), tracked under its own counter.
/// `FailRegion` and `Wan` are the chaos harness's correlated-outage
/// and link-brownout injections; `Panic` is supervised-crash fault
/// injection (worker/shard scope), gated behind `--fault-injection`.
#[derive(Clone, Copy, Debug)]
pub enum AdminOp {
    Join { region: Region, gpu: GpuModel, n_gpus: usize },
    Fail { machine: usize },
    Revoke { machine: usize },
    FailRegion { region: Region },
    Wan { factor: f64 },
    Panic { scope: PanicScope },
}

/// Which thread class a `panic` admin op crashes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PanicScope {
    /// The worker thread handling the connection (after the reply is
    /// written, so the injector sees an acknowledgment).
    Worker,
    /// A batcher shard (a poison job makes the shard loop panic
    /// mid-batch).
    Shard,
}

/// Ceiling for the `wan` admin op's degradation factor — large enough
/// for any brownout sweep, small enough that a typo (`factor: 4000`)
/// is a typed error instead of an unplannable world.
pub const MAX_WAN_FACTOR: f64 = 64.0;

/// Largest `n_gpus` a join may claim (matches the synthetic fleet
/// generator's ceiling; a typo like `n_gpus: 80000` should be a typed
/// error, not a fleet-distorting machine).
pub const MAX_JOIN_GPUS: usize = 64;

/// Parse one frame payload into a [`Request`]. Every failure mode —
/// empty frame, bad UTF-8, malformed JSON, missing/unknown fields —
/// returns a message for [`error_reply`].
pub fn parse_request(payload: &[u8]) -> Result<Request, String> {
    if payload.is_empty() {
        return Err("empty frame (a request is a JSON object with an \
                    \"op\" field)".to_string());
    }
    let text = std::str::from_utf8(payload)
        .map_err(|_| "frame payload is not UTF-8".to_string())?;
    let json = Json::parse(text).map_err(|e| format!("malformed JSON: {e}"))?;
    let op = json
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| "request needs a string \"op\" field \
                        (place|admin|stats|shutdown)".to_string())?;
    match op {
        "place" => parse_place(&json).map(Request::Place),
        "admin" => parse_admin(&json).map(Request::Admin),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(format!(
            "unknown op {other:?} (place|admin|stats|shutdown)")),
    }
}

fn parse_place(json: &Json) -> Result<PlaceRequest, String> {
    let items = json
        .get("workload")
        .and_then(Json::as_arr)
        .ok_or_else(|| "place needs a \"workload\" array of \
                        {\"model\": slug} items".to_string())?;
    if items.is_empty() {
        return Err("\"workload\" must not be empty".to_string());
    }
    let mut workload = Vec::with_capacity(items.len());
    for item in items {
        let slug = item
            .get("model")
            .and_then(Json::as_str)
            .ok_or_else(|| "every workload item needs a string \
                            \"model\" slug".to_string())?;
        let mut spec = ModelSpec::from_slug(slug).ok_or_else(|| {
            let known: Vec<&str> =
                ModelSpec::paper_six().iter().map(|m| m.slug()).collect();
            format!("unknown model slug {slug:?} (known: {})",
                    known.join(", "))
        })?;
        if let Some(batch) = item.get("batch") {
            let b = batch.as_usize().ok_or_else(|| {
                format!("\"batch\" for {slug} must be a positive integer")
            })?;
            if b == 0 {
                return Err(format!("\"batch\" for {slug} must be >= 1"));
            }
            spec.batch = b;
        }
        workload.push(spec);
    }
    ModelSpec::sort_largest_first(&mut workload);
    let systems = match json.get("systems") {
        None => vec!["hulk".to_string()],
        Some(v) => {
            let arr = v.as_arr().ok_or_else(|| {
                "\"systems\" must be an array of planner slugs".to_string()
            })?;
            if arr.is_empty() {
                return Err("\"systems\" must not be empty".to_string());
            }
            arr.iter()
                .map(|s| {
                    s.as_str().map(str::to_string).ok_or_else(|| {
                        "\"systems\" entries must be strings".to_string()
                    })
                })
                .collect::<Result<Vec<String>, String>>()?
        }
    };
    Ok(PlaceRequest { workload, systems })
}

fn parse_admin(json: &Json) -> Result<AdminOp, String> {
    let action = json
        .get("action")
        .and_then(Json::as_str)
        .ok_or_else(|| "admin needs a string \"action\" field \
                        (join|fail|revoke|fail_region|wan|panic)"
                        .to_string())?;
    match action {
        "join" => {
            let region = parse_region(
                json.get("region").and_then(Json::as_str).ok_or_else(
                    || "join needs a \"region\" name".to_string())?)?;
            let gpu = parse_gpu(
                json.get("gpu").and_then(Json::as_str).ok_or_else(
                    || "join needs a \"gpu\" name".to_string())?)?;
            let n_gpus = json
                .get("n_gpus")
                .and_then(Json::as_usize)
                .ok_or_else(|| "join needs a positive integer \
                                \"n_gpus\"".to_string())?;
            if n_gpus == 0 || n_gpus > MAX_JOIN_GPUS {
                return Err(format!(
                    "\"n_gpus\" must be in 1..={MAX_JOIN_GPUS}, \
                     got {n_gpus}"));
            }
            Ok(AdminOp::Join { region, gpu, n_gpus })
        }
        "fail" | "revoke" => {
            let machine = json
                .get("machine")
                .and_then(Json::as_usize)
                .ok_or_else(|| format!(
                    "{action} needs a non-negative integer \"machine\""))?;
            Ok(if action == "fail" {
                AdminOp::Fail { machine }
            } else {
                AdminOp::Revoke { machine }
            })
        }
        "fail_region" => {
            let region = parse_region(
                json.get("region").and_then(Json::as_str).ok_or_else(
                    || "fail_region needs a \"region\" name".to_string())?)?;
            Ok(AdminOp::FailRegion { region })
        }
        "wan" => {
            let factor = json
                .get("factor")
                .and_then(Json::as_f64)
                .ok_or_else(|| "wan needs a numeric \"factor\"".to_string())?;
            if !factor.is_finite() || factor < 1.0
                || factor > MAX_WAN_FACTOR
            {
                return Err(format!(
                    "\"factor\" must be in 1.0..={MAX_WAN_FACTOR}, \
                     got {factor}"));
            }
            Ok(AdminOp::Wan { factor })
        }
        "panic" => {
            let scope = match json.get("scope").and_then(Json::as_str) {
                Some("worker") => PanicScope::Worker,
                Some("shard") => PanicScope::Shard,
                _ => return Err("panic needs a \"scope\" of \
                                 \"worker\" or \"shard\"".to_string()),
            };
            Ok(AdminOp::Panic { scope })
        }
        other => Err(format!(
            "unknown admin action {other:?} \
             (join|fail|revoke|fail_region|wan|panic)")),
    }
}

fn parse_region(name: &str) -> Result<Region, String> {
    Region::ALL
        .iter()
        .copied()
        .find(|r| r.name() == name)
        .ok_or_else(|| {
            let known: Vec<&str> =
                Region::ALL.iter().map(|r| r.name()).collect();
            format!("unknown region {name:?} (known: {})", known.join(", "))
        })
}

fn parse_gpu(name: &str) -> Result<GpuModel, String> {
    GpuModel::ALL
        .iter()
        .copied()
        .find(|g| g.name() == name)
        .ok_or_else(|| {
            let known: Vec<&str> =
                GpuModel::ALL.iter().map(|g| g.name()).collect();
            format!("unknown gpu {name:?} (known: {})", known.join(", "))
        })
}

/// The typed error reply: `{"ok":false,"error":"…"}`. Receiving one
/// means the *request* was bad or declined — the connection is still
/// usable unless the error was framing-fatal (oversized frame).
pub fn error_reply(msg: &str) -> String {
    let mut obj = Json::obj();
    obj.set("ok", Json::Bool(false));
    obj.set("error", Json::from(msg));
    obj.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Request, String> {
        parse_request(s.as_bytes())
    }

    #[test]
    fn place_parses_sorts_and_defaults_systems() {
        let req = parse(r#"{"op":"place","workload":[
            {"model":"bert_large"},{"model":"t5_11b","batch":32}]}"#)
            .unwrap();
        let Request::Place(p) = req else { panic!("expected place") };
        // Canonical order: largest model first.
        assert_eq!(p.workload[0].slug(), "t5_11b");
        assert_eq!(p.workload[0].batch, 32);
        assert_eq!(p.workload[1].slug(), "bert_large");
        assert_eq!(p.systems, vec!["hulk"]);
    }

    #[test]
    fn admin_ops_parse_by_display_name() {
        let region = Region::ALL[0].name();
        let gpu = GpuModel::ALL[0].name();
        let req = parse(&format!(
            r#"{{"op":"admin","action":"join","region":"{region}",
                 "gpu":"{gpu}","n_gpus":8}}"#)).unwrap();
        assert!(matches!(req, Request::Admin(AdminOp::Join {
            n_gpus: 8, .. })));
        let req = parse(r#"{"op":"admin","action":"fail","machine":3}"#)
            .unwrap();
        assert!(matches!(req,
            Request::Admin(AdminOp::Fail { machine: 3 })));
        let req = parse(r#"{"op":"admin","action":"revoke","machine":0}"#)
            .unwrap();
        assert!(matches!(req,
            Request::Admin(AdminOp::Revoke { machine: 0 })));
    }

    #[test]
    fn chaos_admin_ops_parse_and_validate() {
        let region = Region::ALL[2].name();
        let req = parse(&format!(
            r#"{{"op":"admin","action":"fail_region","region":"{region}"}}"#))
            .unwrap();
        assert!(matches!(req,
            Request::Admin(AdminOp::FailRegion { .. })));
        let req = parse(r#"{"op":"admin","action":"wan","factor":4.5}"#)
            .unwrap();
        let Request::Admin(AdminOp::Wan { factor }) = req else {
            panic!("expected wan op")
        };
        assert_eq!(factor, 4.5);
        // factor 1.0 (restore) is legal.
        assert!(parse(r#"{"op":"admin","action":"wan","factor":1.0}"#)
                    .is_ok());
        let req = parse(r#"{"op":"admin","action":"panic",
                            "scope":"worker"}"#).unwrap();
        assert!(matches!(req, Request::Admin(AdminOp::Panic {
            scope: PanicScope::Worker })));
        let req = parse(r#"{"op":"admin","action":"panic",
                            "scope":"shard"}"#).unwrap();
        assert!(matches!(req, Request::Admin(AdminOp::Panic {
            scope: PanicScope::Shard })));
        // Out-of-range, missing, and malformed chaos fields are typed
        // errors.
        for (payload, needle) in [
            (r#"{"op":"admin","action":"wan","factor":0.5}"#, "factor"),
            (r#"{"op":"admin","action":"wan","factor":1000}"#, "factor"),
            (r#"{"op":"admin","action":"wan"}"#, "factor"),
            (r#"{"op":"admin","action":"fail_region"}"#, "region"),
            (r#"{"op":"admin","action":"fail_region",
                 "region":"Atlantis"}"#, "unknown region"),
            (r#"{"op":"admin","action":"panic"}"#, "scope"),
            (r#"{"op":"admin","action":"panic","scope":"daemon"}"#,
             "scope"),
        ] {
            let err = parse(payload).unwrap_err();
            assert!(err.contains(needle),
                    "payload {payload:?}: error {err:?} missing {needle:?}");
        }
    }

    #[test]
    fn malformed_requests_are_typed_errors_not_panics() {
        for (payload, needle) in [
            ("", "empty frame"),
            ("{", "malformed JSON"),
            ("[1,2]", "\"op\""),
            (r#"{"op":"warp"}"#, "unknown op"),
            (r#"{"op":"place"}"#, "\"workload\""),
            (r#"{"op":"place","workload":[]}"#, "must not be empty"),
            (r#"{"op":"place","workload":[{"model":"gpt5"}]}"#,
             "unknown model slug"),
            (r#"{"op":"place","workload":[{"model":"t5_11b",
                "batch":0}]}"#, "batch"),
            (r#"{"op":"place","workload":[{"model":"t5_11b"}],
                "systems":[]}"#, "must not be empty"),
            (r#"{"op":"admin","action":"evict","machine":1}"#,
             "unknown admin action"),
            (r#"{"op":"admin","action":"fail"}"#, "\"machine\""),
            (r#"{"op":"admin","action":"fail","machine":-1}"#,
             "\"machine\""),
            (r#"{"op":"admin","action":"join","region":"Atlantis",
                "gpu":"NVIDIA A100","n_gpus":8}"#, "unknown region"),
            (r#"{"op":"admin","action":"join","region":"Atlantis"}"#,
             "unknown region"),
        ] {
            let err = parse(payload).unwrap_err();
            assert!(err.contains(needle),
                    "payload {payload:?}: error {err:?} missing {needle:?}");
        }
        // Non-UTF-8 payloads likewise.
        let err = parse_request(&[0xff, 0xfe, 0x00]).unwrap_err();
        assert!(err.contains("UTF-8"), "{err}");
    }

    #[test]
    fn digest_separates_workload_batch_and_systems() {
        let place = |json: &str| -> PlaceRequest {
            let Ok(Request::Place(p)) = parse_request(json.as_bytes())
            else { panic!("fixture parses: {json}") };
            p
        };
        let base = place(r#"{"op":"place","workload":[
            {"model":"bert_large"},{"model":"t5_11b","batch":32}]}"#);
        // Same request (even written in the other order — the parser
        // canonicalizes) digests the same.
        let reordered = place(r#"{"op":"place","workload":[
            {"model":"t5_11b","batch":32},{"model":"bert_large"}]}"#);
        assert_eq!(base.digest(), reordered.digest());
        // Different batch, different systems, different workload: all
        // distinct digests.
        let batch = place(r#"{"op":"place","workload":[
            {"model":"bert_large"},{"model":"t5_11b","batch":64}]}"#);
        let systems = place(r#"{"op":"place","workload":[
            {"model":"bert_large"},{"model":"t5_11b","batch":32}],
            "systems":["hulk","a"]}"#);
        let workload = place(r#"{"op":"place","workload":[
            {"model":"t5_11b","batch":32}]}"#);
        assert_ne!(base.digest(), batch.digest());
        assert_ne!(base.digest(), systems.digest());
        assert_ne!(base.digest(), workload.digest());
    }

    #[test]
    fn error_reply_is_valid_json() {
        let reply = error_reply("bad \"quoted\" thing");
        let parsed = Json::parse(&reply).unwrap();
        assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(parsed.get("error").and_then(Json::as_str),
                   Some("bad \"quoted\" thing"));
    }
}
