//! The daemon's live world: a fleet and its hierarchical graph kept in
//! lockstep, mutated **only** through the incremental graph-update
//! seam, published to the request plane as immutable epoch snapshots.
//!
//! Ownership: the current world lives inside a [`WorldCell`] as an
//! `Arc<LiveWorld>`. `Place` and `Stats` requests take a
//! [`snapshot`](WorldCell::snapshot) — an `Arc` clone, never a lock
//! held across planning — while `Admin` requests go through
//! [`mutate`](WorldCell::mutate): clone the current world, apply the
//! join/failure, publish the clone as the next epoch. A batcher shard
//! mid-plan keeps its old snapshot alive through the `Arc`, so admin
//! mutations never stall the request plane and readers never observe a
//! half-applied mutation.
//!
//! There is no rebuild path — joins and failures go through
//! [`HierarchicalGraph::apply_join`] / [`apply_failure`]
//! (coarse-level-only rebuilds), and [`LiveWorld::dense_rebuilds`]
//! stays 0 by construction. The `Stats` reply exposes both the counter
//! and [`max_dense_n`] so tests and operators can verify no admin
//! mutation ever paid an O(n²) dense-oracle rebuild.
//!
//! The fleet grows in lockstep with the graph: a join appends to *both*
//! ([`Fleet::add_machine`] and `apply_join` hand out the same dense id),
//! because placement pricing ([`Placement::cost`]) and validation index
//! `fleet.machines` directly — a graph-only join would panic the first
//! time a placement lands on the new machine.
//!
//! [`PlacementCache`] closes the loop: rendered `Place` replies keyed
//! on the canonical workload digest, scoped to one
//! `(epoch, graph memo key)` generation. Every successful mutation
//! bumps [`LiveWorld::epoch`], so a cached placement can never outlive
//! the world it was planned against — stale entries are cleared on the
//! first lookup under the new scope, before anything can be served.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cluster::{Fleet, GpuModel, Region, WanModel};
use crate::gnn::{Classifier, GnnSplitter, RefGcn, RefGcnConfig};
use crate::graph::{GraphView, HierarchicalGraph, FEATURE_DIM};
use crate::planner::{CostBackend, HulkSplitterKind, Placement,
                     PlanContext, PlannerKind, PlannerRegistry};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::protocol::{error_reply, PlaceRequest, MAX_WAN_FACTOR};

/// Padded GCN slot count for the serving classifier: room for the
/// 220-machine planet fleet plus live joins (the daemon declines joins
/// past this, with a typed error).
pub const SERVE_SLOTS: usize = 384;

/// The serving classifier: the pure-Rust reference GCN at
/// [`SERVE_SLOTS`] slots with seeded weights — same construction as the
/// `bench micro` planet classifier, so serve latencies and micro rows
/// measure the same forward.
pub fn default_classifier(seed: u64) -> (Classifier, Vec<f32>) {
    let cfg = RefGcnConfig { n: SERVE_SLOTS, f: FEATURE_DIM,
                             h: 64, h2: 32, c: 8 };
    let mut rng = Rng::new(seed ^ 0x4743_4E21); // "GCN!"
    let params: Vec<f32> = (0..cfg.n_params())
        .map(|_| (rng.normal() * 0.1) as f32)
        .collect();
    (Classifier::Reference(RefGcn::new(cfg, &params)), params)
}

/// The daemon's mutable world. See the module docs for the ownership
/// and lockstep invariants. `Clone` is the mutation primitive: the
/// [`WorldCell`] clones the published world, mutates the clone, and
/// publishes it as the next epoch (a 220-machine clone is a few small
/// vectors — cheap at admin rates).
#[derive(Clone)]
pub struct LiveWorld {
    /// Grows on `Join`; never shrinks (failed machines keep their id —
    /// jitter stability, and placements must stay indexable).
    pub fleet: Fleet,
    /// The plan graph *and* the mutation seam: alive mask, joined
    /// machines, coarse level. All planning goes through it.
    pub hier: HierarchicalGraph,
    backend: CostBackend,
    slots: usize,
    /// The pristine WAN matrix from construction — `wan` admin ops
    /// always scale *this*, never the current matrix, so brownout
    /// factors replace each other instead of compounding and
    /// `factor: 1.0` restores the exact original latencies.
    base_wan: WanModel,
    /// The currently applied degradation factor (1.0 = healthy).
    wan_factor: f64,
    /// Bumped by every *successful* mutation (`join`/`fail`/
    /// `fail_region`/`wan`) — the scope token placement caches and
    /// stats key on. Declined mutations (capacity, double-fail) leave
    /// it unchanged, so they invalidate nothing.
    epoch: u64,
    /// World rebuilds from scratch. No code path increments it — the
    /// field exists so the `Stats` reply can prove that, and so any
    /// future rebuild path has to show up in the serve round-trip test.
    pub dense_rebuilds: u64,
}

impl LiveWorld {
    pub fn new(fleet: Fleet, backend: CostBackend, slots: usize)
        -> Result<LiveWorld, String>
    {
        if fleet.len() > slots {
            return Err(format!(
                "fleet of {} machines exceeds the classifier's {slots} \
                 slots", fleet.len()));
        }
        let hier = HierarchicalGraph::from_fleet(Arc::new(fleet.clone()));
        let base_wan = fleet.wan.clone();
        Ok(LiveWorld { fleet, hier, backend, slots, base_wan,
                       wan_factor: 1.0, epoch: 0, dense_rebuilds: 0 })
    }

    /// The serving default: the planet_scale synthetic fleet
    /// (220 machines, 12 regions) under [`SERVE_SLOTS`].
    pub fn planet(seed: u64, backend: CostBackend) -> LiveWorld {
        LiveWorld::new(Fleet::synthetic(220, 12, seed), backend,
                       SERVE_SLOTS)
            .expect("220 machines fit 384 slots")
    }

    /// The graph identity a batcher shard keys its shared splitter on —
    /// changes on every admin mutation *and* on every world clone (the
    /// coarse adjacency reallocates), so a stale forward can never
    /// serve a different world generation.
    pub fn graph_key(&self) -> (usize, usize) {
        self.hier.memo_key()
    }

    /// Monotone world generation: 0 at construction, +1 per successful
    /// mutation. See the field docs.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The token one [`PlacementCache`] generation is scoped to.
    pub fn cache_scope(&self) -> CacheScope {
        (self.epoch, self.graph_key())
    }

    pub fn alive_machines(&self) -> usize {
        (0..self.fleet.len())
            .filter(|&m| self.hier.is_alive(m))
            .count()
    }

    /// Scale-out: append to fleet and graph in lockstep. Declined (not
    /// panicked) past classifier capacity.
    pub fn join(&mut self, region: Region, gpu: GpuModel, n_gpus: usize)
        -> Result<usize, String>
    {
        if self.fleet.len() >= self.slots {
            return Err(format!(
                "fleet is at classifier capacity ({} slots); join \
                 declined", self.slots));
        }
        let id = self.fleet.add_machine(region, gpu, n_gpus);
        let hier_id = self.hier.apply_join(region, gpu, n_gpus);
        assert_eq!(id, hier_id, "fleet and graph must stay in lockstep");
        self.epoch += 1;
        Ok(id)
    }

    /// Failure / spot revocation: the machine keeps its id but drops
    /// out of every edge weight and planning pool. Pre-validated so
    /// wire input can never hit `apply_failure`'s alive assertion.
    pub fn fail(&mut self, machine: usize) -> Result<(), String> {
        if machine >= self.fleet.len() {
            return Err(format!(
                "machine {machine} out of range (fleet has machines \
                 0..{})", self.fleet.len()));
        }
        if !self.hier.is_alive(machine) {
            return Err(format!("machine {machine} already failed"));
        }
        self.hier.apply_failure(machine);
        self.epoch += 1;
        Ok(())
    }

    /// Correlated regional outage: every alive machine of `region` dies
    /// in **one** epoch (one cache invalidation, one snapshot swap —
    /// readers never observe a half-dead region). Returns the failed
    /// ids. Declined if the region has no alive machines, or if the
    /// outage would leave the daemon with nothing to plan on.
    pub fn fail_region(&mut self, region: Region)
        -> Result<Vec<usize>, String>
    {
        let doomed: Vec<usize> = (0..self.fleet.len())
            .filter(|&m| self.hier.is_alive(m)
                         && self.hier.machine(m).region == region)
            .collect();
        if doomed.is_empty() {
            return Err(format!(
                "no alive machines in region {:?}", region.name()));
        }
        if doomed.len() == self.alive_machines() {
            return Err(format!(
                "failing region {:?} would kill every alive machine; \
                 declined", region.name()));
        }
        for &m in &doomed {
            self.hier.apply_failure(m);
        }
        self.epoch += 1;
        Ok(doomed)
    }

    /// Link brownout / flap: swap in `base_wan` scaled by `factor`
    /// (inter-region latencies only; `1.0` restores the pristine
    /// matrix bit-for-bit, so a restored world plans byte-identically
    /// to one that never browned out). Fleet and graph swap in
    /// lockstep — pricing reads `fleet.wan`, planning reads the
    /// graph's copy. Declined when the factor is already applied (a
    /// no-op must not invalidate caches).
    pub fn set_wan_factor(&mut self, factor: f64) -> Result<f64, String> {
        if !factor.is_finite() || !(1.0..=MAX_WAN_FACTOR).contains(&factor)
        {
            return Err(format!(
                "wan factor must be in 1.0..={MAX_WAN_FACTOR}, \
                 got {factor}"));
        }
        if factor == self.wan_factor {
            return Err(format!("wan factor is already {factor}"));
        }
        let wan = self.base_wan.scaled(factor);
        self.fleet.wan = wan.clone();
        self.hier.apply_wan(wan);
        self.wan_factor = factor;
        self.epoch += 1;
        Ok(factor)
    }

    /// The currently applied WAN degradation factor (1.0 = healthy).
    pub fn wan_factor(&self) -> f64 {
        self.wan_factor
    }

    /// Answer one `Place` request: plan the workload with every
    /// requested system and render the reply.
    ///
    /// The reply is **deterministic in the world state** — placements,
    /// digests and predicted per-iteration costs, never wall-clock —
    /// which is what makes "batched and unbatched answers are
    /// byte-identical" a testable contract. `splitter` is the caller's
    /// (possibly batch-shared) forward-pass memo; a batch of requests
    /// against one frozen world pays one GCN forward total.
    pub fn plan_place(&self, req: &PlaceRequest, splitter: &GnnSplitter)
        -> String
    {
        match self.place_json(req, splitter) {
            Ok(reply) => reply.render(),
            Err(msg) => error_reply(&msg),
        }
    }

    fn place_json(&self, req: &PlaceRequest, splitter: &GnnSplitter)
        -> Result<Json, String>
    {
        let max_tasks = splitter.classifier.n_classes();
        if req.workload.len() > max_tasks {
            return Err(format!(
                "workload has {} tasks but the classifier supports at \
                 most {max_tasks}", req.workload.len()));
        }
        let registry = PlannerRegistry::resolve(&req.systems.join(","))
            .map_err(|e| e.to_string())?;
        let mut results = Json::arr();
        let mut any_degraded = false;
        for planner in registry.iter() {
            let ctx = PlanContext::new(
                &self.fleet, &self.hier, &req.workload,
                HulkSplitterKind::SharedGnn { splitter })
                .with_backend(self.backend)
                .with_hier(&self.hier);
            let mut entry = Json::obj();
            entry.set("system", Json::from(planner.slug()));
            // Degraded-mode rung: only the full Hulk planner consults
            // the shared GCN forward, so only it has an oracle path to
            // fall back to when that forward fails (or grouped the
            // surviving fleet unplannably). Everything else keeps its
            // plain decline.
            let (planned, degraded) = plan_or_degrade(
                planner.plan(&ctx),
                || {
                    anyhow::ensure!(
                        matches!(planner.kind(), PlannerKind::Hulk),
                        "no oracle fallback for {}", planner.slug());
                    let oracle_ctx = PlanContext::new(
                        &self.fleet, &self.hier, &req.workload,
                        HulkSplitterKind::Oracle)
                        .with_backend(self.backend)
                        .with_hier(&self.hier);
                    planner.plan(&oracle_ctx)
                });
            match planned {
                Ok(placement) => {
                    placement
                        .validate_machines(&self.fleet)
                        .map_err(|e| format!(
                            "{} produced an invalid placement: {e}",
                            planner.slug()))?;
                    let summary = placement.summary(&self.fleet);
                    let priced = planner.price(&ctx, &placement);
                    entry.set("ok", Json::Bool(true));
                    entry.set("groups", Json::from(summary.groups));
                    entry.set("stages", Json::from(summary.stages));
                    entry.set("cross_region_edges",
                              Json::from(summary.cross_region_edges));
                    let mut tasks = Json::arr();
                    for (t, model) in req.workload.iter().enumerate() {
                        let cost = &priced.per_task[t];
                        let mut tj = Json::obj();
                        tj.set("model", Json::from(model.slug()));
                        let mut machines = Json::arr();
                        for &m in placement.machines(t) {
                            machines.push(Json::from(m));
                        }
                        tj.set("machines", machines);
                        tj.set("comm_ms", Json::from(cost.comm_ms));
                        tj.set("comp_ms", Json::from(cost.comp_ms));
                        tj.set("total_ms", Json::from(cost.total_ms()));
                        tasks.push(tj);
                    }
                    entry.set("tasks", tasks);
                    if degraded {
                        entry.set("degraded", Json::Bool(true));
                        any_degraded = true;
                    }
                }
                Err(e) => {
                    // A planner declining (infeasible workload, empty
                    // pool) is a per-system answer, not a request
                    // failure — other systems still reply.
                    entry.set("ok", Json::Bool(false));
                    entry.set("error", Json::from(e.to_string().as_str()));
                }
            }
            results.push(entry);
        }
        let mut reply = Json::obj();
        reply.set("ok", Json::Bool(true));
        reply.set("type", Json::from("place"));
        reply.set("results", results);
        if any_degraded {
            reply.set("degraded", Json::Bool(true));
        }
        Ok(reply)
    }
}

/// The degraded-planning decision, factored out so the ladder rung is
/// testable without a failing classifier in hand: a primary plan that
/// succeeded is served as-is (`degraded = false`, fallback never runs —
/// the healthy path stays byte-identical); a failed primary retries
/// through `fallback`, and only a fallback that actually served flags
/// `degraded`. If both fail, the *primary* error is reported (it names
/// the real decline; the fallback's is usually a duplicate).
fn plan_or_degrade(
    primary: anyhow::Result<Placement>,
    fallback: impl FnOnce() -> anyhow::Result<Placement>,
) -> (anyhow::Result<Placement>, bool) {
    match primary {
        Ok(p) => (Ok(p), false),
        Err(primary_err) => match fallback() {
            Ok(p) => (Ok(p), true),
            Err(_) => (Err(primary_err), false),
        },
    }
}

/// The epoch-swapped world holder: readers clone an `Arc` (microseconds
/// under the `published` mutex), mutators serialize on `admin` and
/// publish copy-on-write.
///
/// Why two locks: `published` is held only long enough to clone or swap
/// one `Arc`, so a `place` snapshot never waits behind a mutation in
/// flight. `admin` is held across the whole clone-mutate-publish
/// sequence, so concurrent admin requests cannot lose updates to each
/// other. Nothing ever holds both for longer than the swap itself.
pub struct WorldCell {
    published: Mutex<Arc<LiveWorld>>,
    admin: Mutex<()>,
}

impl WorldCell {
    pub fn new(world: LiveWorld) -> WorldCell {
        WorldCell {
            published: Mutex::new(Arc::new(world)),
            admin: Mutex::new(()),
        }
    }

    /// The current world generation. The returned `Arc` stays valid (and
    /// immutable) for as long as the caller holds it, no matter how many
    /// mutations publish newer generations meanwhile.
    pub fn snapshot(&self) -> Arc<LiveWorld> {
        // Poisoning can't corrupt an Arc swap; keep serving.
        Arc::clone(&self.published.lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner))
    }

    /// Clone-mutate-publish. The clone is published as the next
    /// generation only if `f` actually advanced the epoch — a declined
    /// mutation (capacity, double-fail) publishes nothing, so readers'
    /// splitter memos and caches are not invalidated for a no-op.
    pub fn mutate<T>(&self, f: impl FnOnce(&mut LiveWorld) -> T) -> T {
        let _admin = self.admin.lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let current = self.snapshot();
        let mut next = (*current).clone();
        let out = f(&mut next);
        if next.epoch != current.epoch {
            *self.published.lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner) =
                Arc::new(next);
        }
        out
    }

    /// Optimistic publish (the admin retry path): install `next` as the
    /// new generation **iff** `expected` is still the published `Arc`.
    /// Returns `false` — publishing nothing — when another mutation won
    /// the epoch race first; the caller re-snapshots, re-applies its op
    /// against the newer world, and retries (with backoff — see
    /// `handle_admin`). Unlike [`mutate`](Self::mutate) this never
    /// holds the `admin` lock, so N concurrent admins make progress
    /// lock-free: exactly one wins each round.
    pub fn publish_if_current(&self, expected: &Arc<LiveWorld>,
                              next: LiveWorld) -> bool
    {
        let mut published = self.published.lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if Arc::ptr_eq(&published, expected) {
            *published = Arc::new(next);
            true
        } else {
            false
        }
    }
}

/// The scope one placement-cache generation is valid for:
/// `(epoch, graph memo key)` of the world the cached replies were
/// planned against. Both components change on every successful admin
/// mutation; either changing invalidates the whole generation.
pub type CacheScope = (u64, (usize, usize));

struct CacheEntry {
    reply: String,
    last_used: u64,
}

/// A bounded, epoch-scoped cache of rendered `Place` replies, keyed on
/// the canonical workload digest ([`PlaceRequest::digest`]).
///
/// Each batcher shard owns one instance privately — requests are
/// hash-routed by the same digest, so a given workload always lands on
/// the same shard and no cross-shard coherence is needed. A hit returns
/// the cached reply string verbatim, which makes "cached replies are
/// byte-identical to planned replies" true by construction.
///
/// Scoping: every `get`/`insert` carries the caller's current
/// [`CacheScope`]; the first call under a new scope clears the previous
/// generation wholesale. A cached placement referencing a machine that
/// later failed is therefore unreachable — the `fail` bumped the epoch,
/// and the entry is gone before the next lookup can return it.
///
/// Callers must only insert deterministic `{"ok":true…}` replies
/// (error replies are cheap to recompute and some are not worth
/// pinning). Eviction is LRU by last-use tick, scanned linearly — at
/// the default capacity (1024) the scan is microseconds and only runs
/// when the cache is full.
pub struct PlacementCache {
    capacity: usize,
    scope: Option<CacheScope>,
    entries: HashMap<u64, CacheEntry>,
    tick: u64,
}

impl PlacementCache {
    /// `capacity == 0` disables the cache: every `get` misses, every
    /// `insert` is a no-op (the uncached-parity configuration).
    pub fn new(capacity: usize) -> PlacementCache {
        PlacementCache {
            capacity,
            scope: None,
            entries: HashMap::new(),
            tick: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop the previous generation if `scope` moved on.
    fn roll_scope(&mut self, scope: CacheScope) {
        if self.scope != Some(scope) {
            self.entries.clear();
            self.scope = Some(scope);
        }
    }

    /// Look up `digest` under `scope`. A scope change clears the cache
    /// and misses; a hit refreshes the entry's LRU tick and returns the
    /// reply bytes verbatim.
    pub fn get(&mut self, scope: CacheScope, digest: u64)
        -> Option<String>
    {
        if self.capacity == 0 {
            return None;
        }
        self.roll_scope(scope);
        self.tick += 1;
        let tick = self.tick;
        self.entries.get_mut(&digest).map(|e| {
            e.last_used = tick;
            e.reply.clone()
        })
    }

    /// Insert `reply` for `digest` under `scope`. Returns `true` if a
    /// least-recently-used entry was evicted to make room.
    pub fn insert(&mut self, scope: CacheScope, digest: u64, reply: &str)
        -> bool
    {
        if self.capacity == 0 {
            return false;
        }
        self.roll_scope(scope);
        self.tick += 1;
        let mut evicted = false;
        if self.entries.len() >= self.capacity
            && !self.entries.contains_key(&digest)
        {
            if let Some(lru) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k)
            {
                self.entries.remove(&lru);
                evicted = true;
            }
        }
        self.entries.insert(digest, CacheEntry {
            reply: reply.to_string(),
            last_used: self.tick,
        });
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelSpec;

    fn place_req(workload: Vec<ModelSpec>, systems: &[&str])
        -> PlaceRequest
    {
        let mut workload = workload;
        ModelSpec::sort_largest_first(&mut workload);
        PlaceRequest {
            workload,
            systems: systems.iter().map(|s| s.to_string()).collect(),
        }
    }

    #[test]
    fn plan_place_is_deterministic_and_valid_json() {
        let world = LiveWorld::planet(0, CostBackend::Analytic);
        let (classifier, params) = default_classifier(0);
        let req = place_req(vec![ModelSpec::bert_large(),
                                 ModelSpec::gpt2_xl()], &["hulk"]);
        let a = {
            let s = GnnSplitter::new(&classifier, &params);
            world.plan_place(&req, &s)
        };
        let b = {
            let s = GnnSplitter::new(&classifier, &params);
            world.plan_place(&req, &s)
        };
        // Fresh splitters, identical world → byte-identical replies.
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true));
        let results = parsed.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("system").and_then(Json::as_str),
                   Some("hulk"));
        assert_eq!(results[0].get("ok").and_then(Json::as_bool),
                   Some(true));
        let tasks = results[0].get("tasks").and_then(Json::as_arr).unwrap();
        assert_eq!(tasks.len(), 2);
        // Canonical order: largest model first.
        assert_eq!(tasks[0].get("model").and_then(Json::as_str),
                   Some("gpt2_xl"));
        assert!(tasks[0].get("total_ms").and_then(Json::as_f64).unwrap()
                > 0.0);
        assert!(!tasks[0].get("machines").and_then(Json::as_arr).unwrap()
                .is_empty());
    }

    #[test]
    fn joins_and_failures_stay_in_lockstep() {
        let mut world = LiveWorld::planet(0, CostBackend::Analytic);
        let n0 = world.fleet.len();
        let key0 = world.graph_key();
        assert_eq!(world.epoch(), 0);
        let id = world
            .join(Region::ALL[0], GpuModel::A100, 8)
            .unwrap();
        assert_eq!(id, n0);
        assert_eq!(world.fleet.len(), n0 + 1);
        assert_eq!(world.hier.n_nodes(), n0 + 1);
        assert_ne!(world.graph_key(), key0, "mutations must re-key memos");
        assert_eq!(world.epoch(), 1, "a join advances the epoch");
        world.fail(id).unwrap();
        assert_eq!(world.epoch(), 2, "a failure advances the epoch");
        assert!(world.fail(id).unwrap_err().contains("already"));
        assert!(world.fail(n0 + 50).is_err(), "out of range declined");
        assert_eq!(world.epoch(), 2,
                   "declined mutations leave the epoch alone");
        assert_eq!(world.alive_machines(), n0);
        assert_eq!(world.dense_rebuilds, 0);
    }

    #[test]
    fn world_cell_snapshots_survive_mutations_and_noops_do_not_publish() {
        let cell = WorldCell::new(
            LiveWorld::planet(0, CostBackend::Analytic));
        let before = cell.snapshot();
        let key_before = before.graph_key();
        // A successful mutation publishes a new generation…
        cell.mutate(|w| w.fail(3)).unwrap();
        let after = cell.snapshot();
        assert_eq!(after.epoch(), 1);
        assert_eq!(after.alive_machines(), 219);
        assert_ne!(after.graph_key(), key_before,
                   "published generations must re-key splitter memos");
        // …while the old snapshot is untouched and still usable.
        assert_eq!(before.epoch(), 0);
        assert_eq!(before.alive_machines(), 220);
        assert_eq!(before.graph_key(), key_before);
        // A declined mutation publishes nothing: same Arc, same key.
        let err = cell.mutate(|w| w.fail(3));
        assert!(err.unwrap_err().contains("already"));
        let still = cell.snapshot();
        assert!(Arc::ptr_eq(&after, &still),
                "a no-op admin must not re-key the request plane");
    }

    #[test]
    fn fail_region_is_one_correlated_epoch() {
        let mut world = LiveWorld::planet(0, CostBackend::Analytic);
        let region = world.fleet.machines[0].region;
        let expected: Vec<usize> = world
            .fleet
            .machines
            .iter()
            .filter(|m| m.region == region)
            .map(|m| m.id)
            .collect();
        let doomed = world.fail_region(region).unwrap();
        assert_eq!(doomed, expected);
        assert!(doomed.len() > 1, "planet regions hold many machines");
        assert_eq!(world.epoch(), 1,
                   "a whole-region outage is one epoch, not one per \
                    machine");
        for &m in &doomed {
            assert!(!world.hier.is_alive(m));
        }
        assert_eq!(world.alive_machines(), 220 - doomed.len());
        // Second outage of the same region: nothing left to kill.
        let err = world.fail_region(region).unwrap_err();
        assert!(err.contains("no alive machines"), "{err}");
        // A single-region world declines a total blackout.
        let mut one = LiveWorld::new(Fleet::synthetic(8, 1, 3),
                                     CostBackend::Analytic, 16)
            .unwrap();
        let r = one.fleet.machines[0].region;
        let err = one.fail_region(r).unwrap_err();
        assert!(err.contains("every alive machine"), "{err}");
        assert_eq!(one.epoch(), 0);
    }

    #[test]
    fn wan_factor_swaps_scales_and_restores_bit_for_bit() {
        let mut world = LiveWorld::planet(0, CostBackend::Analytic);
        let (classifier, params) = default_classifier(0);
        let req = place_req(vec![ModelSpec::bert_large()], &["hulk"]);
        let healthy = {
            let s = GnnSplitter::new(&classifier, &params);
            world.plan_place(&req, &s)
        };
        let (ra, rb) = (Region::ALL[0], Region::ALL[2]);
        let base = world.fleet.wan.latency_ms(ra, rb).unwrap();
        world.set_wan_factor(4.0).unwrap();
        assert_eq!(world.epoch(), 1);
        assert_eq!(world.wan_factor(), 4.0);
        assert_eq!(world.fleet.wan.latency_ms(ra, rb), Some(base * 4.0));
        // Factors replace each other (scale from base, not compound).
        world.set_wan_factor(2.0).unwrap();
        assert_eq!(world.fleet.wan.latency_ms(ra, rb), Some(base * 2.0));
        assert_eq!(world.epoch(), 2);
        // Same factor again is a declined no-op.
        let err = world.set_wan_factor(2.0).unwrap_err();
        assert!(err.contains("already"), "{err}");
        assert_eq!(world.epoch(), 2);
        // Out-of-range factors are typed declines.
        assert!(world.set_wan_factor(0.5).is_err());
        assert!(world.set_wan_factor(f64::NAN).is_err());
        assert!(world.set_wan_factor(1e9).is_err());
        // Restore: the world is value-identical to one that never
        // browned out, so the (deterministic) reply is byte-identical.
        world.set_wan_factor(1.0).unwrap();
        assert_eq!(world.fleet.wan.latency_ms(ra, rb), Some(base));
        let restored = {
            let s = GnnSplitter::new(&classifier, &params);
            world.plan_place(&req, &s)
        };
        assert_eq!(healthy, restored,
                   "a flapped-and-restored link must not change \
                    placements");
    }

    #[test]
    fn plan_or_degrade_only_flags_actual_fallbacks() {
        let plan = || Placement { per_task: Vec::new() };
        // Healthy primary: served as-is, fallback never consulted.
        let (out, degraded) = plan_or_degrade(Ok(plan()), || {
            panic!("fallback must not run when the primary planned")
        });
        assert!(out.is_ok());
        assert!(!degraded);
        // Failed primary, fallback serves: degraded.
        let (out, degraded) = plan_or_degrade(
            Err(anyhow::anyhow!("forward failed")), || Ok(plan()));
        assert!(out.is_ok());
        assert!(degraded);
        // Both fail: the primary's error surfaces, not the fallback's.
        let (out, degraded) = plan_or_degrade(
            Err(anyhow::anyhow!("primary decline")),
            || Err(anyhow::anyhow!("fallback decline")));
        assert!(out.unwrap_err().to_string().contains("primary"));
        assert!(!degraded);
    }

    #[test]
    fn healthy_replies_never_carry_a_degraded_flag() {
        let world = LiveWorld::planet(0, CostBackend::Analytic);
        let (classifier, params) = default_classifier(0);
        let s = GnnSplitter::new(&classifier, &params);
        let req = place_req(vec![ModelSpec::bert_large(),
                                 ModelSpec::gpt2_xl()], &["hulk"]);
        let reply = world.plan_place(&req, &s);
        assert!(!reply.contains("degraded"),
                "the non-degraded path must stay byte-identical: \
                 {reply}");
    }

    #[test]
    fn publish_if_current_loses_cleanly_to_a_newer_generation() {
        let cell = WorldCell::new(
            LiveWorld::planet(0, CostBackend::Analytic));
        let stale = cell.snapshot();
        // Someone else wins the race first.
        cell.mutate(|w| w.fail(7)).unwrap();
        let mut attempt = (*stale).clone();
        attempt.fail(9).unwrap();
        assert!(!cell.publish_if_current(&stale, attempt),
                "a stale expected snapshot must not publish");
        assert_eq!(cell.snapshot().epoch(), 1,
                   "the loser published nothing");
        // Retry against the fresh snapshot wins.
        let current = cell.snapshot();
        let mut retry = (*current).clone();
        retry.fail(9).unwrap();
        assert!(cell.publish_if_current(&current, retry));
        let now = cell.snapshot();
        assert_eq!(now.epoch(), 2);
        assert!(!now.hier.is_alive(7));
        assert!(!now.hier.is_alive(9));
    }

    #[test]
    fn placement_cache_scopes_bounds_and_evicts_lru() {
        let mut cache = PlacementCache::new(2);
        let scope_a: CacheScope = (0, (220, 1));
        assert!(cache.get(scope_a, 1).is_none());
        assert!(!cache.insert(scope_a, 1, "{\"ok\":true,\"r\":1}"));
        assert_eq!(cache.get(scope_a, 1).as_deref(),
                   Some("{\"ok\":true,\"r\":1}"));
        assert!(!cache.insert(scope_a, 2, "{\"ok\":true,\"r\":2}"));
        // Touch 1 so digest 2 is the LRU victim, then overflow.
        assert!(cache.get(scope_a, 1).is_some());
        assert!(cache.insert(scope_a, 3, "{\"ok\":true,\"r\":3}"),
                "inserting past capacity must evict");
        assert_eq!(cache.len(), 2);
        assert!(cache.get(scope_a, 2).is_none(), "LRU entry evicted");
        assert!(cache.get(scope_a, 1).is_some());
        assert!(cache.get(scope_a, 3).is_some());
        // A scope change (epoch bump) clears the whole generation.
        let scope_b: CacheScope = (1, (220, 7));
        assert!(cache.get(scope_b, 1).is_none());
        assert!(cache.is_empty());
        // Re-inserting the same digest twice is an update, not an evict.
        assert!(!cache.insert(scope_b, 1, "x"));
        assert!(!cache.insert(scope_b, 1, "y"));
        assert_eq!(cache.get(scope_b, 1).as_deref(), Some("y"));
        // Capacity 0 = disabled.
        let mut off = PlacementCache::new(0);
        assert!(!off.insert(scope_a, 1, "z"));
        assert!(off.get(scope_a, 1).is_none());
        assert_eq!(off.capacity(), 0);
    }

    #[test]
    fn join_declined_at_classifier_capacity() {
        let fleet = Fleet::synthetic(10, 3, 1);
        let mut world =
            LiveWorld::new(fleet, CostBackend::Analytic, 11).unwrap();
        world.join(Region::ALL[0], GpuModel::V100, 4).unwrap();
        let err = world
            .join(Region::ALL[0], GpuModel::V100, 4)
            .unwrap_err();
        assert!(err.contains("capacity"), "{err}");
        // And a too-big seed fleet is rejected up front.
        assert!(LiveWorld::new(Fleet::synthetic(12, 3, 1),
                               CostBackend::Analytic, 11).is_err());
    }

    #[test]
    fn oversized_workloads_and_unknown_systems_decline() {
        let world = LiveWorld::planet(0, CostBackend::Analytic);
        let (classifier, params) = default_classifier(0);
        let s = GnnSplitter::new(&classifier, &params);
        let nine = vec![ModelSpec::bert_large(); 9];
        let reply = world.plan_place(&place_req(nine, &["hulk"]), &s);
        assert!(reply.contains("\"ok\":false"), "{reply}");
        assert!(reply.contains("at most"), "{reply}");
        let reply = world.plan_place(
            &place_req(vec![ModelSpec::bert_large()], &["warp"]), &s);
        assert!(reply.contains("\"ok\":false"), "{reply}");
        assert!(reply.contains("unknown planner"), "{reply}");
    }
}
