//! The daemon's live world: a fleet and its hierarchical graph kept in
//! lockstep, mutated **only** through the incremental graph-update
//! seam, published to the request plane as immutable epoch snapshots.
//!
//! Ownership: the current world lives inside a [`WorldCell`] as an
//! `Arc<LiveWorld>`. `Place` and `Stats` requests take a
//! [`snapshot`](WorldCell::snapshot) — an `Arc` clone, never a lock
//! held across planning — while `Admin` requests go through
//! [`mutate`](WorldCell::mutate): clone the current world, apply the
//! join/failure, publish the clone as the next epoch. A batcher shard
//! mid-plan keeps its old snapshot alive through the `Arc`, so admin
//! mutations never stall the request plane and readers never observe a
//! half-applied mutation.
//!
//! There is no rebuild path — joins and failures go through
//! [`HierarchicalGraph::apply_join`] / [`apply_failure`]
//! (coarse-level-only rebuilds), and [`LiveWorld::dense_rebuilds`]
//! stays 0 by construction. The `Stats` reply exposes both the counter
//! and [`max_dense_n`] so tests and operators can verify no admin
//! mutation ever paid an O(n²) dense-oracle rebuild.
//!
//! The fleet grows in lockstep with the graph: a join appends to *both*
//! ([`Fleet::add_machine`] and `apply_join` hand out the same dense id),
//! because placement pricing ([`Placement::cost`]) and validation index
//! `fleet.machines` directly — a graph-only join would panic the first
//! time a placement lands on the new machine.
//!
//! [`PlacementCache`] closes the loop: rendered `Place` replies keyed
//! on the canonical workload digest, scoped to one
//! `(epoch, graph memo key)` generation. Every successful mutation
//! bumps [`LiveWorld::epoch`], so a cached placement can never outlive
//! the world it was planned against — stale entries are cleared on the
//! first lookup under the new scope, before anything can be served.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cluster::{Fleet, GpuModel, Region};
use crate::gnn::{Classifier, GnnSplitter, RefGcn, RefGcnConfig};
use crate::graph::{GraphView, HierarchicalGraph, FEATURE_DIM};
use crate::planner::{CostBackend, HulkSplitterKind, PlanContext,
                     PlannerRegistry};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::protocol::{error_reply, PlaceRequest};

/// Padded GCN slot count for the serving classifier: room for the
/// 220-machine planet fleet plus live joins (the daemon declines joins
/// past this, with a typed error).
pub const SERVE_SLOTS: usize = 384;

/// The serving classifier: the pure-Rust reference GCN at
/// [`SERVE_SLOTS`] slots with seeded weights — same construction as the
/// `bench micro` planet classifier, so serve latencies and micro rows
/// measure the same forward.
pub fn default_classifier(seed: u64) -> (Classifier, Vec<f32>) {
    let cfg = RefGcnConfig { n: SERVE_SLOTS, f: FEATURE_DIM,
                             h: 64, h2: 32, c: 8 };
    let mut rng = Rng::new(seed ^ 0x4743_4E21); // "GCN!"
    let params: Vec<f32> = (0..cfg.n_params())
        .map(|_| (rng.normal() * 0.1) as f32)
        .collect();
    (Classifier::Reference(RefGcn::new(cfg, &params)), params)
}

/// The daemon's mutable world. See the module docs for the ownership
/// and lockstep invariants. `Clone` is the mutation primitive: the
/// [`WorldCell`] clones the published world, mutates the clone, and
/// publishes it as the next epoch (a 220-machine clone is a few small
/// vectors — cheap at admin rates).
#[derive(Clone)]
pub struct LiveWorld {
    /// Grows on `Join`; never shrinks (failed machines keep their id —
    /// jitter stability, and placements must stay indexable).
    pub fleet: Fleet,
    /// The plan graph *and* the mutation seam: alive mask, joined
    /// machines, coarse level. All planning goes through it.
    pub hier: HierarchicalGraph,
    backend: CostBackend,
    slots: usize,
    /// Bumped by every *successful* `join`/`fail` — the scope token
    /// placement caches and stats key on. Declined mutations (capacity,
    /// double-fail) leave it unchanged, so they invalidate nothing.
    epoch: u64,
    /// World rebuilds from scratch. No code path increments it — the
    /// field exists so the `Stats` reply can prove that, and so any
    /// future rebuild path has to show up in the serve round-trip test.
    pub dense_rebuilds: u64,
}

impl LiveWorld {
    pub fn new(fleet: Fleet, backend: CostBackend, slots: usize)
        -> Result<LiveWorld, String>
    {
        if fleet.len() > slots {
            return Err(format!(
                "fleet of {} machines exceeds the classifier's {slots} \
                 slots", fleet.len()));
        }
        let hier = HierarchicalGraph::from_fleet(Arc::new(fleet.clone()));
        Ok(LiveWorld { fleet, hier, backend, slots, epoch: 0,
                       dense_rebuilds: 0 })
    }

    /// The serving default: the planet_scale synthetic fleet
    /// (220 machines, 12 regions) under [`SERVE_SLOTS`].
    pub fn planet(seed: u64, backend: CostBackend) -> LiveWorld {
        LiveWorld::new(Fleet::synthetic(220, 12, seed), backend,
                       SERVE_SLOTS)
            .expect("220 machines fit 384 slots")
    }

    /// The graph identity a batcher shard keys its shared splitter on —
    /// changes on every admin mutation *and* on every world clone (the
    /// coarse adjacency reallocates), so a stale forward can never
    /// serve a different world generation.
    pub fn graph_key(&self) -> (usize, usize) {
        self.hier.memo_key()
    }

    /// Monotone world generation: 0 at construction, +1 per successful
    /// mutation. See the field docs.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The token one [`PlacementCache`] generation is scoped to.
    pub fn cache_scope(&self) -> CacheScope {
        (self.epoch, self.graph_key())
    }

    pub fn alive_machines(&self) -> usize {
        (0..self.fleet.len())
            .filter(|&m| self.hier.is_alive(m))
            .count()
    }

    /// Scale-out: append to fleet and graph in lockstep. Declined (not
    /// panicked) past classifier capacity.
    pub fn join(&mut self, region: Region, gpu: GpuModel, n_gpus: usize)
        -> Result<usize, String>
    {
        if self.fleet.len() >= self.slots {
            return Err(format!(
                "fleet is at classifier capacity ({} slots); join \
                 declined", self.slots));
        }
        let id = self.fleet.add_machine(region, gpu, n_gpus);
        let hier_id = self.hier.apply_join(region, gpu, n_gpus);
        assert_eq!(id, hier_id, "fleet and graph must stay in lockstep");
        self.epoch += 1;
        Ok(id)
    }

    /// Failure / spot revocation: the machine keeps its id but drops
    /// out of every edge weight and planning pool. Pre-validated so
    /// wire input can never hit `apply_failure`'s alive assertion.
    pub fn fail(&mut self, machine: usize) -> Result<(), String> {
        if machine >= self.fleet.len() {
            return Err(format!(
                "machine {machine} out of range (fleet has machines \
                 0..{})", self.fleet.len()));
        }
        if !self.hier.is_alive(machine) {
            return Err(format!("machine {machine} already failed"));
        }
        self.hier.apply_failure(machine);
        self.epoch += 1;
        Ok(())
    }

    /// Answer one `Place` request: plan the workload with every
    /// requested system and render the reply.
    ///
    /// The reply is **deterministic in the world state** — placements,
    /// digests and predicted per-iteration costs, never wall-clock —
    /// which is what makes "batched and unbatched answers are
    /// byte-identical" a testable contract. `splitter` is the caller's
    /// (possibly batch-shared) forward-pass memo; a batch of requests
    /// against one frozen world pays one GCN forward total.
    pub fn plan_place(&self, req: &PlaceRequest, splitter: &GnnSplitter)
        -> String
    {
        match self.place_json(req, splitter) {
            Ok(reply) => reply.render(),
            Err(msg) => error_reply(&msg),
        }
    }

    fn place_json(&self, req: &PlaceRequest, splitter: &GnnSplitter)
        -> Result<Json, String>
    {
        let max_tasks = splitter.classifier.n_classes();
        if req.workload.len() > max_tasks {
            return Err(format!(
                "workload has {} tasks but the classifier supports at \
                 most {max_tasks}", req.workload.len()));
        }
        let registry = PlannerRegistry::resolve(&req.systems.join(","))
            .map_err(|e| e.to_string())?;
        let mut results = Json::arr();
        for planner in registry.iter() {
            let ctx = PlanContext::new(
                &self.fleet, &self.hier, &req.workload,
                HulkSplitterKind::SharedGnn { splitter })
                .with_backend(self.backend)
                .with_hier(&self.hier);
            let mut entry = Json::obj();
            entry.set("system", Json::from(planner.slug()));
            match planner.plan(&ctx) {
                Ok(placement) => {
                    placement
                        .validate_machines(&self.fleet)
                        .map_err(|e| format!(
                            "{} produced an invalid placement: {e}",
                            planner.slug()))?;
                    let summary = placement.summary(&self.fleet);
                    let priced = planner.price(&ctx, &placement);
                    entry.set("ok", Json::Bool(true));
                    entry.set("groups", Json::from(summary.groups));
                    entry.set("stages", Json::from(summary.stages));
                    entry.set("cross_region_edges",
                              Json::from(summary.cross_region_edges));
                    let mut tasks = Json::arr();
                    for (t, model) in req.workload.iter().enumerate() {
                        let cost = &priced.per_task[t];
                        let mut tj = Json::obj();
                        tj.set("model", Json::from(model.slug()));
                        let mut machines = Json::arr();
                        for &m in placement.machines(t) {
                            machines.push(Json::from(m));
                        }
                        tj.set("machines", machines);
                        tj.set("comm_ms", Json::from(cost.comm_ms));
                        tj.set("comp_ms", Json::from(cost.comp_ms));
                        tj.set("total_ms", Json::from(cost.total_ms()));
                        tasks.push(tj);
                    }
                    entry.set("tasks", tasks);
                }
                Err(e) => {
                    // A planner declining (infeasible workload, empty
                    // pool) is a per-system answer, not a request
                    // failure — other systems still reply.
                    entry.set("ok", Json::Bool(false));
                    entry.set("error", Json::from(e.to_string().as_str()));
                }
            }
            results.push(entry);
        }
        let mut reply = Json::obj();
        reply.set("ok", Json::Bool(true));
        reply.set("type", Json::from("place"));
        reply.set("results", results);
        Ok(reply)
    }
}

/// The epoch-swapped world holder: readers clone an `Arc` (microseconds
/// under the `published` mutex), mutators serialize on `admin` and
/// publish copy-on-write.
///
/// Why two locks: `published` is held only long enough to clone or swap
/// one `Arc`, so a `place` snapshot never waits behind a mutation in
/// flight. `admin` is held across the whole clone-mutate-publish
/// sequence, so concurrent admin requests cannot lose updates to each
/// other. Nothing ever holds both for longer than the swap itself.
pub struct WorldCell {
    published: Mutex<Arc<LiveWorld>>,
    admin: Mutex<()>,
}

impl WorldCell {
    pub fn new(world: LiveWorld) -> WorldCell {
        WorldCell {
            published: Mutex::new(Arc::new(world)),
            admin: Mutex::new(()),
        }
    }

    /// The current world generation. The returned `Arc` stays valid (and
    /// immutable) for as long as the caller holds it, no matter how many
    /// mutations publish newer generations meanwhile.
    pub fn snapshot(&self) -> Arc<LiveWorld> {
        // Poisoning can't corrupt an Arc swap; keep serving.
        Arc::clone(&self.published.lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner))
    }

    /// Clone-mutate-publish. The clone is published as the next
    /// generation only if `f` actually advanced the epoch — a declined
    /// mutation (capacity, double-fail) publishes nothing, so readers'
    /// splitter memos and caches are not invalidated for a no-op.
    pub fn mutate<T>(&self, f: impl FnOnce(&mut LiveWorld) -> T) -> T {
        let _admin = self.admin.lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let current = self.snapshot();
        let mut next = (*current).clone();
        let out = f(&mut next);
        if next.epoch != current.epoch {
            *self.published.lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner) =
                Arc::new(next);
        }
        out
    }
}

/// The scope one placement-cache generation is valid for:
/// `(epoch, graph memo key)` of the world the cached replies were
/// planned against. Both components change on every successful admin
/// mutation; either changing invalidates the whole generation.
pub type CacheScope = (u64, (usize, usize));

struct CacheEntry {
    reply: String,
    last_used: u64,
}

/// A bounded, epoch-scoped cache of rendered `Place` replies, keyed on
/// the canonical workload digest ([`PlaceRequest::digest`]).
///
/// Each batcher shard owns one instance privately — requests are
/// hash-routed by the same digest, so a given workload always lands on
/// the same shard and no cross-shard coherence is needed. A hit returns
/// the cached reply string verbatim, which makes "cached replies are
/// byte-identical to planned replies" true by construction.
///
/// Scoping: every `get`/`insert` carries the caller's current
/// [`CacheScope`]; the first call under a new scope clears the previous
/// generation wholesale. A cached placement referencing a machine that
/// later failed is therefore unreachable — the `fail` bumped the epoch,
/// and the entry is gone before the next lookup can return it.
///
/// Callers must only insert deterministic `{"ok":true…}` replies
/// (error replies are cheap to recompute and some are not worth
/// pinning). Eviction is LRU by last-use tick, scanned linearly — at
/// the default capacity (1024) the scan is microseconds and only runs
/// when the cache is full.
pub struct PlacementCache {
    capacity: usize,
    scope: Option<CacheScope>,
    entries: HashMap<u64, CacheEntry>,
    tick: u64,
}

impl PlacementCache {
    /// `capacity == 0` disables the cache: every `get` misses, every
    /// `insert` is a no-op (the uncached-parity configuration).
    pub fn new(capacity: usize) -> PlacementCache {
        PlacementCache {
            capacity,
            scope: None,
            entries: HashMap::new(),
            tick: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop the previous generation if `scope` moved on.
    fn roll_scope(&mut self, scope: CacheScope) {
        if self.scope != Some(scope) {
            self.entries.clear();
            self.scope = Some(scope);
        }
    }

    /// Look up `digest` under `scope`. A scope change clears the cache
    /// and misses; a hit refreshes the entry's LRU tick and returns the
    /// reply bytes verbatim.
    pub fn get(&mut self, scope: CacheScope, digest: u64)
        -> Option<String>
    {
        if self.capacity == 0 {
            return None;
        }
        self.roll_scope(scope);
        self.tick += 1;
        let tick = self.tick;
        self.entries.get_mut(&digest).map(|e| {
            e.last_used = tick;
            e.reply.clone()
        })
    }

    /// Insert `reply` for `digest` under `scope`. Returns `true` if a
    /// least-recently-used entry was evicted to make room.
    pub fn insert(&mut self, scope: CacheScope, digest: u64, reply: &str)
        -> bool
    {
        if self.capacity == 0 {
            return false;
        }
        self.roll_scope(scope);
        self.tick += 1;
        let mut evicted = false;
        if self.entries.len() >= self.capacity
            && !self.entries.contains_key(&digest)
        {
            if let Some(lru) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k)
            {
                self.entries.remove(&lru);
                evicted = true;
            }
        }
        self.entries.insert(digest, CacheEntry {
            reply: reply.to_string(),
            last_used: self.tick,
        });
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelSpec;

    fn place_req(workload: Vec<ModelSpec>, systems: &[&str])
        -> PlaceRequest
    {
        let mut workload = workload;
        ModelSpec::sort_largest_first(&mut workload);
        PlaceRequest {
            workload,
            systems: systems.iter().map(|s| s.to_string()).collect(),
        }
    }

    #[test]
    fn plan_place_is_deterministic_and_valid_json() {
        let world = LiveWorld::planet(0, CostBackend::Analytic);
        let (classifier, params) = default_classifier(0);
        let req = place_req(vec![ModelSpec::bert_large(),
                                 ModelSpec::gpt2_xl()], &["hulk"]);
        let a = {
            let s = GnnSplitter::new(&classifier, &params);
            world.plan_place(&req, &s)
        };
        let b = {
            let s = GnnSplitter::new(&classifier, &params);
            world.plan_place(&req, &s)
        };
        // Fresh splitters, identical world → byte-identical replies.
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true));
        let results = parsed.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("system").and_then(Json::as_str),
                   Some("hulk"));
        assert_eq!(results[0].get("ok").and_then(Json::as_bool),
                   Some(true));
        let tasks = results[0].get("tasks").and_then(Json::as_arr).unwrap();
        assert_eq!(tasks.len(), 2);
        // Canonical order: largest model first.
        assert_eq!(tasks[0].get("model").and_then(Json::as_str),
                   Some("gpt2_xl"));
        assert!(tasks[0].get("total_ms").and_then(Json::as_f64).unwrap()
                > 0.0);
        assert!(!tasks[0].get("machines").and_then(Json::as_arr).unwrap()
                .is_empty());
    }

    #[test]
    fn joins_and_failures_stay_in_lockstep() {
        let mut world = LiveWorld::planet(0, CostBackend::Analytic);
        let n0 = world.fleet.len();
        let key0 = world.graph_key();
        assert_eq!(world.epoch(), 0);
        let id = world
            .join(Region::ALL[0], GpuModel::A100, 8)
            .unwrap();
        assert_eq!(id, n0);
        assert_eq!(world.fleet.len(), n0 + 1);
        assert_eq!(world.hier.n_nodes(), n0 + 1);
        assert_ne!(world.graph_key(), key0, "mutations must re-key memos");
        assert_eq!(world.epoch(), 1, "a join advances the epoch");
        world.fail(id).unwrap();
        assert_eq!(world.epoch(), 2, "a failure advances the epoch");
        assert!(world.fail(id).unwrap_err().contains("already"));
        assert!(world.fail(n0 + 50).is_err(), "out of range declined");
        assert_eq!(world.epoch(), 2,
                   "declined mutations leave the epoch alone");
        assert_eq!(world.alive_machines(), n0);
        assert_eq!(world.dense_rebuilds, 0);
    }

    #[test]
    fn world_cell_snapshots_survive_mutations_and_noops_do_not_publish() {
        let cell = WorldCell::new(
            LiveWorld::planet(0, CostBackend::Analytic));
        let before = cell.snapshot();
        let key_before = before.graph_key();
        // A successful mutation publishes a new generation…
        cell.mutate(|w| w.fail(3)).unwrap();
        let after = cell.snapshot();
        assert_eq!(after.epoch(), 1);
        assert_eq!(after.alive_machines(), 219);
        assert_ne!(after.graph_key(), key_before,
                   "published generations must re-key splitter memos");
        // …while the old snapshot is untouched and still usable.
        assert_eq!(before.epoch(), 0);
        assert_eq!(before.alive_machines(), 220);
        assert_eq!(before.graph_key(), key_before);
        // A declined mutation publishes nothing: same Arc, same key.
        let err = cell.mutate(|w| w.fail(3));
        assert!(err.unwrap_err().contains("already"));
        let still = cell.snapshot();
        assert!(Arc::ptr_eq(&after, &still),
                "a no-op admin must not re-key the request plane");
    }

    #[test]
    fn placement_cache_scopes_bounds_and_evicts_lru() {
        let mut cache = PlacementCache::new(2);
        let scope_a: CacheScope = (0, (220, 1));
        assert!(cache.get(scope_a, 1).is_none());
        assert!(!cache.insert(scope_a, 1, "{\"ok\":true,\"r\":1}"));
        assert_eq!(cache.get(scope_a, 1).as_deref(),
                   Some("{\"ok\":true,\"r\":1}"));
        assert!(!cache.insert(scope_a, 2, "{\"ok\":true,\"r\":2}"));
        // Touch 1 so digest 2 is the LRU victim, then overflow.
        assert!(cache.get(scope_a, 1).is_some());
        assert!(cache.insert(scope_a, 3, "{\"ok\":true,\"r\":3}"),
                "inserting past capacity must evict");
        assert_eq!(cache.len(), 2);
        assert!(cache.get(scope_a, 2).is_none(), "LRU entry evicted");
        assert!(cache.get(scope_a, 1).is_some());
        assert!(cache.get(scope_a, 3).is_some());
        // A scope change (epoch bump) clears the whole generation.
        let scope_b: CacheScope = (1, (220, 7));
        assert!(cache.get(scope_b, 1).is_none());
        assert!(cache.is_empty());
        // Re-inserting the same digest twice is an update, not an evict.
        assert!(!cache.insert(scope_b, 1, "x"));
        assert!(!cache.insert(scope_b, 1, "y"));
        assert_eq!(cache.get(scope_b, 1).as_deref(), Some("y"));
        // Capacity 0 = disabled.
        let mut off = PlacementCache::new(0);
        assert!(!off.insert(scope_a, 1, "z"));
        assert!(off.get(scope_a, 1).is_none());
        assert_eq!(off.capacity(), 0);
    }

    #[test]
    fn join_declined_at_classifier_capacity() {
        let fleet = Fleet::synthetic(10, 3, 1);
        let mut world =
            LiveWorld::new(fleet, CostBackend::Analytic, 11).unwrap();
        world.join(Region::ALL[0], GpuModel::V100, 4).unwrap();
        let err = world
            .join(Region::ALL[0], GpuModel::V100, 4)
            .unwrap_err();
        assert!(err.contains("capacity"), "{err}");
        // And a too-big seed fleet is rejected up front.
        assert!(LiveWorld::new(Fleet::synthetic(12, 3, 1),
                               CostBackend::Analytic, 11).is_err());
    }

    #[test]
    fn oversized_workloads_and_unknown_systems_decline() {
        let world = LiveWorld::planet(0, CostBackend::Analytic);
        let (classifier, params) = default_classifier(0);
        let s = GnnSplitter::new(&classifier, &params);
        let nine = vec![ModelSpec::bert_large(); 9];
        let reply = world.plan_place(&place_req(nine, &["hulk"]), &s);
        assert!(reply.contains("\"ok\":false"), "{reply}");
        assert!(reply.contains("at most"), "{reply}");
        let reply = world.plan_place(
            &place_req(vec![ModelSpec::bert_large()], &["warp"]), &s);
        assert!(reply.contains("\"ok\":false"), "{reply}");
        assert!(reply.contains("unknown planner"), "{reply}");
    }
}
