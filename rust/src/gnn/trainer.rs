//! The Fig. 4 training loop, driven from Rust through the PJRT
//! `train_step` artifact. Python is not involved: the artifact was lowered
//! once at build time; Rust owns the optimizer state round-trip.

use anyhow::Result;

use crate::runtime::client::{GcnRuntime, TrainState};

use super::dataset::LabeledGraph;

/// One point of the Fig. 4 curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainCurvePoint {
    pub step: u32,
    pub loss: f32,
    pub acc: f32,
}

/// Training options. Defaults follow the paper: lr = 0.01, 10 steps for
/// the Fig. 4 reproduction (the end-to-end example trains longer).
#[derive(Clone, Debug)]
pub struct TrainerOptions {
    pub steps: u32,
    pub lr: f32,
    /// Log every k steps to stdout (0 = silent).
    pub log_every: u32,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions { steps: 10, lr: 0.01, log_every: 0 }
    }
}

/// Train on a dataset of labeled graphs (one graph per step, cycling) and
/// return the loss/accuracy curve. `state` is updated in place so callers
/// can continue training or hand the params to inference.
///
/// Hot path (§Perf): graph tensors are marshalled to literals once per
/// dataset entry and the parameter/moment vectors stay literal-resident
/// across steps — only loss/acc scalars cross back per step.
pub fn train_gcn(rt: &GcnRuntime, state: &mut TrainState,
                 dataset: &[LabeledGraph], opts: &TrainerOptions)
    -> Result<Vec<TrainCurvePoint>>
{
    anyhow::ensure!(!dataset.is_empty(), "empty dataset");
    let graphs = dataset
        .iter()
        .map(|g| rt.graph_literals(&g.adj, &g.feats, &g.labels, &g.mask))
        .collect::<Result<Vec<_>>>()?;
    let mut lit_state = rt.lit_state(state)?;
    let mut curve = Vec::with_capacity(opts.steps as usize);
    for s in 0..opts.steps {
        let g = &graphs[(s as usize) % graphs.len()];
        let out = rt.train_step_fast(&mut lit_state, g, opts.lr)?;
        let point = TrainCurvePoint { step: lit_state.step, loss: out.loss,
                                      acc: out.acc };
        if opts.log_every > 0 && lit_state.step % opts.log_every == 0 {
            println!("step {:>4}  loss {:>8.4}  acc {:>6.3}",
                     point.step, point.loss, point.acc);
        }
        curve.push(point);
    }
    *state = rt.host_state(&lit_state)?;
    Ok(curve)
}

/// Evaluate current params on a dataset: mean (loss-free) accuracy via the
/// forward artifact.
pub fn evaluate_accuracy(rt: &GcnRuntime, params: &[f32],
                         dataset: &[LabeledGraph]) -> Result<f64>
{
    anyhow::ensure!(!dataset.is_empty(), "empty dataset");
    let c = rt.manifest.c;
    let mut correct = 0usize;
    let mut total = 0usize;
    for g in dataset {
        let probs = rt.forward(params, &g.adj, &g.feats, &g.mask)?;
        for i in 0..g.n_real {
            let row = &probs[i * c..(i + 1) * c];
            // NaN-safe: diverged training (NaN logits) must depress
            // accuracy, not panic the evaluation loop.
            let pred =
                crate::gnn::inference::argmax_class(row) as i32;
            if pred == g.labels[i] {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

// PJRT-dependent tests live in rust/tests/runtime_integration.rs
// (they require `make artifacts`).

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_match_paper() {
        let o = TrainerOptions::default();
        assert_eq!(o.steps, 10); // Fig. 4: "10 steps of training"
        assert_eq!(o.lr, 0.01); // "the learning rate is 0.01"
    }
}
