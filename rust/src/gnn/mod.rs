//! The Hulk GCN on the Rust side.
//!
//! - [`reference`] — pure-Rust mirror of the JAX forward pass (same math
//!   as `python/compile/model.py`), used for artifact-free tests and as a
//!   CPU fallback when `artifacts/` is absent.
//! - [`dataset`] — synthetic labeled graphs: random fleets partitioned by
//!   the `scheduler::oracle` (the paper's "sparse labels").
//! - [`trainer`] — the Fig. 4 training loop, driven from Rust through the
//!   PJRT `train_step` artifact.
//! - [`inference`] — node classification for Algorithm 1, via the PJRT
//!   `forward` artifact or the reference forward.

pub mod dataset;
pub mod quality;
pub mod inference;
pub mod reference;
pub mod trainer;

pub use dataset::{make_dataset, LabeledGraph};
pub use quality::{assignment_quality, cost_vs_random, AssignmentQuality};
pub use inference::{classify, classify_with_graph, Classifier,
                    GnnSplitter};
pub use reference::{RefGcn, RefGcnConfig};
pub use trainer::{train_gcn, TrainCurvePoint, TrainerOptions};
