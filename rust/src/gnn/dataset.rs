//! Training data for the GCN: random fleets labeled by the oracle
//! partitioner (the paper's "sparsely label this subgraph to enable the
//! neural network to learn ... in a supervised manner", §3).
//!
//! Labels: machine → class = task index in the sampled workload
//! (largest model = class 0, …); spare machines get [`SPARE_CLASS`].

use crate::cluster::Fleet;
use crate::graph::{node_features, ClusterGraph};
use crate::models::ModelSpec;
use crate::scheduler::{oracle_partition, OracleOptions};
use crate::util::rng::Rng;

/// Class id for machines the oracle leaves unassigned. Must be <
/// manifest `c` (8).
pub const SPARE_CLASS: i32 = 7;

/// One labeled, padded training example.
#[derive(Clone, Debug)]
pub struct LabeledGraph {
    /// Row-major `[slots, slots]`.
    pub adj: Vec<f32>,
    /// Row-major `[slots, FEATURE_DIM]`.
    pub feats: Vec<f32>,
    /// `[slots]`, class ids (padding rows are 0 and masked out).
    pub labels: Vec<i32>,
    /// `[slots]`, 1.0 = real machine.
    pub mask: Vec<f32>,
    /// Real machine count.
    pub n_real: usize,
}

impl LabeledGraph {
    /// Build from a fleet + tasks via the oracle.
    pub fn from_fleet(fleet: &Fleet, tasks: &[ModelSpec], slots: usize)
        -> LabeledGraph
    {
        let graph = ClusterGraph::from_fleet(fleet);
        let assignment = oracle_partition(fleet, &graph, tasks,
                                          &OracleOptions::default());
        let mut labels = vec![0i32; slots];
        for m in 0..fleet.len() {
            labels[m] = match assignment.task_of(m) {
                Some(t) => t as i32,
                None => SPARE_CLASS,
            };
        }
        LabeledGraph {
            adj: graph.padded_adj(slots),
            feats: node_features(&fleet.machines, &graph, slots),
            labels,
            mask: graph.padded_mask(slots),
            n_real: fleet.len(),
        }
    }
}

/// Sample a workload of 2–3 *distinct-scale* tasks, sized to be trainable
/// on the fleet. Near-identical model sizes (BERT 340M vs RoBERTa 355M vs
/// XLNet 340M) are excluded from the training catalog: the oracle labels
/// either grouping arbitrarily, which puts an irreducible noise floor on
/// supervised accuracy — distinct scales keep the imitation target
/// well-defined. (Inference generalizes to same-size tasks regardless:
/// Algorithm 1 consumes classes by rank, not identity.)
fn sample_tasks(rng: &mut Rng, fleet_gb: f64) -> Vec<ModelSpec> {
    let catalog = [
        ModelSpec::t5_11b(),    // 176 GB
        ModelSpec::gpt2_xl(),   // 24 GB
        ModelSpec::bert_large(), // 5.4 GB
    ];
    let n_tasks = 2 + rng.below(2);
    let pick = rng.sample_indices(catalog.len(), n_tasks.min(catalog.len()));
    let mut tasks: Vec<ModelSpec> = Vec::new();
    let mut budget = fleet_gb * 0.8;
    for &i in &pick {
        let t = catalog[i].clone();
        if t.train_gb() <= budget {
            budget -= t.train_gb();
            tasks.push(t);
        }
    }
    if tasks.is_empty() {
        tasks.push(ModelSpec::bert_large());
    }
    // Largest first — class 0 is always the biggest model, matching how
    // the Hulk planner feeds Algorithm 1.
    ModelSpec::sort_largest_first(&mut tasks);
    tasks
}

/// Generate `count` labeled graphs with `slots` node slots.
pub fn make_dataset(count: usize, slots: usize, seed: u64)
    -> Vec<LabeledGraph>
{
    let mut rng = Rng::new(seed ^ 0x4441_5441); // "DATA"
    (0..count)
        .map(|i| {
            let n = 8 + rng.below(slots.min(46) - 7); // 8..=min(46,slots)
            let fleet = Fleet::random(n, seed.wrapping_add(i as u64 * 977));
            let tasks = sample_tasks(&mut rng, fleet.total_memory_gb());
            LabeledGraph::from_fleet(&fleet, &tasks, slots)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::FEATURE_DIM;

    #[test]
    fn shapes_are_padded_consistently() {
        let ds = make_dataset(5, 64, 0);
        assert_eq!(ds.len(), 5);
        for g in &ds {
            assert_eq!(g.adj.len(), 64 * 64);
            assert_eq!(g.feats.len(), 64 * FEATURE_DIM);
            assert_eq!(g.labels.len(), 64);
            assert_eq!(g.mask.len(), 64);
            assert_eq!(g.mask.iter().sum::<f32>() as usize, g.n_real);
        }
    }

    #[test]
    fn labels_are_valid_classes() {
        let ds = make_dataset(10, 64, 1);
        for g in &ds {
            for i in 0..g.n_real {
                let l = g.labels[i];
                assert!((0..=SPARE_CLASS).contains(&l), "label {l}");
            }
            // At least two distinct classes among real nodes (it's a
            // partition of ≥2 tasks or tasks+spares).
            let mut classes: Vec<i32> =
                g.labels[..g.n_real].to_vec();
            classes.sort_unstable();
            classes.dedup();
            assert!(!classes.is_empty());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = make_dataset(3, 64, 42);
        let b = make_dataset(3, 64, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.labels, y.labels);
            assert_eq!(x.adj, y.adj);
        }
    }

    #[test]
    fn paper_fleet_example_has_class_zero_for_opt() {
        let fleet = Fleet::paper_evaluation(0);
        let g = LabeledGraph::from_fleet(&fleet, &ModelSpec::paper_four(), 64);
        assert_eq!(g.n_real, 46);
        // Class 0 (OPT) must be populated with multiple machines.
        let opt_count =
            g.labels[..46].iter().filter(|&&l| l == 0).count();
        assert!(opt_count >= 8, "OPT group has {opt_count} machines");
    }
}
