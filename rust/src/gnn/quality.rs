//! Assignment-quality metrics: how good is a grouping *operationally*,
//! independent of exact label match?
//!
//! Exact-match accuracy under-credits the GCN: machines with identical
//! `{region, GPU}` are interchangeable and the oracle breaks ties
//! arbitrarily (EXPERIMENTS.md §Fig4). What the system actually cares
//! about is the quality of the groups Algorithm 1 produces — measured
//! here as intra-group communication cost, memory slack and feasibility,
//! comparable across splitters (GNN vs oracle vs random).

use crate::cluster::Fleet;
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::scheduler::Assignment;
use crate::util::rng::Rng;

/// Quality metrics for one assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct AssignmentQuality {
    /// Σ intra-group pairwise latency (the Hulk objective; lower=better).
    pub comm_cost: f64,
    /// Min over tasks of group-memory / required-memory (≥1 = feasible).
    pub min_memory_slack: f64,
    /// Are all groups connected subgraphs?
    pub all_connected: bool,
    /// Number of spare machines (recovery pool).
    pub spares: usize,
}

/// Compute quality of `assignment` for `tasks`.
pub fn assignment_quality(fleet: &Fleet, graph: &ClusterGraph,
                          assignment: &Assignment, tasks: &[ModelSpec])
    -> AssignmentQuality
{
    let comm_cost = assignment.total_cost(graph);
    let mut min_slack = f64::INFINITY;
    for (t, group) in assignment.groups.iter().enumerate() {
        let mem: f64 = group
            .iter()
            .map(|&m| fleet.machines[m].total_memory_gb())
            .sum();
        min_slack = min_slack.min(mem / tasks[t].train_gb());
    }
    AssignmentQuality {
        comm_cost,
        min_memory_slack: min_slack,
        all_connected: assignment.validate_connected(graph).is_ok(),
        spares: assignment.spares(fleet.len()).len(),
    }
}

/// Baseline: random assignment with the same group sizes (averaged over
/// `trials` shuffles). Returns the mean comm cost — the denominator for
/// a "how much better than chance" ratio.
pub fn random_baseline_cost(fleet: &Fleet, graph: &ClusterGraph,
                            sizes: &[usize], seed: u64, trials: usize)
    -> f64
{
    let mut rng = Rng::new(seed ^ 0x5155_414C); // "QUAL"
    let mut total = 0.0;
    for _ in 0..trials {
        let mut ids: Vec<usize> = (0..fleet.len()).collect();
        rng.shuffle(&mut ids);
        let mut off = 0;
        let mut groups = Vec::with_capacity(sizes.len());
        for &s in sizes {
            let end = (off + s).min(ids.len());
            groups.push(ids[off..end].to_vec());
            off = end;
        }
        total += Assignment::new(groups).total_cost(graph);
    }
    total / trials as f64
}

/// Comm-cost ratio of an assignment vs the random baseline with matched
/// group sizes (0 = perfect co-location, 1 = no better than chance).
pub fn cost_vs_random(fleet: &Fleet, graph: &ClusterGraph,
                      assignment: &Assignment, seed: u64) -> f64
{
    let sizes: Vec<usize> =
        assignment.groups.iter().map(Vec::len).collect();
    let baseline = random_baseline_cost(fleet, graph, &sizes, seed, 16);
    if baseline <= 0.0 {
        return 0.0;
    }
    assignment.total_cost(graph) / baseline
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{oracle_partition, OracleOptions};

    fn setup() -> (Fleet, ClusterGraph, Assignment, Vec<ModelSpec>) {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let mut tasks = ModelSpec::paper_four();
        ModelSpec::sort_largest_first(&mut tasks);
        let a = oracle_partition(&fleet, &graph, &tasks,
                                 &OracleOptions::default());
        (fleet, graph, a, tasks)
    }

    #[test]
    fn oracle_quality_is_feasible_and_connected() {
        let (fleet, graph, a, tasks) = setup();
        let q = assignment_quality(&fleet, &graph, &a, &tasks);
        assert!(q.min_memory_slack >= 1.0, "slack {}", q.min_memory_slack);
        assert!(q.all_connected);
        assert!(q.comm_cost > 0.0);
    }

    #[test]
    fn oracle_beats_random_baseline() {
        let (fleet, graph, a, _) = setup();
        let ratio = cost_vs_random(&fleet, &graph, &a, 1);
        assert!(ratio < 0.9, "oracle/random cost ratio {ratio}");
    }

    #[test]
    fn random_baseline_is_deterministic_per_seed() {
        let (fleet, graph, a, _) = setup();
        let sizes: Vec<usize> = a.groups.iter().map(Vec::len).collect();
        let x = random_baseline_cost(&fleet, &graph, &sizes, 5, 8);
        let y = random_baseline_cost(&fleet, &graph, &sizes, 5, 8);
        assert_eq!(x, y);
        let z = random_baseline_cost(&fleet, &graph, &sizes, 6, 8);
        assert_ne!(x, z);
    }

    #[test]
    fn worse_assignment_scores_worse() {
        let (fleet, graph, a, tasks) = setup();
        // Scatter the first two groups' members across each other.
        let mut bad = a.clone();
        let k = bad.groups[0].len().min(bad.groups[1].len()) / 2;
        for i in 0..k {
            let x = bad.groups[0][i];
            bad.groups[0][i] = bad.groups[1][i];
            bad.groups[1][i] = x;
        }
        let qa = assignment_quality(&fleet, &graph, &a, &tasks);
        let qb = assignment_quality(&fleet, &graph, &bad, &tasks);
        assert!(qb.comm_cost >= qa.comm_cost,
                "swap should not reduce the oracle's optimized cost");
    }
}
