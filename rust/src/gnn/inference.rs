//! GCN inference for Algorithm 1: classify machines into task classes.
//!
//! Two backends behind one enum: the PJRT `forward` artifact (production
//! path) and the pure-Rust reference forward (artifact-free tests, CI
//! without the python toolchain).

use anyhow::Result;

use crate::cluster::Fleet;
use crate::graph::{node_features, ClusterGraph};
use crate::models::ModelSpec;
use crate::runtime::GcnRuntime;
use crate::scheduler::TaskSplitter;

use super::reference::RefGcn;

/// A classification backend.
pub enum Classifier {
    /// AOT-compiled GCN through PJRT.
    Runtime(GcnRuntime),
    /// Pure-Rust reference forward (same math).
    Reference(RefGcn),
}

impl Classifier {
    pub fn slots(&self) -> usize {
        match self {
            Classifier::Runtime(rt) => rt.manifest.n,
            Classifier::Reference(r) => r.cfg.n,
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Classifier::Runtime(rt) => rt.manifest.c,
            Classifier::Reference(r) => r.cfg.c,
        }
    }

    /// Class probabilities, row-major `[slots, c]`.
    pub fn probs(&self, params: &[f32], adj: &[f32], feats: &[f32],
                 mask: &[f32]) -> Result<Vec<f32>>
    {
        match self {
            Classifier::Runtime(rt) => rt.forward(params, adj, feats, mask),
            Classifier::Reference(r) => {
                Ok(r.forward(adj, feats, mask).data)
            }
        }
    }
}

/// Classify every real machine of a fleet: returns per-machine class ids.
pub fn classify(classifier: &Classifier, params: &[f32], fleet: &Fleet)
    -> Result<Vec<usize>>
{
    let slots = classifier.slots();
    let graph = ClusterGraph::from_fleet(fleet);
    let adj = graph.padded_adj(slots);
    let feats = node_features(&fleet.machines, &graph, slots);
    let mask = graph.padded_mask(slots);
    let probs = classifier.probs(params, &adj, &feats, &mask)?;
    let c = classifier.n_classes();
    Ok((0..fleet.len())
        .map(|i| {
            let row = &probs[i * c..(i + 1) * c];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap()
        })
        .collect())
}

/// The trained-GNN splitter `F` for Algorithm 1: rank the remaining
/// machines by class-`i` probability and take the top slice that clears
/// the task's memory threshold.
pub struct GnnSplitter<'a> {
    pub classifier: &'a Classifier,
    pub params: &'a [f32],
}

impl TaskSplitter for GnnSplitter<'_> {
    fn split(&self, fleet: &Fleet, graph: &ClusterGraph,
             remaining: &[usize], task: &ModelSpec, class_idx: usize)
        -> Vec<usize>
    {
        let slots = self.classifier.slots();
        let adj = graph.padded_adj(slots);
        let feats = node_features(&fleet.machines, &graph, slots);
        let mask = graph.padded_mask(slots);
        let Ok(probs) =
            self.classifier.probs(self.params, &adj, &feats, &mask)
        else {
            return Vec::new();
        };
        let c = self.classifier.n_classes();
        let mut ranked: Vec<usize> = remaining.to_vec();
        ranked.sort_by(|&a, &b| {
            let pa = probs[a * c + class_idx];
            let pb = probs[b * c + class_idx];
            pb.partial_cmp(&pa).unwrap()
        });
        // Take machines until the memory threshold Mₙ is cleared, with
        // 20% headroom, then stop — Algorithm 1 wants "the smaller graph".
        let mut group = Vec::new();
        let mut mem = 0.0;
        for &m in &ranked {
            group.push(m);
            mem += fleet.machines[m].total_memory_gb();
            if mem >= task.train_gb() * 1.2 && group.len() >= 2 {
                break;
            }
        }
        group
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::reference::RefGcnConfig;
    use crate::util::rng::Rng;

    fn reference_classifier() -> (Classifier, Vec<f32>) {
        let cfg = RefGcnConfig { n: 64, f: crate::graph::FEATURE_DIM,
                                 h: 16, h2: 8, c: 8 };
        let mut rng = Rng::new(11);
        let params: Vec<f32> =
            (0..cfg.n_params()).map(|_| (rng.normal() * 0.1) as f32).collect();
        (Classifier::Reference(RefGcn::new(cfg, &params)), params)
    }

    #[test]
    fn classify_returns_one_class_per_machine() {
        let (clf, params) = reference_classifier();
        let fleet = Fleet::paper_toy(0);
        let classes = classify(&clf, &params, &fleet).unwrap();
        assert_eq!(classes.len(), 8);
        assert!(classes.iter().all(|&c| c < clf.n_classes()));
    }

    #[test]
    fn gnn_splitter_respects_remaining_pool() {
        let (clf, params) = reference_classifier();
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let splitter = GnnSplitter { classifier: &clf, params: &params };
        let remaining: Vec<usize> = (10..30).collect();
        let group = splitter.split(&fleet, &graph, &remaining,
                                   &ModelSpec::gpt2_xl(), 0);
        assert!(!group.is_empty());
        assert!(group.iter().all(|m| remaining.contains(m)));
        // Memory threshold reached.
        let mem: f64 = group
            .iter()
            .map(|&m| fleet.machines[m].total_memory_gb())
            .sum();
        assert!(mem >= ModelSpec::gpt2_xl().train_gb());
    }
}
