//! GCN inference for Algorithm 1: classify machines into task classes.
//!
//! Two backends behind one enum: the PJRT `forward` artifact (production
//! path) and the pure-Rust reference forward (artifact-free tests, CI
//! without the python toolchain).

use std::sync::OnceLock;

use anyhow::Result;

use crate::cluster::{Fleet, Machine};
use crate::graph::{node_features_csr, ClusterGraph, CsrGraph, GraphView,
                   CSR_DENSITY_MAX};
use crate::models::ModelSpec;
use crate::runtime::GcnRuntime;
use crate::scheduler::TaskSplitter;

use super::reference::RefGcn;

/// A classification backend.
pub enum Classifier {
    /// AOT-compiled GCN through PJRT.
    Runtime(GcnRuntime),
    /// Pure-Rust reference forward (same math).
    Reference(RefGcn),
}

impl Classifier {
    pub fn slots(&self) -> usize {
        match self {
            Classifier::Runtime(rt) => rt.manifest.n,
            Classifier::Reference(r) => r.cfg.n,
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Classifier::Runtime(rt) => rt.manifest.c,
            Classifier::Reference(r) => r.cfg.c,
        }
    }

    /// Class probabilities, row-major `[slots, c]`.
    pub fn probs(&self, params: &[f32], adj: &[f32], feats: &[f32],
                 mask: &[f32]) -> Result<Vec<f32>>
    {
        match self {
            Classifier::Runtime(rt) => rt.forward(params, adj, feats, mask),
            Classifier::Reference(r) => {
                Ok(r.forward(adj, feats, mask).data)
            }
        }
    }

    /// Does this backend aggregate `csr` through the sparse forward?
    /// True for the reference backend on a sparse-enough padded
    /// adjacency ([`CSR_DENSITY_MAX`]); the PJRT artifact always
    /// consumes the dense padded tensors its HLO was compiled for.
    /// The single definition of the selection rule — cached-tensor
    /// holders ([`ScenarioWorld`](crate::scenarios::ScenarioWorld))
    /// branch on it to feed the right cached tensor.
    pub fn uses_csr(&self, csr: &CsrGraph) -> bool {
        matches!(self, Classifier::Reference(_))
            && csr.density() <= CSR_DENSITY_MAX
    }

    /// Class probabilities from prebuilt (cached) padded tensors — the
    /// hot-path entry point consumed by
    /// [`ScenarioWorld::classify`](crate::scenarios::ScenarioWorld),
    /// whose `PaddedWorld` cache feeds it. Path selection is
    /// [`uses_csr`](Classifier::uses_csr); on the dense arm the padded
    /// adjacency is materialized from the CSR view (callers holding a
    /// cached dense tensor should branch on `uses_csr` and call
    /// [`probs`](Classifier::probs) directly instead).
    pub fn probs_for_padded(&self, params: &[f32], csr: &CsrGraph,
                            feats: &[f32], mask: &[f32])
        -> Result<Vec<f32>>
    {
        match self {
            Classifier::Reference(r) if self.uses_csr(csr) => {
                Ok(r.forward_csr(csr, feats, mask).data)
            }
            _ => self.probs(params, &csr.to_dense(), feats, mask),
        }
    }

    /// [`probs_for_padded`](Classifier::probs_for_padded) for callers
    /// without a cached context: builds the CSR view, features (O(E)
    /// instead of O(n²)), and mask from the graph first. `machines[i]`
    /// must describe the graph's node i — the fleet's machines for a
    /// machine-level graph, or one region representative per node for a
    /// hierarchical coarse graph.
    pub fn probs_for_graph(&self, params: &[f32], machines: &[Machine],
                           graph: &dyn GraphView) -> Result<Vec<f32>>
    {
        let slots = self.slots();
        let csr = graph.padded_csr(slots);
        let feats = node_features_csr(machines, &csr);
        let mask = graph.padded_mask(slots);
        self.probs_for_padded(params, &csr, &feats, &mask)
    }
}

/// NaN-safe row argmax: `total_cmp` ordering with the lowest index
/// winning ties — a degenerate forward (NaN probabilities) can no
/// longer panic the scheduler. Matches the PR 2 `total_cmp` +
/// deterministic-tiebreak convention (under the IEEE total order a
/// positive NaN ranks above every number, exactly as in
/// `ModelSpec::sort_largest_first`).
pub(crate) fn argmax_class(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1).then_with(|| b.0.cmp(&a.0)))
        .map(|(k, _)| k)
        .unwrap_or(0)
}

/// Per-machine class ids from a `[*, c]` probability buffer — the one
/// probs→classes loop shared by [`classify_with_graph`] and the cached
/// [`ScenarioWorld::classify`](crate::scenarios::ScenarioWorld) path.
pub(crate) fn classes_from_probs(probs: &[f32], n_machines: usize,
                                 c: usize) -> Vec<usize>
{
    (0..n_machines)
        .map(|i| argmax_class(&probs[i * c..(i + 1) * c]))
        .collect()
}

/// Classify every real machine of a fleet: returns per-machine class ids.
pub fn classify(classifier: &Classifier, params: &[f32], fleet: &Fleet)
    -> Result<Vec<usize>>
{
    let graph = ClusterGraph::from_fleet(fleet);
    classify_with_graph(classifier, params, fleet, &graph)
}

/// [`classify`] against a caller-provided (cached) graph — the hot-path
/// entry point for consumers holding a
/// [`ScenarioWorld`](crate::scenarios::ScenarioWorld)-style context.
pub fn classify_with_graph(classifier: &Classifier, params: &[f32],
                           fleet: &Fleet, graph: &dyn GraphView)
    -> Result<Vec<usize>>
{
    let probs =
        classifier.probs_for_graph(params, &fleet.machines, graph)?;
    Ok(classes_from_probs(&probs, fleet.len(), classifier.n_classes()))
}

/// The trained-GNN splitter `F` for Algorithm 1: rank the remaining
/// machines by class-`i` probability and take the top slice that clears
/// the task's memory threshold.
///
/// One instance serves one planning call over one (fleet, graph): the
/// class probabilities depend only on those, not on the task, so the
/// forward pass runs **once** and every per-task `split` reuses it
/// (Algorithm 1 used to pay a full GCN forward per task).
///
/// The serve daemon's batcher stretches the same contract across a
/// whole request batch: every `Place` in one batch window plans against
/// the same frozen (fleet, graph), so one splitter instance — hence one
/// forward — serves them all (`HulkSplitterKind::SharedGnn`). The memo
/// is tagged with [`GraphView::memo_key`], so reuse across *different*
/// graphs stays loud in debug builds and self-healing in release.
pub struct GnnSplitter<'a> {
    pub classifier: &'a Classifier,
    pub params: &'a [f32],
    /// Memoized forward pass (`None` = the forward failed), tagged
    /// with the identity of the graph it was computed for.
    probs: OnceLock<ProbsMemo>,
}

/// One memoized forward + the graph it belongs to (the graph's
/// [`GraphView::memo_key`]: node count and storage allocation address —
/// enough to catch a splitter reused across planning contexts in debug
/// builds).
struct ProbsMemo {
    graph_key: (usize, usize),
    probs: Option<Vec<f32>>,
}

impl<'a> GnnSplitter<'a> {
    pub fn new(classifier: &'a Classifier, params: &'a [f32])
        -> GnnSplitter<'a>
    {
        GnnSplitter { classifier, params, probs: OnceLock::new() }
    }

    /// Has the memoized forward pass run? The serve batcher reads this
    /// after a batch to count actual GCN forwards (a batch of non-GNN
    /// requests never triggers one).
    pub fn forward_ran(&self) -> bool {
        self.probs.get().is_some()
    }

    fn cached_probs(&self, fleet: &Fleet, graph: &dyn GraphView)
        -> Option<std::borrow::Cow<'_, [f32]>>
    {
        let key = graph.memo_key();
        let memo = self.probs.get_or_init(|| ProbsMemo {
            graph_key: key,
            probs: self
                .classifier
                .probs_for_graph(self.params, &fleet.machines, graph)
                .ok(),
        });
        if memo.graph_key == key {
            return memo.probs.as_deref().map(std::borrow::Cow::Borrowed);
        }
        // A splitter reused across planning contexts: loud in debug
        // builds, self-healing (fresh un-memoized forward) in release —
        // never stale probabilities for the wrong graph.
        debug_assert!(
            false,
            "GnnSplitter memoizes one (fleet, graph) — construct a new \
             splitter per planning call"
        );
        self.classifier
            .probs_for_graph(self.params, &fleet.machines, graph)
            .ok()
            .map(std::borrow::Cow::Owned)
    }
}

impl TaskSplitter for GnnSplitter<'_> {
    fn split(&self, fleet: &Fleet, graph: &dyn GraphView,
             remaining: &[usize], task: &ModelSpec, class_idx: usize)
        -> Vec<usize>
    {
        let Some(probs) = self.cached_probs(fleet, graph) else {
            return Vec::new();
        };
        let probs: &[f32] = &probs;
        let c = self.classifier.n_classes();
        let mut ranked: Vec<usize> = remaining.to_vec();
        ranked.sort_by(|&a, &b| {
            let pa = probs[a * c + class_idx];
            let pb = probs[b * c + class_idx];
            pb.total_cmp(&pa)
        });
        // Take machines until the memory threshold Mₙ is cleared, with
        // 20% headroom, then stop — Algorithm 1 wants "the smaller graph".
        let mut group = Vec::new();
        let mut mem = 0.0;
        for &m in &ranked {
            group.push(m);
            mem += fleet.machines[m].total_memory_gb();
            if mem >= task.train_gb() * 1.2 && group.len() >= 2 {
                break;
            }
        }
        group
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::reference::RefGcnConfig;
    use crate::util::rng::Rng;

    fn reference_classifier() -> (Classifier, Vec<f32>) {
        let cfg = RefGcnConfig { n: 64, f: crate::graph::FEATURE_DIM,
                                 h: 16, h2: 8, c: 8 };
        let mut rng = Rng::new(11);
        let params: Vec<f32> =
            (0..cfg.n_params()).map(|_| (rng.normal() * 0.1) as f32).collect();
        (Classifier::Reference(RefGcn::new(cfg, &params)), params)
    }

    #[test]
    fn classify_returns_one_class_per_machine() {
        let (clf, params) = reference_classifier();
        let fleet = Fleet::paper_toy(0);
        let classes = classify(&clf, &params, &fleet).unwrap();
        assert_eq!(classes.len(), 8);
        assert!(classes.iter().all(|&c| c < clf.n_classes()));
    }

    #[test]
    fn argmax_is_nan_safe_and_breaks_ties_low() {
        assert_eq!(argmax_class(&[0.1, 0.7, 0.2]), 1);
        // Ties break toward the lowest index (PR 2 convention).
        assert_eq!(argmax_class(&[0.4, 0.4, 0.2]), 0);
        // A degenerate forward must not panic. Under the IEEE total
        // order a positive NaN ranks above every number (the
        // sort_largest_first convention), and equal NaNs tie-break low.
        assert_eq!(argmax_class(&[f32::NAN, 0.3, f32::NAN]), 0);
        assert_eq!(argmax_class(&[0.3, f32::NAN, f32::NAN]), 1);
        assert_eq!(argmax_class(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_class(&[]), 0);
    }

    #[test]
    fn graph_probs_match_dense_probs() {
        // The auto-selected (CSR) path must agree with the padded-dense
        // tensors the PJRT artifact would see.
        let (clf, params) = reference_classifier();
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let slots = clf.slots();
        let adj = graph.padded_adj(slots);
        let feats =
            crate::graph::node_features(&fleet.machines, &graph, slots);
        let mask = graph.padded_mask(slots);
        let dense = clf.probs(&params, &adj, &feats, &mask).unwrap();
        let auto =
            clf.probs_for_graph(&params, &fleet.machines, &graph).unwrap();
        let c = clf.n_classes();
        for i in 0..fleet.len() {
            for k in 0..c {
                let (d, a) = (dense[i * c + k], auto[i * c + k]);
                assert!((d - a).abs() < 1e-5, "({i},{k}): {d} vs {a}");
            }
        }
        // classify() and the explicit-graph variant agree.
        assert_eq!(classify(&clf, &params, &fleet).unwrap(),
                   classify_with_graph(&clf, &params, &fleet, &graph)
                       .unwrap());
    }

    #[test]
    fn gnn_splitter_memoizes_the_forward_pass() {
        let (clf, params) = reference_classifier();
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let splitter = GnnSplitter::new(&clf, &params);
        let remaining: Vec<usize> = (0..fleet.len()).collect();
        let first = splitter.split(&fleet, &graph, &remaining,
                                   &ModelSpec::gpt2_xl(), 0);
        // Second split on the same context reuses the memoized probs —
        // and must rank identically.
        let second = splitter.split(&fleet, &graph, &remaining,
                                    &ModelSpec::gpt2_xl(), 0);
        assert_eq!(first, second);
        assert!(splitter.probs.get().is_some(), "forward not memoized");
    }

    #[test]
    fn gnn_splitter_respects_remaining_pool() {
        let (clf, params) = reference_classifier();
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let splitter = GnnSplitter::new(&clf, &params);
        let remaining: Vec<usize> = (10..30).collect();
        let group = splitter.split(&fleet, &graph, &remaining,
                                   &ModelSpec::gpt2_xl(), 0);
        assert!(!group.is_empty());
        assert!(group.iter().all(|m| remaining.contains(m)));
        // Memory threshold reached.
        let mem: f64 = group
            .iter()
            .map(|&m| fleet.machines[m].total_memory_gb())
            .sum();
        assert!(mem >= ModelSpec::gpt2_xl().train_gb());
    }
}
