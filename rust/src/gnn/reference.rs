//! Pure-Rust reference GCN forward pass — a line-for-line mirror of
//! `python/compile/model.py::forward` (edge pool → 3 GCN layers → GCN
//! head → masked softmax) over the flat parameter vector laid out by
//! `ModelConfig.param_layout()`.
//!
//! Used (a) to unit-test the marshalling path without the python
//! toolchain, (b) to cross-check the PJRT artifact numerics in
//! integration tests, (c) as an inference fallback when `artifacts/` is
//! missing. Training always goes through PJRT (there is deliberately no
//! Rust backward pass — the paper's training math lives in L2).

use crate::util::MatF32;
use crate::graph::csr::{sym_normalize_csr, CsrGraph, CsrNormalized};
use crate::graph::normalize::sym_normalize;

/// Must match `WSUM_SCALE` in model.py.
pub const WSUM_SCALE: f32 = 0.01;

/// Shape contract (mirrors python `ModelConfig`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RefGcnConfig {
    pub n: usize,
    pub f: usize,
    pub h: usize,
    pub h2: usize,
    pub c: usize,
}

impl RefGcnConfig {
    /// Default artifact dims (manifest.kv).
    pub fn default_artifact() -> RefGcnConfig {
        RefGcnConfig { n: 64, f: 18, h: 192, h2: 96, c: 8 }
    }

    /// (name, rows, cols) layout in flat-vector order; biases are 1×d.
    pub fn param_layout(&self) -> Vec<(&'static str, usize, usize)> {
        vec![
            ("ep_w_self", self.f, self.h),
            ("ep_w_nbr", self.f, self.h),
            ("ep_w_e", 1, self.h),
            ("ep_b", 1, self.h),
            ("g1_w", self.h, self.h),
            ("g1_ws", self.h, self.h),
            ("g1_b", 1, self.h),
            ("g2_w", self.h, self.h),
            ("g2_ws", self.h, self.h),
            ("g2_b", 1, self.h),
            ("g3_w", self.h, self.h2),
            ("g3_ws", self.h, self.h2),
            ("g3_b", 1, self.h2),
            ("hd_w", self.h2, self.c),
            ("hd_ws", self.h2, self.c),
            ("hd_b", 1, self.c),
        ]
    }

    pub fn n_params(&self) -> usize {
        self.param_layout().iter().map(|(_, r, c)| r * c).sum()
    }
}

/// The reference model: config + sliced parameter matrices.
pub struct RefGcn {
    pub cfg: RefGcnConfig,
    params: Vec<MatF32>,
}

impl RefGcn {
    pub fn new(cfg: RefGcnConfig, flat: &[f32]) -> RefGcn {
        assert_eq!(flat.len(), cfg.n_params(), "param vector length");
        let mut params = Vec::new();
        let mut off = 0;
        for (_, r, c) in cfg.param_layout() {
            params.push(MatF32::from_vec(r, c, flat[off..off + r * c].to_vec()));
            off += r * c;
        }
        RefGcn { cfg, params }
    }

    fn p(&self, idx: usize) -> &MatF32 {
        &self.params[idx]
    }

    /// Dense forward pass → probabilities [n, c]. Inputs are padded
    /// row-major tensors exactly as fed to the PJRT artifact. This is
    /// the padded-dense **oracle**: O(n²·F) aggregation over every slot
    /// pair. The evaluation hot path goes through
    /// [`forward_csr`](RefGcn::forward_csr), which this path
    /// cross-checks in the parity tests.
    pub fn forward(&self, adj: &[f32], feats: &[f32], mask: &[f32]) -> MatF32 {
        let (n, f) = (self.cfg.n, self.cfg.f);
        assert_eq!(adj.len(), n * n);
        assert_eq!(feats.len(), n * f);
        assert_eq!(mask.len(), n);
        let x = MatF32::from_vec(n, f, feats.to_vec());
        let a_hat = sym_normalize(adj, n);

        // Edge pooling (model.py::_edge_pool).
        let mut nbr_sum = MatF32::zeros(n, f);
        let mut deg = vec![0.0f32; n];
        let mut wsum = vec![0.0f32; n];
        for i in 0..n {
            for j in 0..n {
                let w = adj[i * n + j];
                if w > 0.0 {
                    deg[i] += 1.0;
                    wsum[i] += w;
                    for k in 0..f {
                        let v = nbr_sum.at(i, k) + x.at(j, k);
                        nbr_sum.set(i, k, v);
                    }
                }
            }
        }
        let degc: Vec<f32> = deg.iter().map(|&d| d.max(1.0)).collect();
        let mut h0 = x.matmul(self.p(0)); // ep_w_self
        let mut nbr_mean = nbr_sum;
        nbr_mean.scale_rows(&degc.iter().map(|d| 1.0 / d).collect::<Vec<_>>());
        let nbr_term = nbr_mean.matmul(self.p(1)); // ep_w_nbr
        let w_e = self.p(2); // 1 × h
        for i in 0..n {
            let wmean = wsum[i] / degc[i] * WSUM_SCALE;
            for k in 0..self.cfg.h {
                let v = h0.at(i, k)
                    + nbr_term.at(i, k)
                    + wmean * w_e.at(0, k)
                    + self.p(3).at(0, k); // ep_b
                h0.set(i, k, v);
            }
        }
        h0.relu_inplace();
        h0.scale_rows(mask);

        // GCN stack (gcn_layer: act(Â (X W) + X W_self + b) · mask).
        let h1 = self.gcn_layer(&a_hat, &h0, 4, 5, 6, true, mask);
        let h2 = self.gcn_layer(&a_hat, &h1, 7, 8, 9, true, mask);
        let h3 = self.gcn_layer(&a_hat, &h2, 10, 11, 12, true, mask);
        let logits =
            self.gcn_layer(&a_hat, &h3, 13, 14, 15, false, &vec![1.0; n]);

        // Row softmax.
        let mut probs = logits;
        for i in 0..n {
            softmax_inplace(&mut probs.data[i * self.cfg.c
                                            ..(i + 1) * self.cfg.c]);
        }
        probs
    }

    /// Sparse forward pass over a (padded) CSR adjacency →
    /// probabilities [n, c]. Same math as [`forward`](RefGcn::forward)
    /// restricted to the `adj.real` machine rows — the padded slots are
    /// all-zero through every masked layer, so only the real block is
    /// ever computed: neighborhood aggregation is O(E·F) instead of the
    /// padded-dense O(n²·F), and the dense per-row products shrink from
    /// `n` (slots) to `real` rows. Padded output rows are left at zero
    /// (they are never consumed; the dense oracle softmaxes them to a
    /// bias-only distribution instead), so parity checks compare the
    /// real rows.
    pub fn forward_csr(&self, adj: &CsrGraph, feats: &[f32], mask: &[f32])
        -> MatF32
    {
        let (n, f) = (self.cfg.n, self.cfg.f);
        assert_eq!(adj.n, n, "CSR slot count must match the model");
        assert_eq!(feats.len(), n * f);
        assert_eq!(mask.len(), n);
        let real = adj.real;
        let x = MatF32::from_vec(real, f, feats[..real * f].to_vec());
        let a_hat = sym_normalize_csr(adj);

        // Edge pooling over the stored edges only (model.py::_edge_pool).
        let mut nbr_sum = MatF32::zeros(real, f);
        let mut deg = vec![0.0f32; real];
        let mut wsum = vec![0.0f32; real];
        for i in 0..real {
            let (cols, vals) = adj.row(i);
            for (&j, &w) in cols.iter().zip(vals) {
                deg[i] += 1.0;
                wsum[i] += w;
                for k in 0..f {
                    let v = nbr_sum.at(i, k) + x.at(j, k);
                    nbr_sum.set(i, k, v);
                }
            }
        }
        let degc: Vec<f32> = deg.iter().map(|&d| d.max(1.0)).collect();
        let mut h0 = x.matmul(self.p(0)); // ep_w_self
        let mut nbr_mean = nbr_sum;
        nbr_mean.scale_rows(&degc.iter().map(|d| 1.0 / d).collect::<Vec<_>>());
        let nbr_term = nbr_mean.matmul(self.p(1)); // ep_w_nbr
        let w_e = self.p(2); // 1 × h
        for i in 0..real {
            let wmean = wsum[i] / degc[i] * WSUM_SCALE;
            for k in 0..self.cfg.h {
                let v = h0.at(i, k)
                    + nbr_term.at(i, k)
                    + wmean * w_e.at(0, k)
                    + self.p(3).at(0, k); // ep_b
                h0.set(i, k, v);
            }
        }
        h0.relu_inplace();
        h0.scale_rows(&mask[..real]);

        let h1 = self.gcn_layer_csr(&a_hat, &h0, 4, 5, 6, true,
                                    &mask[..real]);
        let h2 = self.gcn_layer_csr(&a_hat, &h1, 7, 8, 9, true,
                                    &mask[..real]);
        let h3 = self.gcn_layer_csr(&a_hat, &h2, 10, 11, 12, true,
                                    &mask[..real]);
        let ones = vec![1.0f32; real];
        let logits = self.gcn_layer_csr(&a_hat, &h3, 13, 14, 15, false,
                                        &ones);

        let mut probs = MatF32::zeros(n, self.cfg.c);
        for i in 0..real {
            let row = &mut probs.data[i * self.cfg.c..(i + 1) * self.cfg.c];
            row.copy_from_slice(logits.row(i));
            softmax_inplace(row);
        }
        probs
    }

    fn gcn_layer(&self, a_hat: &MatF32, x: &MatF32, w_idx: usize,
                 ws_idx: usize, b_idx: usize, relu: bool, mask: &[f32])
        -> MatF32
    {
        let xw = x.matmul(self.p(w_idx));
        // Branch-free dense aggregation — the same O(n²·F) contraction
        // model.py runs, which is exactly what makes this path the
        // oracle rather than the hot path.
        let out = a_hat.matmul(&xw);
        self.finish_layer(out, x, ws_idx, b_idx, relu, mask)
    }

    fn gcn_layer_csr(&self, a_hat: &CsrNormalized, x: &MatF32,
                     w_idx: usize, ws_idx: usize, b_idx: usize, relu: bool,
                     mask: &[f32]) -> MatF32
    {
        let xw = x.matmul(self.p(w_idx));
        let out = a_hat.matmul_real(&xw);
        self.finish_layer(out, x, ws_idx, b_idx, relu, mask)
    }

    /// Shared layer tail: `+ X·W_self + b`, activation, node mask.
    fn finish_layer(&self, mut out: MatF32, x: &MatF32, ws_idx: usize,
                    b_idx: usize, relu: bool, mask: &[f32]) -> MatF32
    {
        let self_term = x.matmul(self.p(ws_idx));
        for (o, s) in out.data.iter_mut().zip(&self_term.data) {
            *o += s;
        }
        out.add_row_bias(self.p(b_idx).row(0));
        if relu {
            out.relu_inplace();
        }
        out.scale_rows(mask);
        out
    }
}

/// Numerically stable in-place softmax of one row.
fn softmax_inplace(row: &mut [f32]) {
    let row_max = row.iter().cloned().fold(f32::MIN, f32::max);
    let mut denom = 0.0;
    for v in row.iter_mut() {
        *v = (*v - row_max).exp();
        denom += *v;
    }
    for v in row.iter_mut() {
        *v /= denom;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> RefGcnConfig {
        RefGcnConfig { n: 8, f: 16, h: 8, h2: 4, c: 2 }
    }

    fn rand_params(cfg: &RefGcnConfig, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..cfg.n_params())
            .map(|_| (r.normal() * 0.2) as f32)
            .collect()
    }

    fn toy_inputs(cfg: &RefGcnConfig) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let n = cfg.n;
        let mut adj = vec![0.0f32; n * n];
        for i in 0..5 {
            for j in (i + 1)..5 {
                let w = 30.0 + 10.0 * (i + j) as f32;
                adj[i * n + j] = w;
                adj[j * n + i] = w;
            }
        }
        let mut feats = vec![0.0f32; n * cfg.f];
        for i in 0..5 {
            feats[i * cfg.f + i] = 1.0;
            feats[i * cfg.f + 15] = 1.0;
        }
        let mut mask = vec![0.0f32; n];
        for m in &mut mask[..5] {
            *m = 1.0;
        }
        (adj, feats, mask)
    }

    #[test]
    fn default_param_count_matches_manifest() {
        assert_eq!(RefGcnConfig::default_artifact().n_params(), 193_640);
    }

    #[test]
    fn forward_outputs_probability_rows() {
        let cfg = tiny_cfg();
        let gcn = RefGcn::new(cfg, &rand_params(&cfg, 1));
        let (adj, feats, mask) = toy_inputs(&cfg);
        let probs = gcn.forward(&adj, &feats, &mask);
        assert_eq!((probs.rows, probs.cols), (cfg.n, cfg.c));
        for i in 0..cfg.n {
            let s: f32 = probs.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
            assert!(probs.row(i).iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn padded_garbage_does_not_leak_into_real_rows() {
        let cfg = tiny_cfg();
        let gcn = RefGcn::new(cfg, &rand_params(&cfg, 2));
        let (adj, mut feats, mask) = toy_inputs(&cfg);
        let base = gcn.forward(&adj, &feats, &mask);
        for i in 5..8 {
            for k in 0..cfg.f {
                feats[i * cfg.f + k] = 999.0;
            }
        }
        let poked = gcn.forward(&adj, &feats, &mask);
        for i in 0..5 {
            for k in 0..cfg.c {
                assert!((base.at(i, k) - poked.at(i, k)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn csr_forward_matches_dense_forward() {
        // With real == slots the sparse path must reproduce every row of
        // the dense oracle (padded-slot behavior is covered by the
        // integration parity suite on real fleets).
        let cfg = tiny_cfg();
        let gcn = RefGcn::new(cfg, &rand_params(&cfg, 5));
        let (adj, feats, mask) = toy_inputs(&cfg);
        let dense = gcn.forward(&adj, &feats, &mask);
        let graph = crate::graph::ClusterGraph { n: cfg.n,
                                                 adj: adj.clone() };
        let csr = CsrGraph::from_graph(&graph);
        let sparse = gcn.forward_csr(&csr, &feats, &mask);
        assert!(dense.max_abs_diff(&sparse) < 1e-5,
                "max diff {}", dense.max_abs_diff(&sparse));
    }

    #[test]
    fn deterministic_forward() {
        let cfg = tiny_cfg();
        let params = rand_params(&cfg, 3);
        let (adj, feats, mask) = toy_inputs(&cfg);
        let a = RefGcn::new(cfg, &params).forward(&adj, &feats, &mask);
        let b = RefGcn::new(cfg, &params).forward(&adj, &feats, &mask);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "param vector length")]
    fn wrong_param_length_panics() {
        let cfg = tiny_cfg();
        RefGcn::new(cfg, &[0.0; 10]);
    }
}
