//! System A (paper §6.4): data parallelism over every machine that can
//! hold the entire model; machines without sufficient memory are
//! discarded. When *no* machine fits the model (OPT-175B on this fleet),
//! the system genuinely cannot train it — reported as infeasible.

use crate::cluster::Fleet;
use crate::models::ModelSpec;
use crate::parallel::data_parallel::{data_parallel_cost, replica_capable};
use crate::parallel::IterCost;

/// Per-iteration cost of training `model` under System A.
pub fn cost(fleet: &Fleet, model: &ModelSpec) -> IterCost {
    let replicas = replica_capable(fleet, model);
    data_parallel_cost(fleet, &replicas, model)
}

/// The machines System A would use for `model` (for reports).
pub fn participants(fleet: &Fleet, model: &ModelSpec) -> Vec<usize> {
    replica_capable(fleet, model)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_uses_whole_fleet() {
        let fleet = Fleet::paper_evaluation(0);
        let model = ModelSpec::bert_large();
        assert_eq!(participants(&fleet, &model).len(), 46);
        assert!(cost(&fleet, &model).is_feasible());
    }

    #[test]
    fn opt_is_infeasible() {
        let fleet = Fleet::paper_evaluation(0);
        let model = ModelSpec::opt_175b();
        assert!(participants(&fleet, &model).is_empty());
        assert!(!cost(&fleet, &model).is_feasible());
    }

    #[test]
    fn t5_uses_a_strict_subset() {
        let fleet = Fleet::paper_evaluation(0);
        let model = ModelSpec::t5_11b();
        let p = participants(&fleet, &model);
        assert!(!p.is_empty() && p.len() < 46,
                "expected a strict subset, got {}", p.len());
    }
}
