//! System C (paper §6.4): "employs tensor parallelism with Megatron-LM
//! across the entire system, requiring all machines to be utilized."

use crate::cluster::Fleet;
use crate::models::ModelSpec;
use crate::parallel::{tensor_parallel_cost, IterCost};

/// Per-iteration cost of training `model` under System C.
pub fn cost(fleet: &Fleet, model: &ModelSpec) -> IterCost {
    let all: Vec<usize> = (0..fleet.len()).collect();
    tensor_parallel_cost(fleet, &all, model)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasible_but_comm_bound_for_every_model() {
        let fleet = Fleet::paper_evaluation(0);
        for model in ModelSpec::paper_six() {
            let c = cost(&fleet, &model);
            assert!(c.is_feasible(), "{}", model.name);
            assert!(c.comm_ms > c.comp_ms,
                    "{}: TP over WAN must be comm-bound", model.name);
        }
    }
}
