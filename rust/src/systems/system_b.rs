//! System B (paper §6.4): "utilizes Gpipe for parallelism, assigning a
//! certain layer of the model to a particular machine until the entire
//! model is distributed across all machines." Stage order is machine-id
//! order — topology-oblivious, so stages routinely straddle continents,
//! which is the pathology Hulk's grouping removes.

use crate::cluster::Fleet;
use crate::models::ModelSpec;
use crate::parallel::{pipeline_cost, IterCost, PipelinePlan};

/// System B's pipeline plan: first `min(layers, n)` machines in id order.
pub fn plan(fleet: &Fleet, model: &ModelSpec) -> PipelinePlan {
    let n_stages = fleet.len().min(model.layers);
    let stages: Vec<usize> = (0..n_stages).collect();
    PipelinePlan::proportional(fleet, stages, model)
}

/// Per-iteration cost of training `model` under System B.
pub fn cost(fleet: &Fleet, model: &ModelSpec) -> IterCost {
    pipeline_cost(fleet, &plan(fleet, model), model)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uses_all_machines_up_to_layer_count() {
        let fleet = Fleet::paper_evaluation(0);
        let p = plan(&fleet, &ModelSpec::opt_175b()); // 96 layers > 46
        assert_eq!(p.n_stages(), 46);
        let p2 = plan(&fleet, &ModelSpec::bert_large()); // 24 layers < 46
        assert_eq!(p2.n_stages(), 24);
    }

    #[test]
    fn feasible_for_all_paper_models() {
        let fleet = Fleet::paper_evaluation(0);
        for model in ModelSpec::paper_six() {
            let c = cost(&fleet, &model);
            assert!(c.is_feasible(), "{} infeasible under B", model.name);
        }
    }

    #[test]
    fn pays_heavy_cross_region_comm() {
        let fleet = Fleet::paper_evaluation(0);
        let c = cost(&fleet, &ModelSpec::gpt2_xl());
        // id-order stages cross regions constantly: comm must dominate
        // compute for a model this small.
        assert!(c.comm_ms > c.comp_ms, "comm {} comp {}", c.comm_ms,
                c.comp_ms);
    }
}
