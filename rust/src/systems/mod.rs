//! Compatibility facade for the paper's four systems (§6.4).
//!
//! The systems themselves — **System A** (pure data parallelism),
//! **System B** (id-order GPipe), **System C** (fleet-wide Megatron
//! tensor parallelism) and **Hulk** (GCN/Algorithm-1 grouping + per-group
//! locality-aware GPipe) — now live behind the [`crate::planner`] seam:
//! each is a [`Planner`](crate::planner::Planner) implementation emitting
//! a typed [`Placement`](crate::planner::Placement), registered in the
//! [`PlannerRegistry`](crate::planner::PlannerRegistry).
//!
//! The divergent per-system free functions this module used to host
//! (`system_a::cost`, `system_b::plan`/`cost`, `system_c::cost`,
//! `hulk::hulk_plan` and the `HulkPlan` type) were deleted once every
//! call site migrated to the trait; the re-exports below point old
//! `crate::systems::…` paths at the planner module and the evaluation
//! harness in [`crate::scenarios`]. New code should import from
//! [`crate::planner`] directly.

pub use crate::planner::{chain_order, CostBackend, HulkNoGcnPlanner,
                         HulkPlanner, HulkSplitterKind, Placement,
                         PlanContext, Planner, PlannerKind,
                         PlannerRegistry, PricedPlacement, SystemAPlanner,
                         SystemBPlanner, SystemCPlanner, SystemMeta,
                         TaskPlacement};
pub use crate::scenarios::evaluate::{evaluate_all, evaluate_with,
                                     evaluate_with_backend, SystemEval};
pub use crate::scenarios::sweep::{fleet_size_sweep, microbatch_sweep,
                                  wan_degradation_sweep, SweepPoint};
