//! The four systems of the paper's evaluation (§6.4):
//!
//! - **System A** ([`system_a`]) — pure data parallelism; drops machines
//!   that cannot hold a full replica.
//! - **System B** ([`system_b`]) — GPipe across every machine, layers
//!   assigned in id order until the model is distributed.
//! - **System C** ([`system_c`]) — Megatron-LM tensor parallelism across
//!   the entire fleet.
//! - **Hulk** ([`hulk`]) — GCN/Algorithm-1 grouping, then GPipe inside
//!   each group with a locality-aware stage order.
//!
//! The evaluation harness that runs a workload through all four
//! (`evaluate_all` → Fig. 8 / Fig. 10 rows) and the ablation sweeps live
//! in [`crate::scenarios`] since the scenario subsystem was introduced;
//! their names are re-exported here so existing callers keep working.

pub mod hulk;
pub mod system_a;
pub mod system_b;
pub mod system_c;

pub use crate::scenarios::evaluate::{evaluate_all, SystemEval, SystemKind};
pub use crate::scenarios::sweep::{fleet_size_sweep, microbatch_sweep,
                                  wan_degradation_sweep, SweepPoint};
pub use hulk::{hulk_plan, HulkPlan, HulkSplitterKind};
