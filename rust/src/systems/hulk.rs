//! The Hulk system: GCN (or oracle) grouping via Algorithm 1, then GPipe
//! inside each group with a locality-aware stage order (paper §5–§6:
//! "we utilize Gpipe to train the model in parallel [within each class];
//! depending on the computational power and memory of each node, we
//! determine which part of the model it will handle").

use anyhow::Result;

use crate::cluster::Fleet;
use crate::gnn::inference::GnnSplitter;
use crate::gnn::Classifier;
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::parallel::{pipeline_cost, IterCost, PipelinePlan};
use crate::scheduler::{algorithm1, Algorithm1Error, Assignment,
                       TaskSplitter};

/// Which splitter `F` drives Algorithm 1.
pub enum HulkSplitterKind<'a> {
    /// The trained GCN (production path).
    Gnn { classifier: &'a Classifier, params: &'a [f32] },
    /// The oracle partitioner (ablation / artifact-free path).
    Oracle,
}

/// A complete Hulk deployment plan for a workload.
#[derive(Clone, Debug)]
pub struct HulkPlan {
    /// Tasks in descending parameter order (the order groups were cut).
    pub tasks: Vec<ModelSpec>,
    pub assignment: Assignment,
    /// Per-task pipeline plan (same index as `tasks`).
    pub pipelines: Vec<PipelinePlan>,
}

/// Oracle-backed splitter for Algorithm 1.
struct OracleSplitter;

impl TaskSplitter for OracleSplitter {
    fn split(&self, fleet: &Fleet, graph: &ClusterGraph,
             remaining: &[usize], task: &ModelSpec, _class: usize)
        -> Vec<usize>
    {
        crate::scheduler::oracle::grow_group(fleet, graph, remaining, task,
                                             1.3)
    }
}

/// Order a group's machines into a pipeline chain by greedy
/// nearest-neighbor on latency: adjacent stages end up in the same or
/// nearby regions.
pub fn chain_order(graph: &ClusterGraph, group: &[usize]) -> Vec<usize> {
    if group.len() <= 2 {
        return group.to_vec();
    }
    // Start from the member with the lowest total latency to the rest.
    let start = *group
        .iter()
        .min_by(|&&a, &&b| {
            let cost = |i: usize| -> f32 {
                group
                    .iter()
                    .map(|&j| {
                        let w = graph.weight(i, j);
                        if j != i && w == 0.0 { 2e3 } else { w }
                    })
                    .sum()
            };
            cost(a).partial_cmp(&cost(b)).unwrap()
        })
        .unwrap();
    let mut chain = vec![start];
    let mut rest: Vec<usize> =
        group.iter().copied().filter(|&m| m != start).collect();
    while !rest.is_empty() {
        let last = *chain.last().unwrap();
        let (k, _) = rest
            .iter()
            .enumerate()
            .min_by(|(_, &a), (_, &b)| {
                let cost = |i: usize| -> f32 {
                    let w = graph.weight(last, i);
                    if w == 0.0 { 2e3 } else { w }
                };
                cost(a).partial_cmp(&cost(b)).unwrap()
            })
            .unwrap();
        chain.push(rest.remove(k));
    }
    chain
}

/// Build the Hulk plan for a workload. Tasks are sorted largest-first
/// (class 0 = biggest model, matching the GCN's training labels).
pub fn hulk_plan(fleet: &Fleet, graph: &ClusterGraph,
                 workload: &[ModelSpec], splitter: HulkSplitterKind)
    -> Result<HulkPlan>
{
    let mut tasks = workload.to_vec();
    ModelSpec::sort_largest_first(&mut tasks);

    let assignment = match &splitter {
        HulkSplitterKind::Gnn { classifier, params } => {
            let f = GnnSplitter { classifier, params };
            run_algorithm1(fleet, graph, &tasks, &f)?
        }
        HulkSplitterKind::Oracle => {
            run_algorithm1(fleet, graph, &tasks, &OracleSplitter)?
        }
    };

    let mut pipelines = Vec::with_capacity(tasks.len());
    for (t, task) in tasks.iter().enumerate() {
        let group = assignment.group(t);
        anyhow::ensure!(!group.is_empty(), "task {} got no machines",
                        task.name);
        let ordered = chain_order(graph, group);
        let n_stages = ordered.len().min(task.layers);
        let stages: Vec<usize> = ordered.into_iter().take(n_stages).collect();
        pipelines.push(PipelinePlan::proportional(fleet, stages, task));
    }
    Ok(HulkPlan { tasks, assignment, pipelines })
}

fn run_algorithm1(fleet: &Fleet, graph: &ClusterGraph, tasks: &[ModelSpec],
                  f: &dyn TaskSplitter) -> Result<Assignment>
{
    match algorithm1(fleet, graph, tasks, f) {
        Ok(a) => Ok(a),
        Err(Algorithm1Error::MustWait { partial, deferred }) => {
            // The coordinator queues deferred tasks; for planning we
            // surface the partial assignment only if nothing is missing
            // entirely.
            anyhow::bail!(
                "Algorithm 1 deferred tasks {:?} (partial groups: {:?})",
                deferred,
                partial.groups.iter().map(Vec::len).collect::<Vec<_>>()
            )
        }
        Err(e) => anyhow::bail!("Algorithm 1 failed: {e}"),
    }
}

/// Per-iteration cost of `model` under the Hulk plan.
pub fn cost(fleet: &Fleet, plan: &HulkPlan, task_idx: usize) -> IterCost {
    pipeline_cost(fleet, &plan.pipelines[task_idx], &plan.tasks[task_idx])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Fleet, ClusterGraph) {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        (fleet, graph)
    }

    #[test]
    fn oracle_plan_covers_paper_workload() {
        let (fleet, graph) = setup();
        let plan = hulk_plan(&fleet, &graph, &ModelSpec::paper_four(),
                             HulkSplitterKind::Oracle)
            .unwrap();
        assert_eq!(plan.tasks.len(), 4);
        assert_eq!(plan.tasks[0].name, "OPT (175B)"); // sorted desc
        plan.assignment.validate_disjoint(fleet.len()).unwrap();
        plan.assignment.validate_memory(&fleet, &plan.tasks).unwrap();
        for t in 0..4 {
            let c = cost(&fleet, &plan, t);
            assert!(c.is_feasible(), "{} infeasible", plan.tasks[t].name);
        }
    }

    #[test]
    fn chain_order_is_a_permutation_and_locality_aware() {
        let (fleet, graph) = setup();
        let group: Vec<usize> = (0..12).collect();
        let chain = chain_order(&graph, &group);
        let mut sorted = chain.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, group);
        // Adjacent chain latency must not exceed a random order's by
        // construction (greedy NN): compare against identity order.
        let adj_cost = |order: &[usize]| -> f32 {
            order
                .windows(2)
                .map(|w| {
                    let x = graph.weight(w[0], w[1]);
                    if x == 0.0 { 2e3 } else { x }
                })
                .sum()
        };
        assert!(adj_cost(&chain) <= adj_cost(&group) * 1.01,
                "chain {} vs id {}", adj_cost(&chain), adj_cost(&group));
        let _ = fleet;
    }

    #[test]
    fn hulk_beats_system_b_on_comm() {
        let (fleet, graph) = setup();
        let plan = hulk_plan(&fleet, &graph, &ModelSpec::paper_four(),
                             HulkSplitterKind::Oracle)
            .unwrap();
        for (t, task) in plan.tasks.iter().enumerate() {
            let hulk_c = cost(&fleet, &plan, t);
            let b_c = crate::systems::system_b::cost(&fleet, task);
            assert!(hulk_c.comm_ms < b_c.comm_ms,
                    "{}: hulk {} vs B {}", task.name, hulk_c.comm_ms,
                    b_c.comm_ms);
        }
    }

    #[test]
    fn infeasible_workload_errors() {
        let fleet = Fleet::paper_toy(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let err = hulk_plan(&fleet, &graph, &[ModelSpec::opt_175b()],
                            HulkSplitterKind::Oracle);
        assert!(err.is_err());
    }
}
