//! Streaming and batch statistics used by `benchkit`, the simulator and the
//! evaluation harness.

/// Batch summary of a sample set: mean / stddev / percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. Panics on empty
    /// input (a bench with zero samples is a bug, not a data point).
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: s[0],
            p50: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p95: percentile_sorted(&s, 95.0),
            p99: percentile_sorted(&s, 99.0),
            max: s[n - 1],
        }
    }
}

/// Nearest-rank percentile over a pre-sorted slice, `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Welford online mean/variance accumulator (single pass, numerically
/// stable) — used on the simulator's hot event path where buffering every
/// sample would dominate.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean =
            self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        *self = Welford { n, mean, m2 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile_sorted(&s, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_rejects_empty() {
        Summary::of(&[]);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.stddev() - s.stddev).abs() < 1e-9);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..500).map(|i| i as f64 * 0.37).collect();
        let (a, b) = xs.split_at(123);
        let mut wa = Welford::new();
        let mut wb = Welford::new();
        for &x in a {
            wa.push(x);
        }
        for &x in b {
            wb.push(x);
        }
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        wa.merge(&wb);
        assert_eq!(wa.count(), all.count());
        assert!((wa.mean() - all.mean()).abs() < 1e-9);
        assert!((wa.variance() - all.variance()).abs() < 1e-6);
    }
}
