//! Tiny JSON *writer* (no parser — nothing at runtime consumes JSON; the
//! writer exists so benches and the coordinator can dump machine-readable
//! metrics for plotting). Substitute for serde_json (offline registry).

use std::fmt::Write as _;

/// A JSON value built imperatively.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffing bench runs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert into an object; panics on non-objects (programming error).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Json {
        match self {
            Json::Obj(fields) => {
                fields.push((key.to_string(), value));
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Push into an array; panics on non-arrays.
    pub fn push(&mut self, value: Json) -> &mut Json {
        match self {
            Json::Arr(items) => {
                items.push(value);
                self
            }
            _ => panic!("Json::push on non-array"),
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Shortest-ish: integers without fraction.
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_structure() {
        let mut obj = Json::obj();
        obj.set("name", "hulk".into());
        obj.set("n", 46usize.into());
        let mut arr = Json::arr();
        arr.push(1.5.into());
        arr.push(Json::Null);
        obj.set("xs", arr);
        assert_eq!(obj.render(), r#"{"name":"hulk","n":46,"xs":[1.5,null]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(3.25).render(), "3.25");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    #[should_panic]
    fn set_on_array_panics() {
        Json::arr().set("k", Json::Null);
    }
}
