//! Tiny JSON writer + parser. The writer exists so benches and the
//! coordinator can dump machine-readable metrics for plotting; the parser
//! ([`Json::parse`]) exists for the `hulk serve` wire protocol — the first
//! runtime surface that *consumes* JSON. Substitute for serde_json
//! (offline registry).

use std::fmt::Write as _;

/// A JSON value built imperatively.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffing bench runs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert into an object; panics on non-objects (programming error).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Json {
        match self {
            Json::Obj(fields) => {
                fields.push((key.to_string(), value));
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Push into an array; panics on non-arrays.
    pub fn push(&mut self, value: Json) -> &mut Json {
        match self {
            Json::Arr(items) => {
                items.push(value);
                self
            }
            _ => panic!("Json::push on non-array"),
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Parse one JSON value from `text` (the whole string must be that
    /// value plus optional whitespace). Errors carry a byte offset so
    /// wire-protocol rejections can point at the garbage.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(value)
    }

    /// Object field lookup (first occurrence); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric field as a non-negative integer (rejects fractions and
    /// negatives — machine ids and GPU counts are exact).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x)
                if x.fract() == 0.0 && *x >= 0.0 && *x < 2.0_f64.powi(53) =>
            {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Shortest-ish: integers without fraction.
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len()
        && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r')
    {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected {lit:?} at byte {pos}", pos = *pos))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'n') => expect(bytes, pos, "null").map(|()| Json::Null),
        Some(b't') => expect(bytes, pos, "true").map(|()| Json::Bool(true)),
        Some(b'f') => {
            expect(bytes, pos, "false").map(|()| Json::Bool(false))
        }
        Some(b'"') => parse_string(bytes, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => {
                        return Err(format!(
                            "expected ',' or ']' at byte {pos}",
                            pos = *pos
                        ))
                    }
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, ":")?;
                let value = parse_value(bytes, pos)?;
                fields.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => {
                        return Err(format!(
                            "expected ',' or '}}' at byte {pos}",
                            pos = *pos
                        ))
                    }
                }
            }
        }
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    if bytes.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}", pos = *pos));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = bytes
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or_else(|| {
                                "truncated \\u escape".to_string()
                            })?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                        // Surrogates (paired or lone) are replaced — the
                        // wire protocol never emits them.
                        out.push(
                            char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    other => {
                        return Err(format!("bad escape {other:?}"));
                    }
                }
                *pos += 1;
            }
            Some(_) => {
                // Multi-byte UTF-8 sequences pass through unchanged:
                // find the char boundary from the source slice.
                let s = std::str::from_utf8(&bytes[*pos..])
                    .map_err(|_| "invalid UTF-8 in string".to_string())?;
                let ch = s.chars().next().unwrap();
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos],
                    b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).unwrap();
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number {text:?} at byte {start}"))
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_structure() {
        let mut obj = Json::obj();
        obj.set("name", "hulk".into());
        obj.set("n", 46usize.into());
        let mut arr = Json::arr();
        arr.push(1.5.into());
        arr.push(Json::Null);
        obj.set("xs", arr);
        assert_eq!(obj.render(), r#"{"name":"hulk","n":46,"xs":[1.5,null]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(3.25).render(), "3.25");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    #[should_panic]
    fn set_on_array_panics() {
        Json::arr().set("k", Json::Null);
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let mut obj = Json::obj();
        obj.set("name", "hulk \"serve\"\n".into());
        obj.set("n", 46usize.into());
        obj.set("x", 3.25.into());
        obj.set("flag", Json::Bool(true));
        obj.set("none", Json::Null);
        let mut arr = Json::arr();
        arr.push(1.5.into());
        arr.push(Json::Str("é漢".to_string()));
        obj.set("xs", arr);
        let text = obj.render();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, obj);
        // And the accessors see through it.
        assert_eq!(parsed.get("n").and_then(Json::as_usize), Some(46));
        assert_eq!(parsed.get("x").and_then(Json::as_f64), Some(3.25));
        assert_eq!(parsed.get("flag").and_then(Json::as_bool), Some(true));
        assert_eq!(parsed.get("xs").and_then(Json::as_arr).map(<[_]>::len),
                   Some(2));
        assert_eq!(parsed.get("missing"), None);
    }

    #[test]
    fn parse_handles_whitespace_escapes_and_nesting() {
        let j = Json::parse(
            " { \"a\" : [ 1 , -2.5e1 , \"x\\u0041\\t\" ] , \"b\" : { } } ",
        )
        .unwrap();
        let xs = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(xs[0].as_f64(), Some(1.0));
        assert_eq!(xs[1].as_f64(), Some(-25.0));
        assert_eq!(xs[2].as_str(), Some("xA\t"));
        assert_eq!(j.get("b"), Some(&Json::obj()));
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in ["", "{", "[1,", "{\"a\":}", "nul", "1 2",
                    "\"unterminated", "{\"a\" 1}", "[1] extra"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
        assert_eq!(Json::Str("7".into()).as_usize(), None);
    }
}
