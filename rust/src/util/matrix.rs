//! Minimal dense f32 matrix used by the pure-Rust reference GCN
//! (`gnn::reference`) and the graph pipeline. Row-major; no BLAS — the
//! matrices here are at most 64×256, where a cache-friendly naive kernel
//! with an ikj loop order is already memory-bound.

#[derive(Clone, Debug, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        MatF32 { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = MatF32::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self @ rhs` with ikj loop order (streams rhs rows, no transpose).
    /// Branch-free: every element participates, so dense weight×weight
    /// products pay no per-element test. For a mostly-zero lhs (a padded
    /// adjacency) use [`matmul_sparse`](MatF32::matmul_sparse), which
    /// keeps the zero skip this kernel historically carried.
    pub fn matmul(&self, rhs: &MatF32) -> MatF32 {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let mut out = MatF32::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            let orow = &mut out.data[i * rhs.cols..(i + 1) * rhs.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                let brow = &rhs.data[k * rhs.cols..(k + 1) * rhs.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self @ rhs` skipping zero lhs elements — the sparse-aware entry
    /// point the zero skip was hoisted into. Identical result to
    /// [`matmul`](MatF32::matmul) for finite operands (a zero
    /// coefficient contributes exactly zero). In-tree the hot sparse
    /// products all moved to the CSR kernels in [`crate::graph::csr`]
    /// (which skip the per-element test entirely) and the dense oracle
    /// deliberately mirrors `model.py`'s branch-free contraction, so
    /// this remains as the explicit middle ground for mostly-zero dense
    /// operands that have no CSR view.
    pub fn matmul_sparse(&self, rhs: &MatF32) -> MatF32 {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let mut out = MatF32::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            let orow = &mut out.data[i * rhs.cols..(i + 1) * rhs.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let brow = &rhs.data[k * rhs.cols..(k + 1) * rhs.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Element-wise `max(0, x)` in place.
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Add a row vector (bias) to every row.
    pub fn add_row_bias(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (v, &b) in row.iter_mut().zip(bias) {
                *v += b;
            }
        }
    }

    /// Scale every row `r` by `scales[r]` (masking / degree normalization).
    pub fn scale_rows(&mut self, scales: &[f32]) {
        assert_eq!(scales.len(), self.rows);
        for r in 0..self.rows {
            let s = scales[r];
            for v in &mut self.data[r * self.cols..(r + 1) * self.cols] {
                *v *= s;
            }
        }
    }

    pub fn transpose(&self) -> MatF32 {
        let mut t = MatF32::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Row-wise argmax (predictions).
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                let mut best = 0;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }

    pub fn max_abs_diff(&self, other: &MatF32) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = MatF32::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = MatF32::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = MatF32::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let i = MatF32::eye(2);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn sparse_entry_point_matches_dense_matmul() {
        // Mostly-zero lhs (adjacency-shaped): the skip changes nothing
        // numerically.
        let a = MatF32::from_vec(3, 3, vec![0.0, 2.0, 0.0,
                                            0.0, 0.0, 0.0,
                                            -1.5, 0.0, 4.0]);
        let b = MatF32::from_vec(3, 2, vec![1.0, -2.0,
                                            3.0, 0.5,
                                            -0.25, 7.0]);
        assert_eq!(a.matmul_sparse(&b), a.matmul(&b));
    }

    #[test]
    fn matmul_rectangular_shapes() {
        let a = MatF32::zeros(3, 5);
        let b = MatF32::zeros(5, 7);
        let c = a.matmul(&b);
        assert_eq!((c.rows, c.cols), (3, 7));
    }

    #[test]
    fn relu_clips_negatives() {
        let mut m = MatF32::from_vec(1, 4, vec![-1.0, 0.0, 2.0, -0.5]);
        m.relu_inplace();
        assert_eq!(m.data, vec![0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn bias_and_row_scaling() {
        let mut m = MatF32::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        m.add_row_bias(&[1.0, 2.0]);
        assert_eq!(m.data, vec![2.0, 3.0, 2.0, 3.0]);
        m.scale_rows(&[2.0, 0.0]);
        assert_eq!(m.data, vec![4.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = MatF32::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(2, 1), 6.0);
    }

    #[test]
    fn argmax_rows_picks_first_max() {
        let m = MatF32::from_vec(2, 3, vec![1.0, 5.0, 5.0, 7.0, 2.0, 3.0]);
        assert_eq!(m.argmax_rows(), vec![1, 0]);
    }
}
