//! Aligned plain-text tables — every bench prints the same rows/series the
//! paper's tables and figures report, through this one formatter.

/// Column-aligned table with a header row.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Table {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(cell);
                let pad = widths[i].saturating_sub(cell.chars().count());
                if i + 1 < cells.len() {
                    line.extend(std::iter::repeat(' ').take(pad));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize =
            widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
        out.extend(std::iter::repeat('-').take(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format milliseconds human-readably (`0.42 ms`, `1.23 s`, `2.1 min`).
pub fn fmt_ms(ms: f64) -> String {
    if ms < 1.0 {
        format!("{:.3} ms", ms)
    } else if ms < 1_000.0 {
        format!("{:.1} ms", ms)
    } else if ms < 60_000.0 {
        format!("{:.2} s", ms / 1_000.0)
    } else {
        format!("{:.1} min", ms / 60_000.0)
    }
}

/// Format a parameter count (`340M`, `1.5B`).
pub fn fmt_params(p: f64) -> String {
    if p >= 1e9 {
        format!("{:.1}B", p / 1e9)
    } else if p >= 1e6 {
        format!("{:.0}M", p / 1e6)
    } else if p >= 1e3 {
        format!("{:.0}k", p / 1e3)
    } else {
        format!("{p:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["longer", "2"]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
        // Both value cells start at the same column.
        let col = lines[2].find('1').unwrap();
        assert_eq!(lines[3].find('2').unwrap(), col);
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        Table::new(&["a", "b"]).row_strs(&["only-one"]);
    }

    #[test]
    fn fmt_ms_ranges() {
        assert_eq!(fmt_ms(0.5), "0.500 ms");
        assert_eq!(fmt_ms(42.0), "42.0 ms");
        assert_eq!(fmt_ms(1_500.0), "1.50 s");
        assert_eq!(fmt_ms(120_000.0), "2.0 min");
    }

    #[test]
    fn fmt_params_ranges() {
        assert_eq!(fmt_params(340e6), "340M");
        assert_eq!(fmt_params(1.5e9), "1.5B");
        assert_eq!(fmt_params(188e3), "188k");
    }
}
