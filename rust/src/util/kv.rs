//! Parser for the `key value` manifest format emitted by
//! `python/compile/aot.py` (`artifacts/manifest.kv`). One pair per line,
//! `#` comments and blank lines ignored. Substitute for serde (offline
//! registry).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Parsed manifest. Keys are unique; duplicate keys are an error (they
/// would mean aot.py and the runtime disagree about the contract).
#[derive(Clone, Debug, Default)]
pub struct KvFile {
    map: HashMap<String, String>,
    /// Insertion order, for faithful round-tripping in tooling.
    order: Vec<String>,
}

impl KvFile {
    pub fn parse(text: &str) -> Result<KvFile> {
        let mut map = HashMap::new();
        let mut order = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((key, value)) = line.split_once(char::is_whitespace)
            else {
                bail!("manifest line {} has no value: {:?}", lineno + 1, raw);
            };
            let key = key.trim().to_string();
            let value = value.trim().to_string();
            if map.insert(key.clone(), value).is_some() {
                bail!("duplicate manifest key {:?} (line {})", key, lineno + 1);
            }
            order.push(key);
        }
        Ok(KvFile { map, order })
    }

    pub fn load(path: &Path) -> Result<KvFile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        KvFile::parse(&text)
    }

    pub fn get(&self, key: &str) -> Result<&str> {
        self.map
            .get(key)
            .map(String::as_str)
            .with_context(|| format!("manifest missing key {key:?}"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        let v = self.get(key)?;
        v.parse()
            .with_context(|| format!("manifest key {key:?}={v:?} not usize"))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.order.iter().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_manifest() {
        let kv = KvFile::parse("format 1\nn 64\np 174216\nforward f.hlo.txt\n")
            .unwrap();
        assert_eq!(kv.get("format").unwrap(), "1");
        assert_eq!(kv.get_usize("n").unwrap(), 64);
        assert_eq!(kv.get_usize("p").unwrap(), 174_216);
        assert_eq!(kv.get("forward").unwrap(), "f.hlo.txt");
        assert_eq!(kv.len(), 4);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let kv = KvFile::parse("# hi\n\nn 8\n   \n# bye\n").unwrap();
        assert_eq!(kv.len(), 1);
        assert_eq!(kv.get_usize("n").unwrap(), 8);
    }

    #[test]
    fn rejects_duplicates() {
        assert!(KvFile::parse("a 1\na 2\n").is_err());
    }

    #[test]
    fn rejects_key_without_value() {
        assert!(KvFile::parse("loner\n").is_err());
    }

    #[test]
    fn missing_key_errors() {
        let kv = KvFile::parse("a 1\n").unwrap();
        assert!(kv.get("b").is_err());
        assert!(kv.get_usize("a").is_ok());
    }

    #[test]
    fn preserves_order() {
        let kv = KvFile::parse("z 1\na 2\nm 3\n").unwrap();
        let keys: Vec<&str> = kv.keys().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn value_with_spaces() {
        let kv = KvFile::parse("desc hello world  \n").unwrap();
        assert_eq!(kv.get("desc").unwrap(), "hello world");
    }
}
