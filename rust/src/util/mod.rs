//! Support utilities hand-rolled for the offline build (the vendored
//! registry carries only `xla` + `anyhow`): PRNG, statistics, matrices,
//! key-value manifests, a JSON writer and text tables.

pub mod json;
pub mod kv;
pub mod matrix;
pub mod rng;
pub mod stats;
pub mod table;

pub use matrix::MatF32;
pub use rng::Rng;
pub use stats::Summary;
pub use table::Table;
