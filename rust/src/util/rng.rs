//! Deterministic PRNG (xoshiro256** seeded via splitmix64).
//!
//! Substitute for the `rand` crate (not in the vendored registry). All
//! randomness in the repo flows through this type so every experiment is
//! reproducible from a seed — benches and tests print their seeds.

/// xoshiro256** generator. Not cryptographic; statistical quality is more
/// than sufficient for workload synthesis and property-test case generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; two `Rng::new(s)` streams are identical.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for parallel generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Rejection sampling to kill modulo bias.
        let bound = n as u64;
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % bound) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// `true` with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with given location/scale of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(8);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }
}
