//! GPipe-style micro-batch pipeline cost model (paper §2.1 "Gpipe",
//! System B, and the per-group execution engine of Hulk §6.3).
//!
//! A plan assigns each participating machine one pipeline stage (a
//! contiguous layer range sized proportionally to the machine's
//! throughput, which is how Hulk "determines which part of the model each
//! node will handle depending on computational power and memory").

use super::cost::{p2p_ms, IterCost};
use crate::cluster::Fleet;
use crate::models::ModelSpec;

/// Micro-batches per iteration (GPipe's K). The paper does not report K;
/// 8 keeps bubble overhead ≈ (S−1)/K reasonable at the paper's scales.
pub const DEFAULT_MICROBATCHES: usize = 8;

/// A pipeline plan over a machine group.
#[derive(Clone, Debug)]
pub struct PipelinePlan {
    /// Machine ids in stage order (stage s runs on `stages[s]`).
    pub stages: Vec<usize>,
    /// Layers per stage (same length as `stages`, sums to model.layers).
    pub layers: Vec<usize>,
    pub microbatches: usize,
}

impl PipelinePlan {
    /// Throughput-proportional layer split over `stages`, capped by each
    /// machine's memory (a fast consumer GPU box must not receive a shard
    /// bigger than its VRAM — the paper's "depending on the computational
    /// power *and memory* of each node"). Every stage gets ≥1 layer;
    /// requires `stages.len() <= model.layers`.
    pub fn proportional(fleet: &Fleet, stages: Vec<usize>, model: &ModelSpec)
        -> PipelinePlan
    {
        assert!(!stages.is_empty());
        assert!(
            stages.len() <= model.layers,
            "more stages than layers ({} > {})",
            stages.len(),
            model.layers
        );
        let tflops: Vec<f64> = stages
            .iter()
            .map(|&i| fleet.machines[i].total_tflops())
            .collect();
        let total: f64 = tflops.iter().sum();
        // Memory cap per stage: how many layer-shards fit the machine.
        let bytes_per_layer = model.train_bytes() / model.layers as f64;
        let caps: Vec<usize> = stages
            .iter()
            .map(|&i| {
                let fit = fleet.machines[i].total_memory_gb() * 1e9
                    / bytes_per_layer;
                (fit.floor() as usize).max(1)
            })
            .collect();
        // Largest-remainder apportionment with a 1-layer floor and the
        // memory caps.
        let mut layers: Vec<usize> = tflops
            .iter()
            .zip(&caps)
            .map(|(t, &cap)| {
                let want =
                    ((t / total) * model.layers as f64).floor() as usize;
                want.clamp(1, cap)
            })
            .collect();
        let mut assigned: usize = layers.iter().sum();
        // Shave overshoot from the largest stages.
        while assigned > model.layers {
            let imax = (0..layers.len()).max_by_key(|&i| layers[i]).unwrap();
            if layers[imax] > 1 {
                layers[imax] -= 1;
                assigned -= 1;
            } else {
                break;
            }
        }
        // Distribute the shortfall to the fastest stages with headroom.
        let mut order: Vec<usize> = (0..layers.len()).collect();
        order.sort_by(|&a, &b| tflops[b].partial_cmp(&tflops[a]).unwrap());
        let mut stuck = 0;
        let mut k = 0;
        while assigned < model.layers && stuck < order.len() {
            let i = order[k % order.len()];
            if layers[i] < caps[i] {
                layers[i] += 1;
                assigned += 1;
                stuck = 0;
            } else {
                stuck += 1;
            }
            k += 1;
        }
        // If caps block full assignment, the plan is left short and
        // `memory_feasible`/`pipeline_cost` report infeasibility; callers
        // (group sizing) guarantee aggregate memory, so this only happens
        // for adversarial stage subsets.
        PipelinePlan { stages, layers, microbatches: DEFAULT_MICROBATCHES }
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Per-stage training-state bytes (proportional to its layer share).
    pub fn stage_bytes(&self, model: &ModelSpec, stage: usize) -> f64 {
        model.train_bytes() * self.layers[stage] as f64
            / model.layers as f64
    }

    /// Does every stage's parameter shard fit its machine's memory, and
    /// does the plan cover the whole model? (A caps-limited split that
    /// could not place every layer is infeasible, not "a smaller model".)
    pub fn memory_feasible(&self, fleet: &Fleet, model: &ModelSpec) -> bool {
        self.layers.iter().sum::<usize>() == model.layers
            && self.stages.iter().enumerate().all(|(s, &m)| {
                self.stage_bytes(model, s) / 1e9
                    <= fleet.machines[m].total_memory_gb()
            })
    }
}

/// Cost of one training iteration under the plan.
///
/// - `comp_ms`: pipeline-clocked compute — the bottleneck stage paces the
///   steady state, plus the fill/drain bubble.
/// - `comm_ms`: activation + gradient traffic over every stage boundary,
///   2 crossings (fwd activation, bwd gradient) × K micro-batches each.
///
/// Returns `IterCost::infeasible()` if a stage boundary is unreachable or
/// a stage shard does not fit in machine memory.
pub fn pipeline_cost(fleet: &Fleet, plan: &PipelinePlan, model: &ModelSpec)
    -> IterCost
{
    if !plan.memory_feasible(fleet, model) {
        return IterCost::infeasible();
    }
    let k = plan.microbatches as f64;
    let micro_batch = (model.batch as f64 / k).ceil() as usize;
    let micro_tokens = (micro_batch * model.seq_len) as f64;
    let act_bytes = model.activation_bytes(micro_batch.max(1));

    // Per-stage per-microbatch compute time.
    let mut stage_ms = Vec::with_capacity(plan.n_stages());
    for (s, &m) in plan.stages.iter().enumerate() {
        let frac = plan.layers[s] as f64 / model.layers as f64;
        let flops = crate::models::FLOPS_PER_TOKEN_FACTOR
            * model.params
            * frac
            * micro_tokens;
        let tflops = fleet.machines[m].total_tflops();
        stage_ms.push(flops / (tflops * 1e12) * 1e3);
    }

    // Boundary costs (fwd + bwd per microbatch).
    let mut boundary_ms = Vec::new();
    for s in 0..plan.n_stages().saturating_sub(1) {
        let a = plan.stages[s];
        let b = plan.stages[s + 1];
        match p2p_ms(fleet, a, b, act_bytes) {
            Some(t) => boundary_ms.push(t),
            None => return IterCost::infeasible(),
        }
    }

    // Steady-state clock = slowest (stage compute + its inbound edge).
    let mut clock: f64 = 0.0;
    for s in 0..plan.n_stages() {
        let inbound = if s == 0 { 0.0 } else { boundary_ms[s - 1] };
        clock = clock.max(stage_ms[s] + inbound);
    }
    // GPipe: K microbatches through S stages ≈ (K + S − 1) clocks for
    // forward+backward combined (bwd ≈ 2× fwd is already inside stage_ms
    // via the 6×params factor).
    let s = plan.n_stages() as f64;
    let total_clocks = k + s - 1.0;

    // Decomposition for the figures: compute share vs communication share.
    let comp_ms = stage_ms.iter().sum::<f64>()
        + (total_clocks - s) * stage_ms.iter().cloned().fold(0.0, f64::max);
    let comm_ms =
        2.0 * k * boundary_ms.iter().sum::<f64>();
    IterCost { comm_ms, comp_ms }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Fleet, Region};

    fn toy() -> Fleet {
        Fleet::paper_toy(0)
    }

    #[test]
    fn proportional_split_sums_to_layers() {
        let fleet = toy();
        let model = ModelSpec::gpt2_xl();
        let plan =
            PipelinePlan::proportional(&fleet, (0..8).collect(), &model);
        assert_eq!(plan.layers.iter().sum::<usize>(), model.layers);
        assert!(plan.layers.iter().all(|&l| l >= 1));
    }

    #[test]
    fn faster_machines_get_more_layers() {
        let fleet = toy();
        let model = ModelSpec::gpt2_xl();
        let plan =
            PipelinePlan::proportional(&fleet, (0..8).collect(), &model);
        // node2 = 8×A100 (fastest), node7 = 8×TITAN Xp (slowest).
        let l2 = plan.layers[2];
        let l7 = plan.layers[7];
        assert!(l2 > l7, "layers {l2} vs {l7}");
    }

    #[test]
    fn cost_is_finite_for_feasible_plan() {
        let fleet = toy();
        let model = ModelSpec::gpt2_xl();
        let plan =
            PipelinePlan::proportional(&fleet, (0..8).collect(), &model);
        let cost = pipeline_cost(&fleet, &plan, &model);
        assert!(cost.is_feasible());
        assert!(cost.comm_ms > 0.0 && cost.comp_ms > 0.0);
    }

    #[test]
    fn single_stage_has_zero_comm() {
        let fleet = toy();
        let model = ModelSpec::bert_large();
        let plan = PipelinePlan::proportional(&fleet, vec![2], &model);
        let cost = pipeline_cost(&fleet, &plan, &model);
        assert_eq!(cost.comm_ms, 0.0);
        assert!(cost.comp_ms > 0.0);
    }

    #[test]
    fn cross_region_pipeline_pays_more_comm() {
        let fleet = toy();
        let model = ModelSpec::gpt2_xl();
        // Same stages, different order: adjacent regional hops vs
        // worst-case alternating continents.
        let near = PipelinePlan::proportional(&fleet, vec![0, 1, 3], &model);
        let far = PipelinePlan::proportional(&fleet, vec![0, 2, 6], &model);
        let c_near = pipeline_cost(&fleet, &near, &model);
        let c_far = pipeline_cost(&fleet, &far, &model);
        assert!(c_far.comm_ms > c_near.comm_ms);
    }

    #[test]
    fn infeasible_when_boundary_blocked() {
        let mut fleet = toy();
        let paris = fleet.add_machine(
            Region::Paris,
            crate::cluster::GpuModel::A100,
            8,
        );
        let model = ModelSpec::gpt2_xl();
        let plan = PipelinePlan {
            stages: vec![0, paris], // Beijing → Paris is blocked
            layers: vec![24, 24],
            microbatches: 8,
        };
        assert!(!pipeline_cost(&fleet, &plan, &model).is_feasible());
    }

    #[test]
    fn infeasible_when_stage_exceeds_memory() {
        let fleet = toy();
        let model = ModelSpec::opt_175b(); // 2.8 TB training state
        let plan = PipelinePlan {
            stages: vec![0, 1], // 192 + 256 GB machines
            layers: vec![48, 48],
            microbatches: 8,
        };
        assert!(!pipeline_cost(&fleet, &plan, &model).is_feasible());
    }

    #[test]
    #[should_panic(expected = "more stages than layers")]
    fn too_many_stages_rejected() {
        let fleet = Fleet::paper_evaluation(0);
        let model = ModelSpec::bert_large(); // 24 layers < 46 stages
        PipelinePlan::proportional(&fleet, (0..46).collect(), &model);
    }
}
