//! Shared communication-cost primitives.

use crate::cluster::Fleet;

/// Per-iteration cost split, milliseconds. The paper's Figures 8/10 report
/// exactly this decomposition per (model, system).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct IterCost {
    pub comm_ms: f64,
    pub comp_ms: f64,
}

impl IterCost {
    pub fn total_ms(&self) -> f64 {
        self.comm_ms + self.comp_ms
    }

    pub fn infeasible() -> IterCost {
        IterCost { comm_ms: f64::INFINITY, comp_ms: f64::INFINITY }
    }

    pub fn is_feasible(&self) -> bool {
        self.comm_ms.is_finite() && self.comp_ms.is_finite()
    }
}

/// Point-to-point transfer between two machines, ms.
/// `None` if the pair cannot communicate.
pub fn p2p_ms(fleet: &Fleet, a: usize, b: usize, bytes: f64) -> Option<f64> {
    fleet
        .wan
        .transfer_ms(fleet.machines[a].region, fleet.machines[b].region, bytes)
}

/// Ring all-reduce of `bytes` over `nodes` (machine ids), ms.
///
/// Standard 2(n−1)-step ring: every step moves a `bytes/n` chunk along each
/// ring edge concurrently, so a step costs the *slowest* ring edge; the ring
/// order is the callers' (baselines use naive id order — topology-oblivious,
/// which is exactly System A/C's weakness the paper exploits).
///
/// Returns `None` if any ring edge is unreachable.
pub fn ring_allreduce_ms(fleet: &Fleet, nodes: &[usize], bytes: f64)
    -> Option<f64>
{
    let n = nodes.len();
    if n <= 1 {
        return Some(0.0);
    }
    let chunk = bytes / n as f64;
    let mut step_ms: f64 = 0.0;
    for k in 0..n {
        let a = nodes[k];
        let b = nodes[(k + 1) % n];
        let t = p2p_ms(fleet, a, b, chunk)?;
        step_ms = step_ms.max(t);
    }
    Some(2.0 * (n as f64 - 1.0) * step_ms)
}

/// Aggregate throughput of a machine set, TFLOP/s.
pub fn group_tflops(fleet: &Fleet, nodes: &[usize]) -> f64 {
    nodes.iter().map(|&i| fleet.machines[i].total_tflops()).sum()
}

/// Total memory of a machine set, GB.
pub fn group_memory_gb(fleet: &Fleet, nodes: &[usize]) -> f64 {
    nodes
        .iter()
        .map(|&i| fleet.machines[i].total_memory_gb())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fleet;

    #[test]
    fn allreduce_zero_for_single_node() {
        let fleet = Fleet::paper_toy(0);
        assert_eq!(ring_allreduce_ms(&fleet, &[2], 1e9), Some(0.0));
    }

    #[test]
    fn allreduce_grows_with_bytes() {
        let fleet = Fleet::paper_toy(0);
        let nodes = [0, 1, 2, 3];
        let small = ring_allreduce_ms(&fleet, &nodes, 1e6).unwrap();
        let big = ring_allreduce_ms(&fleet, &nodes, 1e9).unwrap();
        assert!(big > small);
    }

    #[test]
    fn allreduce_fails_on_blocked_ring_edge() {
        // Beijing (node 0) and a Paris machine cannot communicate.
        let mut fleet = Fleet::paper_toy(0);
        let paris = fleet.add_machine(
            crate::cluster::Region::Paris,
            crate::cluster::GpuModel::V100,
            8,
        );
        assert!(ring_allreduce_ms(&fleet, &[0, paris], 1e6).is_none());
    }

    #[test]
    fn wan_ring_is_slower_than_regional_ring() {
        let fleet = Fleet::paper_evaluation(0);
        // First two Beijing machines vs a Beijing–Brasilia pair.
        let regional: Vec<usize> = (0..fleet.len())
            .filter(|&i| fleet.machines[i].region == crate::cluster::Region::Beijing)
            .take(2)
            .collect();
        let wan: Vec<usize> = vec![
            regional[0],
            (0..fleet.len())
                .find(|&i| fleet.machines[i].region == crate::cluster::Region::Brasilia)
                .unwrap(),
        ];
        let t_regional = ring_allreduce_ms(&fleet, &regional, 1e8).unwrap();
        let t_wan = ring_allreduce_ms(&fleet, &wan, 1e8).unwrap();
        assert!(t_wan > t_regional * 2.0, "{t_wan} vs {t_regional}");
    }

    #[test]
    fn group_aggregates_are_sums() {
        let fleet = Fleet::paper_toy(0);
        let all: Vec<usize> = (0..fleet.len()).collect();
        let total_mem = group_memory_gb(&fleet, &all);
        assert!((total_mem - fleet.total_memory_gb()).abs() < 1e-9);
        assert!(group_tflops(&fleet, &all) > 0.0);
    }

    #[test]
    fn infeasible_cost_propagates() {
        let c = IterCost::infeasible();
        assert!(!c.is_feasible());
        assert!(c.total_ms().is_infinite());
    }
}
