//! Megatron-LM tensor-parallel cost model — the paper's System C:
//! "employs tensor parallelism with Megatron-LM across the entire system,
//! requiring all machines to be utilized for model training."
//!
//! Megatron splits every transformer layer across the group and pays two
//! activation all-reduces in forward and two in backward per layer — over
//! WAN links this is the catastrophic case the paper's Figure 8/10 shows.

use super::cost::{group_memory_gb, group_tflops, ring_allreduce_ms, IterCost};
use crate::cluster::Fleet;
use crate::models::ModelSpec;

/// All-reduces per layer per iteration (2 fwd + 2 bwd).
pub const ALLREDUCES_PER_LAYER: f64 = 4.0;

/// One iteration of tensor parallelism over `nodes`.
///
/// - `comp_ms`: perfect FLOP split across the group (optimistic for
///   System C — its loss is all communication).
/// - `comm_ms`: `layers × 4` ring all-reduces of the full-batch activation
///   tensor across every machine in id order.
pub fn tensor_parallel_cost(fleet: &Fleet, nodes: &[usize],
                            model: &ModelSpec) -> IterCost
{
    if nodes.is_empty() {
        return IterCost::infeasible();
    }
    // Sharded weights must fit the aggregate memory.
    if group_memory_gb(fleet, nodes) < model.train_gb() {
        return IterCost::infeasible();
    }
    let act_bytes = model.activation_bytes(model.batch);
    let per_allreduce = match ring_allreduce_ms(fleet, nodes, act_bytes) {
        Some(t) => t,
        None => return IterCost::infeasible(),
    };
    let comm_ms =
        model.layers as f64 * ALLREDUCES_PER_LAYER * per_allreduce;
    let comp_ms = model.flops_per_iter()
        / (group_tflops(fleet, nodes) * 1e12)
        * 1e3;
    IterCost { comm_ms, comp_ms }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasible_on_full_fleet_for_all_paper_models() {
        let fleet = Fleet::paper_evaluation(0);
        let all: Vec<usize> = (0..fleet.len()).collect();
        for model in ModelSpec::paper_six() {
            let cost = tensor_parallel_cost(&fleet, &all, &model);
            assert!(cost.is_feasible(), "{} infeasible", model.name);
        }
    }

    #[test]
    fn comm_dominates_over_wan() {
        // The defining System C pathology: comm ≫ comp across regions.
        let fleet = Fleet::paper_evaluation(0);
        let all: Vec<usize> = (0..fleet.len()).collect();
        let cost = tensor_parallel_cost(&fleet, &all, &ModelSpec::gpt2_xl());
        assert!(cost.comm_ms > 10.0 * cost.comp_ms,
                "comm {} comp {}", cost.comm_ms, cost.comp_ms);
    }

    #[test]
    fn comm_scales_with_layer_count() {
        let fleet = Fleet::paper_evaluation(0);
        let all: Vec<usize> = (0..fleet.len()).collect();
        let mut shallow = ModelSpec::bert_large();
        shallow.layers = 12;
        let mut deep = ModelSpec::bert_large();
        deep.layers = 24;
        let c_shallow = tensor_parallel_cost(&fleet, &all, &shallow);
        let c_deep = tensor_parallel_cost(&fleet, &all, &deep);
        assert!((c_deep.comm_ms / c_shallow.comm_ms - 2.0).abs() < 0.01);
    }

    #[test]
    fn infeasible_when_memory_insufficient() {
        let fleet = Fleet::paper_toy(0);
        // One small machine cannot shard OPT-175B.
        let cost = tensor_parallel_cost(&fleet, &[7], &ModelSpec::opt_175b());
        assert!(!cost.is_feasible());
    }

    #[test]
    fn empty_group_infeasible() {
        let fleet = Fleet::paper_toy(0);
        assert!(!tensor_parallel_cost(&fleet, &[], &ModelSpec::bert_large())
            .is_feasible());
    }
}
