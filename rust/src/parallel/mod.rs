//! Parallelism cost models (per-iteration communication + computation
//! time) for the three baselines and Hulk's per-group pipelines:
//!
//! - [`data_parallel`] — System A: full replicas + gradient all-reduce.
//! - [`pipeline`] — System B / Hulk groups: GPipe micro-batch pipelining.
//! - [`tensor_parallel`] — System C: Megatron-LM tensor parallelism.
//! - [`cost`] — shared primitives (ring all-reduce over WAN links,
//!   point-to-point transfer costs).
//!
//! Absolute numbers are a simulator's, not the authors' testbed's; the
//! reproduced quantity is the *shape* of Figures 8/10 (who wins, by what
//! factor). The analytic models here are cross-validated against the
//! discrete-event simulator in `sim::` (see tests and the ablation bench).

pub mod cost;
pub mod data_parallel;
pub mod pipeline;
pub mod tensor_parallel;

pub use cost::{ring_allreduce_ms, IterCost};
pub use data_parallel::data_parallel_cost;
pub use pipeline::{pipeline_cost, PipelinePlan};
pub use tensor_parallel::tensor_parallel_cost;
