//! Data-parallel cost model — the paper's System A: "utilizes all
//! available machines ... while discarding any machine that does not have
//! sufficient memory to accommodate the entire model", then splits the
//! batch and all-reduces gradients.

use super::cost::{ring_allreduce_ms, IterCost};
use crate::cluster::Fleet;
use crate::models::ModelSpec;

/// Machines (ids) that can hold a full training replica.
pub fn replica_capable(fleet: &Fleet, model: &ModelSpec) -> Vec<usize> {
    (0..fleet.len())
        .filter(|&i| {
            fleet.machines[i].total_memory_gb() >= model.train_gb()
        })
        .collect()
}

/// One iteration of synchronous data parallelism over `replicas`.
///
/// - `comp_ms`: batch split proportionally to throughput; the slowest
///   replica paces the step (synchronous SGD barrier).
/// - `comm_ms`: ring all-reduce of fp16 gradients over the replica set in
///   id order (topology-oblivious, as System A is).
///
/// Infeasible when no machine fits the model or the ring is disconnected.
pub fn data_parallel_cost(fleet: &Fleet, replicas: &[usize],
                          model: &ModelSpec) -> IterCost
{
    if replicas.is_empty() {
        return IterCost::infeasible();
    }
    let total_tflops: f64 = replicas
        .iter()
        .map(|&i| fleet.machines[i].total_tflops())
        .sum();
    // Proportional batch shares → every replica finishes in the same time
    // in the ideal case; model stragglers with a 5% sync overhead.
    let ideal_ms =
        model.flops_per_iter() / (total_tflops * 1e12) * 1e3;
    let comp_ms = ideal_ms * 1.05;
    let comm_ms = match ring_allreduce_ms(fleet, replicas, model.grad_bytes())
    {
        Some(t) => t,
        None => return IterCost::infeasible(),
    };
    IterCost { comm_ms, comp_ms }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_model_fits_everywhere_large_fits_nowhere() {
        let fleet = Fleet::paper_evaluation(0);
        let bert = ModelSpec::bert_large(); // 5.4 GB training state
        assert_eq!(replica_capable(&fleet, &bert).len(), fleet.len());
        let opt = ModelSpec::opt_175b(); // 2.8 TB
        assert!(replica_capable(&fleet, &opt).is_empty());
    }

    #[test]
    fn medium_model_fits_some() {
        let fleet = Fleet::paper_evaluation(0);
        let t5 = ModelSpec::t5_11b(); // 176 GB training state
        let capable = replica_capable(&fleet, &t5);
        assert!(!capable.is_empty());
        assert!(capable.len() < fleet.len());
    }

    #[test]
    fn cost_infeasible_with_no_replicas() {
        let fleet = Fleet::paper_evaluation(0);
        let opt = ModelSpec::opt_175b();
        let cost =
            data_parallel_cost(&fleet, &replica_capable(&fleet, &opt), &opt);
        assert!(!cost.is_feasible());
    }

    #[test]
    fn single_replica_has_zero_comm() {
        let fleet = Fleet::paper_toy(0);
        let model = ModelSpec::bert_large();
        let cost = data_parallel_cost(&fleet, &[2], &model);
        assert!(cost.is_feasible());
        assert_eq!(cost.comm_ms, 0.0);
    }

    #[test]
    fn more_replicas_less_compute_more_comm() {
        let fleet = Fleet::paper_evaluation(0);
        let model = ModelSpec::bert_large();
        let all = replica_capable(&fleet, &model);
        let one = data_parallel_cost(&fleet, &all[..1], &model);
        let many = data_parallel_cost(&fleet, &all, &model);
        assert!(many.comp_ms < one.comp_ms);
        assert!(many.comm_ms > one.comm_ms);
    }
}
