//! Mini property-testing harness (proptest is not in the offline vendored
//! registry). Deterministic xorshift-seeded case generation + failure
//! reporting with the reproducing seed; shrinking is by halving numeric
//! sizes, which covers the "find a smaller cluster that still fails"
//! workflow the scheduler invariant tests need.
//!
//! ```no_run
//! // (no_run: doctest binaries link libxla_extension, whose rpath the
//! // rustdoc runner does not propagate; the same example runs as a unit
//! // test below.)
//! use hulk::prop::forall;
//! forall("sorted stays sorted", 100, |g| {
//!     let mut xs = g.vec_f64(0..=32, -1e6, 1e6);
//!     xs.sort_by(f64::total_cmp);
//!     xs.windows(2).all(|w| w[0] <= w[1])
//! });
//! ```

use std::ops::RangeInclusive;

use crate::util::rng::Rng;

/// Case generator handed to property bodies.
pub struct Gen {
    rng: Rng,
    /// Current size budget; shrunk on failure re-runs.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen { rng: Rng::new(seed), size }
    }

    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let hi = hi.min(lo + self.size); // size-bounded
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn vec_f64(&mut self, len: RangeInclusive<usize>, lo: f64, hi: f64)
        -> Vec<f64>
    {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: RangeInclusive<usize>,
                     each: RangeInclusive<usize>) -> Vec<usize>
    {
        let n = self.usize_in(len);
        (0..n).map(|_| self.usize_in(each.clone())).collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `property`. On failure, retries with halved
/// size budgets to report the smallest failing size, then panics with the
/// reproducing `(seed, size)` pair.
pub fn forall(name: &str, cases: u64, property: impl Fn(&mut Gen) -> bool) {
    // Fixed master seed: CI-stable. Override with HULK_PROP_SEED for fuzzing
    // sessions.
    let master: u64 = std::env::var("HULK_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x48554C4B); // "HULK"
    for case in 0..cases {
        let seed = master.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut size = 64usize;
        let mut g = Gen::new(seed, size);
        if property(&mut g) {
            continue;
        }
        // Shrink: halve the size budget while it still fails.
        let mut smallest = size;
        while size > 1 {
            size /= 2;
            let mut g = Gen::new(seed, size);
            if !property(&mut g) {
                smallest = size;
            }
        }
        panic!(
            "property {name:?} failed: case {case}, seed {seed:#x}, \
             smallest failing size {smallest} \
             (rerun with HULK_PROP_SEED={master})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("reverse twice is identity", 50, |g| {
            let xs = g.vec_f64(0..=16, -10.0, 10.0);
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            xs == ys
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        forall("always false on nonempty", 10, |g| {
            let xs = g.vec_f64(1..=8, 0.0, 1.0);
            xs.is_empty()
        });
    }

    #[test]
    fn gen_respects_bounds() {
        let mut g = Gen::new(7, 64);
        for _ in 0..1000 {
            let v = g.usize_in(3..=9);
            assert!((3..=9).contains(&v));
            let f = g.f64_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
        }
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let a: Vec<usize> =
            (0..20).map(|_| Gen::new(5, 64).usize_in(0..=100)).collect();
        let b: Vec<usize> =
            (0..20).map(|_| Gen::new(5, 64).usize_in(0..=100)).collect();
        assert_eq!(a, b);
    }
}
