//! Run a workload through every registered planner → the rows of Fig. 8 /
//! Fig. 10 (communication time and calculation time per model per system).
//!
//! Since the planner seam landed this file no longer knows the four
//! systems by name: [`evaluate_with`] iterates a
//! [`PlannerRegistry`] and [`SystemEval`] is as wide as that registry.
//! [`evaluate_all`] is the convenience wrapper over
//! [`PlannerRegistry::standard`] — the paper's four, producing exactly
//! the pre-seam numbers.

use anyhow::Result;

use crate::cluster::Fleet;
use crate::models::ModelSpec;
use crate::parallel::IterCost;
use crate::planner::{CostBackend, ExecReport, HulkSplitterKind,
                     PlacementSummary, Planner, PlannerKind,
                     PlannerRegistry, SystemMeta};
use crate::util::table::{fmt_ms, Table};

use super::world::ScenarioWorld;

/// One evaluated workload: per-model, per-planner iteration costs plus
/// each planner's placement digest.
#[derive(Clone, Debug)]
pub struct SystemEval {
    /// Column metadata, registry insertion order.
    pub systems: Vec<SystemMeta>,
    pub models: Vec<ModelSpec>,
    /// `costs[m][s]` for model m under `systems[s]`.
    pub costs: Vec<Vec<IterCost>>,
    /// `placements[s]`: the placement summary of `systems[s]`.
    pub placements: Vec<PlacementSummary>,
    /// Which backend priced `costs`.
    pub backend: CostBackend,
    /// `exec[s]`: the execution digest of `systems[s]` — present iff
    /// `backend` is [`CostBackend::Simulated`].
    pub exec: Vec<Option<ExecReport>>,
}

impl SystemEval {
    /// Column index of the Hulk system, if registered.
    pub fn hulk_column(&self) -> Option<usize> {
        self.systems.iter().position(|s| s.kind == PlannerKind::Hulk)
    }

    /// Hulk's total-time improvement over the best feasible baseline,
    /// summed over the workload (the paper's ">20%" headline). 0.0 when
    /// the evaluation ran without Hulk or without any baseline.
    pub fn hulk_improvement(&self) -> f64 {
        let Some(h) = self.hulk_column() else { return 0.0 };
        let mut hulk_total = 0.0;
        let mut best_baseline_total = 0.0;
        for row in &self.costs {
            let hulk = row[h].total_ms();
            let best = row
                .iter()
                .zip(&self.systems)
                .filter(|(_, meta)| meta.kind == PlannerKind::Baseline)
                .map(|(c, _)| c.total_ms())
                .fold(f64::INFINITY, f64::min);
            if best.is_finite() && hulk.is_finite() {
                hulk_total += hulk;
                best_baseline_total += best;
            }
        }
        if best_baseline_total == 0.0 {
            return 0.0;
        }
        1.0 - hulk_total / best_baseline_total
    }

    /// Render the per-system execution digests (makespan, straggler
    /// wait, hottest WAN link) — empty string under the analytic
    /// backend, so analytic reports stay byte-identical.
    pub fn render_exec(&self) -> String {
        if self.exec.iter().all(Option::is_none) {
            return String::new();
        }
        let mut t = Table::new(&["System", "Makespan", "Straggler wait",
                                 "Hottest link", "Util"]);
        for (meta, exec) in self.systems.iter().zip(&self.exec) {
            let Some(exec) = exec else { continue };
            let (link, util) = match exec.hottest_link() {
                Some(l) => (format!("{}–{}", l.a.name(), l.b.name()),
                            format!("{:.0}%", l.utilization * 100.0)),
                None => ("—".into(), "—".into()),
            };
            t.row(&[
                meta.name.to_string(),
                fmt_ms(exec.makespan_ms),
                fmt_ms(exec.straggler_wait_ms),
                link,
                util,
            ]);
        }
        format!("— simulated execution (shared WAN contention) —\n{}",
                t.render())
    }

    /// Render the Fig. 8 / Fig. 10 data as a table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["Model", "System", "Comm", "Comp",
                                 "Total"]);
        for (m, model) in self.models.iter().enumerate() {
            for (s, meta) in self.systems.iter().enumerate() {
                let c = self.costs[m][s];
                let (comm, comp, total) = if c.is_feasible() {
                    (fmt_ms(c.comm_ms), fmt_ms(c.comp_ms),
                     fmt_ms(c.total_ms()))
                } else {
                    ("—".into(), "—".into(), "infeasible".into())
                };
                t.row(&[
                    model.name.to_string(),
                    meta.name.to_string(),
                    comm,
                    comp,
                    total,
                ]);
            }
        }
        t.render()
    }
}

/// Evaluate a prebuilt [`ScenarioWorld`] under every planner in
/// `planners`, priced by `backend` — the core loop; nothing here
/// rebuilds fleet, graph, or workload. Hulk-family planners drive
/// Algorithm 1 with the given splitter (GNN in production, oracle for
/// artifact-free runs).
pub fn evaluate_world(planners: &PlannerRegistry, world: &ScenarioWorld,
                      splitter: HulkSplitterKind,
                      backend: CostBackend) -> Result<SystemEval>
{
    let ctx = world.context(splitter).with_backend(backend);
    let mut columns: Vec<Vec<IterCost>> = Vec::with_capacity(planners.len());
    let mut placements = Vec::with_capacity(planners.len());
    let mut exec = Vec::with_capacity(planners.len());
    for planner in planners.iter() {
        let placement = planner.plan(&ctx)?;
        let priced = planner.price(&ctx, &placement);
        columns.push(priced.per_task);
        exec.push(priced.exec);
        placements.push(placement.summary(world.fleet()));
    }
    let models = world.workload().to_vec();
    let costs = (0..models.len())
        .map(|m| columns.iter().map(|col| col[m]).collect())
        .collect();
    Ok(SystemEval { systems: planners.metas(), models, costs, placements,
                    backend, exec })
}

/// [`evaluate_world`] over a freshly built world — the from-scratch
/// entry point for callers without a cached context (byte-identical
/// output; the world build is exactly the setup this function always
/// performed inline).
pub fn evaluate_with_backend(planners: &PlannerRegistry, fleet: &Fleet,
                             workload: &[ModelSpec],
                             splitter: HulkSplitterKind,
                             backend: CostBackend) -> Result<SystemEval>
{
    let world = ScenarioWorld::new(fleet.clone(), workload.to_vec());
    evaluate_world(planners, &world, splitter, backend)
}

/// [`evaluate_with_backend`] under the default analytic formulas — the
/// historical entry point, byte-identical output.
pub fn evaluate_with(planners: &PlannerRegistry, fleet: &Fleet,
                     workload: &[ModelSpec], splitter: HulkSplitterKind)
    -> Result<SystemEval>
{
    evaluate_with_backend(planners, fleet, workload, splitter,
                          CostBackend::Analytic)
}

/// Evaluate `workload` under the standard four systems (§6.4).
pub fn evaluate_all(fleet: &Fleet, workload: &[ModelSpec],
                    splitter: HulkSplitterKind) -> Result<SystemEval>
{
    evaluate_with(&PlannerRegistry::standard(), fleet, workload, splitter)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_shape_holds() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        assert_eq!(eval.models.len(), 4);
        assert_eq!(eval.systems.len(), 4);
        let h = eval.hulk_column().unwrap();
        assert_eq!(h, 3, "standard registry keeps hulk last");
        for (m, row) in eval.costs.iter().enumerate() {
            let hulk = row[h];
            assert!(hulk.is_feasible(), "hulk infeasible for {}",
                    eval.models[m].name);
            // Hulk comm beats B and C everywhere (the paper's Figure 8).
            assert!(hulk.comm_ms < row[1].comm_ms);
            assert!(hulk.comm_ms < row[2].comm_ms);
        }
    }

    #[test]
    fn headline_improvement_over_20_percent() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        let imp = eval.hulk_improvement();
        assert!(imp > 0.20, "Hulk improvement only {:.1}%", imp * 100.0);
    }

    #[test]
    fn render_mentions_every_system_and_model() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        let out = eval.render();
        for meta in &eval.systems {
            assert!(out.contains(meta.name));
        }
        assert!(out.contains("OPT (175B)"));
        assert!(out.contains("infeasible")); // System A × OPT
    }

    #[test]
    fn slugs_are_stable_and_unique() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &[ModelSpec::bert_large()],
                                HulkSplitterKind::Oracle)
            .unwrap();
        let slugs: Vec<&str> =
            eval.systems.iter().map(|s| s.slug).collect();
        assert_eq!(slugs, vec!["system_a", "system_b", "system_c", "hulk"]);
    }

    #[test]
    fn filtered_registry_narrows_the_eval() {
        let fleet = Fleet::paper_evaluation(0);
        let planners = PlannerRegistry::resolve("b,hulk").unwrap();
        let eval = evaluate_with(&planners, &fleet,
                                 &[ModelSpec::gpt2_xl()],
                                 HulkSplitterKind::Oracle)
            .unwrap();
        assert_eq!(eval.systems.len(), 2);
        assert_eq!(eval.costs[0].len(), 2);
        assert_eq!(eval.placements.len(), 2);
        // Improvement still computes: B is the only baseline present.
        assert!(eval.hulk_improvement().is_finite());
        // Without Hulk the improvement degenerates to 0.
        let b_only = PlannerRegistry::resolve("b").unwrap();
        let eval = evaluate_with(&b_only, &fleet, &[ModelSpec::gpt2_xl()],
                                 HulkSplitterKind::Oracle)
            .unwrap();
        assert_eq!(eval.hulk_improvement(), 0.0);
    }

    #[test]
    fn simulated_backend_reports_exec_digests_and_keeps_hulk_ahead() {
        let fleet = Fleet::paper_evaluation(0);
        let workload = [ModelSpec::gpt2_xl(), ModelSpec::bert_large()];
        let analytic = evaluate_all(&fleet, &workload,
                                    HulkSplitterKind::Oracle)
            .unwrap();
        assert_eq!(analytic.backend, CostBackend::Analytic);
        assert!(analytic.exec.iter().all(Option::is_none));
        assert!(analytic.render_exec().is_empty());

        let sim = evaluate_with_backend(&PlannerRegistry::standard(),
                                        &fleet, &workload,
                                        HulkSplitterKind::Oracle,
                                        CostBackend::Simulated)
            .unwrap();
        assert_eq!(sim.backend, CostBackend::Simulated);
        assert!(sim.exec.iter().all(Option::is_some));
        let rendered = sim.render_exec();
        assert!(rendered.contains("Makespan"));
        // Feasibility agrees cell-by-cell between the backends, and the
        // headline survives pricing-by-execution: Hulk's disjoint groups
        // dodge the contention the baselines create for themselves.
        for (a_row, s_row) in analytic.costs.iter().zip(&sim.costs) {
            for (a, s) in a_row.iter().zip(s_row) {
                assert_eq!(a.is_feasible(), s.is_feasible());
            }
        }
        assert!(sim.hulk_improvement() > 0.0,
                "Hulk loses under contention: {:.1}%",
                sim.hulk_improvement() * 100.0);
    }

    #[test]
    fn placements_summarize_each_column() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        // System C shards every task across all 46 machines → 4 groups,
        // 0 pipeline stages; System B pipelines every task.
        assert_eq!(eval.placements[2].groups, 4);
        assert_eq!(eval.placements[2].stages, 0);
        assert!(eval.placements[1].stages > 0);
        // Hulk's regional grouping crosses far fewer region boundaries
        // than System B's id-order pipelines.
        assert!(eval.placements[3].cross_region_edges
                    < eval.placements[1].cross_region_edges,
                "hulk {} vs B {}",
                eval.placements[3].cross_region_edges,
                eval.placements[1].cross_region_edges);
    }
}
