//! Run a workload through all four systems → the rows of Fig. 8 / Fig. 10
//! (communication time and calculation time per model per system).
//!
//! Moved here from `systems::evaluate` when the scenario subsystem was
//! introduced; `crate::systems` re-exports the public names for
//! compatibility.

use anyhow::Result;

use crate::cluster::Fleet;
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::parallel::IterCost;
use crate::systems::hulk::{hulk_plan, HulkSplitterKind};
use crate::systems::{system_a, system_b, system_c};
use crate::util::table::{fmt_ms, Table};

/// The four systems of §6.4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    SystemA,
    SystemB,
    SystemC,
    Hulk,
}

impl SystemKind {
    pub const ALL: [SystemKind; 4] = [
        SystemKind::SystemA,
        SystemKind::SystemB,
        SystemKind::SystemC,
        SystemKind::Hulk,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SystemKind::SystemA => "System A (DP)",
            SystemKind::SystemB => "System B (GPipe)",
            SystemKind::SystemC => "System C (Megatron)",
            SystemKind::Hulk => "Hulk",
        }
    }

    /// Stable machine-readable id used in `BENCH_*.json` entry names.
    pub fn slug(self) -> &'static str {
        match self {
            SystemKind::SystemA => "system_a",
            SystemKind::SystemB => "system_b",
            SystemKind::SystemC => "system_c",
            SystemKind::Hulk => "hulk",
        }
    }
}

/// One evaluated workload: per-model, per-system iteration costs.
#[derive(Clone, Debug)]
pub struct SystemEval {
    pub models: Vec<ModelSpec>,
    /// `costs[m][s]` for model m under `SystemKind::ALL[s]`.
    pub costs: Vec<[IterCost; 4]>,
}

impl SystemEval {
    /// Hulk's total-time improvement over the best feasible baseline,
    /// summed over the workload (the paper's ">20%" headline).
    pub fn hulk_improvement(&self) -> f64 {
        let mut hulk_total = 0.0;
        let mut best_baseline_total = 0.0;
        for row in &self.costs {
            let hulk = row[3].total_ms();
            let best = row[..3]
                .iter()
                .map(IterCost::total_ms)
                .fold(f64::INFINITY, f64::min);
            if best.is_finite() && hulk.is_finite() {
                hulk_total += hulk;
                best_baseline_total += best;
            }
        }
        if best_baseline_total == 0.0 {
            return 0.0;
        }
        1.0 - hulk_total / best_baseline_total
    }

    /// Render the Fig. 8 / Fig. 10 data as a table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["Model", "System", "Comm", "Comp",
                                 "Total"]);
        for (m, model) in self.models.iter().enumerate() {
            for (s, kind) in SystemKind::ALL.iter().enumerate() {
                let c = self.costs[m][s];
                let (comm, comp, total) = if c.is_feasible() {
                    (fmt_ms(c.comm_ms), fmt_ms(c.comp_ms),
                     fmt_ms(c.total_ms()))
                } else {
                    ("—".into(), "—".into(), "infeasible".into())
                };
                t.row(&[
                    model.name.to_string(),
                    kind.name().to_string(),
                    comm,
                    comp,
                    total,
                ]);
            }
        }
        t.render()
    }
}

/// Evaluate `workload` under all four systems. Hulk uses the given
/// splitter (GNN in production, oracle for artifact-free runs).
pub fn evaluate_all(fleet: &Fleet, workload: &[ModelSpec],
                    splitter: HulkSplitterKind) -> Result<SystemEval>
{
    let graph = ClusterGraph::from_fleet(fleet);
    let plan = hulk_plan(fleet, &graph, workload, splitter)?;

    // hulk_plan sorts tasks desc; keep that canonical order for rows.
    let models = plan.tasks.clone();
    let mut costs = Vec::with_capacity(models.len());
    for (t, model) in models.iter().enumerate() {
        costs.push([
            system_a::cost(fleet, model),
            system_b::cost(fleet, model),
            system_c::cost(fleet, model),
            crate::systems::hulk::cost(fleet, &plan, t),
        ]);
    }
    Ok(SystemEval { models, costs })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_shape_holds() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        assert_eq!(eval.models.len(), 4);
        for (m, row) in eval.costs.iter().enumerate() {
            let hulk = row[3];
            assert!(hulk.is_feasible(), "hulk infeasible for {}",
                    eval.models[m].name);
            // Hulk comm beats B and C everywhere (the paper's Figure 8).
            assert!(hulk.comm_ms < row[1].comm_ms);
            assert!(hulk.comm_ms < row[2].comm_ms);
        }
    }

    #[test]
    fn headline_improvement_over_20_percent() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        let imp = eval.hulk_improvement();
        assert!(imp > 0.20, "Hulk improvement only {:.1}%", imp * 100.0);
    }

    #[test]
    fn render_mentions_every_system_and_model() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        let out = eval.render();
        for kind in SystemKind::ALL {
            assert!(out.contains(kind.name()));
        }
        assert!(out.contains("OPT (175B)"));
        assert!(out.contains("infeasible")); // System A × OPT
    }

    #[test]
    fn slugs_are_stable_and_unique() {
        let slugs: Vec<&str> =
            SystemKind::ALL.iter().map(|k| k.slug()).collect();
        assert_eq!(slugs, vec!["system_a", "system_b", "system_c", "hulk"]);
    }
}
