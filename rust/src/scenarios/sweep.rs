//! Parameter sweeps around the paper's evaluation — the ablation studies
//! DESIGN.md calls out:
//!
//! - **fleet-size sweep**: Hulk's improvement vs the best baseline as the
//!   fleet grows from 12 to 46 servers (where does grouping start to
//!   pay?),
//! - **microbatch sweep**: GPipe bubble amortization inside Hulk groups,
//! - **WAN-degradation sweep**: improvement as every inter-region latency
//!   is scaled ×1..×8 (the paper's motivation: the worse the WAN, the
//!   bigger Hulk's win).
//!
//! Every sweep takes the caller's [`PlannerRegistry`], so ablation
//! planners and `--systems` filters flow through; the named scenarios in
//! [`super::registry`] build on these sweeps.

use anyhow::Result;

use crate::cluster::{Fleet, Machine};
use crate::models::ModelSpec;
use crate::planner::{CostBackend, HulkSplitterKind, Placement, Planner,
                     PlannerRegistry, TaskPlacement};

use super::evaluate::evaluate_with_backend;
use super::world::ScenarioWorld;

/// One sweep point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub x: f64,
    /// Hulk total-time improvement over the best feasible baseline.
    pub improvement: f64,
}

/// The evaluation fleet truncated to its first `n` machines with
/// re-densified ids (fleet-growth experiments).
pub fn truncated_fleet(full: &Fleet, n: usize) -> Fleet {
    assert!((2..=full.len()).contains(&n), "bad truncation size {n}");
    let machines: Vec<Machine> = full.machines[..n]
        .iter()
        .enumerate()
        .map(|(i, m)| Machine::new(i, m.region, m.gpu, m.n_gpus))
        .collect();
    Fleet::new(machines, full.wan.clone())
}

/// Drop workload models `fleet` cannot host at all (sweeps over small
/// fleets must not fail wholesale because OPT-175B needs 2.8 TB).
pub fn feasible_workload(fleet: &Fleet, workload: &[ModelSpec])
    -> Vec<ModelSpec>
{
    workload
        .iter()
        .filter(|t| t.train_gb() * 1.1 <= fleet.total_memory_gb())
        .cloned()
        .collect()
}

/// Fleet-size sweep: truncate the evaluation fleet to its first `n`
/// machines (re-densified ids) and re-evaluate the workload, priced by
/// `backend`.
pub fn fleet_size_sweep(planners: &PlannerRegistry, backend: CostBackend,
                        seed: u64, sizes: &[usize],
                        workload: &[ModelSpec]) -> Result<Vec<SweepPoint>>
{
    let full = Fleet::paper_evaluation(seed);
    let mut out = Vec::with_capacity(sizes.len());
    for &n in sizes {
        anyhow::ensure!((2..=full.len()).contains(&n), "bad sweep size {n}");
        let fleet = truncated_fleet(&full, n);
        let feasible = feasible_workload(&fleet, workload);
        if feasible.is_empty() {
            continue;
        }
        match evaluate_with_backend(planners, &fleet, &feasible,
                                    HulkSplitterKind::Oracle, backend) {
            Ok(eval) => out.push(SweepPoint {
                x: n as f64,
                improvement: eval.hulk_improvement(),
            }),
            Err(_) => continue, // Algorithm 1 deferred: skip the point
        }
    }
    Ok(out)
}

/// Microbatch sweep: per-iteration total of one Hulk group's pipeline as
/// K varies (the GPipe bubble-amortization curve), priced by `backend`.
/// Requires a Hulk planner in the registry (it alone emits a grouped
/// pipeline placement).
pub fn microbatch_sweep(planners: &PlannerRegistry, backend: CostBackend,
                        seed: u64, model: &ModelSpec, ks: &[usize])
    -> Result<Vec<SweepPoint>>
{
    let hulk = planners.find("hulk").ok_or_else(|| {
        anyhow::anyhow!("microbatch sweep needs a registered hulk planner")
    })?;
    let world = ScenarioWorld::new(Fleet::paper_evaluation(seed),
                                   vec![model.clone()]);
    let ctx = world.context(HulkSplitterKind::Oracle);
    let placement = hulk.plan(&ctx)?;
    let base = placement.pipeline(0).expect("hulk tasks are pipelined");
    let mut out = Vec::with_capacity(ks.len());
    for &k in ks {
        let mut p = base.clone();
        p.microbatches = k;
        let single = Placement {
            per_task: vec![TaskPlacement::PipelineStages {
                stages: p.stages,
                layers: p.layers,
                microbatches: p.microbatches,
            }],
        };
        let cost = backend
            .price(world.fleet(), world.workload(), &single)
            .per_task[0];
        out.push(SweepPoint { x: k as f64, improvement: cost.total_ms() });
    }
    Ok(out)
}

/// WAN-degradation sweep: scale every *inter-region* latency by `factor`
/// and re-evaluate, priced by `backend`. Returns (factor, improvement)
/// points.
pub fn wan_degradation_sweep(planners: &PlannerRegistry,
                             backend: CostBackend, seed: u64,
                             factors: &[f64], workload: &[ModelSpec])
    -> Result<Vec<SweepPoint>>
{
    let mut out = Vec::with_capacity(factors.len());
    for &factor in factors {
        anyhow::ensure!(factor >= 1.0, "degradation factor must be ≥ 1");
        let fleet = Fleet::paper_evaluation(seed)
            .with_wan_scaled(factor);
        let eval = evaluate_with_backend(planners, &fleet, workload,
                                         HulkSplitterKind::Oracle,
                                         backend)?;
        out.push(SweepPoint { x: factor,
                              improvement: eval.hulk_improvement() });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn four() -> PlannerRegistry {
        PlannerRegistry::standard()
    }

    #[test]
    fn fleet_size_sweep_produces_points() {
        let points = fleet_size_sweep(&four(), CostBackend::Analytic, 0,
                                      &[16, 24, 46],
                                      &ModelSpec::paper_four())
            .unwrap();
        assert!(!points.is_empty());
        // At full size the improvement must clear the paper's headline.
        let last = points.last().unwrap();
        assert_eq!(last.x, 46.0);
        assert!(last.improvement > 0.20);
    }

    #[test]
    fn truncation_redensifies_ids() {
        let full = Fleet::paper_evaluation(0);
        let small = truncated_fleet(&full, 12);
        assert_eq!(small.len(), 12);
        for (i, m) in small.machines.iter().enumerate() {
            assert_eq!(m.id, i);
            assert_eq!(m.region, full.machines[i].region);
        }
    }

    #[test]
    fn feasibility_filter_drops_oversized_models() {
        let full = Fleet::paper_evaluation(0);
        let small = truncated_fleet(&full, 2);
        let kept = feasible_workload(&small, &ModelSpec::paper_four());
        assert!(kept.iter().all(|m| m.name != "OPT (175B)"));
        assert!(kept.iter().any(|m| m.name.starts_with("BERT")));
    }

    #[test]
    fn microbatch_sweep_amortizes_bubble() {
        let points = microbatch_sweep(&four(), CostBackend::Analytic, 0,
                                      &ModelSpec::gpt2_xl(), &[1, 4, 16])
            .unwrap();
        assert_eq!(points.len(), 3);
        // Per-iteration time is not monotone in K in general (comm grows
        // with K) but K=1 must be strictly worse than the best of the
        // larger Ks: an unpipelined single batch serializes every stage.
        let k1 = points[0].improvement;
        let best_other = points[1..]
            .iter()
            .map(|p| p.improvement)
            .fold(f64::INFINITY, f64::min);
        assert!(k1 > best_other, "K=1 {} vs best other {}", k1, best_other);
    }

    #[test]
    fn microbatch_sweep_requires_a_hulk_planner() {
        let baselines = PlannerRegistry::resolve("a,b,c").unwrap();
        let err = microbatch_sweep(&baselines, CostBackend::Analytic, 0,
                                   &ModelSpec::gpt2_xl(), &[1, 4])
            .unwrap_err();
        assert!(err.to_string().contains("hulk planner"), "{err}");
    }

    #[test]
    fn wan_degradation_grows_the_win() {
        let points = wan_degradation_sweep(&four(), CostBackend::Analytic,
                                           0, &[1.0, 4.0],
                                           &ModelSpec::paper_four())
            .unwrap();
        assert_eq!(points.len(), 2);
        // Hulk keeps traffic regional: degrading the WAN hurts the
        // baselines more, so the improvement must not shrink.
        assert!(points[1].improvement >= points[0].improvement - 0.02,
                "×1: {:.3} vs ×4: {:.3}", points[0].improvement,
                points[1].improvement);
    }

    #[test]
    fn degradation_factor_below_one_rejected() {
        assert!(wan_degradation_sweep(&four(), CostBackend::Analytic, 0,
                                      &[0.5], &ModelSpec::paper_four())
            .is_err());
    }

    #[test]
    fn simulated_microbatch_sweep_still_amortizes_the_bubble() {
        // The unpipelined K=1 schedule serializes every stage under
        // execution too — backend choice must not flip the curve's shape.
        let points = microbatch_sweep(&four(), CostBackend::Simulated, 0,
                                      &ModelSpec::gpt2_xl(), &[1, 8])
            .unwrap();
        assert_eq!(points.len(), 2);
        assert!(points[0].improvement > points[1].improvement,
                "K=1 {} vs K=8 {}", points[0].improvement,
                points[1].improvement);
    }
}
