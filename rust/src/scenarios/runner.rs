//! The scenario execution engine.
//!
//! [`ScenarioSpec`] turns a scenario into *data* — a name, a seed
//! policy, and a body that is either the standard "fleet × workload
//! through every registered planner" shape or an opaque custom runner.
//! The engine decomposes specs into **cells** (one per (scenario ×
//! registered planner) for the standard shape, one per scenario
//! otherwise), executes the cells either inline or across a
//! `std::thread` worker pool, and merges the outputs back **in registry
//! insertion order**.
//!
//! Which planners run is the caller's [`PlannerRegistry`] — the CLI's
//! `--systems` filter hands a subset, the default is
//! [`PlannerRegistry::standard`] (the paper's four, byte-identical
//! artifacts to the pre-seam engine).
//!
//! Which backend prices the cells is the caller's
//! [`CostBackend`] — `--cost analytic` (the default, closed-form
//! formulas) or `--cost sim` (whole-placement discrete-event execution
//! with shared WAN-link contention, plus per-system contention digests
//! in the entries and the rendering).
//!
//! Determinism contract: every cell is a pure function of
//! `(spec, planner, seed, backend)` — no wall clock, no global state —
//! and the merge order is fixed by the spec list and the registry, not
//! by completion order. Therefore `hulk scenarios run all --json
//! --parallel` writes a `BENCH_scenarios.json` that is byte-identical
//! to the serial run's (for either backend), which CI enforces as a
//! gate.
//!
//! Cells of one spec share a single [`ScenarioWorld`] (fleet + cluster
//! graph + canonical workload), built once per (scenario, seed) instead
//! of once per cell. The world is itself a pure function of
//! `(spec, seed)`, so sharing is invisible in the artifacts —
//! [`WorldSharing::Rebuild`] is the cache-off mode the byte-identity
//! tests diff against.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use crate::benchkit::BenchEntry;
use crate::cluster::Fleet;
use crate::models::ModelSpec;
use crate::parallel::IterCost;
use crate::planner::{CostBackend, ExecReport, HulkSplitterKind,
                     PlacementSummary, Planner, PlannerRegistry};

use super::evaluate::SystemEval;
use super::world::ScenarioWorld;

/// How a scenario derives its effective seed from the CLI seed.
#[derive(Clone, Copy, Debug)]
pub enum SeedPolicy {
    /// Use the CLI seed unchanged.
    Global,
    /// XOR a domain-separation tag into the CLI seed so sibling
    /// scenarios draw decorrelated random streams.
    Tagged(u64),
}

impl SeedPolicy {
    pub fn apply(self, seed: u64) -> u64 {
        match self {
            SeedPolicy::Global => seed,
            SeedPolicy::Tagged(tag) => seed ^ tag,
        }
    }
}

/// What a scenario *is*, as data. Only `fn` pointers — specs are
/// `Send + Sync + Clone` for free, which is what lets the worker pool
/// execute their cells on any thread.
#[derive(Clone)]
pub enum ScenarioBody {
    /// The standard shape: build a fleet from the effective seed, pick
    /// a workload on it, and run the workload through every registered
    /// planner. The engine fans this out as one cell per planner.
    Evaluate {
        /// Effective seed → fleet.
        fleet: fn(u64) -> Fleet,
        /// Workload on that fleet. The engine sorts it canonically
        /// (largest-first, name tie-break) before costing.
        workload: fn(&Fleet) -> Vec<ModelSpec>,
        /// Assemble `BENCH_*.json` entries + the human-readable report
        /// from the merged evaluation.
        finish: fn(&Fleet, &SystemEval) -> (Vec<BenchEntry>, String),
    },
    /// Anything more elaborate (leader-loop streams, failure storms,
    /// multi-step sweeps): a single opaque cell. Receives the planner
    /// registry so its baseline comparisons honor `--systems` filters,
    /// and the [`CostBackend`] so `--cost sim` prices its evaluations by
    /// execution.
    Custom(fn(u64, &PlannerRegistry, CostBackend) -> Result<ScenarioResult>),
}

/// A registered scenario: definition as data, executed by [`run_specs`].
#[derive(Clone)]
pub struct ScenarioSpec {
    pub name: &'static str,
    pub description: &'static str,
    pub seed: SeedPolicy,
    pub body: ScenarioBody,
    /// Scenarios that only make sense under shared-link contention
    /// (`contended_links`, `sim_vs_analytic`): excluded from analytic
    /// `all` runs so the default artifact keeps its historical shape,
    /// and rejected with a pointer to `--cost sim` when named
    /// explicitly under the analytic backend.
    pub sim_only: bool,
    /// Scale scenarios (`continent_scale`, `global_scale`): fleets of
    /// 10k–100k machines that plan through the hierarchical substrate
    /// in seconds but would dwarf every other scenario's runtime.
    /// Excluded from `all` under **both** backends — run them by name.
    pub heavy: bool,
}

/// Output of one scenario run.
#[derive(Debug)]
pub struct ScenarioResult {
    pub scenario: &'static str,
    /// Machine-readable rows for the `BENCH_scenarios.json` report.
    pub entries: Vec<BenchEntry>,
    /// Placement-digest rows (`BENCH_placements.json`) — kept out of
    /// `entries` so the scenarios artifact stays byte-identical to its
    /// pre-planner-seam shape.
    pub placements: Vec<BenchEntry>,
    /// Human-readable rendering for the CLI.
    pub rendered: String,
}

impl ScenarioSpec {
    /// Run this scenario alone, serially, under the standard planners
    /// and the analytic backend.
    pub fn run(&self, seed: u64) -> Result<ScenarioResult> {
        self.run_with(seed, &PlannerRegistry::standard())
    }

    /// Run this scenario alone, serially, under `planners` (analytic
    /// backend).
    pub fn run_with(&self, seed: u64, planners: &PlannerRegistry)
        -> Result<ScenarioResult>
    {
        self.run_with_backend(seed, planners, CostBackend::Analytic)
    }

    /// Run this scenario alone, serially, under `planners` × `backend`.
    pub fn run_with_backend(&self, seed: u64, planners: &PlannerRegistry,
                            backend: CostBackend) -> Result<ScenarioResult>
    {
        let mut results = run_specs(std::slice::from_ref(self), seed, 1,
                                    planners, backend)?;
        Ok(results.remove(0))
    }

    /// How many schedulable cells this spec fans out into.
    fn n_cells(&self, planners: &PlannerRegistry) -> usize {
        match self.body {
            ScenarioBody::Evaluate { .. } => planners.len(),
            ScenarioBody::Custom(_) => 1,
        }
    }
}

/// One executed cell's output.
enum CellOut {
    /// Per-model costs + placement digest + (simulated-backend)
    /// execution report for a single planner (canonical task order).
    Column(Vec<IterCost>, PlacementSummary, Option<ExecReport>),
    /// A complete custom scenario result.
    Whole(ScenarioResult),
}

/// Whether `Evaluate` cells of one spec share a single
/// [`ScenarioWorld`] or rebuild it per cell.
///
/// `Shared` is the production mode: the world is a pure function of
/// `(spec, seed)`, so sharing the one allocation across every planner
/// cell (and the merge) changes no output byte — it only stops paying
/// the fleet + graph rebuild once per cell. `Rebuild` is the cache-off
/// reference mode the determinism tests diff against. `DenseOracle`
/// plans every `Evaluate` cell on the demoted dense [`ClusterGraph`]
/// (no hierarchical context) — the reference substrate
/// `rust/tests/hier_parity.rs` diffs the hierarchical run against.
///
/// [`ClusterGraph`]: crate::graph::ClusterGraph
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorldSharing {
    Shared,
    Rebuild,
    DenseOracle,
}

/// Build the world of an `Evaluate` spec from the CLI seed.
fn spec_world(spec: &ScenarioSpec, seed: u64, dense: bool) -> ScenarioWorld {
    match &spec.body {
        ScenarioBody::Evaluate { fleet, workload, .. } => {
            let eff = spec.seed.apply(seed);
            if dense {
                ScenarioWorld::for_evaluate_dense(*fleet, *workload, eff)
            } else {
                ScenarioWorld::for_evaluate(*fleet, *workload, eff)
            }
        }
        ScenarioBody::Custom(_) => {
            unreachable!("custom bodies build their own contexts")
        }
    }
}

/// Execute one cell. Pure in `(spec, cell_idx, seed, planners,
/// backend)` — the shared world is itself a pure function of
/// `(spec, seed)`, so sharing it does not weaken the contract.
fn run_cell(spec: &ScenarioSpec, cell_idx: usize, seed: u64,
            planners: &PlannerRegistry, backend: CostBackend,
            world: Option<Arc<ScenarioWorld>>)
    -> Result<CellOut>
{
    match &spec.body {
        ScenarioBody::Custom(f) => {
            Ok(CellOut::Whole(f(spec.seed.apply(seed), planners,
                                backend)?))
        }
        ScenarioBody::Evaluate { .. } => {
            let world = world.expect("evaluate cell carries a world");
            let ctx = world
                .context(HulkSplitterKind::Oracle)
                .with_backend(backend);
            let planner = planners.get(cell_idx);
            let placement = planner.plan(&ctx)?;
            let priced = planner.price(&ctx, &placement);
            Ok(CellOut::Column(priced.per_task,
                               placement.summary(world.fleet()),
                               priced.exec))
        }
    }
}

/// Placement-digest entries for one evaluated scenario (also used by
/// `Custom` scenario bodies that run a full evaluation internally).
pub(crate) fn placement_entries(scenario: &str, eval: &SystemEval)
    -> Vec<BenchEntry>
{
    let mut out = Vec::with_capacity(eval.systems.len() * 3);
    for (meta, summary) in eval.systems.iter().zip(&eval.placements) {
        let prefix = format!("{scenario}/{}/placement", meta.slug);
        out.push(BenchEntry::new(format!("{prefix}/group_count"),
                                 summary.groups as f64, "count"));
        out.push(BenchEntry::new(format!("{prefix}/stage_count"),
                                 summary.stages as f64, "count"));
        out.push(BenchEntry::new(format!("{prefix}/cross_region_edges"),
                                 summary.cross_region_edges as f64,
                                 "count"));
    }
    out
}

/// Execution-digest entries for one evaluated scenario — empty under the
/// analytic backend, so analytic artifacts keep their historical shape.
/// Also used by `Custom` bodies embedding a simulated evaluation.
pub(crate) fn exec_entries(scenario: &str, eval: &SystemEval)
    -> Vec<BenchEntry>
{
    let mut out = Vec::new();
    for (meta, exec) in eval.systems.iter().zip(&eval.exec) {
        let Some(exec) = exec else { continue };
        let prefix = format!("{scenario}/{}/sim", meta.slug);
        if exec.makespan_ms.is_finite() {
            out.push(BenchEntry::new(format!("{prefix}/makespan_ms"),
                                     exec.makespan_ms, "ms"));
            out.push(BenchEntry::new(
                format!("{prefix}/straggler_wait_ms"),
                exec.straggler_wait_ms,
                "ms",
            ));
        }
        let max_util = exec
            .hottest_link()
            .map(|l| l.utilization * 100.0)
            .unwrap_or(0.0);
        out.push(BenchEntry::new(
            format!("{prefix}/max_link_utilization_pct"),
            max_util,
            "%",
        ));
        out.push(BenchEntry::new(format!("{prefix}/events"),
                                 exec.events_processed as f64, "count"));
    }
    out
}

/// Merge one spec's cell outputs back into a [`ScenarioResult`].
/// Errors propagate in cell order, so the first failing cell of the
/// first failing scenario wins — the same error a serial run reports.
fn merge_spec(spec: &ScenarioSpec, planners: &PlannerRegistry,
              backend: CostBackend, outs: Vec<Result<CellOut>>,
              world: Option<Arc<ScenarioWorld>>)
    -> Result<ScenarioResult>
{
    match &spec.body {
        ScenarioBody::Custom(_) => {
            let out = outs.into_iter().next().expect("custom spec has a cell");
            match out? {
                CellOut::Whole(result) => Ok(result),
                CellOut::Column(..) => unreachable!("custom cell → Whole"),
            }
        }
        ScenarioBody::Evaluate { finish, .. } => {
            let mut columns = Vec::with_capacity(planners.len());
            let mut placements = Vec::with_capacity(planners.len());
            let mut exec = Vec::with_capacity(planners.len());
            for out in outs {
                match out? {
                    CellOut::Column(column, summary, report) => {
                        columns.push(column);
                        placements.push(summary);
                        exec.push(report);
                    }
                    CellOut::Whole(_) => unreachable!("eval cell → Column"),
                }
            }
            let world = world.expect("evaluate spec carries a world");
            let wl = world.workload().to_vec();
            let costs: Vec<Vec<IterCost>> = (0..wl.len())
                .map(|m| columns.iter().map(|col| col[m]).collect())
                .collect();
            let eval = SystemEval {
                systems: planners.metas(),
                models: wl,
                costs,
                placements,
                backend,
                exec,
            };
            let (mut entries, mut rendered) = finish(world.fleet(), &eval);
            // Under the simulated backend every evaluated scenario also
            // reports its contention digest; under analytic these are
            // no-ops, keeping the artifact byte-identical.
            entries.extend(exec_entries(spec.name, &eval));
            let exec_rendered = eval.render_exec();
            if !exec_rendered.is_empty() {
                rendered.push_str(&exec_rendered);
            }
            Ok(ScenarioResult {
                scenario: spec.name,
                entries,
                placements: placement_entries(spec.name, &eval),
                rendered,
            })
        }
    }
}

/// Run `specs` with one CLI seed on `threads` workers (`<= 1` = inline
/// serial execution, no threads spawned), evaluating under `planners`
/// priced by `backend`. Results come back in spec order with identical
/// contents regardless of `threads` — callers may diff the serialized
/// reports byte-for-byte, for either backend. Each spec's
/// [`ScenarioWorld`] is built once and shared across its cells.
pub fn run_specs(specs: &[ScenarioSpec], seed: u64, threads: usize,
                 planners: &PlannerRegistry, backend: CostBackend)
    -> Result<Vec<ScenarioResult>>
{
    run_specs_sharing(specs, seed, threads, planners, backend,
                      WorldSharing::Shared)
}

/// [`run_specs`] with an explicit [`WorldSharing`] mode. `Rebuild`
/// reconstructs the world inside every cell — the cache-off reference
/// the byte-identity tests compare against; never faster, only honest.
pub fn run_specs_sharing(specs: &[ScenarioSpec], seed: u64,
                         threads: usize, planners: &PlannerRegistry,
                         backend: CostBackend, sharing: WorldSharing)
    -> Result<Vec<ScenarioResult>>
{
    // Flatten to (spec, cell) pairs — the schedulable unit.
    let cells: Vec<(usize, usize)> = specs
        .iter()
        .enumerate()
        .flat_map(|(si, s)| (0..s.n_cells(planners)).map(move |ci| (si, ci)))
        .collect();

    // One lazily built world per spec, shared by that spec's cells and
    // its merge. `OnceLock` keeps the build race-free under `--parallel`
    // (first worker to touch the spec builds; the rest share the Arc).
    let worlds: Vec<OnceLock<Arc<ScenarioWorld>>> =
        specs.iter().map(|_| OnceLock::new()).collect();
    let world_for = |si: usize| -> Option<Arc<ScenarioWorld>> {
        let spec = &specs[si];
        if !matches!(spec.body, ScenarioBody::Evaluate { .. }) {
            return None;
        }
        Some(match sharing {
            WorldSharing::Shared => worlds[si]
                .get_or_init(|| Arc::new(spec_world(spec, seed, false)))
                .clone(),
            WorldSharing::DenseOracle => worlds[si]
                .get_or_init(|| Arc::new(spec_world(spec, seed, true)))
                .clone(),
            WorldSharing::Rebuild => Arc::new(spec_world(spec, seed, false)),
        })
    };

    let outs: Vec<Result<CellOut>> = if threads <= 1 || cells.len() <= 1 {
        // Serial: stop executing after the first failure — later cells
        // get a placeholder error that can never win the merge (errors
        // surface in cell order, and the real failure comes first).
        let mut outs = Vec::with_capacity(cells.len());
        let mut failed = false;
        for &(si, ci) in &cells {
            if failed {
                outs.push(Err(anyhow::anyhow!(
                    "cell not run: an earlier scenario cell failed")));
                continue;
            }
            let out = run_cell(&specs[si], ci, seed, planners, backend,
                               world_for(si));
            failed = out.is_err();
            outs.push(out);
        }
        outs
    } else {
        let n_workers = threads.min(cells.len());
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<CellOut>>>> =
            cells.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..n_workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(&(si, ci)) = cells.get(i) else { break };
                    let out = run_cell(&specs[si], ci, seed, planners,
                                       backend, world_for(si));
                    *slots[i].lock().expect("cell slot poisoned") = Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .expect("cell slot poisoned")
                    .expect("worker pool executed every cell")
            })
            .collect()
    };

    // Deterministic merge: strictly spec order, then cell order.
    let mut outs = outs.into_iter();
    specs
        .iter()
        .enumerate()
        .map(|(si, spec)| {
            let cell_outs: Vec<Result<CellOut>> =
                outs.by_ref().take(spec.n_cells(planners)).collect();
            merge_spec(spec, planners, backend, cell_outs, world_for(si))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "toy_eval",
            description: "paper fleet, small workload",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Evaluate {
                fleet: Fleet::paper_evaluation,
                workload: |_| vec![ModelSpec::gpt2_xl(),
                                   ModelSpec::bert_large()],
                finish: |_, eval| {
                    let entries = vec![BenchEntry::new(
                        "toy_eval/hulk_improvement_pct",
                        eval.hulk_improvement() * 100.0,
                        "%",
                    )];
                    (entries, eval.render())
                },
            },
            sim_only: false,
            heavy: false,
        }
    }

    #[test]
    fn evaluate_body_matches_evaluate_all() {
        // The cell-decomposed path must reproduce the monolithic
        // `evaluate_all` numbers exactly.
        let spec = toy_spec();
        let result = spec.run(3).unwrap();
        let fleet = Fleet::paper_evaluation(3);
        let eval = super::super::evaluate::evaluate_all(
            &fleet,
            &[ModelSpec::gpt2_xl(), ModelSpec::bert_large()],
            HulkSplitterKind::Oracle,
        )
        .unwrap();
        assert_eq!(result.entries[0].value,
                   eval.hulk_improvement() * 100.0);
        assert_eq!(result.rendered, eval.render());
        // The runner's placement digest matches the monolithic one.
        assert_eq!(result.placements.len(), 4 * 3);
        assert_eq!(
            result.placements[0].name,
            "toy_eval/system_a/placement/group_count"
        );
        assert_eq!(result.placements[0].value,
                   eval.placements[0].groups as f64);
    }

    #[test]
    fn parallel_equals_serial_for_mixed_bodies() {
        fn custom(seed: u64, _planners: &PlannerRegistry,
                  _backend: CostBackend) -> Result<ScenarioResult>
        {
            Ok(ScenarioResult {
                scenario: "toy_custom",
                entries: vec![BenchEntry::new("toy_custom/seed",
                                              seed as f64, "count")],
                placements: Vec::new(),
                rendered: format!("seed {seed}\n"),
            })
        }
        let specs = vec![
            toy_spec(),
            ScenarioSpec {
                name: "toy_custom",
                description: "custom body",
                seed: SeedPolicy::Tagged(0xBEEF),
                body: ScenarioBody::Custom(custom),
                sim_only: false,
                heavy: false,
            },
        ];
        let planners = PlannerRegistry::standard();
        let serial =
            run_specs(&specs, 5, 1, &planners, CostBackend::Analytic)
                .unwrap();
        let parallel =
            run_specs(&specs, 5, 4, &planners, CostBackend::Analytic)
                .unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.rendered, b.rendered);
            let rows = |r: &ScenarioResult| -> Vec<(String, f64, String)> {
                r.entries
                    .iter()
                    .chain(&r.placements)
                    .map(|e| (e.name.clone(), e.value, e.unit.clone()))
                    .collect()
            };
            assert_eq!(rows(a), rows(b));
        }
        // The tagged custom body saw seed ^ tag, not the raw seed.
        assert_eq!(serial[1].entries[0].value, (5u64 ^ 0xBEEF) as f64);
    }

    #[test]
    fn filtered_registry_shrinks_the_cells() {
        let planners = PlannerRegistry::resolve("a,hulk").unwrap();
        let result = toy_spec().run_with(0, &planners).unwrap();
        // Two planners → 2 × 3 placement-digest rows, and the rendered
        // table mentions only the selected systems.
        assert_eq!(result.placements.len(), 2 * 3);
        assert!(result.rendered.contains("System A (DP)"));
        assert!(!result.rendered.contains("System C (Megatron)"));
        assert!(result.rendered.contains("Hulk"));
    }

    #[test]
    fn errors_propagate_in_spec_order() {
        fn failing(_seed: u64, _planners: &PlannerRegistry,
                   _backend: CostBackend) -> Result<ScenarioResult>
        {
            anyhow::bail!("first failure")
        }
        fn also_failing(_seed: u64, _planners: &PlannerRegistry,
                        _backend: CostBackend) -> Result<ScenarioResult>
        {
            anyhow::bail!("second failure")
        }
        let specs = vec![
            ScenarioSpec {
                name: "boom_a",
                description: "",
                seed: SeedPolicy::Global,
                body: ScenarioBody::Custom(failing),
                sim_only: false,
                heavy: false,
            },
            ScenarioSpec {
                name: "boom_b",
                description: "",
                seed: SeedPolicy::Global,
                body: ScenarioBody::Custom(also_failing),
                sim_only: false,
                heavy: false,
            },
        ];
        let planners = PlannerRegistry::standard();
        for threads in [1, 4] {
            let err = run_specs(&specs, 0, threads, &planners,
                                CostBackend::Analytic)
                .unwrap_err();
            assert!(err.to_string().contains("first failure"),
                    "threads {threads}: {err}");
        }
    }

    #[test]
    fn simulated_backend_cells_merge_deterministically_with_digests() {
        let specs = vec![toy_spec()];
        let planners = PlannerRegistry::standard();
        let serial =
            run_specs(&specs, 3, 1, &planners, CostBackend::Simulated)
                .unwrap();
        let parallel =
            run_specs(&specs, 3, 4, &planners, CostBackend::Simulated)
                .unwrap();
        let rows = |r: &ScenarioResult| -> Vec<(String, f64)> {
            r.entries
                .iter()
                .map(|e| (e.name.clone(), e.value))
                .collect()
        };
        assert_eq!(rows(&serial[0]), rows(&parallel[0]));
        assert_eq!(serial[0].rendered, parallel[0].rendered);
        // Every planner contributes a contention digest on top of the
        // finish()-assembled entries.
        for slug in ["system_a", "system_b", "system_c", "hulk"] {
            let name = format!("toy_eval/{slug}/sim/makespan_ms");
            assert!(serial[0].entries.iter().any(|e| e.name == name),
                    "missing {name}");
        }
        assert!(serial[0].rendered.contains("simulated execution"));
        // The analytic run of the same spec carries no sim rows at all.
        let analytic =
            run_specs(&specs, 3, 1, &planners, CostBackend::Analytic)
                .unwrap();
        assert!(analytic[0]
            .entries
            .iter()
            .all(|e| !e.name.contains("/sim/")));
    }
}
