//! The scenario execution engine.
//!
//! [`ScenarioSpec`] turns a scenario into *data* — a name, a seed
//! policy, and a body that is either the standard "fleet × workload
//! through all four systems" shape or an opaque custom runner. The
//! engine decomposes specs into **cells** (one per (scenario, system)
//! for the standard shape, one per scenario otherwise), executes the
//! cells either inline or across a `std::thread` worker pool, and
//! merges the outputs back **in registry insertion order**.
//!
//! Determinism contract: every cell is a pure function of
//! `(spec, seed)` — no wall clock, no global state — and the merge
//! order is fixed by the spec list, not by completion order. Therefore
//! `hulk scenarios run all --json --parallel` writes a
//! `BENCH_scenarios.json` that is byte-identical to the serial run's,
//! which CI enforces as a gate.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::benchkit::BenchEntry;
use crate::cluster::Fleet;
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::parallel::IterCost;
use crate::systems::hulk::{hulk_plan, HulkSplitterKind};
use crate::systems::{system_a, system_b, system_c};

use super::evaluate::{SystemEval, SystemKind};

/// How a scenario derives its effective seed from the CLI seed.
#[derive(Clone, Copy, Debug)]
pub enum SeedPolicy {
    /// Use the CLI seed unchanged.
    Global,
    /// XOR a domain-separation tag into the CLI seed so sibling
    /// scenarios draw decorrelated random streams.
    Tagged(u64),
}

impl SeedPolicy {
    pub fn apply(self, seed: u64) -> u64 {
        match self {
            SeedPolicy::Global => seed,
            SeedPolicy::Tagged(tag) => seed ^ tag,
        }
    }
}

/// What a scenario *is*, as data. Only `fn` pointers — specs are
/// `Send + Sync + Clone` for free, which is what lets the worker pool
/// execute their cells on any thread.
#[derive(Clone)]
pub enum ScenarioBody {
    /// The standard shape: build a fleet from the effective seed, pick
    /// a workload on it, and run the workload through Systems A/B/C and
    /// Hulk. The engine fans this out as one cell per system.
    Evaluate {
        /// Effective seed → fleet.
        fleet: fn(u64) -> Fleet,
        /// Workload on that fleet. The engine sorts it canonically
        /// (largest-first, name tie-break) before costing.
        workload: fn(&Fleet) -> Vec<ModelSpec>,
        /// Assemble `BENCH_*.json` entries + the human-readable report
        /// from the merged four-system evaluation.
        finish: fn(&Fleet, &SystemEval) -> (Vec<BenchEntry>, String),
    },
    /// Anything more elaborate (leader-loop streams, failure storms,
    /// multi-step sweeps): a single opaque cell.
    Custom(fn(u64) -> Result<ScenarioResult>),
}

/// A registered scenario: definition as data, executed by [`run_specs`].
#[derive(Clone)]
pub struct ScenarioSpec {
    pub name: &'static str,
    pub description: &'static str,
    pub seed: SeedPolicy,
    pub body: ScenarioBody,
}

/// Output of one scenario run.
#[derive(Debug)]
pub struct ScenarioResult {
    pub scenario: &'static str,
    /// Machine-readable rows for the `BENCH_*.json` report.
    pub entries: Vec<BenchEntry>,
    /// Human-readable rendering for the CLI.
    pub rendered: String,
}

impl ScenarioSpec {
    /// Run this scenario alone, serially.
    pub fn run(&self, seed: u64) -> Result<ScenarioResult> {
        let mut results = run_specs(std::slice::from_ref(self), seed, 1)?;
        Ok(results.remove(0))
    }

    /// How many schedulable cells this spec fans out into.
    fn n_cells(&self) -> usize {
        match self.body {
            ScenarioBody::Evaluate { .. } => SystemKind::ALL.len(),
            ScenarioBody::Custom(_) => 1,
        }
    }
}

/// One executed cell's output.
enum CellOut {
    /// Per-model costs for a single system (canonical task order).
    Column(Vec<IterCost>),
    /// A complete custom scenario result.
    Whole(ScenarioResult),
}

/// Fleet + canonically ordered workload for an `Evaluate` body.
///
/// Deliberately rebuilt inside every cell (and once more in the merge):
/// keeping each cell a pure function of `(spec, seed)` is what makes
/// parallel output byte-identical to serial. Fleet/workload construction
/// is microseconds next to the cost models, so the duplication is noise.
fn eval_inputs(fleet: fn(u64) -> Fleet,
               workload: fn(&Fleet) -> Vec<ModelSpec>, eff_seed: u64)
    -> (Fleet, Vec<ModelSpec>)
{
    let fl = fleet(eff_seed);
    let mut wl = workload(&fl);
    ModelSpec::sort_largest_first(&mut wl);
    (fl, wl)
}

/// Execute one cell. Pure in `(spec, cell_idx, seed)`.
fn run_cell(spec: &ScenarioSpec, cell_idx: usize, seed: u64)
    -> Result<CellOut>
{
    let eff = spec.seed.apply(seed);
    match &spec.body {
        ScenarioBody::Custom(f) => Ok(CellOut::Whole(f(eff)?)),
        ScenarioBody::Evaluate { fleet, workload, .. } => {
            let (fl, wl) = eval_inputs(*fleet, *workload, eff);
            let costs: Vec<IterCost> = match SystemKind::ALL[cell_idx] {
                SystemKind::SystemA => {
                    wl.iter().map(|m| system_a::cost(&fl, m)).collect()
                }
                SystemKind::SystemB => {
                    wl.iter().map(|m| system_b::cost(&fl, m)).collect()
                }
                SystemKind::SystemC => {
                    wl.iter().map(|m| system_c::cost(&fl, m)).collect()
                }
                SystemKind::Hulk => {
                    let graph = ClusterGraph::from_fleet(&fl);
                    let plan = hulk_plan(&fl, &graph, &wl,
                                         HulkSplitterKind::Oracle)?;
                    (0..wl.len())
                        .map(|t| crate::systems::hulk::cost(&fl, &plan, t))
                        .collect()
                }
            };
            Ok(CellOut::Column(costs))
        }
    }
}

/// Merge one spec's cell outputs back into a [`ScenarioResult`].
/// Errors propagate in cell order, so the first failing cell of the
/// first failing scenario wins — the same error a serial run reports.
fn merge_spec(spec: &ScenarioSpec, seed: u64, outs: Vec<Result<CellOut>>)
    -> Result<ScenarioResult>
{
    match &spec.body {
        ScenarioBody::Custom(_) => {
            let out = outs.into_iter().next().expect("custom spec has a cell");
            match out? {
                CellOut::Whole(result) => Ok(result),
                CellOut::Column(_) => unreachable!("custom cell → Whole"),
            }
        }
        ScenarioBody::Evaluate { fleet, workload, finish } => {
            let mut columns = Vec::with_capacity(SystemKind::ALL.len());
            for out in outs {
                match out? {
                    CellOut::Column(column) => columns.push(column),
                    CellOut::Whole(_) => unreachable!("eval cell → Column"),
                }
            }
            let (fl, wl) = eval_inputs(*fleet, *workload,
                                       spec.seed.apply(seed));
            let costs: Vec<[IterCost; 4]> = (0..wl.len())
                .map(|m| [columns[0][m], columns[1][m], columns[2][m],
                          columns[3][m]])
                .collect();
            let eval = SystemEval { models: wl, costs };
            let (entries, rendered) = finish(&fl, &eval);
            Ok(ScenarioResult { scenario: spec.name, entries, rendered })
        }
    }
}

/// Run `specs` with one CLI seed on `threads` workers (`<= 1` = inline
/// serial execution, no threads spawned). Results come back in spec
/// order with identical contents regardless of `threads` — callers may
/// diff the serialized reports byte-for-byte.
pub fn run_specs(specs: &[ScenarioSpec], seed: u64, threads: usize)
    -> Result<Vec<ScenarioResult>>
{
    // Flatten to (spec, cell) pairs — the schedulable unit.
    let cells: Vec<(usize, usize)> = specs
        .iter()
        .enumerate()
        .flat_map(|(si, s)| (0..s.n_cells()).map(move |ci| (si, ci)))
        .collect();

    let outs: Vec<Result<CellOut>> = if threads <= 1 || cells.len() <= 1 {
        // Serial: stop executing after the first failure — later cells
        // get a placeholder error that can never win the merge (errors
        // surface in cell order, and the real failure comes first).
        let mut outs = Vec::with_capacity(cells.len());
        let mut failed = false;
        for &(si, ci) in &cells {
            if failed {
                outs.push(Err(anyhow::anyhow!(
                    "cell not run: an earlier scenario cell failed")));
                continue;
            }
            let out = run_cell(&specs[si], ci, seed);
            failed = out.is_err();
            outs.push(out);
        }
        outs
    } else {
        let n_workers = threads.min(cells.len());
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<CellOut>>>> =
            cells.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..n_workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(&(si, ci)) = cells.get(i) else { break };
                    let out = run_cell(&specs[si], ci, seed);
                    *slots[i].lock().expect("cell slot poisoned") = Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .expect("cell slot poisoned")
                    .expect("worker pool executed every cell")
            })
            .collect()
    };

    // Deterministic merge: strictly spec order, then cell order.
    let mut outs = outs.into_iter();
    specs
        .iter()
        .map(|spec| {
            let cell_outs: Vec<Result<CellOut>> =
                outs.by_ref().take(spec.n_cells()).collect();
            merge_spec(spec, seed, cell_outs)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "toy_eval",
            description: "paper fleet, small workload",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Evaluate {
                fleet: Fleet::paper_evaluation,
                workload: |_| vec![ModelSpec::gpt2_xl(),
                                   ModelSpec::bert_large()],
                finish: |_, eval| {
                    let entries = vec![BenchEntry::new(
                        "toy_eval/hulk_improvement_pct",
                        eval.hulk_improvement() * 100.0,
                        "%",
                    )];
                    (entries, eval.render())
                },
            },
        }
    }

    #[test]
    fn evaluate_body_matches_evaluate_all() {
        // The cell-decomposed path must reproduce the monolithic
        // `evaluate_all` numbers exactly.
        let spec = toy_spec();
        let result = spec.run(3).unwrap();
        let fleet = Fleet::paper_evaluation(3);
        let eval = super::super::evaluate::evaluate_all(
            &fleet,
            &[ModelSpec::gpt2_xl(), ModelSpec::bert_large()],
            HulkSplitterKind::Oracle,
        )
        .unwrap();
        assert_eq!(result.entries[0].value,
                   eval.hulk_improvement() * 100.0);
        assert_eq!(result.rendered, eval.render());
    }

    #[test]
    fn parallel_equals_serial_for_mixed_bodies() {
        fn custom(seed: u64) -> Result<ScenarioResult> {
            Ok(ScenarioResult {
                scenario: "toy_custom",
                entries: vec![BenchEntry::new("toy_custom/seed",
                                              seed as f64, "count")],
                rendered: format!("seed {seed}\n"),
            })
        }
        let specs = vec![
            toy_spec(),
            ScenarioSpec {
                name: "toy_custom",
                description: "custom body",
                seed: SeedPolicy::Tagged(0xBEEF),
                body: ScenarioBody::Custom(custom),
            },
        ];
        let serial = run_specs(&specs, 5, 1).unwrap();
        let parallel = run_specs(&specs, 5, 4).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.rendered, b.rendered);
            let rows = |r: &ScenarioResult| -> Vec<(String, f64, String)> {
                r.entries
                    .iter()
                    .map(|e| (e.name.clone(), e.value, e.unit.clone()))
                    .collect()
            };
            assert_eq!(rows(a), rows(b));
        }
        // The tagged custom body saw seed ^ tag, not the raw seed.
        assert_eq!(serial[1].entries[0].value, (5u64 ^ 0xBEEF) as f64);
    }

    #[test]
    fn errors_propagate_in_spec_order() {
        fn failing(_seed: u64) -> Result<ScenarioResult> {
            anyhow::bail!("first failure")
        }
        fn also_failing(_seed: u64) -> Result<ScenarioResult> {
            anyhow::bail!("second failure")
        }
        let specs = vec![
            ScenarioSpec {
                name: "boom_a",
                description: "",
                seed: SeedPolicy::Global,
                body: ScenarioBody::Custom(failing),
            },
            ScenarioSpec {
                name: "boom_b",
                description: "",
                seed: SeedPolicy::Global,
                body: ScenarioBody::Custom(also_failing),
            },
        ];
        for threads in [1, 4] {
            let err = run_specs(&specs, 0, threads).unwrap_err();
            assert!(err.to_string().contains("first failure"),
                    "threads {threads}: {err}");
        }
    }
}
